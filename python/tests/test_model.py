"""L2 model correctness: FCS graphs vs references, TRN shapes, train-step
descent, and Eq. 8 ↔ Eq. 13 equivalence inside the lowered graph."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rng_for(seed=0):
    return np.random.default_rng(seed)


def make_params(rng, scale=0.1):
    return [
        jnp.asarray(rng.normal(size=s) * scale, jnp.float32)
        for _, s in model.param_shapes()
    ]


def make_mode_tables(rng, jm):
    i1, i2, i3 = model.ACT_SHAPE
    hs, ss = [], []
    for i in (i1, i2, i3):
        hs.append(rng.integers(0, jm, size=i))
        ss.append(rng.choice([-1.0, 1.0], size=i))
    return hs, ss


def composite_tables(hs, ss, jm, method):
    """Column-major composite table (Eq. 7) — mirrors the Rust builder."""
    i1, i2, i3 = model.ACT_SHAPE
    hx = np.zeros(model.ACT_DIM, np.int64)
    sx = np.ones(model.ACT_DIM)
    l = 0
    for k in range(i3):
        for j in range(i2):
            for i in range(i1):
                tot = hs[0][i] + hs[1][j] + hs[2][k]
                hx[l] = tot % jm if method == "ts" else tot
                sx[l] = ss[0][i] * ss[1][j] * ss[2][k]
                l += 1
    return hx, sx


def full_tables(rng, method, jm):
    hs, ss = make_mode_tables(rng, jm)
    if method == "cs":
        sdim = model.sketch_dim(method, jm)
        hx = rng.integers(0, sdim, size=model.ACT_DIM)
        sx = rng.choice([-1.0, 1.0], size=model.ACT_DIM)
    else:
        hx, sx = composite_tables(hs, ss, jm, method)
    out = []
    for h, s in zip(hs, ss):
        out += [jnp.asarray(h, jnp.int32), jnp.asarray(s, jnp.float32)]
    out += [jnp.asarray(hx, jnp.int32), jnp.asarray(sx, jnp.float32)]
    return out


def test_fcs_rank1_graph_matches_materialized_ref():
    rng = rng_for(1)
    i, r, j = 12, 3, 10
    fn = model.fcs_rank1_graph(j)
    u = [jnp.asarray(rng.normal(size=(i, r)), jnp.float32) for _ in range(3)]
    lam = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
    hs = [jnp.asarray(rng.integers(0, j, size=i), jnp.int32) for _ in range(3)]
    ss = [jnp.asarray(rng.choice([-1.0, 1.0], size=i), jnp.float32) for _ in range(3)]
    (out,) = fn(u[0], u[1], u[2], lam, hs[0], ss[0], hs[1], ss[1], hs[2], ss[2])
    expect = ref.fcs_rank1_ref([u[0] * lam[None, :], u[1], u[2]], hs, ss, j)
    assert out.shape == (3 * j - 2,)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("method", ["cs", "ts", "fcs"])
def test_weight_sketch_equals_composite_cs_of_dense_weight(method):
    """Eq. 8 / Eq. 3 fast paths inside the model == CS of vec(W) under the
    composite table (Eq. 6), for each head variant."""
    rng = rng_for(2)
    jm = 9
    params = make_params(rng, scale=0.5)
    tables = full_tables(rng, method, jm)
    w_sk = model.sketch_weight(method, params, tables, jm)  # [S, C]
    # dense W per class, vec'd column-major
    u1, u2, u3, q = params[4], params[5], params[6], params[7]
    w = jnp.einsum("ir,jr,kr,cr->ijkc", u1, u2, u3, q)
    wv = jnp.transpose(w, (3, 2, 1, 0)).reshape(model.NUM_CLASSES, -1)  # [C, ACT_DIM]
    hx, sx = tables[6], tables[7]
    sdim = model.sketch_dim(method, jm)
    expect = ref.count_sketch_batch_ref(wv, hx, sx, sdim).T  # [S, C]
    np.testing.assert_allclose(w_sk, expect, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("method", ["cs", "ts", "fcs"])
def test_train_step_decreases_loss(method):
    rng = rng_for(3)
    jm = 12
    params = make_params(rng)
    tables = full_tables(rng, method, jm)
    b = 8
    x = jnp.asarray(rng.normal(size=(b, 28, 28, 1)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, size=b), jnp.int32)
    step = jax.jit(model.make_train_step(method, jm))
    lr = jnp.float32(0.05)
    outs = step(*params, x, y, lr, *tables)
    first = float(outs[-1])
    for _ in range(20):
        outs = step(*outs[:-1], x, y, lr, *tables)
    last = float(outs[-1])
    assert last < first, f"{method}: loss {first} -> {last}"


def test_infer_shapes():
    rng = rng_for(4)
    jm = 8
    params = make_params(rng)
    tables = full_tables(rng, "fcs", jm)
    x = jnp.asarray(rng.normal(size=(5, 28, 28, 1)), jnp.float32)
    infer = model.make_infer("fcs", jm)
    (logits,) = infer(*params, x, *tables)
    assert logits.shape == (5, model.NUM_CLASSES)


def test_conv_features_shape():
    rng = rng_for(5)
    params = make_params(rng)
    x = jnp.asarray(rng.normal(size=(3, 28, 28, 1)), jnp.float32)
    act = model.conv_features(params, x)
    assert act.shape == (3,) + model.ACT_SHAPE


def test_vec_colmajor_order():
    # [B, i, j, k] with value i + 10 j + 100 k must flatten i-fastest.
    b = 1
    act = jnp.zeros((b,) + model.ACT_SHAPE)
    i1, i2, i3 = model.ACT_SHAPE
    vals = (
        jnp.arange(i1)[:, None, None]
        + 10 * jnp.arange(i2)[None, :, None]
        + 100 * jnp.arange(i3)[None, None, :]
    )
    act = act.at[0].set(vals.astype(jnp.float32))
    v = model.vec_colmajor(act)[0]
    assert float(v[0]) == 0.0
    assert float(v[1]) == 1.0  # i fastest
    assert float(v[i1]) == 10.0  # then j
    assert float(v[i1 * i2]) == 100.0  # then k


def test_cs_batch_graph_output():
    rng = rng_for(6)
    x = jnp.asarray(rng.normal(size=(4, 30)), jnp.float32)
    h = jnp.asarray(rng.integers(0, 16, size=30), jnp.int32)
    s = jnp.asarray(rng.choice([-1.0, 1.0], size=30), jnp.float32)
    (out,) = model.cs_batch_graph(x, h, s, out_dim=16)
    expect = ref.count_sketch_batch_ref(x, h, s, 16)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)
