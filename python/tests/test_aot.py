"""AOT pipeline checks: every artifact lowers, parses as HLO text, and the
manifest is consistent. Also executes one lowered graph through
xla_client to prove the HLO text is runnable (the same path Rust takes)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_j_for_cr_monotone():
    js = [aot.j_for_cr(cr) for cr in aot.CR_FULL]
    assert all(a >= b for a, b in zip(js, js[1:]))
    # CR=20 on a 1568-dim activation → sketch ≈ 78
    assert abs((3 * aot.j_for_cr(20.0) - 2) - 1568 / 20) < 5


def test_hlo_text_lowering_is_wellformed():
    """Lower the cs_batch graph to HLO text and sanity-check its structure
    (parameter count/shapes). The execute-from-text roundtrip is proven by
    the Rust integration test `tests/runtime_roundtrip.rs`, which is the
    actual consumer of these artifacts."""
    b, i, j = 4, 50, 16
    fn = lambda x, h, s: model.cs_batch_graph(x, h, s, out_dim=j)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((b, i), jnp.float32),
        jax.ShapeDtypeStruct((i,), jnp.int32),
        jax.ShapeDtypeStruct((i,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert f"f32[{b},{i}]" in text
    assert f"s32[{i}]" in text
    assert f"f32[{b},{j}]" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistent_with_files():
    art_dir = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(art_dir, "manifest.json")) as f:
        manifest = json.load(f)
    assert "cs_batch" in manifest
    assert "fcs_rank1" in manifest
    for name, entry in manifest.items():
        path = os.path.join(art_dir, entry["file"])
        assert os.path.exists(path), f"{name}: missing {path}"
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name}: not HLO text"
        assert entry["inputs"], f"{name}: no inputs recorded"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_trn_artifacts_cover_methods_and_crs():
    art_dir = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(art_dir, "manifest.json")) as f:
        manifest = json.load(f)
    for method in ("cs", "ts", "fcs"):
        for cr in aot.CR_SUBSET:
            tag = f"{cr:g}".replace(".", "p")
            assert f"trn_train_{method}_cr{tag}" in manifest
            assert f"trn_infer_{method}_cr{tag}" in manifest
            meta = manifest[f"trn_train_{method}_cr{tag}"]["meta"]
            assert meta["method"] == method
            # all methods share the same sketched dimension at a given CR
            assert meta["sketch_dim"] == manifest[f"trn_train_fcs_cr{tag}"]["meta"]["sketch_dim"]
