"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles, swept over
shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv_mult import complex_mult, spectra_product
from compile.kernels.count_sketch import count_sketch_batch, count_sketch_cols

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def rng_for(seed):
    return np.random.default_rng(seed)


def make_tables(rng, i, j):
    h = jnp.asarray(rng.integers(0, j, size=i), jnp.int32)
    s = jnp.asarray(rng.choice([-1.0, 1.0], size=i), jnp.float32)
    return h, s


@given(
    b=st.integers(1, 8),
    i=st.integers(1, 96),
    j=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_cs_batch_matches_ref(b, i, j, seed):
    rng = rng_for(seed)
    x = jnp.asarray(rng.normal(size=(b, i)), jnp.float32)
    h, s = make_tables(rng, i, j)
    out = count_sketch_batch(x, h, s, out_dim=j)
    expect = ref.count_sketch_batch_ref(x, h, s, j)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@given(
    i=st.integers(1, 64),
    r=st.integers(1, 6),
    j=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_cs_cols_matches_ref(i, r, j, seed):
    rng = rng_for(seed)
    m = jnp.asarray(rng.normal(size=(i, r)), jnp.float32)
    h, s = make_tables(rng, i, j)
    out = count_sketch_cols(m, h, s, out_dim=j)
    expect = ref.count_sketch_cols_ref(m, h, s, j)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@given(
    r=st.integers(1, 4),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_complex_mult_matches_ref(r, n, seed):
    rng = rng_for(seed)
    planes = [jnp.asarray(rng.normal(size=(r, n)), jnp.float32) for _ in range(4)]
    cr, ci = complex_mult(*planes)
    er, ei = ref.complex_mult_ref(*planes)
    np.testing.assert_allclose(cr, er, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ci, ei, rtol=1e-5, atol=1e-5)


def test_cs_kernel_matches_onehot_mxu_formulation():
    rng = rng_for(0)
    x = jnp.asarray(rng.normal(size=(4, 50)), jnp.float32)
    h, s = make_tables(rng, 50, 16)
    out = count_sketch_batch(x, h, s, out_dim=16)
    expect = ref.count_sketch_onehot_ref(x, h, s, 16)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_cs_batch_gradient_is_signed_gather():
    rng = rng_for(1)
    x = jnp.asarray(rng.normal(size=(3, 20)), jnp.float32)
    h, s = make_tables(rng, 20, 8)

    def f(x):
        return count_sketch_batch(x, h, s, out_dim=8).sum()

    g = jax.grad(f)(x)
    # d/dx_i Σ_j out_j = s_i (each x_i lands in exactly one bucket)
    expect = jnp.broadcast_to(s[None, :], x.shape)
    np.testing.assert_allclose(g, expect, rtol=1e-6)


def test_cs_cols_gradient():
    rng = rng_for(2)
    m = jnp.asarray(rng.normal(size=(20, 3)), jnp.float32)
    h, s = make_tables(rng, 20, 8)
    w = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)

    def f(m):
        return (count_sketch_cols(m, h, s, out_dim=8) * w).sum()

    g = jax.grad(f)(m)
    expect = s[:, None] * w[np.asarray(h), :]
    np.testing.assert_allclose(g, expect, rtol=1e-5, atol=1e-6)


def test_spectra_product_three_way():
    rng = rng_for(3)
    specs = [
        (
            jnp.asarray(rng.normal(size=(2, 9)), jnp.float32),
            jnp.asarray(rng.normal(size=(2, 9)), jnp.float32),
        )
        for _ in range(3)
    ]
    pr, pi = spectra_product(specs)
    acc = (specs[0][0] + 1j * specs[0][1]) * (specs[1][0] + 1j * specs[1][1]) * (
        specs[2][0] + 1j * specs[2][1]
    )
    np.testing.assert_allclose(pr, jnp.real(acc), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(pi, jnp.imag(acc), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("j", [4, 16, 33])
def test_cs_linearity(j):
    rng = rng_for(4)
    x = jnp.asarray(rng.normal(size=(2, 30)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(2, 30)), jnp.float32)
    h, s = make_tables(rng, 30, j)
    lhs = count_sketch_batch(x + 2.0 * y, h, s, out_dim=j)
    rhs = count_sketch_batch(x, h, s, out_dim=j) + 2.0 * count_sketch_batch(
        y, h, s, out_dim=j
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)
