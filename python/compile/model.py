"""Layer-2 JAX model: sketched tensor regression network (CP-TRL) + the
standalone sketch graphs served by the coordinator.

Everything here is build-time only — `aot.py` lowers these functions to HLO
text once; the Rust runtime executes them forever after.

The TRN (§4.2, Fig. 4): two conv+maxpool blocks producing a `7×7×32`
activation, followed by a *sketched* CP tensor regression layer:

    Ŷ = FCS(X_(1)ᵀ)ᵀ · FCS(W_(N+1)ᵀ) + b                       (Eq. 21)

with `W = Σ_r u_r ∘ v_r ∘ w_r ∘ q_r` a rank-R CP weight, so the weight
sketch is computed *from the CP factors through Eq. 8* (FFT of the per-mode
count sketches) inside the differentiable graph — the trainable parameters
are the factors, never the dense `W`.

The head has three variants (Table 4): `fcs` (linear convolution, length
`3J−2`), `ts` (circular convolution, length `J`), `cs` (materialize
`vec(W_c)` and hash it with the long table — the strawman).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.count_sketch import count_sketch_batch, count_sketch_cols
from .kernels.conv_mult import spectra_product

# Activation tensor shape fed to the TRL (paper default).
ACT_SHAPE = (7, 7, 32)
ACT_DIM = ACT_SHAPE[0] * ACT_SHAPE[1] * ACT_SHAPE[2]  # 1568
NUM_CLASSES = 10
CP_RANK = 5

PARAM_NAMES = ("c1w", "c1b", "c2w", "c2b", "u1", "u2", "u3", "q", "bias")


def param_shapes(rank=CP_RANK, classes=NUM_CLASSES):
    """Ordered (name, shape) list — the Rust driver mirrors this."""
    return [
        ("c1w", (3, 3, 1, 16)),
        ("c1b", (16,)),
        ("c2w", (3, 3, 16, 32)),
        ("c2b", (32,)),
        ("u1", (ACT_SHAPE[0], rank)),
        ("u2", (ACT_SHAPE[1], rank)),
        ("u3", (ACT_SHAPE[2], rank)),
        ("q", (classes, rank)),
        ("bias", (classes,)),
    ]


def conv_features(params, x):
    """Two conv(3×3, SAME) + max-pool(2×2) blocks: [B,28,28,1] → [B,7,7,32]."""
    c1w, c1b, c2w, c2b = params[0], params[1], params[2], params[3]

    def block(h, w, b):
        h = lax.conv_general_dilated(
            h, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = jax.nn.relu(h + b[None, None, None, :])
        return lax.reduce_window(
            h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )

    h = block(x, c1w, c1b)
    h = block(h, c2w, c2b)
    return h  # [B, 7, 7, 32]


def vec_colmajor(act):
    """Column-major vectorization of [B, i, j, k] activations (first mode
    fastest) — matches the Rust `Tensor` layout and Eq. 7's index order."""
    b = act.shape[0]
    return jnp.transpose(act, (0, 3, 2, 1)).reshape(b, -1)


def _rfft_planes(x, n):
    """rFFT along the last axis → (re, im) planes (Pallas kernels are real)."""
    spec = jnp.fft.rfft(x, n=n, axis=-1)
    return jnp.real(spec).astype(x.dtype), jnp.imag(spec).astype(x.dtype)


def sketch_weight(method, params, tables, j):
    """Sketch of the CP weight `W_(N+1)ᵀ` columns → ``f32[S, C]``.

    `j` is the per-mode hash length; the sketch length `S` is `3j−2` for fcs
    and `j` for ts; for cs, `S` equals the long-table range (passed as `j`).
    """
    u1, u2, u3, q = params[4], params[5], params[6], params[7]
    h1, s1, h2, s2, h3, s3, hx, sx = tables
    if method == "cs":
        # vec(u1∘u2∘u3) per rank (column-major), then the long hash.
        def vec_rank(r):
            v = u1[:, r]
            v = (u2[:, r][:, None] * v[None, :]).reshape(-1)
            v = (u3[:, r][:, None] * v[None, :]).reshape(-1)
            return v

        vecs = jnp.stack([vec_rank(r) for r in range(q.shape[1])])  # [R, ACT_DIM]
        sk = count_sketch_batch(vecs, hx, sx, out_dim=j)  # [R, S]
        return (q @ sk).T  # [S, C]

    cs1 = count_sketch_cols(u1, h1, s1, out_dim=j)  # [j, R]
    cs2 = count_sketch_cols(u2, h2, s2, out_dim=j)
    cs3 = count_sketch_cols(u3, h3, s3, out_dim=j)
    n = 3 * j - 2 if method == "fcs" else j  # linear vs circular conv
    specs = [_rfft_planes(c.T, n) for c in (cs1, cs2, cs3)]  # [R, nf] planes
    pr, pi = spectra_product(specs)
    conv = jnp.fft.irfft(pr + 1j * pi, n=n, axis=-1).astype(u1.dtype)  # [R, n]
    return (q @ conv).T  # [n, C]


def sketch_dim(method, j):
    return 3 * j - 2 if method == "fcs" else j


def trl_logits(method, params, x, tables, j):
    """Full forward pass: conv features → sketched TRL head (Eq. 21)."""
    hx, sx = tables[6], tables[7]
    act = conv_features(params, x)
    xv = vec_colmajor(act)  # [B, 1568]
    s_dim = sketch_dim(method, j)
    x_sk = count_sketch_batch(xv, hx, sx, out_dim=s_dim)  # [B, S]
    w_sk = sketch_weight(method, params, tables, j)  # [S, C]
    logits = x_sk @ w_sk + params[8][None, :]
    if method == "cs":
        # The cs head never touches the per-mode tables; keep a zero-valued
        # dependency so every method lowers with the same 8 table parameters
        # (otherwise jax drops the unused args and the Rust driver's uniform
        # argument list would mismatch the compiled program).
        keep = jnp.float32(0.0)
        for t in tables[:6]:
            keep = keep + t[0].astype(jnp.float32) * jnp.float32(0.0)
        logits = logits + keep
    return logits


def loss_fn(method, params, x, y, tables, j):
    logits = trl_logits(method, params, x, tables, j)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    return nll


def make_train_step(method, j):
    """SGD train step: (params…, x, y, lr, tables…) → (params…, loss)."""

    def step(*args):
        n_params = len(PARAM_NAMES)
        params = list(args[:n_params])
        x, y, lr = args[n_params], args[n_params + 1], args[n_params + 2]
        tables = args[n_params + 3:]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(method, p, x, y, tables, j)
        )(params)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss,)

    return step


def make_infer(method, j):
    """Inference: (params…, x, tables…) → logits."""

    def infer(*args):
        n_params = len(PARAM_NAMES)
        params = list(args[:n_params])
        x = args[n_params]
        tables = args[n_params + 1:]
        return (trl_logits(method, params, x, tables, j),)

    return infer


# ---------------------------------------------------------------------------
# Standalone sketch graphs (coordinator-served artifacts)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("out_dim",))
def cs_batch_graph(x, h, s, *, out_dim):
    """The coordinator's batched count-sketch service (Pallas kernel)."""
    return (count_sketch_batch(x, h, s, out_dim=out_dim),)


def fcs_rank1_graph(j):
    """Rank-R FCS of a 3rd-order CP tensor via Eq. 8 (FFT linear conv)."""

    def fn(u1, u2, u3, lam, h1, s1, h2, s2, h3, s3):
        cs1 = count_sketch_cols(u1, h1, s1, out_dim=j)
        cs2 = count_sketch_cols(u2, h2, s2, out_dim=j)
        cs3 = count_sketch_cols(u3, h3, s3, out_dim=j)
        n = 3 * j - 2
        specs = [_rfft_planes(c.T, n) for c in (cs1, cs2, cs3)]
        pr, pi = spectra_product(specs)
        conv = jnp.fft.irfft(pr + 1j * pi, n=n, axis=-1).astype(u1.dtype)  # [R, n]
        return (lam @ conv,)  # [n]

    return fn
