"""Layer-1 Pallas kernel: batched count sketch.

The paper's `O(nnz)` primitive (Definition 1): for each row `x` of a batch,
``out[h[i]] += s[i] * x[i]``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch dimension is the
Pallas grid; each program keeps its length-`J` accumulator resident in VMEM
and streams its `x` row HBM→VMEM via BlockSpec. The sign flip fuses into the
load. Arbitrary scatter is VPU work — the MXU alternative (one-hot matmul)
is kept in `ref.py` as `count_sketch_onehot_ref` for comparison.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter into plain
HLO (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes
from jax.experimental import pallas as pl


def _cs_kernel(x_ref, h_ref, s_ref, o_ref):
    """One grid step: count-sketch one row of the batch."""
    x = x_ref[0, :]  # [I]  f32
    h = h_ref[...]   # [I]  i32
    s = s_ref[...]   # [I]  f32 (±1)
    acc = jnp.zeros((o_ref.shape[-1],), o_ref.dtype)
    o_ref[0, :] = acc.at[h].add(s * x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _cs_batch_vjp(x, h, s, out_dim):
    return _cs_batch_impl(x, h, s, out_dim)


def _cs_batch_fwd(x, h, s, out_dim):
    return _cs_batch_impl(x, h, s, out_dim), (h, s)


def _cs_batch_bwd(out_dim, res, g):
    # CS is linear in x: the adjoint of scatter-add is a (signed) gather.
    h, s = res
    dx = s[None, :] * g[:, h]
    return dx, np.zeros(h.shape, dtypes.float0), jnp.zeros(s.shape, s.dtype)


_cs_batch_vjp.defvjp(_cs_batch_fwd, _cs_batch_bwd)


def count_sketch_batch(x, h, s, *, out_dim):
    """Count sketch of each row of ``x``.

    Args:
      x: ``f32[B, I]`` batch of vectors.
      h: ``i32[I]`` bucket table, values in ``[0, out_dim)``.
      s: ``f32[I]`` sign table (±1).
      out_dim: ``J`` — sketch length.

    Returns:
      ``f32[B, out_dim]``.
    """
    return _cs_batch_vjp(x, h, s, out_dim)


@functools.partial(jax.jit, static_argnames=("out_dim",))
def _cs_batch_impl(x, h, s, out_dim):
    b, i = x.shape
    return pl.pallas_call(
        _cs_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, i), lambda bi: (bi, 0)),
            pl.BlockSpec((i,), lambda bi: (0,)),
            pl.BlockSpec((i,), lambda bi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, out_dim), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, out_dim), x.dtype),
        interpret=True,
    )(x, h, s)


def _cs_cols_kernel(m_ref, h_ref, s_ref, o_ref):
    """Count-sketch one column of a factor matrix (CS_n(U)(:, r))."""
    m = m_ref[0, :]  # [I]
    h = h_ref[...]
    s = s_ref[...]
    acc = jnp.zeros((o_ref.shape[-1],), o_ref.dtype)
    o_ref[0, :] = acc.at[h].add(s * m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _cs_cols_vjp(m, h, s, out_dim):
    return _cs_cols_impl(m, h, s, out_dim)


def _cs_cols_fwd(m, h, s, out_dim):
    return _cs_cols_impl(m, h, s, out_dim), (h, s)


def _cs_cols_bwd(out_dim, res, g):
    h, s = res
    dm = s[:, None] * g[h, :]
    return dm, np.zeros(h.shape, dtypes.float0), jnp.zeros(s.shape, s.dtype)


_cs_cols_vjp.defvjp(_cs_cols_fwd, _cs_cols_bwd)


def count_sketch_cols(m, h, s, *, out_dim):
    """Column-wise count sketch of a factor matrix.

    Args:
      m: ``f32[I, R]`` factor matrix.
      h: ``i32[I]``, s: ``f32[I]``.
      out_dim: ``J``.

    Returns:
      ``f32[out_dim, R]`` — ``CS(U)`` column by column (Eqs. 3/5/8).
    """
    return _cs_cols_vjp(m, h, s, out_dim)


@functools.partial(jax.jit, static_argnames=("out_dim",))
def _cs_cols_impl(m, h, s, out_dim):
    i, r = m.shape
    mt = m.T  # grid over R columns
    out = pl.pallas_call(
        _cs_cols_kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, i), lambda ri: (ri, 0)),
            pl.BlockSpec((i,), lambda ri: (0,)),
            pl.BlockSpec((i,), lambda ri: (0,)),
        ],
        out_specs=pl.BlockSpec((1, out_dim), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((r, out_dim), m.dtype),
        interpret=True,
    )(mt, h, s)
    return out.T
