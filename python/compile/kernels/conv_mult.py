"""Layer-1 Pallas kernel: FFT-domain Hadamard product.

The spectral multiply at the heart of Eq. 8 (`F(CS₁)·F(CS₂)·…`): elementwise
complex multiplication over `[R, n]` spectra. Pure VPU map kernel; the FFTs
themselves stay at Layer 2 (XLA's FFT is already optimal). Complex numbers
are carried as separate re/im planes because Pallas TPU tiling is over real
dtypes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmul_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    or_ref[...] = ar * br - ai * bi
    oi_ref[...] = ar * bi + ai * br


@jax.custom_vjp
def complex_mult(ar, ai, br, bi):
    """Elementwise complex product of two spectra given as re/im planes.

    All four inputs share one shape (typically ``f32[R, n]``).
    Returns ``(re, im)``.
    """
    return _complex_mult_impl(ar, ai, br, bi)


def _complex_mult_fwd(ar, ai, br, bi):
    return _complex_mult_impl(ar, ai, br, bi), (ar, ai, br, bi)


def _complex_mult_bwd(res, g):
    # c = a·b  ⇒  ā += ḡ·conj(b), b̄ += ḡ·conj(a) (Wirtinger calculus on
    # the real/imag planes).
    ar, ai, br, bi = res
    gr, gi = g
    dar = gr * br + gi * bi
    dai = gi * br - gr * bi
    dbr = gr * ar + gi * ai
    dbi = gi * ar - gr * ai
    return dar, dai, dbr, dbi


complex_mult.defvjp(_complex_mult_fwd, _complex_mult_bwd)


@jax.jit
def _complex_mult_impl(ar, ai, br, bi):
    assert ar.shape == ai.shape == br.shape == bi.shape
    shape = ar.shape
    out_shape = (
        jax.ShapeDtypeStruct(shape, ar.dtype),
        jax.ShapeDtypeStruct(shape, ar.dtype),
    )
    return pl.pallas_call(
        _cmul_kernel,
        out_shape=out_shape,
        interpret=True,
    )(ar, ai, br, bi)


def spectra_product(specs):
    """Fold ``complex_mult`` over a list of (re, im) spectra."""
    acc_r, acc_i = specs[0]
    for r, i in specs[1:]:
        acc_r, acc_i = complex_mult(acc_r, acc_i, r, i)
    return acc_r, acc_i
