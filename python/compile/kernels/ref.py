"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the ground truth the pytest/hypothesis suite checks the kernels
against, and the MXU-alternative formulations discussed in DESIGN.md
§Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp


def count_sketch_batch_ref(x, h, s, out_dim):
    """Reference batched count sketch via segment_sum (Definition 1)."""
    weighted = x * s[None, :]  # [B, I]
    return jax.vmap(
        lambda row: jax.ops.segment_sum(row, h, num_segments=out_dim)
    )(weighted)


def count_sketch_onehot_ref(x, h, s, out_dim):
    """MXU formulation: CS as a dense sketch-matrix product ``x @ (s·1_h)``."""
    onehot = jax.nn.one_hot(h, out_dim, dtype=x.dtype)  # [I, J]
    return x @ (onehot * s[:, None])


def count_sketch_cols_ref(m, h, s, out_dim):
    """Column-wise CS of a factor matrix: ``CS(U)(:, r)``."""
    return count_sketch_batch_ref(m.T, h, s, out_dim).T


def complex_mult_ref(ar, ai, br, bi):
    """Elementwise complex product on re/im planes."""
    a = ar + 1j * ai
    b = br + 1j * bi
    c = a * b
    return jnp.real(c).astype(ar.dtype), jnp.imag(c).astype(ar.dtype)


def fcs_rank1_ref(factors, hs, ss, j):
    """FCS of a CP tensor via materialization — oracle for the Eq. 8 path.

    Args:
      factors: list of ``f32[I_n, R]`` factor matrices.
      hs/ss: per-mode hash tables (``i32[I_n]`` / ``f32[I_n]``), range ``j``.
      j: per-mode hash length (uniform).

    Returns:
      ``f32[N*j - N + 1]``.
    """
    n = len(factors)
    r = factors[0].shape[1]
    j_tilde = n * j - n + 1
    out = jnp.zeros((j_tilde,), factors[0].dtype)
    for rr in range(r):
        # vec(u1 ∘ u2 ∘ ... ∘ uN), column-major (first mode fastest)
        vec = factors[0][:, rr]
        comp_h = hs[0].astype(jnp.int32)
        comp_s = ss[0]
        for nn in range(1, n):
            vec = jnp.reshape(factors[nn][:, rr][:, None] * vec[None, :], (-1,))
            comp_h = jnp.reshape(hs[nn][:, None] + comp_h[None, :], (-1,))
            comp_s = jnp.reshape(ss[nn][:, None] * comp_s[None, :], (-1,))
        out = out + jax.ops.segment_sum(comp_s * vec, comp_h, num_segments=j_tilde)
    return out
