"""AOT pipeline: lower every Layer-2 graph to HLO **text** artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--full]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Table-4 compression ratios. The default subset keeps `make artifacts`
# fast; --full emits every CR from the paper.
CR_SUBSET = [20.0, 50.0, 100.0, 200.0]
CR_FULL = [20.0, 22.22, 25.0, 28.57, 33.33, 40.0, 50.0, 66.67, 100.0, 200.0]

TRN_BATCH = 64
CS_BATCH = 32
CS_IN_DIM = model.ACT_DIM
CS_OUT_DIM = 256
FCS_RANK1_DIM = 64
FCS_RANK1_RANK = 8
FCS_RANK1_J = 128


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def j_for_cr(cr):
    """Per-mode hash length J s.t. the FCS sketch length 3J−2 ≈ ACT_DIM/cr."""
    target = max(4, round(model.ACT_DIM / cr))
    return max(2, (target + 2) // 3)


def table_specs():
    """Hash-table inputs shared by every TRN artifact."""
    i1, i2, i3 = model.ACT_SHAPE
    return [
        spec((i1,), jnp.int32), spec((i1,)),
        spec((i2,), jnp.int32), spec((i2,)),
        spec((i3,), jnp.int32), spec((i3,)),
        spec((model.ACT_DIM,), jnp.int32), spec((model.ACT_DIM,)),
    ]


def emit(out_dir, name, fn, arg_specs, manifest, meta=None):
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest[name] = {
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in arg_specs
        ],
        "meta": meta or {},
    }
    print(f"  wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="emit all Table-4 CRs")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}

    # --- coordinator-served sketch graphs -------------------------------
    emit(
        args.out_dir,
        "cs_batch",
        lambda x, h, s: model.cs_batch_graph(x, h, s, out_dim=CS_OUT_DIM),
        [
            spec((CS_BATCH, CS_IN_DIM)),
            spec((CS_IN_DIM,), jnp.int32),
            spec((CS_IN_DIM,)),
        ],
        manifest,
        meta={"batch": CS_BATCH, "in_dim": CS_IN_DIM, "out_dim": CS_OUT_DIM},
    )

    i, r, j = FCS_RANK1_DIM, FCS_RANK1_RANK, FCS_RANK1_J
    emit(
        args.out_dir,
        "fcs_rank1",
        model.fcs_rank1_graph(j),
        [
            spec((i, r)), spec((i, r)), spec((i, r)), spec((r,)),
            spec((i,), jnp.int32), spec((i,)),
            spec((i,), jnp.int32), spec((i,)),
            spec((i,), jnp.int32), spec((i,)),
        ],
        manifest,
        meta={"dim": i, "rank": r, "j": j, "j_tilde": 3 * j - 2},
    )

    # --- TRN train/infer artifacts (Table 4) ----------------------------
    crs = CR_FULL if args.full else CR_SUBSET
    pshapes = [spec(s) for _, s in model.param_shapes()]
    for method in ("cs", "ts", "fcs"):
        for cr in crs:
            j = j_for_cr(cr)
            s_dim = model.sketch_dim(method, j)
            # cs/ts use sketch length == fcs's 3J−2 so all methods share the
            # exact same CR (the paper equalizes sketched dims).
            if method in ("cs", "ts"):
                jj = 3 * j - 2
            else:
                jj = j
            s_dim = model.sketch_dim(method, jj)
            cr_tag = f"{cr:g}".replace(".", "p")
            train_args = (
                pshapes
                + [spec((TRN_BATCH, 28, 28, 1)), spec((TRN_BATCH,), jnp.int32), spec(())]
                + table_specs()
            )
            emit(
                args.out_dir,
                f"trn_train_{method}_cr{cr_tag}",
                model.make_train_step(method, jj),
                train_args,
                manifest,
                meta={
                    "method": method, "cr": cr, "j": jj, "sketch_dim": s_dim,
                    "batch": TRN_BATCH, "rank": model.CP_RANK,
                },
            )
            infer_args = pshapes + [spec((TRN_BATCH, 28, 28, 1))] + table_specs()
            emit(
                args.out_dir,
                f"trn_infer_{method}_cr{cr_tag}",
                model.make_infer(method, jj),
                infer_args,
                manifest,
                meta={
                    "method": method, "cr": cr, "j": jj, "sketch_dim": s_dim,
                    "batch": TRN_BATCH,
                },
            )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
