//! Conformance suite for the split-plane radix-4 FFT core and its batched
//! entry points: exhaustive cross-checks against the `dft_naive` oracle for
//! every length 1..=128 (power-of-two → radix-4 kernel, everything else →
//! Bluestein composed over it), representative larger Bluestein lengths,
//! real-packed roundtrips, agreement with the retired scalar radix-2 kernel,
//! and qcheck properties pinning `process_many`/`*_many_into` to a loop of
//! their single-signal counterparts.

use fcs::fft::{
    dft_naive, fft_real, fft_real_into, fft_real_many_into, ifft_to_real, inverse_real_into,
    inverse_real_many_into, C64, Dir, FftScratch, FftWorkspace, Plan, ScalarRadix2Plan,
};
use fcs::util::prng::Rng;
use fcs::util::qcheck::qcheck;

fn rand_signal(rng: &mut Rng, n: usize) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
}

fn max_err(a: &[C64], b: &[C64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
}

#[test]
fn exhaustive_forward_matches_naive_for_lengths_1_to_128() {
    let mut rng = Rng::seed_from_u64(1);
    for n in 1usize..=128 {
        let plan = Plan::new(n);
        let x = rand_signal(&mut rng, n);
        let mut y = x.clone();
        plan.process(&mut y, Dir::Forward);
        let naive = dft_naive(&x, Dir::Forward);
        let err = max_err(&y, &naive);
        assert!(err < 1e-8 * (n as f64 + 1.0), "forward n={n} err={err}");
    }
}

#[test]
fn exhaustive_inverse_matches_naive_and_roundtrips_for_lengths_1_to_128() {
    let mut rng = Rng::seed_from_u64(2);
    for n in 1usize..=128 {
        let plan = Plan::new(n);
        let x = rand_signal(&mut rng, n);
        // direct inverse vs the oracle
        let mut y = x.clone();
        plan.process(&mut y, Dir::Inverse);
        let naive = dft_naive(&x, Dir::Inverse);
        let err = max_err(&y, &naive);
        assert!(err < 1e-8 * (n as f64 + 1.0), "inverse n={n} err={err}");
        // forward ∘ inverse roundtrip
        let mut z = x.clone();
        plan.process(&mut z, Dir::Forward);
        plan.process(&mut z, Dir::Inverse);
        let err = max_err(&z, &x);
        assert!(err < 1e-9 * (n as f64 + 1.0), "roundtrip n={n} err={err}");
    }
}

#[test]
fn exhaustive_real_packed_roundtrip_for_lengths_1_to_128() {
    let mut rng = Rng::seed_from_u64(3);
    for n in 1usize..=128 {
        let x: Vec<f64> = rng.normal_vec(n);
        let spec = fft_real(&x, n);
        let full: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
        let naive = dft_naive(&full, Dir::Forward);
        let err = max_err(&spec, &naive);
        assert!(err < 1e-8 * (n as f64 + 1.0), "rfft n={n} err={err}");
        let back = ifft_to_real(spec);
        let rerr = x
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(rerr < 1e-9 * (n as f64 + 1.0), "rfft roundtrip n={n} err={rerr}");
    }
}

#[test]
fn representative_bluestein_lengths() {
    let mut rng = Rng::seed_from_u64(4);
    // Odd primes, an even composite, and 2^k ± 1 — the shapes TS's circular
    // J lands on; forward checked against the oracle, then roundtripped.
    for &n in &[251usize, 509, 997, 1000, 1023] {
        let plan = Plan::new(n);
        let x = rand_signal(&mut rng, n);
        let mut y = x.clone();
        plan.process(&mut y, Dir::Forward);
        let naive = dft_naive(&x, Dir::Forward);
        let err = max_err(&y, &naive);
        assert!(err < 1e-8 * n as f64, "bluestein n={n} err={err}");
        plan.process(&mut y, Dir::Inverse);
        let err = max_err(&y, &x);
        assert!(err < 1e-9 * n as f64, "bluestein roundtrip n={n} err={err}");
        // real-packed path at the same length
        let xr: Vec<f64> = rng.normal_vec(n);
        let back = ifft_to_real(fft_real(&xr, n));
        let rerr = xr
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(rerr < 1e-9 * n as f64, "bluestein rfft roundtrip n={n} err={rerr}");
    }
    // One big length, roundtrip only (the O(n²) oracle is too slow here).
    let n = 4093usize;
    let plan = Plan::new(n);
    let x = rand_signal(&mut rng, n);
    let mut y = x.clone();
    plan.process(&mut y, Dir::Forward);
    plan.process(&mut y, Dir::Inverse);
    assert!(max_err(&y, &x) < 1e-9 * n as f64, "bluestein roundtrip n={n}");
}

#[test]
fn scalar_radix2_oracle_agrees_with_split_plane_kernel() {
    let mut rng = Rng::seed_from_u64(5);
    let mut n = 1usize;
    while n <= 1024 {
        let plan = Plan::new(n);
        let oracle = ScalarRadix2Plan::new(n);
        let x = rand_signal(&mut rng, n);
        for dir in [Dir::Forward, Dir::Inverse] {
            let mut a = x.clone();
            plan.process(&mut a, dir);
            let mut b = x.clone();
            oracle.process(&mut b, dir);
            let err = max_err(&a, &b);
            assert!(err < 1e-10 * (n as f64 + 1.0), "n={n} dir={dir:?} err={err}");
        }
        n *= 2;
    }
}

#[test]
fn qcheck_process_many_equals_loop_of_process() {
    qcheck(40, |g| {
        let n = g.usize_in(1, 160);
        let batch = g.usize_in(1, 6);
        let dir = if g.bool() { Dir::Forward } else { Dir::Inverse };
        let lanes: Vec<Vec<C64>> = (0..batch)
            .map(|_| {
                (0..n)
                    .map(|_| C64::new(g.f64_in(-1.0, 1.0), g.f64_in(-1.0, 1.0)))
                    .collect()
            })
            .collect();
        // lane-major split planes
        let mut re = vec![0.0; n * batch];
        let mut im = vec![0.0; n * batch];
        for (b, lane) in lanes.iter().enumerate() {
            for (k, z) in lane.iter().enumerate() {
                re[k * batch + b] = z.re;
                im[k * batch + b] = z.im;
            }
        }
        let plan = Plan::new(n);
        let mut scratch = FftScratch::new();
        plan.process_many(&mut re, &mut im, batch, dir, &mut scratch);
        for (b, lane) in lanes.iter().enumerate() {
            let mut single = lane.clone();
            plan.process(&mut single, dir);
            for (k, z) in single.iter().enumerate() {
                let d = (re[k * batch + b] - z.re).abs() + (im[k * batch + b] - z.im).abs();
                assert!(
                    d < 1e-10 * (n as f64 + 1.0),
                    "case {}: n={n} batch={batch} lane={b} k={k} d={d}",
                    g.case
                );
            }
        }
    });
}

#[test]
fn qcheck_batched_real_transforms_equal_loop_of_single() {
    let mut ws = FftWorkspace::new();
    qcheck(40, |g| {
        let n = g.usize_in(1, 96);
        let stride = g.usize_in(1, n);
        let batch = g.usize_in(1, 5);
        let xs = g.f64_vec(stride * batch, -1.0, 1.0);
        let mut sre = Vec::new();
        let mut sim = Vec::new();
        fft_real_many_into(&xs, stride, batch, n, &mut ws, &mut sre, &mut sim);
        let mut single = Vec::new();
        for b in 0..batch {
            fft_real_into(&xs[b * stride..(b + 1) * stride], n, &mut ws, &mut single);
            for (k, z) in single.iter().enumerate() {
                let d = (sre[k * batch + b] - z.re).abs() + (sim[k * batch + b] - z.im).abs();
                assert!(
                    d < 1e-10 * (n as f64 + 1.0),
                    "case {}: forward n={n} stride={stride} batch={batch} b={b} k={k}",
                    g.case
                );
            }
        }
        // Batched inverse returns every lane's (zero-padded) signal,
        // signal-major; cross-check against the single-spectrum inverse.
        let mut back = Vec::new();
        fft_real_many_into(&xs, stride, batch, n, &mut ws, &mut sre, &mut sim);
        inverse_real_many_into(&mut sre, &mut sim, batch, &mut ws, &mut back);
        let mut one = Vec::new();
        for b in 0..batch {
            fft_real_into(&xs[b * stride..(b + 1) * stride], n, &mut ws, &mut single);
            inverse_real_into(&mut single, &mut ws, &mut one);
            for (j, v) in one.iter().enumerate() {
                assert!(
                    (back[b * n + j] - v).abs() < 1e-10 * (n as f64 + 1.0),
                    "case {}: inverse n={n} stride={stride} batch={batch} b={b} j={j}",
                    g.case
                );
            }
        }
    });
}
