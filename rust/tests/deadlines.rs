//! Deadline semantics and retry budgeting, end to end through the service:
//! already-expired requests are refused without executing; queue-expired
//! jobs are shed at dequeue; shedding *inside* a fused flight never
//! perturbs the survivors' bit-exact outputs; the client-side retry loop
//! respects its shared anti-amplification budget under a Busy storm; and
//! the admission controller refuses jobs the queue-wait estimate says
//! cannot make their deadline.

use fcs::coordinator::{
    job_rng, BudgetConfig, Request, Response, RetryBudget, RetryPolicy, Service, ServiceConfig,
    ServiceError, SketchMethod, WorkerState,
};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service seed shared by the start helper and reference constructions.
const SEED: u64 = 17;

fn start(workers: usize, cap: usize) -> Service {
    Service::start(
        ServiceConfig {
            workers,
            queue_capacity: cap,
            batch_deadline: Duration::from_micros(200),
            seed: SEED,
        },
        None,
    )
    .unwrap()
}

/// Bitwise slice equality — the shed-inside-flight contract is bit-identity
/// for survivors, not approximate agreement.
fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A CP request heavy enough to occupy a worker for many milliseconds —
/// the blocker that lets queues build behind it.
fn heavy_cp(rng: &mut Rng) -> Request {
    Request::SketchCp { cp: CpTensor::randn(rng, &[40, 40, 40], 64), j: 2048 }
}

#[test]
fn already_expired_requests_never_execute() {
    let svc = start(2, 256);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(1);
    let expired = Instant::now();
    let total = 40usize;
    for i in 0..total {
        let req = match i % 4 {
            0 => Request::SketchDense {
                tensor: Tensor::randn(&mut rng, &[5, 5, 5]),
                method: SketchMethod::Fcs,
                j: 16,
            },
            1 => Request::SketchCp { cp: CpTensor::randn(&mut rng, &[5, 4, 6], 2), j: 12 },
            2 => Request::MergeShards { parts: vec![vec![1.0; 8], vec![2.0; 8]] },
            // The batcher path sheds on an expired deadline too.
            _ => Request::CsVec { x: vec![0.0; h.cs_in_dim] },
        };
        match h.submit_with_deadline(req, Some(expired)) {
            Err(ServiceError::DeadlineExceeded) => {}
            other => panic!("request {i}: expired submit must be refused, got {other:?}"),
        }
    }
    let report = svc.stats();
    assert_eq!(report.shed_submit as usize, total, "every refusal booked at the submit stage");
    assert_eq!(report.total_completed, 0, "an expired request executed");
    assert_eq!(report.shed_dequeue + report.shed_flight, 0);
    svc.shutdown();
}

#[test]
fn queue_expired_jobs_are_shed_at_dequeue_without_executing() {
    // One worker, blocked on a heavy CP job: small jobs whose deadline is a
    // fraction of the blocker's runtime must come back DeadlineExceeded and
    // never reach the sketch kernels.
    let svc = start(1, 256);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(2);
    let blocker = h.submit(heavy_cp(&mut rng)).unwrap();
    // Let the worker dequeue the blocker (its fuse window is 100µs).
    std::thread::sleep(Duration::from_millis(2));
    let n = 4usize;
    let mut rxs = Vec::new();
    let mut submit_shed = 0usize;
    for _ in 0..n {
        let req = Request::SketchDense {
            tensor: Tensor::randn(&mut rng, &[5, 5, 5]),
            method: SketchMethod::Fcs,
            j: 16,
        };
        match h.submit_with_deadline(req, Some(Instant::now() + Duration::from_micros(500))) {
            Ok(rx) => rxs.push(rx),
            // Possible only if an earlier run of this service already
            // raised the queue-wait estimate — still a correct refusal.
            Err(ServiceError::DeadlineExceeded) => submit_shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv().expect("reply sender dropped — response lost") {
            Err(ServiceError::DeadlineExceeded) => {}
            other => panic!("job {i}: expected a shed, got {other:?}"),
        }
    }
    let Response::Sketch(v) = blocker.recv().unwrap().unwrap() else {
        panic!("wrong blocker response kind")
    };
    assert!(v.iter().all(|x| x.is_finite()));
    let report = svc.stats();
    assert_eq!(report.shed_submit as usize, submit_shed);
    assert_eq!(
        report.shed_submit as usize + report.shed_dequeue as usize + report.shed_flight as usize,
        n,
        "every shed booked exactly once: {report:?}"
    );
    let dense = report.per_op.iter().find(|o| o.op == "sketch_dense");
    assert_eq!(
        dense.map_or(0, |o| o.completed),
        0,
        "a queue-expired dense job burned a sketch pass"
    );
    svc.shutdown();
}

#[test]
fn shed_inside_fused_flight_preserves_survivor_bit_identity() {
    // Two heavy blockers build a backlog; six *identical* small CP jobs
    // queue behind them, alternating a tight deadline with none. At flight
    // start the expired half is shed and the survivors execute as a fused
    // flight — whose outputs must stay bit-identical to serial references,
    // because every job's RNG is keyed to its up-front req_id, shed or not.
    let svc = start(1, 256);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(3);
    let b0 = h.submit(heavy_cp(&mut rng)).unwrap();
    let b1 = h.submit(heavy_cp(&mut rng)).unwrap();
    std::thread::sleep(Duration::from_millis(2));
    let cp = CpTensor::randn(&mut rng, &[12, 11, 10], 3);
    let j = 64usize;
    let k = 6usize;
    let mut rxs = Vec::new();
    let mut submit_shed = 0usize;
    for i in 0..k {
        let deadline =
            if i % 2 == 0 { Some(Instant::now() + Duration::from_micros(500)) } else { None };
        match h.submit_with_deadline(Request::SketchCp { cp: cp.clone(), j }, deadline) {
            Ok(rx) => rxs.push((i, rx)),
            Err(ServiceError::DeadlineExceeded) => submit_shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    // Serial references for every req_id the six jobs could have drawn (the
    // blockers hold ids 0 and 1; the flight draws ids in 2..2+k, shed jobs
    // included).
    let mut st = WorkerState::new();
    let refs: Vec<Vec<f64>> = (2..(2 + k) as u64)
        .map(|id| {
            let mut out = Vec::new();
            st.sketch_cp_into(&cp, j, &mut job_rng(SEED, id), &mut out);
            out
        })
        .collect();
    let mut used = vec![false; k];
    let (mut ok, mut shed) = (0usize, submit_shed);
    for (i, rx) in rxs {
        match rx.recv().expect("reply sender dropped — response lost") {
            Ok(Response::Sketch(v)) => {
                assert!(i % 2 == 1, "job {i}: tight-deadline job survived a multi-ms backlog");
                let id = (0..k).find(|&id| !used[id] && bits_eq(&v, &refs[id])).unwrap_or_else(
                    || panic!("job {i}: survivor not bit-identical to any serial reference"),
                );
                used[id] = true;
                ok += 1;
            }
            Err(ServiceError::DeadlineExceeded) => {
                assert!(i % 2 == 0, "job {i}: no-deadline job was shed");
                shed += 1;
            }
            other => panic!("job {i}: unexpected reply {other:?}"),
        }
    }
    assert_eq!(ok, k / 2, "all three no-deadline jobs must survive");
    assert_eq!(shed, k / 2, "all three tight-deadline jobs must be shed");
    for b in [b0, b1] {
        let Response::Sketch(v) = b.recv().unwrap().unwrap() else {
            panic!("wrong blocker response kind")
        };
        assert!(v.iter().all(|x| x.is_finite()));
    }
    let report = svc.stats();
    assert!(
        report.flights.iter().any(|f| f.width > 1),
        "survivors did not execute as a fused flight: {:?}",
        report.flights
    );
    svc.shutdown();
}

#[test]
fn retry_loop_respects_the_shared_budget_under_busy_storm() {
    // A one-slot queue behind a blocked single worker turns every submit
    // into Busy; the retry loop may spend at most
    // (initial + calls·deposit) / withdraw retries on the storm, then must
    // surface Busy immediately instead of amplifying it.
    let svc = start(1, 1);
    let budget = Arc::new(RetryBudget::new(BudgetConfig {
        initial_m: 2000,
        deposit_m: 100,
        withdraw_m: 1000,
        cap_m: 10_000,
    }));
    let h = svc.handle().with_retry_budget(budget.clone());
    let mut rng = Rng::seed_from_u64(4);
    let blocker = h.submit(heavy_cp(&mut rng)).unwrap();
    std::thread::sleep(Duration::from_millis(2));
    // Occupy the single queue slot for the blocker's whole runtime.
    let filler = h.submit(heavy_cp(&mut rng)).unwrap();
    // Short backoffs keep the whole storm inside the blocker's runtime.
    let policy = RetryPolicy {
        max_retries: 3,
        base_backoff: Duration::from_micros(50),
        max_backoff: Duration::from_micros(200),
        jitter_seed: 0x5EED,
    };
    let calls = 40usize;
    let (mut busy, mut other_ok) = (0usize, 0usize);
    for _ in 0..calls {
        let req = Request::SketchDense {
            tensor: Tensor::randn(&mut rng, &[4, 4, 4]),
            method: SketchMethod::Fcs,
            j: 8,
        };
        match h.call_with_retry(req, None, &policy) {
            Err(ServiceError::Busy) => busy += 1,
            // A call can slip into the queue in the instant the worker
            // dequeues the filler; rare and harmless to the budget claims.
            Ok(_) => other_ok += 1,
            Err(e) => panic!("unexpected retry outcome: {e}"),
        }
    }
    assert_eq!(busy + other_ok, calls);
    assert!(busy >= 30, "the storm should be mostly Busy ({busy}/{calls})");
    let report = svc.stats();
    let max_retries = (2000 + 100 * calls as u64) / 1000;
    assert!(
        report.retries <= max_retries,
        "{} retries exceed the budget's ceiling of {max_retries}",
        report.retries
    );
    assert!(
        report.retry_budget_exhausted >= 1,
        "a broke budget must be observed at least once"
    );
    assert!(budget.balance_m("sketch_dense") < 2000 + 100 * calls as i64);
    for b in [blocker, filler] {
        b.recv().unwrap().unwrap();
    }
    svc.shutdown();
}

#[test]
fn admission_rejects_when_queue_wait_estimate_exceeds_deadline() {
    // Flood a single worker so completed jobs teach the queue-wait EWMA a
    // multi-hundred-µs wait, then ask for a deadline far below it: the
    // admission controller must refuse at submit, before the queue grows.
    let svc = start(1, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(5);
    let cp = CpTensor::randn(&mut rng, &[10, 10, 10], 4);
    let mut rxs = Vec::new();
    for _ in 0..60 {
        rxs.push(h.submit(Request::SketchCp { cp: cp.clone(), j: 256 }).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let report = svc.stats();
    let est = report.queue_wait_estimate_us;
    assert!(est > 100, "the flood must leave a visible queue-wait estimate, got {est}µs");
    let before = report.total_completed;
    match h.call_with_deadline(
        Request::SketchCp { cp: cp.clone(), j: 256 },
        Instant::now() + Duration::from_micros(100),
    ) {
        Err(ServiceError::DeadlineExceeded) => {}
        other => panic!("admission must refuse an unmeetable deadline, got {other:?}"),
    }
    let report = svc.stats();
    assert!(report.shed_submit >= 1, "refusal must be booked at the submit stage");
    assert_eq!(report.total_completed, before, "the refused job must not execute");
    // A generous deadline sails through the same controller.
    let resp = h
        .call_with_deadline(
            Request::SketchCp { cp, j: 256 },
            Instant::now() + Duration::from_secs(30),
        )
        .expect("a generous deadline must be admitted");
    let Response::Sketch(v) = resp else { panic!("wrong response kind") };
    assert!(v.iter().all(|x| x.is_finite()));
    svc.shutdown();
}
