//! Model-checked interleavings for the lock-free serving stack.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (the CI `analysis` job runs
//! `cargo test --features failpoints --test loom_models` with that flag); a
//! normal `cargo test` builds this target empty. Every component below pulls
//! its primitives from `fcs::sync`, which under `--cfg loom` resolves to the
//! vendored loom facade: each atomic op and mutex acquisition is a possible
//! preemption point, and `loom::model` replays every closure across many
//! seeded schedules (`FCS_LOOM_ITERS` tunes the budget). On a networked
//! host the facade swaps for the real `loom = "0.7"` exhaustive checker
//! without touching this file.
//!
//! Model matrix (component × property) — see EXPERIMENTS.md §Static
//! analysis for the prose version:
//!
//! | component              | property under concurrency                     |
//! |------------------------|------------------------------------------------|
//! | `obs::registry`        | render never sees a half-registered family     |
//! | `obs::trace`           | record vs dump stays structurally ordered      |
//! | `coordinator::stats`   | EWMA never negative, decays to zero            |
//! | `coordinator::stats`   | reservoir wraparound never tears a window      |
//! | `coordinator::retry`   | deposit/withdraw books exact, refusals refund  |
//! | `fault`                | ARMED fast path consistent with the registry   |
//! | `coordinator::service` | stop latch: no respawn after shutdown, one per crash |

#![cfg(loom)]

use fcs::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use fcs::sync::{Arc, Mutex};

// ---------------------------------------------------------------------------
// obs::registry — registration vs render
// ---------------------------------------------------------------------------

/// Two threads register counter families while a third renders. A render
/// must only ever observe fully-formed entries (name/help/labels all
/// consistent with one of the two writers), in registration order, and the
/// final state must contain every family exactly once.
#[test]
fn registry_render_never_sees_half_registered_family() {
    loom::model(|| {
        let reg = Arc::new(fcs::obs::registry::Registry::new());
        let r1 = Arc::clone(&reg);
        let r2 = Arc::clone(&reg);
        let r3 = Arc::clone(&reg);
        let t1 = loom::thread::spawn(move || {
            let c = r1.counter("fcs_model_a_total", "help a", "op=\"a\"");
            c.inc();
        });
        let t2 = loom::thread::spawn(move || {
            let c = r2.counter("fcs_model_b_total", "help b", "");
            c.add(2);
        });
        let reader = loom::thread::spawn(move || {
            r3.with_entries(|entries| {
                for e in entries {
                    match e.name {
                        "fcs_model_a_total" => {
                            assert_eq!(e.help, "help a");
                            assert_eq!(e.labels, "op=\"a\"");
                        }
                        "fcs_model_b_total" => {
                            assert_eq!(e.help, "help b");
                            assert_eq!(e.labels, "");
                        }
                        other => panic!("torn registry entry: {other:?}"),
                    }
                }
            });
        });
        t1.join().unwrap();
        t2.join().unwrap();
        reader.join().unwrap();
        reg.with_entries(|entries| {
            assert_eq!(entries.len(), 2, "each family registered exactly once");
            let mut names: Vec<_> = entries.iter().map(|e| e.name).collect();
            names.sort_unstable();
            assert_eq!(names, ["fcs_model_a_total", "fcs_model_b_total"]);
        });
    });
}

// ---------------------------------------------------------------------------
// obs::trace — record vs dump
// ---------------------------------------------------------------------------

/// Two workers record spans (wrapping their shared ring: span count exceeds
/// the loom-shrunk `TRACE_RING_CAP`) while a reader dumps. Every span a
/// dump observes must be structurally ordered (submit ≤ queue ≤ flight ≤
/// reply — the record-time clamp invariant) and `recent` must come back
/// reply-sorted; no interleaving may expose a torn span.
#[test]
fn trace_ring_record_vs_dump_structurally_ordered() {
    use fcs::obs::trace::{TraceBook, TraceSpan, TRACE_RING_CAP};
    fn span(req_id: u64, base: u64) -> TraceSpan {
        TraceSpan {
            req_id,
            op: "sketch_cp",
            submit_us: base,
            queue_us: base + 1,
            flight_start_us: base + 2,
            reply_us: base + 3,
            width: 1,
            ok: true,
        }
    }
    loom::model(|| {
        let book = Arc::new(TraceBook::new());
        let writers: Vec<_> = (0..2u64)
            .map(|w| {
                let book = Arc::clone(&book);
                loom::thread::spawn(move || {
                    // Both land on shard 0 (worker 0 and TRACE_SHARDS), so the
                    // shared ring wraps: 2 * (CAP/2 + 2) > CAP.
                    for i in 0..(TRACE_RING_CAP as u64 / 2 + 2) {
                        book.record(
                            (w as usize) * fcs::obs::trace::TRACE_SHARDS,
                            span(w * 1000 + i, 10 * i),
                        );
                    }
                })
            })
            .collect();
        let reader = {
            let book = Arc::clone(&book);
            loom::thread::spawn(move || {
                let spans = book.recent(TRACE_RING_CAP);
                for s in &spans {
                    assert!(
                        s.submit_us <= s.queue_us
                            && s.queue_us <= s.flight_start_us
                            && s.flight_start_us <= s.reply_us,
                        "torn span: {s:?}"
                    );
                }
                for w in spans.windows(2) {
                    assert!(w[0].reply_us <= w[1].reply_us, "recent() not reply-sorted");
                }
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        reader.join().unwrap();
        // Post-join: ring holds at most CAP spans, all structurally ordered.
        let final_spans = book.recent(2 * TRACE_RING_CAP);
        assert!(final_spans.len() <= TRACE_RING_CAP);
    });
}

// ---------------------------------------------------------------------------
// coordinator::stats — EWMA bounds + decay
// ---------------------------------------------------------------------------

/// Concurrent `record_job` streams never drive the queue-wait EWMA negative
/// (it is stored as `u64`; the model asserts it also never exceeds the max
/// sample ever offered), and once both streams go quiet at zero queue-wait,
/// the signum step decays the estimate all the way to zero — dropped
/// updates from racing read-modify-write pairs may slow convergence but
/// must never corrupt the value.
#[test]
fn stats_ewma_bounded_and_decays() {
    loom::model(|| {
        let stats = Arc::new(fcs::coordinator::Stats::new());
        const MAX_SAMPLE: u64 = 800;
        let writers: Vec<_> = (0..2)
            .map(|w| {
                let stats = Arc::clone(&stats);
                loom::thread::spawn(move || {
                    let q = if w == 0 { 500.0 } else { MAX_SAMPLE as f64 };
                    for _ in 0..4 {
                        stats.record_job("sketch_cp", q + 100.0, q, 100.0);
                    }
                })
            })
            .collect();
        let observer = {
            let stats = Arc::clone(&stats);
            loom::thread::spawn(move || {
                for _ in 0..4 {
                    let est = stats.queue_wait_estimate_us();
                    assert!(est <= MAX_SAMPLE, "EWMA {est} overshot the max sample");
                }
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        observer.join().unwrap();
        assert!(stats.queue_wait_estimate_us() <= MAX_SAMPLE);
        // Quiet stream at zero queue-wait: the signum step must reach 0
        // exactly (the α=1/8 truncated step alone would plateau near 7).
        for _ in 0..2000 {
            stats.record_job("sketch_cp", 100.0, 0.0, 100.0);
        }
        assert_eq!(stats.queue_wait_estimate_us(), 0, "EWMA must decay to zero when idle");
    });
}

// ---------------------------------------------------------------------------
// coordinator::stats — reservoir ring wraparound
// ---------------------------------------------------------------------------

/// Writers push a latticed value stream past `RESERVOIR_CAP` (loom-shrunk,
/// so slots get overwritten) while a reader snapshots percentiles mid-wrap.
/// A torn window would surface as a percentile outside the lattice hull or
/// an inverted p50/p95/p99 ladder.
#[test]
fn stats_reservoir_wraparound_never_tears_window() {
    loom::model(|| {
        let stats = Arc::new(fcs::coordinator::Stats::new());
        stats.mark_started();
        let writers: Vec<_> = (0..2)
            .map(|w| {
                let stats = Arc::clone(&stats);
                loom::thread::spawn(move || {
                    let v = (w + 1) as f64 * 1000.0; // lattice: {1000, 2000}
                    for _ in 0..48 {
                        // 2 × 48 > loom RESERVOIR_CAP (64): the ring wraps.
                        stats.record("cs_vec", v);
                    }
                })
            })
            .collect();
        let reader = {
            let stats = Arc::clone(&stats);
            loom::thread::spawn(move || {
                for _ in 0..3 {
                    let r = stats.report();
                    if let Some(op) = r.per_op.iter().find(|o| o.op == "cs_vec") {
                        if op.completed == 0 {
                            continue;
                        }
                        for p in [op.p50_us, op.p95_us, op.p99_us] {
                            assert!(
                                (1000.0..=2000.0).contains(&p),
                                "percentile {p} escaped the lattice — torn window"
                            );
                        }
                        assert!(op.p50_us <= op.p95_us && op.p95_us <= op.p99_us);
                    }
                }
            })
        };
        for t in writers {
            t.join().unwrap();
        }
        reader.join().unwrap();
        let r = stats.report();
        let op = r.per_op.iter().find(|o| o.op == "cs_vec").unwrap();
        assert_eq!(op.completed, 96);
    });
}

// ---------------------------------------------------------------------------
// coordinator::retry — budget books
// ---------------------------------------------------------------------------

/// Depositors and withdrawers race on one op-class bucket. The books must
/// balance exactly: final = initial + deposit_m·deposits − withdraw_m·grants
/// (every refusal refunds its debit in full), under any interleaving. The
/// cap is set unreachably high so the clamp path cannot blur the equation.
#[test]
fn retry_budget_books() {
    use fcs::coordinator::retry::{BudgetConfig, RetryBudget};
    loom::model(|| {
        let cfg = BudgetConfig {
            initial_m: 2_000,
            deposit_m: 100,
            withdraw_m: 1_000,
            cap_m: 1_000_000,
        };
        let budget = Arc::new(RetryBudget::new(cfg));
        const DEPOSITS: i64 = 6;
        let depositor = {
            let budget = Arc::clone(&budget);
            loom::thread::spawn(move || {
                for _ in 0..DEPOSITS {
                    budget.deposit("sketch_dense");
                }
            })
        };
        let withdrawers: Vec<_> = (0..2)
            .map(|_| {
                let budget = Arc::clone(&budget);
                loom::thread::spawn(move || {
                    let mut granted = 0i64;
                    for _ in 0..3 {
                        if budget.try_withdraw("sketch_dense") {
                            granted += 1;
                        }
                    }
                    granted
                })
            })
            .collect();
        depositor.join().unwrap();
        let granted: i64 = withdrawers.into_iter().map(|t| t.join().unwrap()).sum();
        let expected = cfg.initial_m + cfg.deposit_m * DEPOSITS - cfg.withdraw_m * granted;
        assert_eq!(
            budget.balance_m("sketch_dense"),
            expected,
            "books must balance: refusals refund exactly"
        );
        // Isolation: a different op class was never touched.
        assert_eq!(budget.balance_m("cs_vec"), cfg.initial_m);
    });
}

// ---------------------------------------------------------------------------
// fault — ARMED fast path vs registry
// ---------------------------------------------------------------------------

/// Arm/disarm races against hot-path checks: the advisory ARMED counter
/// must end exactly consistent with the registry contents, checks on
/// unarmed sites must never fire, and checks on an armed always-fire site
/// must fire whenever the registry lock shows it armed. The fault registry
/// is process-global, so the model brackets itself with `clear_all` and
/// uses sites no other test touches.
#[cfg(feature = "failpoints")]
#[test]
fn fault_armed_counter_consistent() {
    use fcs::fault::{self, FaultAction, FaultSpec};
    const SPEC: FaultSpec =
        FaultSpec { action: FaultAction::Error, prob: 1.0, max_hits: None, seed: 7 };
    loom::model(|| {
        fault::clear_all();
        let armer = loom::thread::spawn(move || {
            fault::configure("loom_site_a", SPEC);
            fault::configure("loom_site_b", SPEC);
            fault::clear("loom_site_b");
        });
        let checker = loom::thread::spawn(move || {
            for _ in 0..4 {
                // Never configured: must never fire, armed or not.
                assert!(fault::check("loom_site_never").is_none());
                // May race the arm: allowed to be None (not yet visible) or
                // the configured Error — anything else is a torn schedule.
                match fault::check("loom_site_a") {
                    None | Some(FaultAction::Error) => {}
                    other => panic!("unexpected action {other:?}"),
                }
            }
        });
        armer.join().unwrap();
        checker.join().unwrap();
        // Post-join quiescence: site a armed, site b cleared; an armed
        // always-fire site must now fire every evaluation.
        assert!(matches!(fault::check("loom_site_a"), Some(FaultAction::Error)));
        assert!(fault::check("loom_site_b").is_none());
        let before = fault::hits("loom_site_a");
        let _ = fault::check("loom_site_a");
        assert_eq!(fault::hits("loom_site_a"), before + 1);
        fault::clear_all();
        // ARMED drained to zero: the fast path must short-circuit again
        // (an armed-count leak would keep routing checks to the registry).
        assert!(fault::check("loom_site_a").is_none());
    });
}

// ---------------------------------------------------------------------------
// coordinator::service — stop latch vs respawn
// ---------------------------------------------------------------------------

/// The supervisor's `should_respawn` predicate racing shutdown: a crashed
/// slot is claimed (taken) at most once, so at most one respawn can ever
/// happen per crash; once the stop latch is raised and observed, no
/// further respawn is possible (the latch is sticky); and a sentinel-clean
/// exit (crashed = false) never respawns regardless of the latch.
#[test]
fn supervisor_latch_no_respawn_after_stop() {
    use fcs::coordinator::should_respawn;
    loom::model(|| {
        let stop = Arc::new(AtomicBool::new(false));
        // One crashed worker slot, swept by two racing supervisor passes —
        // `take` models `slots[w].take()` claiming the dead thread's join.
        let crashed_slot = Arc::new(Mutex::new(Some(())));
        let spawns = Arc::new(AtomicUsize::new(0));
        let sweeps: Vec<_> = (0..2)
            .map(|_| {
                let stop = Arc::clone(&stop);
                let slot = Arc::clone(&crashed_slot);
                let spawns = Arc::clone(&spawns);
                loom::thread::spawn(move || {
                    let crashed = slot.lock().unwrap().take().is_some();
                    if should_respawn(crashed, &stop) {
                        // ordering: Relaxed — model bookkeeping; read after join.
                        spawns.fetch_add(1, Ordering::Relaxed);
                    }
                    // Clean exits never respawn, latched or not.
                    assert!(!should_respawn(false, &stop));
                })
            })
            .collect();
        let shutdown = {
            let stop = Arc::clone(&stop);
            loom::thread::spawn(move || {
                // ordering: SeqCst — mirrors `Service::shutdown`'s latch store.
                stop.store(true, Ordering::SeqCst);
            })
        };
        for t in sweeps {
            t.join().unwrap();
        }
        shutdown.join().unwrap();
        // At most one sweep claimed the crash, so at most one respawn —
        // and possibly zero, when the latch won the race.
        // ordering: Relaxed — all writers joined above; no concurrency left.
        assert!(spawns.load(Ordering::Relaxed) <= 1, "double-spawned one crash");
        // Sticky latch: after shutdown joined, a crash can never respawn.
        assert!(!should_respawn(true, &stop));
    });
}
