//! Coordinator stress: concurrent clients hammering the workspace-backed
//! worker pool with mixed same-shape/different-shape jobs — including while
//! `shutdown()` runs — must never lose a response, never panic, and leave
//! stats that add up.
//!
//! "Never lose" means: every `submit` that returned `Ok(rx)` resolves — the
//! client either receives exactly one response, or observes a clean
//! disconnect for jobs that were still queued behind the stop sentinels.
//! `answered == total_completed` ties the two books together.

use fcs::coordinator::{
    Request, Response, Service, ServiceConfig, ServiceError, SketchMethod,
};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;
use std::time::Duration;

fn start(workers: usize, cap: usize) -> Service {
    Service::start(
        ServiceConfig {
            workers,
            queue_capacity: cap,
            batch_deadline: Duration::from_micros(200),
            seed: 9,
        },
        None,
    )
    .unwrap()
}

/// Expected sketch length for a `SketchDense` request.
fn dense_len(order: usize, method: SketchMethod, j: usize) -> usize {
    match method {
        SketchMethod::Ts => j,
        SketchMethod::Fcs => order * j - order + 1,
    }
}

#[test]
fn mixed_shapes_all_answered_with_correct_lengths() {
    // Same-shape bursts interleaved with shape changes force the worker's
    // drain-and-group path to reorder jobs; replies must still route to the
    // right clients (verified via per-request expected lengths).
    let svc = start(3, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(1);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..300 {
        let (shape, j, method): (Vec<usize>, usize, SketchMethod) = match i % 4 {
            0 | 1 => (vec![6, 6, 6], 32, SketchMethod::Fcs), // same-shape burst
            2 => (vec![3, 8, 4], 16, SketchMethod::Ts),
            _ => (
                vec![rng.below(5) as usize + 2, 4, rng.below(4) as usize + 2],
                8,
                SketchMethod::Fcs,
            ),
        };
        let t = Tensor::randn(&mut rng, &shape);
        expected.push(dense_len(shape.len(), method, j));
        rxs.push(h.submit(Request::SketchDense { tensor: t, method, j }).unwrap());
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let Response::Sketch(v) = rx.recv().unwrap().unwrap() else {
            panic!("wrong response kind")
        };
        assert_eq!(v.len(), want);
        assert!(v.iter().all(|x| x.is_finite()));
        assert!(rx.try_recv().is_err(), "answered more than once");
    }
    let report = svc.stats();
    assert_eq!(report.total_completed, 300);
    svc.shutdown();
}

#[test]
fn shutdown_under_fire_loses_no_response() {
    let svc = start(3, 64);
    let h = svc.handle();
    let clients = 6;
    let per_client = 100;
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(1000 + c);
                let mut pending = Vec::new();
                let (mut accepted, mut busy, mut closed_submit) = (0u64, 0u64, 0u64);
                for i in 0..per_client {
                    let req = match i % 3 {
                        0 => Request::SketchDense {
                            tensor: Tensor::randn(&mut rng, &[6, 6, 6]),
                            method: SketchMethod::Fcs,
                            j: 24,
                        },
                        1 => Request::SketchDense {
                            tensor: Tensor::randn(&mut rng, &[4, 7, 3]),
                            method: SketchMethod::Ts,
                            j: 16,
                        },
                        _ => Request::SketchCp {
                            cp: CpTensor::randn(&mut rng, &[5, 5, 5], 2),
                            j: 12,
                        },
                    };
                    match h.submit(req) {
                        Ok(rx) => {
                            accepted += 1;
                            pending.push(rx);
                        }
                        Err(ServiceError::Busy) => busy += 1,
                        Err(ServiceError::Closed) => closed_submit += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                let (mut answered, mut dropped) = (0u64, 0u64);
                for rx in pending {
                    match rx.recv() {
                        Ok(resp) => {
                            match resp.expect("execution must not fail") {
                                Response::Sketch(v) => {
                                    assert!(!v.is_empty());
                                    assert!(v.iter().all(|x| x.is_finite()));
                                }
                                Response::Scalar(_) => panic!("wrong response kind"),
                            }
                            answered += 1;
                        }
                        // Reply sender dropped: the job was still queued
                        // behind the stop sentinels at shutdown. A clean,
                        // observable drop — not a lost response.
                        Err(_) => dropped += 1,
                    }
                }
                assert_eq!(answered + dropped, accepted, "client {c}: response unaccounted");
                (accepted, busy, closed_submit, answered, dropped)
            })
        })
        .collect();

    // Let traffic build, then pull the plug while clients are mid-stream.
    std::thread::sleep(Duration::from_millis(15));
    let stats_handle = h.clone();
    drop(h);
    svc.shutdown();

    let mut totals = (0u64, 0u64, 0u64, 0u64, 0u64);
    for t in threads {
        let (a, b, c, ans, d) = t.join().expect("client panicked");
        totals.0 += a;
        totals.1 += b;
        totals.2 += c;
        totals.3 += ans;
        totals.4 += d;
    }
    let (accepted, busy, _closed, answered, dropped) = totals;
    assert_eq!(answered + dropped, accepted, "global response accounting");

    // Stats must agree with the clients' books: every answered worker-pool
    // job was recorded exactly once, every Busy rejection counted.
    let report = stats_handle.stats();
    let worker_ops: u64 = report
        .per_op
        .iter()
        .filter(|o| o.op == "sketch_dense" || o.op == "sketch_cp")
        .map(|o| o.completed)
        .sum();
    assert_eq!(worker_ops, answered, "stats vs client books");
    assert_eq!(report.rejected_busy, busy, "busy rejections must be counted");
}

#[test]
fn poison_jobs_never_lose_responses_and_workers_survive() {
    // Adversarial/degenerate traffic interleaved with healthy jobs: NaN
    // tensors (estimator medians must be NaN-tolerant, not panic) and NaN CP
    // factors (in debug builds the non-Hermitian-residue kernel assert fires
    // — the per-job catch_unwind must convert that into an Exec error, keep
    // the worker alive, and keep every other drained job's reply flowing).
    // The contract under test: EVERY accepted submission resolves, and the
    // healthy jobs around the poison keep producing finite sketches.
    let svc = start(2, 512);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(0xBAD);
    let nan_tensor = |rng: &mut Rng, shape: &[usize]| {
        let mut t = Tensor::randn(rng, shape);
        let mid = t.data.len() / 2;
        t.data[0] = f64::NAN;
        t.data[mid] = f64::NAN;
        t
    };
    let nan_cp = |rng: &mut Rng| {
        let mut cp = CpTensor::randn(rng, &[5, 4, 6], 2);
        cp.factors[1].data[3] = f64::NAN;
        cp
    };
    let mut rxs = Vec::new();
    let total = 160usize;
    for i in 0..total {
        let req = match i % 4 {
            0 => Request::SketchDense {
                tensor: Tensor::randn(&mut rng, &[6, 6, 6]),
                method: SketchMethod::Fcs,
                j: 16,
            },
            1 => Request::InnerEstimate {
                a: nan_tensor(&mut rng, &[4, 4, 4]),
                b: nan_tensor(&mut rng, &[4, 4, 4]),
                method: SketchMethod::Fcs,
                j: 24,
                d: 3,
            },
            2 => Request::SketchCp { cp: nan_cp(&mut rng), j: 12 },
            _ => Request::SketchCp { cp: CpTensor::randn(&mut rng, &[5, 5, 5], 2), j: 12 },
        };
        rxs.push(h.submit(req).expect("validation must accept these"));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv()
            .unwrap_or_else(|_| panic!("job {i}: reply sender dropped — response lost"));
        match (i % 4, resp) {
            // Healthy jobs must succeed with finite payloads even when a
            // poison job panicked earlier in the same drained batch.
            (0, Ok(Response::Sketch(v))) | (3, Ok(Response::Sketch(v))) => {
                assert!(!v.is_empty());
                assert!(v.iter().all(|x| x.is_finite()), "job {i}: healthy sketch corrupted");
            }
            (0, other) | (3, other) => panic!("job {i}: healthy job failed: {other:?}"),
            // NaN inner estimates: a NaN scalar (total_cmp median) is fine;
            // a caught panic surfacing as Exec is fine; a lost reply is not.
            (1, Ok(Response::Scalar(_))) => {}
            (1, Err(ServiceError::Exec(_))) => {}
            (1, other) => panic!("job {i}: unexpected NaN-estimate outcome: {other:?}"),
            // NaN CP sketches: debug builds trip the Hermitian-residue
            // assert (caught → Exec); release builds return a NaN sketch.
            (2, Ok(Response::Sketch(_))) => {}
            (2, Err(ServiceError::Exec(msg))) => {
                assert!(msg.contains("panicked"), "job {i}: unexpected Exec: {msg}");
            }
            (2, other) => panic!("job {i}: unexpected poison-CP outcome: {other:?}"),
            _ => unreachable!("i % 4 ∈ 0..4"),
        }
    }
    // The pool must still be fully alive: a healthy tail job round-trips.
    let tail = h
        .call(Request::SketchDense {
            tensor: Tensor::randn(&mut rng, &[6, 6, 6]),
            method: SketchMethod::Ts,
            j: 16,
        })
        .expect("worker pool dead after poison batch");
    let Response::Sketch(v) = tail else { panic!("wrong response kind") };
    assert!(v.iter().all(|x| x.is_finite()));
    // Books reconcile: every accepted job (poison included) was recorded
    // exactly once — panicked jobs still count as completed-with-error.
    let report = svc.stats();
    assert_eq!(report.total_completed as usize, total + 1, "stats lost a job");
    assert_eq!(report.rejected_busy, 0);
    svc.shutdown();
}

#[test]
fn repeated_start_shutdown_cycles_are_clean() {
    // Shutdown determinism: cycles must neither deadlock nor leak panics,
    // with and without in-flight work.
    for cycle in 0..5 {
        let svc = start(2, 32);
        let h = svc.handle();
        let mut rng = Rng::seed_from_u64(cycle);
        let mut rxs = Vec::new();
        for _ in 0..(cycle as usize * 3) {
            let t = Tensor::randn(&mut rng, &[4, 4, 4]);
            if let Ok(rx) =
                h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 8 })
            {
                rxs.push(rx);
            }
        }
        svc.shutdown();
        // Submitting after shutdown must fail cleanly, not hang.
        let t = Tensor::randn(&mut rng, &[4, 4, 4]);
        assert!(matches!(
            h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 8 }),
            Err(ServiceError::Closed)
        ));
        for rx in rxs {
            // Every accepted pre-shutdown job resolved or dropped cleanly.
            let _ = rx.recv();
        }
    }
}
