//! Coordinator stress: concurrent clients hammering the workspace-backed
//! worker pool with mixed same-shape/different-shape jobs — including while
//! `shutdown()` runs — must never lose a response, never panic, and leave
//! stats that add up.
//!
//! "Never lose" means: every `submit` that returned `Ok(rx)` resolves — the
//! client either receives exactly one response, or observes a clean
//! disconnect for jobs that were still queued behind the stop sentinels.
//! `answered == total_completed` ties the two books together.
//!
//! The fused-flight tests additionally pin the cross-request micro-batching
//! contract: fused execution is **bit-identical** to serial (asserted as a
//! permutation match against per-`req_id` serial references, because the
//! job → `req_id` pairing is timing-dependent), flights wider than one job
//! actually occur under a single-worker flood, and a poisoned job inside a
//! fused flight costs exactly its own reply.

use fcs::coordinator::{
    job_rng, Request, Response, Service, ServiceConfig, ServiceError, SketchMethod, WorkerState,
};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;
use std::time::Duration;

/// Service seed shared by [`start`] and the reference-table constructions.
const SEED: u64 = 9;

fn start(workers: usize, cap: usize) -> Service {
    Service::start(
        ServiceConfig {
            workers,
            queue_capacity: cap,
            batch_deadline: Duration::from_micros(200),
            seed: SEED,
        },
        None,
    )
    .unwrap()
}

/// Bitwise slice equality — the fused-path contract is bit-identity, not
/// approximate agreement, so compare `f64::to_bits`, not `==`.
fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Expected sketch length for a `SketchDense` request.
fn dense_len(order: usize, method: SketchMethod, j: usize) -> usize {
    match method {
        SketchMethod::Ts => j,
        SketchMethod::Fcs => order * j - order + 1,
    }
}

#[test]
fn mixed_shapes_all_answered_with_correct_lengths() {
    // Same-shape bursts interleaved with shape changes force the worker's
    // drain-and-group path to reorder jobs; replies must still route to the
    // right clients (verified via per-request expected lengths).
    let svc = start(3, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(1);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..300 {
        let (shape, j, method): (Vec<usize>, usize, SketchMethod) = match i % 4 {
            0 | 1 => (vec![6, 6, 6], 32, SketchMethod::Fcs), // same-shape burst
            2 => (vec![3, 8, 4], 16, SketchMethod::Ts),
            _ => (
                vec![rng.below(5) as usize + 2, 4, rng.below(4) as usize + 2],
                8,
                SketchMethod::Fcs,
            ),
        };
        let t = Tensor::randn(&mut rng, &shape);
        expected.push(dense_len(shape.len(), method, j));
        rxs.push(h.submit(Request::SketchDense { tensor: t, method, j }).unwrap());
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let Response::Sketch(v) = rx.recv().unwrap().unwrap() else {
            panic!("wrong response kind")
        };
        assert_eq!(v.len(), want);
        assert!(v.iter().all(|x| x.is_finite()));
        assert!(rx.try_recv().is_err(), "answered more than once");
    }
    let report = svc.stats();
    assert_eq!(report.total_completed, 300);
    svc.shutdown();
}

#[test]
fn shutdown_under_fire_loses_no_response() {
    let svc = start(3, 64);
    let h = svc.handle();
    let clients = 6;
    let per_client = 100;
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(1000 + c);
                let mut pending = Vec::new();
                let (mut accepted, mut busy, mut closed_submit) = (0u64, 0u64, 0u64);
                for i in 0..per_client {
                    let req = match i % 3 {
                        0 => Request::SketchDense {
                            tensor: Tensor::randn(&mut rng, &[6, 6, 6]),
                            method: SketchMethod::Fcs,
                            j: 24,
                        },
                        1 => Request::SketchDense {
                            tensor: Tensor::randn(&mut rng, &[4, 7, 3]),
                            method: SketchMethod::Ts,
                            j: 16,
                        },
                        _ => Request::SketchCp {
                            cp: CpTensor::randn(&mut rng, &[5, 5, 5], 2),
                            j: 12,
                        },
                    };
                    match h.submit(req) {
                        Ok(rx) => {
                            accepted += 1;
                            pending.push(rx);
                        }
                        Err(ServiceError::Busy) => busy += 1,
                        Err(ServiceError::Closed) => closed_submit += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                let (mut answered, mut dropped) = (0u64, 0u64);
                for rx in pending {
                    match rx.recv() {
                        Ok(resp) => {
                            match resp.expect("execution must not fail") {
                                Response::Sketch(v) => {
                                    assert!(!v.is_empty());
                                    assert!(v.iter().all(|x| x.is_finite()));
                                }
                                Response::Scalar(_) => panic!("wrong response kind"),
                            }
                            answered += 1;
                        }
                        // Reply sender dropped: the job was still queued
                        // behind the stop sentinels at shutdown. A clean,
                        // observable drop — not a lost response.
                        Err(_) => dropped += 1,
                    }
                }
                assert_eq!(answered + dropped, accepted, "client {c}: response unaccounted");
                (accepted, busy, closed_submit, answered, dropped)
            })
        })
        .collect();

    // Let traffic build, then pull the plug while clients are mid-stream.
    std::thread::sleep(Duration::from_millis(15));
    let stats_handle = h.clone();
    drop(h);
    svc.shutdown();

    let mut totals = (0u64, 0u64, 0u64, 0u64, 0u64);
    for t in threads {
        let (a, b, c, ans, d) = t.join().expect("client panicked");
        totals.0 += a;
        totals.1 += b;
        totals.2 += c;
        totals.3 += ans;
        totals.4 += d;
    }
    let (accepted, busy, _closed, answered, dropped) = totals;
    assert_eq!(answered + dropped, accepted, "global response accounting");

    // Stats must agree with the clients' books: every answered worker-pool
    // job was recorded exactly once, every Busy rejection counted.
    let report = stats_handle.stats();
    let worker_ops: u64 = report
        .per_op
        .iter()
        .filter(|o| o.op == "sketch_dense" || o.op == "sketch_cp")
        .map(|o| o.completed)
        .sum();
    assert_eq!(worker_ops, answered, "stats vs client books");
    assert_eq!(report.rejected_busy, busy, "busy rejections must be counted");
}

#[test]
fn poison_jobs_never_lose_responses_and_workers_survive() {
    // Adversarial/degenerate traffic interleaved with healthy jobs: NaN
    // tensors (estimator medians must be NaN-tolerant, not panic) and NaN CP
    // factors (in debug builds the non-Hermitian-residue kernel assert fires
    // — the per-job catch_unwind must convert that into an Exec error, keep
    // the worker alive, and keep every other drained job's reply flowing).
    // The contract under test: EVERY accepted submission resolves, and the
    // healthy jobs around the poison keep producing finite sketches.
    let svc = start(2, 512);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(0xBAD);
    let nan_tensor = |rng: &mut Rng, shape: &[usize]| {
        let mut t = Tensor::randn(rng, shape);
        let mid = t.data.len() / 2;
        t.data[0] = f64::NAN;
        t.data[mid] = f64::NAN;
        t
    };
    let nan_cp = |rng: &mut Rng| {
        let mut cp = CpTensor::randn(rng, &[5, 4, 6], 2);
        cp.factors[1].data[3] = f64::NAN;
        cp
    };
    let mut rxs = Vec::new();
    let total = 160usize;
    for i in 0..total {
        let req = match i % 4 {
            0 => Request::SketchDense {
                tensor: Tensor::randn(&mut rng, &[6, 6, 6]),
                method: SketchMethod::Fcs,
                j: 16,
            },
            1 => Request::InnerEstimate {
                a: nan_tensor(&mut rng, &[4, 4, 4]),
                b: nan_tensor(&mut rng, &[4, 4, 4]),
                method: SketchMethod::Fcs,
                j: 24,
                d: 3,
            },
            2 => Request::SketchCp { cp: nan_cp(&mut rng), j: 12 },
            _ => Request::SketchCp { cp: CpTensor::randn(&mut rng, &[5, 5, 5], 2), j: 12 },
        };
        rxs.push(h.submit(req).expect("validation must accept these"));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv()
            .unwrap_or_else(|_| panic!("job {i}: reply sender dropped — response lost"));
        match (i % 4, resp) {
            // Healthy jobs must succeed with finite payloads even when a
            // poison job panicked earlier in the same drained batch.
            (0, Ok(Response::Sketch(v))) | (3, Ok(Response::Sketch(v))) => {
                assert!(!v.is_empty());
                assert!(v.iter().all(|x| x.is_finite()), "job {i}: healthy sketch corrupted");
            }
            (0, other) | (3, other) => panic!("job {i}: healthy job failed: {other:?}"),
            // NaN inner estimates: a NaN scalar (total_cmp median) is fine;
            // a caught panic surfacing as Exec is fine; a lost reply is not.
            (1, Ok(Response::Scalar(_))) => {}
            (1, Err(ServiceError::Exec(_))) => {}
            (1, other) => panic!("job {i}: unexpected NaN-estimate outcome: {other:?}"),
            // NaN CP sketches: debug builds trip the Hermitian-residue
            // assert (caught → Exec); release builds return a NaN sketch.
            (2, Ok(Response::Sketch(_))) => {}
            (2, Err(ServiceError::Exec(msg))) => {
                assert!(msg.contains("panicked"), "job {i}: unexpected Exec: {msg}");
            }
            (2, other) => panic!("job {i}: unexpected poison-CP outcome: {other:?}"),
            _ => unreachable!("i % 4 ∈ 0..4"),
        }
    }
    // The pool must still be fully alive: a healthy tail job round-trips.
    let tail = h
        .call(Request::SketchDense {
            tensor: Tensor::randn(&mut rng, &[6, 6, 6]),
            method: SketchMethod::Ts,
            j: 16,
        })
        .expect("worker pool dead after poison batch");
    let Response::Sketch(v) = tail else { panic!("wrong response kind") };
    assert!(v.iter().all(|x| x.is_finite()));
    // Books reconcile: every accepted job (poison included) was recorded
    // exactly once — panicked jobs still count as completed-with-error.
    let report = svc.stats();
    assert_eq!(report.total_completed as usize, total + 1, "stats lost a job");
    assert_eq!(report.rejected_busy, 0);
    svc.shutdown();
}

#[test]
fn worker_state_fused_path_matches_serial_bitwise() {
    // Mixed-rank, same-geometry flight straight through WorkerState: the
    // fused entry point must reproduce each job's serial sketch bit for bit
    // when driven with the same per-job RNGs.
    let mut rng = Rng::seed_from_u64(7);
    let j = 16usize;
    let cps: Vec<CpTensor> =
        (0..5).map(|w| CpTensor::randn(&mut rng, &[6, 5, 4], 1 + w % 3)).collect();
    let mut serial = Vec::new();
    for (id, cp) in cps.iter().enumerate() {
        // Fresh state per job: the serial reference must not depend on
        // arena warmth from earlier jobs (and provably does not — but the
        // reference should not assume that).
        let mut st = WorkerState::new();
        let mut out = Vec::new();
        st.sketch_cp_into(cp, j, &mut job_rng(SEED, id as u64), &mut out);
        serial.push(out);
    }
    let mut st = WorkerState::new();
    let refs: Vec<&CpTensor> = cps.iter().collect();
    let mut rngs: Vec<Rng> = (0..cps.len()).map(|id| job_rng(SEED, id as u64)).collect();
    let mut outs = Vec::new();
    st.sketch_cp_fused(&refs, j, &mut rngs, &mut outs);
    assert_eq!(outs.len(), serial.len());
    for (w, (f, s)) in outs.iter().zip(&serial).enumerate() {
        assert!(bits_eq(f, s), "job {w}: fused sketch is not bit-identical to serial");
    }
}

#[test]
fn fused_flights_are_bit_identical_to_serial() {
    // One worker ⇒ the pool is always "saturated", so the drain-and-fuse
    // path engages; a moderately expensive class lets the queue build while
    // the first flight executes, so flights wider than one job actually
    // occur. Two fusion classes with *identical payloads within each class*:
    // the job → req_id pairing is nondeterministic (unstable sort + timing-
    // dependent drain boundaries), so correctness is asserted as a
    // permutation match — every response must equal the serial output of its
    // payload under exactly one unused req_id, and all req_ids must be used.
    let svc = start(1, 512);
    let h = svc.handle();
    let k = 24usize;
    let total = 2 * k;
    let mut rng = Rng::seed_from_u64(42);
    let cp_a = CpTensor::randn(&mut rng, &[30, 30, 30], 4);
    let cp_b = CpTensor::randn(&mut rng, &[9, 7, 11], 2);
    let (ja, jb) = (64usize, 16usize);
    let mut rxs = Vec::new();
    for i in 0..total {
        let req = if i % 2 == 0 {
            Request::SketchCp { cp: cp_a.clone(), j: ja }
        } else {
            Request::SketchCp { cp: cp_b.clone(), j: jb }
        };
        rxs.push(h.submit(req).expect("queue sized for the flood"));
    }
    // Per-req_id serial references: what a pre-fusion worker would have
    // produced for either payload under each possible req_id (the service's
    // counter starts at 0 and draws exactly one id per accepted job).
    let mut st = WorkerState::new();
    let (mut ref_a, mut ref_b) = (Vec::with_capacity(total), Vec::with_capacity(total));
    for id in 0..total as u64 {
        let mut out = Vec::new();
        st.sketch_cp_into(&cp_a, ja, &mut job_rng(SEED, id), &mut out);
        ref_a.push(out);
        let mut out = Vec::new();
        st.sketch_cp_into(&cp_b, jb, &mut job_rng(SEED, id), &mut out);
        ref_b.push(out);
    }
    let mut used = vec![false; total];
    for (i, rx) in rxs.into_iter().enumerate() {
        let Response::Sketch(v) = rx.recv().unwrap().unwrap() else {
            panic!("job {i}: wrong response kind")
        };
        let refs = if i % 2 == 0 { &ref_a } else { &ref_b };
        let id = (0..total)
            .find(|&id| !used[id] && bits_eq(&v, &refs[id]))
            .unwrap_or_else(|| {
                panic!("job {i}: fused output matches no unused serial reference")
            });
        used[id] = true;
    }
    assert!(used.iter().all(|&u| u), "req_ids not covered exactly once");
    let report = svc.stats();
    assert_eq!(report.total_completed as usize, total);
    // The tentpole's observable: flights wider than one job occurred, and
    // the per-width books account for every worker-pool job exactly once.
    assert!(
        report.flights.iter().any(|f| f.width > 1),
        "no fused flight wider than 1 under a single-worker flood: {:?}",
        report.flights
    );
    assert_eq!(report.flights.iter().map(|f| f.jobs).sum::<u64>() as usize, total);
    let op = report.per_op.iter().find(|o| o.op == "sketch_cp").unwrap();
    assert_eq!(op.completed as usize, total);
    assert!(op.exec_p50_us > 0.0, "queue/exec split must be recorded for pool ops");
    svc.shutdown();
}

#[test]
fn poisoned_job_inside_fused_flight_costs_only_its_own_reply() {
    // Identical-class CP flood with NaN-factor jobs interleaved: every job
    // fuses into the same class, so the poison rides *inside* shared
    // flights. Contract: healthy jobs stay bit-identical to their serial
    // references (the post-panic retry re-derives each RNG from its stored
    // req_id), the poison costs exactly its own reply, and the pool
    // survives.
    let svc = start(1, 512);
    let h = svc.handle();
    let k = 40usize;
    let mut rng = Rng::seed_from_u64(0xF00D);
    let cp_h = CpTensor::randn(&mut rng, &[5, 4, 6], 2);
    let mut cp_p = CpTensor::randn(&mut rng, &[5, 4, 6], 2);
    cp_p.factors[1].data[3] = f64::NAN;
    let j = 12usize;
    let mut rxs = Vec::new();
    for i in 0..k {
        let cp = if i % 5 == 0 { cp_p.clone() } else { cp_h.clone() };
        rxs.push(h.submit(Request::SketchCp { cp, j }).unwrap());
    }
    let mut st = WorkerState::new();
    let refs: Vec<Vec<f64>> = (0..k as u64)
        .map(|id| {
            let mut out = Vec::new();
            st.sketch_cp_into(&cp_h, j, &mut job_rng(SEED, id), &mut out);
            out
        })
        .collect();
    let mut used = vec![false; k];
    let mut poison_execs = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv()
            .unwrap_or_else(|_| panic!("job {i}: reply sender dropped — response lost"));
        if i % 5 == 0 {
            match resp {
                // Release builds: the fused flight succeeds and the NaN
                // stays confined to its own job's lanes.
                Ok(Response::Sketch(_)) => {}
                // Debug builds: the Hermitian-residue assert unwinds the
                // whole fused attempt; the serial retry's own catch_unwind
                // converts this job (and only this job) into an Exec.
                Err(ServiceError::Exec(msg)) => {
                    assert!(msg.contains("panicked"), "job {i}: unexpected Exec: {msg}");
                    poison_execs += 1;
                }
                other => panic!("job {i}: unexpected poison outcome: {other:?}"),
            }
        } else {
            let Ok(Response::Sketch(v)) = resp else {
                panic!("job {i}: healthy job failed inside a poisoned flight")
            };
            assert!(v.iter().all(|x| x.is_finite()), "job {i}: NaN leaked across fused lanes");
            let id = (0..k)
                .find(|&id| !used[id] && bits_eq(&v, &refs[id]))
                .unwrap_or_else(|| {
                    panic!("job {i}: healthy output not bit-identical to any serial reference")
                });
            used[id] = true;
        }
    }
    if cfg!(debug_assertions) {
        assert_eq!(poison_execs, k / 5, "every poison job must surface as Exec in debug");
    }
    // The pool must still be fully alive after repeated poisoned flights.
    let tail = h
        .call(Request::SketchCp { cp: cp_h.clone(), j })
        .expect("worker pool dead after poisoned flights");
    let Response::Sketch(v) = tail else { panic!("wrong response kind") };
    assert!(v.iter().all(|x| x.is_finite()));
    let report = svc.stats();
    assert_eq!(report.total_completed as usize, k + 1, "a reply went missing from the books");
    assert_eq!(report.flights.iter().map(|f| f.jobs).sum::<u64>() as usize, k + 1);
    svc.shutdown();
}

#[test]
fn shard_merge_flood_reconciles_with_poison_isolation() {
    // Mixed shard/merge flood across many merge groups, interleaved with
    // unrelated dense traffic so shard jobs share drained batches with
    // other ops. Contracts: no lost replies; each healthy group's service
    // merge is bit-identical to its library-side ShardSketch reference
    // (the shared-seed protocol end to end, under concurrency); a poisoned
    // merge group — one shard reply truncated before the MergeShards
    // submission, tripping the execution-time equal-length assert — fails
    // only its own merge, never a sibling group or the worker; and the
    // stats books account for every request exactly once.
    let svc = start(3, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(0x5A4D);
    let groups = 12usize;
    let shards_per_group = 4usize;
    let poisoned: usize = 5; // group index whose merge gets a truncated part
    let shape = vec![4usize, 5, 3];
    let j = 6usize;
    let total: usize = shape.iter().product();

    // Integer-valued data so merge ≡ whole is exact (any IEEE association
    // of exactly dyadic partial sums yields identical bits).
    let tensors: Vec<Tensor> = (0..groups)
        .map(|_| {
            let data: Vec<f64> = (0..total).map(|_| rng.below(41) as f64 - 20.0).collect();
            Tensor::from_data(&shape, data)
        })
        .collect();
    let method = |g: usize| if g % 2 == 0 { SketchMethod::Fcs } else { SketchMethod::Ts };

    // Submit every group's shards interleaved (group-major round-robin)
    // with dense noise traffic, so batches mix ops and groups.
    let mut shard_rxs: Vec<Vec<_>> = (0..groups).map(|_| Vec::new()).collect();
    let mut noise_rxs = Vec::new();
    for s in 0..shards_per_group {
        for g in 0..groups {
            // Uneven fixed cuts: 4 shards with fiber-misaligned boundaries.
            let cuts = [0usize, 7, 30, 53, total];
            let (lo, hi) = (cuts[s], cuts[s + 1]);
            shard_rxs[g].push(
                h.submit(Request::SketchShard {
                    slab: tensors[g].data[lo..hi].to_vec(),
                    offset: lo,
                    dims: shape.clone(),
                    method: method(g),
                    j,
                    group: g as u64,
                })
                .unwrap(),
            );
            if (g + s) % 3 == 0 {
                noise_rxs.push(
                    h.submit(Request::SketchDense {
                        tensor: Tensor::randn(&mut rng, &[3, 4, 3]),
                        method: SketchMethod::Fcs,
                        j: 8,
                    })
                    .unwrap(),
                );
            }
        }
    }
    let shard_count = groups * shards_per_group;

    // Collect shard replies per group, then submit the merges — with one
    // group's parts deliberately corrupted (truncated last part).
    let mut merge_rxs = Vec::new();
    for (g, rxs) in shard_rxs.into_iter().enumerate() {
        let mut parts: Vec<Vec<f64>> = rxs
            .into_iter()
            .map(|rx| match rx.recv().unwrap().unwrap() {
                Response::Sketch(v) => v,
                other => panic!("group {g}: wrong shard response kind: {other:?}"),
            })
            .collect();
        if g == poisoned {
            let last = parts.last_mut().unwrap();
            last.truncate(last.len() - 1);
        }
        merge_rxs.push(h.submit(Request::MergeShards { parts }).unwrap());
    }
    for rx in noise_rxs {
        rx.recv().unwrap().unwrap();
    }

    for (g, rx) in merge_rxs.into_iter().enumerate() {
        let resp = rx
            .recv()
            .unwrap_or_else(|_| panic!("group {g}: merge reply sender dropped — response lost"));
        if g == poisoned {
            match resp {
                Err(ServiceError::Exec(msg)) => {
                    assert!(
                        msg.contains("shard sketch lengths differ"),
                        "group {g}: unexpected Exec: {msg}"
                    );
                }
                other => panic!("group {g}: poisoned merge did not fail as Exec: {other:?}"),
            }
            continue;
        }
        let Ok(Response::Sketch(merged)) = resp else {
            panic!("group {g}: healthy merge failed next to a poisoned sibling")
        };
        // Library-side whole-tensor reference under the same (seed, group).
        let mut lib = fcs::sketch::ShardSketch::for_group(
            SEED,
            g as u64,
            &shape,
            j,
            method(g) == SketchMethod::Ts,
        );
        lib.absorb_slab(&tensors[g].data, 0);
        assert!(
            bits_eq(&merged, lib.sketch()),
            "group {g}: concurrent service merge ≠ library whole-tensor reference"
        );
    }

    // The pool survives the poisoned merge.
    let tail = h
        .call(Request::SketchShard {
            slab: tensors[0].data.clone(),
            offset: 0,
            dims: shape.clone(),
            method: SketchMethod::Fcs,
            j,
            group: 0,
        })
        .expect("worker pool dead after poisoned merge");
    let Response::Sketch(v) = tail else { panic!("wrong response kind") };
    assert!(v.iter().all(|x| x.is_finite()));

    // Books reconcile: per-op completions match the submission counts
    // exactly (the poisoned merge still completes — with an error).
    let report = svc.stats();
    let completed = |op: &str| {
        report.per_op.iter().filter(|o| o.op == op).map(|o| o.completed).sum::<u64>()
    };
    assert_eq!(completed("sketch_shard") as usize, shard_count + 1, "shard books off");
    assert_eq!(completed("merge_shards") as usize, groups, "merge books off");
    assert_eq!(report.rejected_busy, 0);
    svc.shutdown();

    // Obs agrees with stats on the new instruments: at least this test's
    // shard widths and merge depths were observed (the registry is
    // process-global and shared with parallel tests, hence >=).
    let m = fcs::obs::metrics();
    assert!(m.shard_width.count() >= shard_count as u64 + 1, "shard_width not recorded");
    assert!(m.merge_depth.count() >= (groups - 1) as u64, "merge_depth not recorded");
}

#[test]
fn trace_spans_stay_ordered_under_mixed_shape_flood() {
    // Every reply leaves a span in the process-global trace book; its edges
    // are clamped at record time, so `submit ≤ queue ≤ flight-start ≤ reply`
    // is a structural invariant — asserted here with zero timing tolerance.
    // The book is shared with the other tests in this binary (they run in
    // parallel and also record spans), so the assertions quantify over every
    // span present, not just this flood's.
    let svc = start(3, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(0x7ACE);
    let mut rxs = Vec::new();
    let flood = 300usize;
    for i in 0..flood {
        let (shape, j, method): (Vec<usize>, usize, SketchMethod) = match i % 4 {
            0 | 1 => (vec![6, 6, 6], 32, SketchMethod::Fcs),
            2 => (vec![3, 8, 4], 16, SketchMethod::Ts),
            _ => (
                vec![rng.below(5) as usize + 2, 4, rng.below(4) as usize + 2],
                8,
                SketchMethod::Fcs,
            ),
        };
        let t = Tensor::randn(&mut rng, &shape);
        rxs.push(h.submit(Request::SketchDense { tensor: t, method, j }).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    svc.shutdown();

    let spans = fcs::obs::trace::global().recent(usize::MAX);
    // Each shard retains 512 spans and this flood spreads over at most 3
    // shards with ≤ 300 spans each, so even with every other test's traffic
    // accounted the book must still hold at least this flood's worth.
    assert!(spans.len() >= flood, "trace book lost spans: {} < {flood}", spans.len());
    let known_ops =
        ["cs_vec", "sketch_dense", "sketch_cp", "inner_estimate", "sketch_shard", "merge_shards"];
    for s in &spans {
        assert!(
            s.submit_us <= s.queue_us
                && s.queue_us <= s.flight_start_us
                && s.flight_start_us <= s.reply_us,
            "span req_id={} violates submit ≤ queue ≤ flight-start ≤ reply: {s:?}",
            s.req_id
        );
        assert!(s.width >= 1, "span req_id={} has zero flight width", s.req_id);
        assert!(known_ops.contains(&s.op), "span req_id={} has unknown op {}", s.req_id, s.op);
    }
    // Oldest-first contract of `recent`.
    for w in spans.windows(2) {
        assert!(w[0].reply_us <= w[1].reply_us, "recent() not sorted by reply time");
    }
}

#[test]
fn repeated_start_shutdown_cycles_are_clean() {
    // Shutdown determinism: cycles must neither deadlock nor leak panics,
    // with and without in-flight work.
    for cycle in 0..5 {
        let svc = start(2, 32);
        let h = svc.handle();
        let mut rng = Rng::seed_from_u64(cycle);
        let mut rxs = Vec::new();
        for _ in 0..(cycle as usize * 3) {
            let t = Tensor::randn(&mut rng, &[4, 4, 4]);
            if let Ok(rx) =
                h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 8 })
            {
                rxs.push(rx);
            }
        }
        svc.shutdown();
        // Submitting after shutdown must fail cleanly, not hang.
        let t = Tensor::randn(&mut rng, &[4, 4, 4]);
        assert!(matches!(
            h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 8 }),
            Err(ServiceError::Closed)
        ));
        for rx in rxs {
            // Every accepted pre-shutdown job resolved or dropped cleanly.
            let _ = rx.recv();
        }
    }
}
