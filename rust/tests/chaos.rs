//! Chaos suite: deterministic fault injection (the `failpoints` feature)
//! driven through the public service API. Each scenario arms an explicit
//! schedule — seeded, probability-gated, hit-capped — and then proves the
//! resilience contracts: zero lost replies, books that reconcile, failures
//! confined to exactly the job that hit them, and a pool that heals back to
//! full width.
//!
//! The failpoint registry and the obs counters are process-global, so the
//! tests serialize on one mutex and start from `clear_all()`; global
//! counters are asserted as deltas, per-service [`fcs::coordinator::Stats`]
//! exactly.
#![cfg(feature = "failpoints")]

use fcs::coordinator::{
    job_rng, Request, Response, Service, ServiceConfig, ServiceError, SketchMethod, WorkerState,
};
use fcs::fault::{clear_all, configure, hits, FaultAction, FaultSpec};
use fcs::obs::exporter::Exporter;
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Service seed shared by [`start`] and the reference constructions.
const SEED: u64 = 23;

/// One chaos scenario at a time: the failpoint registry is process-global,
/// and a schedule armed by one test must not fire in another. Poisoned by a
/// failing sibling is fine — we clear the registry on entry either way.
static GATE: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    clear_all();
    g
}

fn start(workers: usize, cap: usize) -> Service {
    Service::start(
        ServiceConfig {
            workers,
            queue_capacity: cap,
            batch_deadline: Duration::from_micros(200),
            seed: SEED,
        },
        None,
    )
    .unwrap()
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn always(action: FaultAction, max_hits: u64, seed: u64) -> FaultSpec {
    FaultSpec { action, prob: 1.0, max_hits: Some(max_hits), seed }
}

#[test]
fn flooded_pool_under_injection_loses_no_replies_and_self_heals() {
    let _g = lock();
    // One worker thread dies at its loop top (outside any catch_unwind,
    // before the queue lock — holding nothing); the first 20 serial jobs
    // are delayed to manufacture backlog and deadline expiry; the first
    // merge sees a torn shard.
    configure("worker_loop", always(FaultAction::Panic, 1, 1));
    configure("worker_job", always(FaultAction::Delay(Duration::from_micros(300)), 20, 2));
    configure("merge_shards", always(FaultAction::TruncateSlab, 1, 3));

    let svc = start(3, 2048);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(4);
    let total = 240usize;
    let mut rxs = Vec::new();
    let (mut submit_shed, mut busy) = (0usize, 0usize);
    for i in 0..total {
        let dense = |rng: &mut Rng, shape: &[usize], j: usize| Request::SketchDense {
            tensor: Tensor::randn(rng, shape),
            method: SketchMethod::Fcs,
            j,
        };
        let (req, deadline) = match i % 6 {
            0 => (dense(&mut rng, &[4, 4, 4], 8), None),
            1 => (dense(&mut rng, &[6, 6, 6], 24), None),
            2 => (Request::SketchCp { cp: CpTensor::randn(&mut rng, &[6, 5, 4], 2), j: 12 }, None),
            3 => (Request::MergeShards { parts: vec![vec![1.0; 16], vec![2.0; 16]] }, None),
            4 => (dense(&mut rng, &[5, 5, 5], 16), Some(Instant::now() + Duration::from_millis(2))),
            // Already expired at submit: a deterministic submit-stage shed.
            _ => (dense(&mut rng, &[5, 5, 5], 16), Some(Instant::now())),
        };
        match h.submit_with_deadline(req, deadline) {
            Ok(rx) => rxs.push(rx),
            Err(ServiceError::DeadlineExceeded) => submit_shed += 1,
            Err(ServiceError::Busy) => busy += 1,
            Err(e) => panic!("request {i}: unexpected submit error {e}"),
        }
    }
    let accepted = rxs.len();
    assert_eq!(accepted + submit_shed + busy, total);
    assert!(submit_shed >= total / 6, "every kind-5 submission must be shed at submit");

    // Zero lost replies: every accepted request resolves exactly once, even
    // though a worker died and every failure class above fired.
    let (mut ok, mut exec, mut dl_x) = (0usize, 0usize, 0usize);
    for rx in rxs {
        match rx.recv().expect("reply sender dropped — a response was lost") {
            Ok(_) => ok += 1,
            Err(ServiceError::Exec(msg)) => {
                assert!(msg.contains("panicked"), "unexpected exec error: {msg}");
                exec += 1;
            }
            Err(ServiceError::DeadlineExceeded) => dl_x += 1,
            Err(e) => panic!("unexpected reply error {e}"),
        }
    }
    assert_eq!(ok + exec + dl_x, accepted);
    assert_eq!(exec, 1, "exactly the torn merge fails, nothing else");

    // The schedules fired exactly as armed.
    assert_eq!(hits("worker_loop"), 1);
    assert_eq!(hits("worker_job"), 20);
    assert_eq!(hits("merge_shards"), 1);

    // The supervisor replaces the dead worker (sweep cadence 10ms — poll).
    let deadline = Instant::now() + Duration::from_secs(5);
    while svc.stats().worker_respawns < 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    let report = svc.stats();
    assert_eq!(report.worker_respawns, 1, "one injected death, one respawn: {report:?}");

    // Books reconcile across every outcome class.
    assert_eq!(report.total_completed as usize, ok + exec);
    assert_eq!(report.shed_submit as usize, submit_shed);
    assert_eq!(report.shed_dequeue as usize + report.shed_flight as usize, dl_x);
    assert_eq!(report.rejected_busy as usize, busy);

    // Disarmed, the healed pool serves normally at full width.
    clear_all();
    let Response::Sketch(v) = h
        .call(Request::SketchDense {
            tensor: Tensor::randn(&mut rng, &[4, 4, 4]),
            method: SketchMethod::Fcs,
            j: 8,
        })
        .unwrap()
    else {
        panic!("wrong response kind")
    };
    assert!(v.iter().all(|x| x.is_finite()));
    svc.shutdown();
}

#[test]
fn injected_driver_panic_inside_fused_flight_recovers_bit_identically() {
    let _g = lock();
    // A delayed merge blocker (req_id 0) lets six identical CP jobs queue
    // behind it; they drain as one fused flight whose shared spectral
    // transform is shot down mid-pass. The abort must fall back to per-job
    // serial retry with the *original* req_ids — every reply Ok and
    // bit-identical to its serial reference.
    configure("worker_job", always(FaultAction::Delay(Duration::from_millis(50)), 1, 1));
    configure("spectral_driver", always(FaultAction::Panic, 1, 2));
    let aborts_before = fcs::obs::metrics().fused_flight_aborts.get();

    let svc = start(1, 256);
    let h = svc.handle();
    let blocker =
        h.submit(Request::MergeShards { parts: vec![vec![1.0; 32], vec![2.0; 32]] }).unwrap();
    // Let the worker dequeue the blocker and park in the injected delay.
    std::thread::sleep(Duration::from_millis(10));

    let mut rng = Rng::seed_from_u64(5);
    let cp = CpTensor::randn(&mut rng, &[12, 11, 10], 3);
    let j = 64usize;
    let k = 6usize;
    let rxs: Vec<_> =
        (0..k).map(|_| h.submit(Request::SketchCp { cp: cp.clone(), j }).unwrap()).collect();

    let mut st = WorkerState::new();
    let refs: Vec<Vec<f64>> = (1..=(k as u64))
        .map(|id| {
            let mut out = Vec::new();
            st.sketch_cp_into(&cp, j, &mut job_rng(SEED, id), &mut out);
            out
        })
        .collect();
    let mut used = vec![false; k];
    for (i, rx) in rxs.into_iter().enumerate() {
        let Response::Sketch(v) = rx.recv().unwrap().unwrap_or_else(|e| {
            panic!("job {i}: fused-abort recovery must answer Ok, got {e}")
        }) else {
            panic!("job {i}: wrong response kind")
        };
        let id = (0..k)
            .find(|&id| !used[id] && bits_eq(&v, &refs[id]))
            .unwrap_or_else(|| panic!("job {i}: reply not bit-identical to any serial reference"));
        used[id] = true;
    }
    blocker.recv().unwrap().unwrap();

    assert_eq!(hits("spectral_driver"), 1, "the panic fired inside the fused transform");
    assert_eq!(hits("worker_job"), 1);
    assert!(
        fcs::obs::metrics().fused_flight_aborts.get() > aborts_before,
        "the fused abort must be visible on fcs_fused_flight_aborts_total"
    );
    svc.shutdown();
}

#[test]
fn truncated_shard_merge_confines_failure_to_its_group() {
    let _g = lock();
    configure("merge_shards", always(FaultAction::TruncateSlab, 1, 7));
    let svc = start(2, 512);
    let h = svc.handle();

    // Submitted and received serially, so the single armed hit lands on the
    // first merge deterministically: the torn shard trips the equal-length
    // assert, and per-job isolation turns it into this group's Exec reply.
    let torn = h.call(Request::MergeShards { parts: vec![vec![1.0; 16], vec![2.0; 16]] });
    match torn {
        Err(ServiceError::Exec(msg)) => {
            assert!(msg.contains("panicked"), "unexpected exec error: {msg}")
        }
        other => panic!("torn merge must fail with Exec, got {other:?}"),
    }

    // The next merge group is untouched — and exact.
    let parts = vec![vec![0.5; 24], vec![1.5; 24], vec![2.5; 24]];
    let Response::Sketch(merged) =
        h.call(Request::MergeShards { parts: parts.clone() }).unwrap()
    else {
        panic!("wrong response kind")
    };
    let (want, _) = fcs::sketch::merge::tree_reduce_parts(&parts);
    assert!(bits_eq(&merged, &want));

    // And unrelated ops never saw the fault.
    let mut rng = Rng::seed_from_u64(6);
    h.call(Request::SketchDense {
        tensor: Tensor::randn(&mut rng, &[5, 5, 5]),
        method: SketchMethod::Fcs,
        j: 16,
    })
    .unwrap();
    assert_eq!(hits("merge_shards"), 1);
    svc.shutdown();
}

#[test]
fn exporter_fault_returns_500_and_recovers() {
    let _g = lock();
    // The exporter site runs on the accept-loop thread, so its schedule maps
    // Error onto a 500 — the scrape fails visibly, the loop survives.
    configure("exporter", always(FaultAction::Error, 1, 9));
    let mut exporter = Exporter::bind("127.0.0.1:0").unwrap();
    let addr = exporter.local_addr();

    let get = |path: &str| {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    };

    let faulted = get("/metrics");
    assert!(faulted.starts_with("HTTP/1.1 500 Internal Server Error\r\n"), "{faulted}");
    assert!(faulted.ends_with("injected fault\n"), "{faulted}");

    let healthy = get("/metrics");
    assert!(healthy.starts_with("HTTP/1.1 200 OK\r\n"), "{healthy}");
    assert!(healthy.contains("fcs_faults_injected_total"), "{healthy}");
    assert_eq!(hits("exporter"), 1);
    exporter.shutdown();
}
