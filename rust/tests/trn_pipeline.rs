//! End-to-end TRN pipeline: train through the AOT XLA train-step artifact
//! and verify learning actually happens (loss decreases, accuracy beats
//! chance) for each sketched head.

use fcs::runtime::spawn_runtime;
use fcs::trn::{train_and_eval, TrnMethod, TrnRunConfig};

fn quick_cfg(method: TrnMethod) -> TrnRunConfig {
    TrnRunConfig {
        method,
        cr_tag: "200".into(), // smallest sketch → fastest artifact
        steps: 40,
        lr: 0.05,
        train_size: 640,
        test_size: 128,
        seed: 42,
        log_every: 0,
    }
}

#[test]
fn fcs_trn_learns() {
    let Ok(rt) = spawn_runtime(None) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let res = train_and_eval(&rt, &quick_cfg(TrnMethod::Fcs)).unwrap();
    let first = res.losses.first().copied().unwrap();
    let last = res.losses.last().copied().unwrap();
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert!(
        res.accuracy > 0.2,
        "accuracy {} should beat chance (0.1)",
        res.accuracy
    );
    assert!(res.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn all_methods_run_and_learn() {
    let Ok(rt) = spawn_runtime(None) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for method in [TrnMethod::Cs, TrnMethod::Ts, TrnMethod::Fcs] {
        let res = train_and_eval(&rt, &quick_cfg(method)).unwrap();
        let first = res.losses.first().copied().unwrap();
        let last = res.losses.last().copied().unwrap();
        assert!(
            last < first,
            "{}: loss should fall: {first} -> {last}",
            method.name()
        );
    }
}

#[test]
fn cr_tags_enumerate() {
    let Ok(rt) = spawn_runtime(None) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let tags = fcs::trn::available_cr_tags(&rt, TrnMethod::Fcs);
    assert!(tags.len() >= 4, "expected ≥4 CRs, got {tags:?}");
    // sorted ascending by CR value
    for w in tags.windows(2) {
        assert!(w[0].0 <= w[1].0);
    }
}
