//! Observability integration: concurrent recording reconciles exactly, and
//! the `/metrics` endpoint serves live crate metrics end to end.
//!
//! The reconcile test uses a **local** `Registry` instance so its totals are
//! exact (the global registry is shared with every other test in the
//! process); the exporter test drives the real coordinator → global
//! registry → TCP exporter path and asserts on the scraped text.

use fcs::coordinator::{Request, Response, Service, ServiceConfig, SketchMethod};
use fcs::obs::exporter::Exporter;
use fcs::obs::registry::Registry;
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn concurrent_writers_reconcile_exactly() {
    // 8 threads × 10k increments and observations on one instrument set:
    // relaxed RMWs must lose nothing, and histogram count/sum/buckets must
    // agree with the arithmetic total.
    let reg = Arc::new(Registry::new());
    let hits = reg.counter("t_hits_total", "test counter", "");
    let depth = reg.gauge("t_depth", "test gauge", "");
    let lat = reg.histogram("t_latency_us", "test histogram", "");
    const THREADS: u64 = 8;
    const PER: u64 = 10_000;
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let (hits, depth, lat) = (hits.clone(), depth.clone(), lat.clone());
            std::thread::spawn(move || {
                for i in 0..PER {
                    hits.inc();
                    depth.inc();
                    lat.observe(i);
                    depth.dec();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(hits.get(), THREADS * PER);
    assert_eq!(depth.get(), 0, "paired inc/dec must cancel exactly");
    assert_eq!(lat.count(), THREADS * PER);
    // Σ_{i<10k} i = 49 995 000, once per thread.
    assert_eq!(lat.sum(), THREADS * (PER * (PER - 1) / 2));
    // Bucket 0 (le=1) holds exactly the i ∈ {0, 1} observations per thread.
    assert_eq!(lat.bucket_counts()[0], THREADS * 2);
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    buf
}

/// Value of the exposition line starting with `series` (exact name + label
/// set), if present.
fn series_value(body: &str, series: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        let rest = l.strip_prefix(series)?;
        rest.strip_prefix(' ')?.trim().parse().ok()
    })
}

#[test]
fn exporter_serves_live_global_metrics() {
    // Drive real traffic through the coordinator so the global registry has
    // nonzero series, then scrape it over TCP exactly as Prometheus would.
    let svc = Service::start(
        ServiceConfig {
            workers: 2,
            queue_capacity: 1024,
            batch_deadline: Duration::from_micros(200),
            seed: 17,
        },
        None,
    )
    .unwrap();
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(5);
    let mut rxs = Vec::new();
    for _ in 0..30 {
        let t = Tensor::randn(&mut rng, &[6, 6, 6]);
        rxs.push(h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 24 }));
    }
    for _ in 0..10 {
        let cp = CpTensor::randn(&mut rng, &[5, 4, 6], 2);
        rxs.push(h.submit(Request::SketchCp { cp, j: 12 }));
    }
    for rx in rxs {
        let Response::Sketch(v) = rx.unwrap().recv().unwrap().unwrap() else {
            panic!("wrong response kind")
        };
        assert!(v.iter().all(|x| x.is_finite()));
    }
    svc.shutdown();

    // Guarantee at least one live stage sample before the scrape: force the
    // sampler and run a driver dispatch directly.
    fcs::obs::force_next_stage_sample();
    let mut st = fcs::coordinator::WorkerState::new();
    let cp = CpTensor::randn(&mut rng, &[5, 4, 6], 2);
    let mut out = Vec::new();
    st.sketch_cp_into(&cp, 12, &mut Rng::seed_from_u64(1), &mut out);

    let mut exp = Exporter::bind("127.0.0.1:0").unwrap();
    let addr = exp.local_addr();

    let health = http_get(addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 200"), "healthz: {health}");
    assert!(health.ends_with("ok\n"), "healthz body: {health}");

    let resp = http_get(addr, "/metrics");
    assert!(resp.starts_with("HTTP/1.1 200"), "metrics status: {resp}");
    assert!(resp.contains("text/plain; version=0.0.4"), "missing exposition content type");
    let body = resp.split_once("\r\n\r\n").expect("no header/body split").1;

    // Families the scrape contract promises (type lines prove the renderer
    // saw the family, independent of sample counts).
    for ty in [
        "# TYPE fcs_plan_cache_hits_total counter",
        "# TYPE fcs_plan_cache_misses_total counter",
        "# TYPE fcs_requests_completed_total counter",
        "# TYPE fcs_request_latency_us histogram",
        "# TYPE fcs_queue_wait_us histogram",
        "# TYPE fcs_exec_us histogram",
        "# TYPE fcs_flight_width histogram",
        "# TYPE fcs_stage_ns histogram",
        "# TYPE fcs_queue_depth gauge",
        "# TYPE fcs_rejected_busy_total counter",
        "# TYPE fcs_poisoned_jobs_total counter",
    ] {
        assert!(body.contains(ty), "missing {ty:?} in:\n{body}");
    }

    // Live values recorded by the flood above.
    let dense = series_value(body, "fcs_requests_completed_total{op=\"sketch_dense\"}").unwrap();
    assert!(dense >= 30.0, "sketch_dense completions not exported: {dense}");
    let cp_done = series_value(body, "fcs_requests_completed_total{op=\"sketch_cp\"}").unwrap();
    assert!(cp_done >= 10.0, "sketch_cp completions not exported: {cp_done}");
    let lat_count = series_value(body, "fcs_request_latency_us_count{op=\"sketch_dense\"}").unwrap();
    assert!(lat_count >= 30.0, "latency histogram not fed: {lat_count}");
    let widths = series_value(body, "fcs_flight_width_count").unwrap();
    assert!(widths >= 1.0, "flight widths not recorded: {widths}");
    assert!(
        series_value(body, "fcs_flight_width_bucket{le=\"+Inf\"}").unwrap() >= widths,
        "+Inf bucket must dominate the count"
    );
    // The transforms above resolve cached plans on both caches after warmup.
    let hits = series_value(body, "fcs_plan_cache_hits_total{cache=\"forward\"}").unwrap()
        + series_value(body, "fcs_plan_cache_hits_total{cache=\"real\"}").unwrap();
    let misses = series_value(body, "fcs_plan_cache_misses_total{cache=\"forward\"}").unwrap()
        + series_value(body, "fcs_plan_cache_misses_total{cache=\"real\"}").unwrap();
    assert!(hits > 0.0, "plan-cache hits not exported");
    assert!(misses > 0.0, "plan builds not exported");
    // Forced sample above: at least one stage series has observations.
    let stage_total: f64 = ["pack", "fft", "fold", "inverse"]
        .iter()
        .map(|s| series_value(body, &format!("fcs_stage_ns_count{{stage=\"{s}\"}}")).unwrap())
        .sum();
    assert!(stage_total >= 1.0, "no stage timings recorded despite forced sample");
    // All accepted jobs were drained before shutdown, so depths are flat.
    assert_eq!(
        series_value(body, "fcs_queue_depth{queue=\"worker\"}").unwrap(),
        0.0,
        "worker queue depth must return to zero after the flood drains"
    );

    let traces = http_get(addr, "/traces");
    assert!(traces.starts_with("HTTP/1.1 200"), "traces: {traces}");
    let tbody = traces.split_once("\r\n\r\n").unwrap().1;
    let j = fcs::util::json::Json::parse(tbody).expect("traces must be valid JSON");
    let spans = j.get("spans").unwrap().as_arr().unwrap();
    assert!(!spans.is_empty(), "flood must leave trace spans");

    let missing = http_get(addr, "/nope");
    assert!(missing.starts_with("HTTP/1.1 404"), "404: {missing}");

    exp.shutdown();
}
