//! Golden fixtures pinning the randomness substrate: the exact
//! `Rng` (xoshiro256++ seeded via SplitMix64) stream, the 2-wise hash
//! family's materialized tables, and an end-to-end FCS/TS sketch of a fixed
//! integer tensor.
//!
//! Every sketch in the crate is a deterministic function of this stream, so
//! a refactor of `hash/` or `util/prng.rs` that changes any of these values
//! silently changes *every* sketch, estimator trajectory, and service
//! response in the library. These literals were computed with an
//! independent reimplementation of SplitMix64 / xoshiro256++ / Lemire
//! `below` / the Mersenne-prime hash in arbitrary-precision arithmetic
//! (Python), not by running this crate — so they also cross-check the Rust
//! implementation itself.

use fcs::hash::{HashPair, ModeHashes};
use fcs::sketch::{FastCountSketch, TensorSketch};
use fcs::tensor::Tensor;
use fcs::util::prng::Rng;

#[test]
fn xoshiro_stream_is_pinned() {
    let mut r = Rng::seed_from_u64(0);
    let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
    assert_eq!(
        got,
        vec![
            0x53175d61490b23df,
            0x61da6f3dc380d507,
            0x5c0fdf91ec9a7bfc,
            0x02eebf8c3bbe5e1a,
        ]
    );
    let mut r = Rng::seed_from_u64(42);
    let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
    assert_eq!(
        got,
        vec![
            0xd0764d4f4476689f,
            0x519e4174576f3791,
            0xfbe07cfb0c24ed8c,
            0xb37d9f600cd835b8,
        ]
    );
}

#[test]
fn hash_pair_draw_is_pinned() {
    // HashPair::draw consumes four Lemire-rejection `below` draws; the
    // resulting (h, s) over domain 10, range 8 is fully determined.
    let mut r = Rng::seed_from_u64(1);
    let hp = HashPair::draw(&mut r, 10, 8);
    let h: Vec<usize> = (0..10).map(|i| hp.h(i)).collect();
    let s: Vec<f64> = (0..10).map(|i| hp.s(i)).collect();
    assert_eq!(h, vec![0, 3, 6, 1, 3, 6, 1, 4, 7, 2]);
    assert_eq!(s, vec![1.0, 1.0, 1.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0]);
    // The materialized table must agree with the evaluating form.
    let t = hp.materialize();
    assert_eq!(t.h, vec![0u32, 3, 6, 1, 3, 6, 1, 4, 7, 2]);
    assert_eq!(t.s, vec![1i8, 1, 1, -1, -1, -1, -1, -1, -1, -1]);
}

#[test]
fn mode_hashes_draw_uniform_is_pinned() {
    let mut r = Rng::seed_from_u64(0xF00D);
    let mh = ModeHashes::draw_uniform(&mut r, &[4, 3, 2], 5);
    assert_eq!(mh.composite_range(), 13);
    assert_eq!(mh.modes[0].h, vec![2u32, 3, 4, 0]);
    assert_eq!(mh.modes[0].s, vec![-1i8, 1, 1, 1]);
    assert_eq!(mh.modes[1].h, vec![2u32, 4, 2]);
    assert_eq!(mh.modes[1].s, vec![1i8, 1, 1]);
    assert_eq!(mh.modes[2].h, vec![2u32, 2]);
    assert_eq!(mh.modes[2].s, vec![-1i8, 1]);
}

#[test]
fn end_to_end_sketch_is_pinned() {
    // FCS and TS of the fixed integer tensor t.data[l] = l + 1 (col-major,
    // shape 4×3×2) under the seed-0xF00D hashes. All bucket sums are exact
    // signed-integer sums, so the comparison is exact.
    let mut r = Rng::seed_from_u64(0xF00D);
    let mh = ModeHashes::draw_uniform(&mut r, &[4, 3, 2], 5);
    let mut t = Tensor::zeros(&[4, 3, 2]);
    for (l, v) in t.data.iter_mut().enumerate() {
        *v = (l + 1) as f64;
    }
    let fcs = FastCountSketch::new(mh.clone());
    let got = fcs.apply_dense(&t);
    let expect = [
        0.0, 0.0, 0.0, 0.0, 24.0, 0.0, -12.0, 24.0, 12.0, 12.0, 12.0, 0.0, 0.0,
    ];
    assert_eq!(got.len(), 13);
    for (k, (a, e)) in got.iter().zip(expect.iter()).enumerate() {
        assert_eq!(a, e, "fcs bucket {k}");
    }
    // TS is the mod-J fold of the same composite hash (§3 point (2)).
    let ts = TensorSketch::new(mh);
    let got_ts = ts.apply_dense(&t);
    let mut folded = [0.0f64; 5];
    for (k, v) in expect.iter().enumerate() {
        folded[k % 5] += v;
    }
    assert_eq!(folded, [12.0, -12.0, 24.0, 12.0, 36.0]);
    assert_eq!(got_ts, folded.to_vec());
}
