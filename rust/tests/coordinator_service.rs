//! Coordinator invariants: exactly-once responses, backpressure, XLA/Rust
//! numeric parity, batching behaviour, concurrent clients.

use fcs::coordinator::{Request, Response, Service, ServiceConfig, ServiceError, SketchMethod};
use fcs::runtime::spawn_runtime;
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;
use std::time::Duration;

fn start_rust_only(workers: usize, cap: usize) -> Service {
    Service::start(
        ServiceConfig {
            workers,
            queue_capacity: cap,
            batch_deadline: Duration::from_micros(300),
            seed: 1,
        },
        None,
    )
    .unwrap()
}

#[test]
fn every_request_answered_exactly_once() {
    let svc = start_rust_only(4, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(2);
    let n = 200;
    let mut rxs = Vec::new();
    for _ in 0..n {
        let x = rng.normal_vec(h.cs_in_dim);
        rxs.push(h.submit(Request::CsVec { x }).unwrap());
    }
    for _ in 0..n {
        let t = Tensor::randn(&mut rng, &[4, 5, 6]);
        rxs.push(
            h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 16 })
                .unwrap(),
        );
    }
    let mut answered = 0;
    for rx in rxs {
        let resp = rx.recv().expect("one response").unwrap();
        match resp {
            Response::Sketch(v) => assert!(!v.is_empty()),
            Response::Scalar(_) => panic!("unexpected scalar"),
        }
        // second recv must fail — exactly once
        assert!(rx.try_recv().is_err());
        answered += 1;
    }
    assert_eq!(answered, 2 * n);
    let report = svc.stats();
    assert_eq!(report.total_completed, 2 * n as u64);
    svc.shutdown();
}

#[test]
fn backpressure_returns_busy() {
    // 1 worker, tiny queue, slow-ish jobs → must observe Busy.
    let svc = start_rust_only(1, 2);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(3);
    let mut busy = 0;
    let mut rxs = Vec::new();
    for _ in 0..300 {
        let t = Tensor::randn(&mut rng, &[12, 12, 12]);
        match h.submit(Request::SketchDense { tensor: t, method: SketchMethod::Fcs, j: 64 }) {
            Ok(rx) => rxs.push(rx),
            Err(ServiceError::Busy) => busy += 1,
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    assert!(busy > 0, "expected at least one Busy rejection");
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(svc.stats().rejected_busy, busy as u64);
    svc.shutdown();
}

#[test]
fn bad_requests_rejected_upfront() {
    let svc = start_rust_only(1, 8);
    let h = svc.handle();
    // wrong cs_vec dimension
    assert!(matches!(
        h.submit(Request::CsVec { x: vec![1.0; 3] }),
        Err(ServiceError::BadRequest(_))
    ));
    // shape mismatch
    let mut rng = Rng::seed_from_u64(4);
    let a = Tensor::randn(&mut rng, &[3, 3, 3]);
    let b = Tensor::randn(&mut rng, &[3, 3, 4]);
    assert!(matches!(
        h.submit(Request::InnerEstimate { a, b, method: SketchMethod::Fcs, j: 8, d: 3 }),
        Err(ServiceError::BadRequest(_))
    ));
    svc.shutdown();
}

#[test]
fn inner_estimate_converges_to_truth() {
    let svc = start_rust_only(4, 256);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(5);
    let a = Tensor::randn(&mut rng, &[8, 8, 8]);
    let truth = a.inner(&a); // ⟨A, A⟩ = ‖A‖² — positive, easy target
    let Response::Scalar(est) = h
        .call(Request::InnerEstimate {
            a: a.clone(),
            b: a,
            method: SketchMethod::Fcs,
            j: 4096,
            d: 15,
        })
        .unwrap()
    else {
        panic!()
    };
    assert!(
        (est - truth).abs() / truth < 0.25,
        "estimate {est} vs truth {truth}"
    );
    svc.shutdown();
}

#[test]
fn xla_and_rust_paths_agree() {
    // When artifacts exist, the XLA-batched cs_vec must match the pure-Rust
    // service (same seed ⇒ same shared hash table).
    let Ok(rt) = spawn_runtime(None) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = ServiceConfig { seed: 99, ..Default::default() };
    let xla_svc = Service::start(cfg.clone(), Some(rt.clone())).unwrap();
    let rust_svc = Service::start(cfg, None).unwrap();
    let (hx, hr) = (xla_svc.handle(), rust_svc.handle());
    assert_eq!(hx.cs_in_dim, hr.cs_in_dim);
    let mut rng = Rng::seed_from_u64(6);
    for _ in 0..8 {
        let x = rng.normal_vec(hx.cs_in_dim);
        let Response::Sketch(a) = hx.call(Request::CsVec { x: x.clone() }).unwrap() else {
            panic!()
        };
        let Response::Sketch(b) = hr.call(Request::CsVec { x }).unwrap() else {
            panic!()
        };
        assert_eq!(a.len(), b.len());
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() < 1e-3 * (1.0 + q.abs()), "{p} vs {q}");
        }
    }
    // CP sketching through the fcs_rank1 artifact must return the right
    // length and finite values.
    let e = rt.manifest().entries.get("fcs_rank1").unwrap().clone();
    let dim = e.meta_usize("dim").unwrap();
    let rank = e.meta_usize("rank").unwrap();
    let j = e.meta_usize("j").unwrap();
    let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
    let Response::Sketch(sk) = hx.call(Request::SketchCp { cp, j }).unwrap() else {
        panic!()
    };
    assert_eq!(sk.len(), 3 * j - 2);
    assert!(sk.iter().all(|v| v.is_finite()));
    xla_svc.shutdown();
    rust_svc.shutdown();
}

#[test]
fn concurrent_clients_all_served() {
    let svc = start_rust_only(4, 4096);
    let h = svc.handle();
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(t);
                let mut ok = 0;
                for _ in 0..50 {
                    let x = rng.normal_vec(h.cs_in_dim);
                    loop {
                        match h.call(Request::CsVec { x: x.clone() }) {
                            Ok(Response::Sketch(v)) => {
                                assert_eq!(v.len(), h.cs_out_dim);
                                ok += 1;
                                break;
                            }
                            Ok(_) => panic!("wrong response type"),
                            Err(ServiceError::Busy) => std::thread::yield_now(),
                            Err(e) => panic!("{e}"),
                        }
                    }
                }
                ok
            })
        })
        .collect();
    let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert_eq!(total, 400);
    let report = svc.stats();
    assert!(report.batches > 0);
    assert!(report.mean_batch_fill >= 1.0);
    svc.shutdown();
}

#[test]
fn batches_respect_capacity() {
    // mean batch fill must never exceed the artifact batch size (32).
    let svc = start_rust_only(2, 4096);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(7);
    let mut rxs = Vec::new();
    for _ in 0..500 {
        rxs.push(h.submit(Request::CsVec { x: rng.normal_vec(h.cs_in_dim) }).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let report = svc.stats();
    assert!(report.mean_batch_fill <= 32.0 + 1e-9);
    svc.shutdown();
}
