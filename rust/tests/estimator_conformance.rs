//! Cross-backend conformance suite: every estimator backend
//! (Plain/CS/TS/HCS/FCS) is exercised on *shared seeded cases* against the
//! same set of behavioural contracts, so the generic spectral core cannot
//! drift from the exact baselines — and no backend can drift from the
//! others — without a failure here.
//!
//! Contracts:
//! 1. `t_iuu` ≡ `t_mode(0, [u,u,u])` ≡ the `_into` variants (API coherence);
//! 2. D=1 spectral `t_mode` ≡ the literal per-coordinate sketch inner
//!    product `⟨st, sketch(e_i ∘ v ∘ w)⟩` (Eq. 17 against Eq. 16's form);
//! 3. sketch-domain `deflate` ≡ rebuilding on the deflated tensor with the
//!    same hash draws (linearity of every sketch);
//! 4. spectral CP path ≡ per-rank oracle ≡ dense path, with TS ≡ the mod-J
//!    fold of FCS under equalized hashes (§3 point (2));
//! 5. median-of-reps estimates are unbiased within statistical tolerance.

use fcs::hash::ModeHashes;
use fcs::sketch::{
    build_equalized, ContractionEstimator, FastCountSketch, Method, TensorSketch,
};
use fcs::tensor::{contract_all_but, t_uuu, CpTensor, Tensor};
use fcs::util::prng::Rng;
use fcs::util::qcheck::qcheck;

const METHODS: [Method; 5] =
    [Method::Plain, Method::Cs, Method::Ts, Method::Hcs, Method::Fcs];

/// Per-method hash length: HCS stores a J×J×J sketch, so it gets a small J.
fn j_for(method: Method, j: usize) -> usize {
    if method == Method::Hcs {
        4
    } else {
        j
    }
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let scale = b.iter().map(|v| v.abs()).fold(1.0, f64::max);
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}: k={k} {x} vs {y} (scale {scale})"
        );
    }
}

#[test]
fn t_iuu_consistent_with_t_mode_all_backends() {
    qcheck(6, |g| {
        let dim = g.usize_in(4, 8);
        let t = Tensor::randn(g.rng(), &[dim, dim, dim]);
        let u = g.normal_vec(dim);
        for method in METHODS {
            let est = method.build(&t, 2, j_for(method, 64), g.rng());
            let via_iuu = est.t_iuu(&u);
            let vs: [&[f64]; 3] = [&u, &u, &u];
            let via_mode = est.t_mode(0, &vs);
            assert_close(&via_iuu, &via_mode, 1e-9, &format!("{} t_iuu vs t_mode", est.name()));
            let mut into = Vec::new();
            est.t_iuu_into(&u, &mut into);
            assert_close(&into, &via_iuu, 1e-12, &format!("{} t_iuu_into", est.name()));
            let mut minto = Vec::new();
            est.t_mode_into(0, &vs, &mut minto);
            assert_close(&minto, &via_mode, 1e-12, &format!("{} t_mode_into", est.name()));
        }
    });
}

#[test]
fn spectral_t_mode_matches_sketch_inner_product_oracle() {
    // The generic correlate-and-gather (one body for TS and FCS) must equal
    // the literal Eq. 17 computation: per free index i, the inner product of
    // the stored sketch with the sketch of e_i ∘ v_1 ∘ v_2. D=1 so the
    // median is the identity.
    qcheck(5, |g| {
        let shape = [g.usize_in(3, 6), g.usize_in(3, 6), g.usize_in(3, 6)];
        let t = Tensor::randn(g.rng(), &shape);
        let j = g.usize_in(5, 12);
        let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);
        let hashes = vec![mh];
        let (ts_est, fcs_est) = (
            fcs::sketch::TsEstimator::build_with_hashes(&t, &hashes),
            fcs::sketch::FcsEstimator::build_with_hashes(&t, &hashes),
        );
        let ts_op = TensorSketch::new(hashes[0].clone());
        let fcs_op = FastCountSketch::new(hashes[0].clone());
        let ts_st = ts_op.apply_dense(&t);
        let fcs_st = fcs_op.apply_dense(&t);
        let v1 = g.normal_vec(shape[1]);
        let v2 = g.normal_vec(shape[2]);
        let dummy = vec![0.0; shape[0]];
        let vs: [&[f64]; 3] = [&dummy, &v1, &v2];
        let got_ts = ts_est.t_mode(0, &vs);
        let got_fcs = fcs_est.t_mode(0, &vs);
        for i in 0..shape[0] {
            let mut e = vec![0.0; shape[0]];
            e[i] = 1.0;
            let ref_ts = fcs::linalg::dot(&ts_st, &ts_op.apply_rank1(&[&e[..], &v1[..], &v2[..]]));
            let ref_fcs =
                fcs::linalg::dot(&fcs_st, &fcs_op.apply_rank1(&[&e[..], &v1[..], &v2[..]]));
            let scale = ref_fcs.abs().max(1.0);
            assert!(
                (got_ts[i] - ref_ts).abs() < 1e-8 * scale,
                "case {}: ts i={i} {} vs oracle {ref_ts}",
                g.case,
                got_ts[i]
            );
            assert!(
                (got_fcs[i] - ref_fcs).abs() < 1e-8 * scale,
                "case {}: fcs i={i} {} vs oracle {ref_fcs}",
                g.case,
                got_fcs[i]
            );
        }
    });
}

#[test]
fn deflate_linearity_all_backends() {
    // deflate(λ, vs) in the sketch domain ≡ building on T − λ·v1∘v2∘v3 with
    // the same hash draws — checked through the public query surface, for
    // every backend, with a shared RNG stream so the hashes match.
    qcheck(5, |g| {
        let dim = g.usize_in(4, 7);
        let t = Tensor::randn(g.rng(), &[dim, dim, dim]);
        let lambda = g.f64_in(-2.0, 2.0);
        let v1 = g.normal_vec(dim);
        let v2 = g.normal_vec(dim);
        let v3 = g.normal_vec(dim);
        let vs: [&[f64]; 3] = [&v1, &v2, &v3];
        let deflated = {
            let r1 = fcs::tensor::outer(&vs);
            t.sub(&r1.scaled(lambda))
        };
        let probe = g.normal_vec(dim);
        let pv: [&[f64]; 3] = [&probe, &probe, &probe];
        for method in METHODS {
            let j = j_for(method, 48);
            let seed = g.rng().next_u64();
            let mut ra = Rng::seed_from_u64(seed);
            let mut rb = Rng::seed_from_u64(seed);
            let mut est = method.build(&t, 2, j, &mut ra);
            est.deflate(lambda, &vs);
            let est2 = method.build(&deflated, 2, j, &mut rb);
            for mode in 0..3 {
                let a = est.t_mode(mode, &pv);
                let b = est2.t_mode(mode, &pv);
                assert_close(
                    &a,
                    &b,
                    1e-7,
                    &format!("case {}: {} deflate mode {mode}", g.case, est.name()),
                );
            }
            let (na, nb) = (est.norm_estimate(), est2.norm_estimate());
            assert!(
                (na - nb).abs() <= 1e-7 * nb.max(1.0),
                "case {}: {} norm {na} vs {nb}",
                g.case,
                est.name()
            );
        }
    });
}

#[test]
fn cp_spectral_path_matches_oracle_and_dense_equalized() {
    // Shared hash draws: the FCS linear path, the TS circular path, their
    // per-rank oracles, the dense paths, and the fold relation TS = fold(FCS)
    // must all cohere on the same case.
    qcheck(8, |g| {
        let order = 3;
        let shape = g.shape(order, 2, 5);
        let j = g.usize_in(3, 9);
        let rank = g.usize_in(1, 3);
        let cp = CpTensor::randn(g.rng(), &shape, rank);
        let dense_t = cp.to_dense();
        let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);
        let ts = TensorSketch::new(mh.clone());
        let fc = FastCountSketch::new(mh);
        let fcs_spectral = fc.apply_cp(&cp);
        let fcs_oracle = fc.apply_cp_per_rank(&cp);
        let fcs_dense = fc.apply_dense(&dense_t);
        let ts_spectral = ts.apply_cp(&cp);
        let ts_oracle = ts.apply_cp_per_rank(&cp);
        let ts_dense = ts.apply_dense(&dense_t);
        let what = format!("case {}", g.case);
        assert_close(&fcs_spectral, &fcs_oracle, 1e-9, &format!("{what}: fcs vs oracle"));
        assert_close(&fcs_spectral, &fcs_dense, 1e-8, &format!("{what}: fcs vs dense"));
        assert_close(&ts_spectral, &ts_oracle, 1e-9, &format!("{what}: ts vs oracle"));
        assert_close(&ts_spectral, &ts_dense, 1e-8, &format!("{what}: ts vs dense"));
        let mut folded = vec![0.0; j];
        for (k, v) in fcs_dense.iter().enumerate() {
            folded[k % j] += v;
        }
        assert_close(&ts_dense, &folded, 1e-9, &format!("{what}: ts = fold(fcs)"));
    });
}

#[test]
fn driver_routed_t_mode_and_deflate_match_per_rep_oracle() {
    // PR 5 pin: the estimator's serial t_mode/deflate no longer own any FFT
    // chunk loops — both dispatch through the core's SpectralDriver. This
    // rebuilds each answer per repetition from the *independent*
    // single-signal kernels (`spectral_corr` = fft_real_into /
    // inverse_real_into chains, `conv_linear_many` / `conv_circular_many`
    // for the rank-1 subtraction) under shared hash draws, and pins the
    // driver-batched cross-repetition path to the looped oracle — before
    // AND after a sketch-domain deflation (which also pins the F(st) cache
    // coherency the driver's forward sweep maintains).
    qcheck(4, |g| {
        let shape = [g.usize_in(3, 6), g.usize_in(4, 7), g.usize_in(3, 6)];
        let t = Tensor::randn(g.rng(), &shape);
        let j = g.usize_in(5, 11);
        let d_reps = g.usize_in(2, 4);
        let hashes: Vec<ModeHashes> = (0..d_reps)
            .map(|_| ModeHashes::draw_uniform(g.rng(), &shape, j))
            .collect();
        let v0 = g.normal_vec(shape[0]);
        let v1 = g.normal_vec(shape[1]);
        let v2 = g.normal_vec(shape[2]);
        let vs: [&[f64]; 3] = [&v0, &v1, &v2];
        let lambda = g.f64_in(-1.5, 1.5);

        // Per-rep sketches under the SAME draws, deflated by hand via the
        // independent convolution kernels.
        let fcs_ops: Vec<FastCountSketch> =
            hashes.iter().map(|h| FastCountSketch::new(h.clone())).collect();
        let ts_ops: Vec<TensorSketch> =
            hashes.iter().map(|h| TensorSketch::new(h.clone())).collect();
        let rank1_fcs = |op: &FastCountSketch| {
            let sk: Vec<Vec<f64>> =
                op.modes.iter().zip(&vs).map(|(cs, v)| cs.apply(v)).collect();
            let refs: Vec<&[f64]> = sk.iter().map(|v| v.as_slice()).collect();
            fcs::fft::conv_linear_many(&refs)
        };
        let rank1_ts = |op: &TensorSketch| {
            let sk: Vec<Vec<f64>> =
                op.modes.iter().zip(&vs).map(|(cs, v)| cs.apply(v)).collect();
            let refs: Vec<&[f64]> = sk.iter().map(|v| v.as_slice()).collect();
            fcs::fft::conv_circular_many(&refs)
        };
        // Looped oracle for one free mode over a set of per-rep sketches.
        fn oracle_t_mode(
            sts: &[Vec<f64>],
            per_rep_modes: &[Vec<&fcs::sketch::CountSketch>],
            vs: &[&[f64]; 3],
            n: usize,
            mode: usize,
        ) -> Vec<f64> {
            let rows: Vec<Vec<f64>> = sts
                .iter()
                .zip(per_rep_modes)
                .map(|(st, cs)| {
                    let contracted: Vec<Vec<f64>> = (0..3)
                        .filter(|&d| d != mode)
                        .map(|d| cs[d].apply(vs[d]))
                        .collect();
                    let refs: Vec<&[f64]> = contracted.iter().map(|v| v.as_slice()).collect();
                    let z = fcs::fft::spectral_corr(st, &refs, n);
                    (0..cs[mode].domain())
                        .map(|i| {
                            let (b, s) = cs[mode].basis(i);
                            s * z[b]
                        })
                        .collect()
                })
                .collect();
            fcs::sketch::elementwise_median(&rows)
        }

        // FCS: driver path vs oracle, fresh and deflated.
        let mut fcs_est = fcs::sketch::FcsEstimator::build_with_hashes(&t, &hashes);
        let mut fcs_sts: Vec<Vec<f64>> = fcs_ops.iter().map(|op| op.apply_dense(&t)).collect();
        let n_fcs = fcs_ops[0].fft_len();
        let fcs_modes: Vec<Vec<&fcs::sketch::CountSketch>> =
            fcs_ops.iter().map(|op| op.modes.iter().collect()).collect();
        for mode in 0..3 {
            let got = fcs_est.t_mode(mode, &vs);
            let want = oracle_t_mode(&fcs_sts, &fcs_modes, &vs, n_fcs, mode);
            assert_close(&got, &want, 1e-8, &format!("case {}: fcs t_mode {mode}", g.case));
        }
        fcs_est.deflate(lambda, &vs);
        for (op, st) in fcs_ops.iter().zip(fcs_sts.iter_mut()) {
            let r1 = rank1_fcs(op);
            for (x, y) in st.iter_mut().zip(&r1) {
                *x -= lambda * y;
            }
        }
        for mode in 0..3 {
            let got = fcs_est.t_mode(mode, &vs);
            let want = oracle_t_mode(&fcs_sts, &fcs_modes, &vs, n_fcs, mode);
            assert_close(
                &got,
                &want,
                1e-7,
                &format!("case {}: fcs deflated t_mode {mode}", g.case),
            );
        }

        // TS: same contract on the circular parameterization.
        let mut ts_est = fcs::sketch::TsEstimator::build_with_hashes(&t, &hashes);
        let mut ts_sts: Vec<Vec<f64>> = ts_ops.iter().map(|op| op.apply_dense(&t)).collect();
        let ts_modes: Vec<Vec<&fcs::sketch::CountSketch>> =
            ts_ops.iter().map(|op| op.modes.iter().collect()).collect();
        for mode in 0..3 {
            let got = ts_est.t_mode(mode, &vs);
            let want = oracle_t_mode(&ts_sts, &ts_modes, &vs, j, mode);
            assert_close(&got, &want, 1e-8, &format!("case {}: ts t_mode {mode}", g.case));
        }
        ts_est.deflate(lambda, &vs);
        for (op, st) in ts_ops.iter().zip(ts_sts.iter_mut()) {
            let r1 = rank1_ts(op);
            for (x, y) in st.iter_mut().zip(&r1) {
                *x -= lambda * y;
            }
        }
        for mode in 0..3 {
            let got = ts_est.t_mode(mode, &vs);
            let want = oracle_t_mode(&ts_sts, &ts_modes, &vs, j, mode);
            assert_close(
                &got,
                &want,
                1e-7,
                &format!("case {}: ts deflated t_mode {mode}", g.case),
            );
        }
    });
}

#[test]
fn median_of_reps_unbiased_within_tolerance() {
    // Statistical contract: averaging many independent D=3 median estimates
    // of T(u,u,u) recovers the true contraction within a generous
    // tolerance, for every sketched backend. (The median of an unbiased,
    // roughly symmetric estimator is approximately unbiased.)
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    let cp = CpTensor::random_orthogonal_symmetric(&mut rng, 6, 2, 3);
    let t = cp.to_dense();
    let mut u = rng.normal_vec(6);
    fcs::linalg::normalize(&mut u);
    let truth = t_uuu(&t, &u);
    for method in [Method::Cs, Method::Ts, Method::Hcs, Method::Fcs] {
        let j = if method == Method::Hcs { 8 } else { 256 };
        let trials = 25;
        let mut acc = 0.0;
        for _ in 0..trials {
            let est = method.build(&t, 3, j, &mut rng);
            acc += est.t_uuu(&u);
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - truth).abs() < 0.4 * truth.abs().max(1.0),
            "{}: mean {mean} vs truth {truth}",
            method.name()
        );
    }
    // Plain is exact, not just unbiased.
    let est = Method::Plain.build(&t, 1, 1, &mut rng);
    assert!((est.t_uuu(&u) - truth).abs() < 1e-10);
}

#[test]
fn norm_estimates_track_frobenius_norm() {
    // ‖T‖_F from sketches: exact for plain, within ~40% for sketched
    // backends at these sizes (it feeds RTPM's λ clamp, so gross drift
    // matters more than precision).
    let mut rng = Rng::seed_from_u64(7);
    let t = Tensor::randn(&mut rng, &[6, 6, 6]);
    let truth = t.frob_norm();
    for method in METHODS {
        let j = j_for(method, 512);
        let est = method.build(&t, 5, j, &mut rng);
        let got = est.norm_estimate();
        let tol = if method == Method::Plain { 1e-12 } else { 0.5 * truth };
        assert!(
            (got - truth).abs() <= tol,
            "{}: norm {got} vs {truth}",
            est.name()
        );
    }
}

#[test]
fn asymmetric_modes_agree_across_spectral_backends() {
    // Non-cubical tensor, every free mode: the two spectral backends (one
    // generic body) and the exact baseline must tell one story. Equalized
    // hashes mean TS and FCS see identical draws; both should land near the
    // exact contraction with enough repetitions.
    let mut rng = Rng::seed_from_u64(0xABCD);
    let cp = CpTensor::random_orthogonal(&mut rng, &[8, 11, 9], 2);
    let t = cp.to_dense();
    let v0 = rng.normal_vec(8);
    let v1 = rng.normal_vec(11);
    let v2 = rng.normal_vec(9);
    let vs: [&[f64]; 3] = [&v0, &v1, &v2];
    let (ts, fc) = build_equalized(&t, 11, 600, &mut rng);
    for mode in 0..3 {
        let truth = contract_all_but(&t, mode, &vs);
        let tn = fcs::linalg::norm2(&truth);
        for (name, got) in [("ts", ts.t_mode(mode, &vs)), ("fcs", fc.t_mode(mode, &vs))] {
            let err = got
                .iter()
                .zip(&truth)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
                / tn;
            assert!(err < 0.8, "{name} mode {mode}: rel err {err}");
        }
    }
}
