//! Cross-module property tests of the paper's core identities, using the
//! qcheck mini-framework over randomized shapes.

use fcs::hash::ModeHashes;
use fcs::sketch::{FastCountSketch, HigherOrderCountSketch, TensorSketch};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::qcheck::qcheck;

#[test]
fn fcs_definition_eq6_random_shapes() {
    // FCS(T) == CS(vec(T); composite hashes) for random shapes/orders.
    qcheck(40, |g| {
        let order = g.usize_in(2, 4);
        let shape = g.shape(order, 2, 6);
        let j = g.usize_in(2, 12);
        let t = Tensor::randn(g.rng(), &shape);
        let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);
        let fcs = FastCountSketch::new(mh);
        let fast = fcs.apply_dense(&t);
        let def = fcs.apply_via_composite_cs(&t);
        for (a, b) in fast.iter().zip(&def) {
            assert!((a - b).abs() < 1e-10, "case {}", g.case);
        }
    });
}

#[test]
fn cp_fast_paths_match_dense_random() {
    // Eq. 3 (TS circular), Eq. 5 (HCS outer), Eq. 8 (FCS linear) all equal
    // their dense-path counterparts on random CP tensors.
    qcheck(25, |g| {
        let shape = g.shape(3, 2, 6);
        let rank = g.usize_in(1, 3);
        let j = g.usize_in(2, 8);
        let cp = CpTensor::randn(g.rng(), &shape, rank);
        let dense = cp.to_dense();
        let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);

        let ts = TensorSketch::new(mh.clone());
        let (a, b) = (ts.apply_cp(&cp), ts.apply_dense(&dense));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-8, "ts case {}", g.case);
        }

        let fcs = FastCountSketch::new(mh.clone());
        let (a, b) = (fcs.apply_cp(&cp), fcs.apply_dense(&dense));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-8, "fcs case {}", g.case);
        }

        let hcs = HigherOrderCountSketch::new(mh);
        let (a, b) = (hcs.apply_cp(&cp), hcs.apply_dense(&dense));
        assert!(a.sub(&b).frob_norm() < 1e-8, "hcs case {}", g.case);
    });
}

#[test]
fn ts_is_modular_fold_of_fcs_random() {
    // The structural relation behind Proposition 1.
    qcheck(30, |g| {
        let order = g.usize_in(2, 4);
        let shape = g.shape(order, 2, 5);
        let j = g.usize_in(2, 9);
        let t = Tensor::randn(g.rng(), &shape);
        let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);
        let fcs = FastCountSketch::new(mh.clone()).apply_dense(&t);
        let ts = TensorSketch::new(mh).apply_dense(&t);
        let mut folded = vec![0.0; j];
        for (k, &v) in fcs.iter().enumerate() {
            folded[k % j] += v;
        }
        for (x, y) in folded.iter().zip(&ts) {
            assert!((x - y).abs() < 1e-10, "case {}", g.case);
        }
    });
}

#[test]
fn sketch_linearity_random() {
    // sketch(αA + B) = α·sketch(A) + sketch(B) — the property deflation
    // relies on.
    qcheck(30, |g| {
        let shape = g.shape(3, 2, 5);
        let j = g.usize_in(2, 10);
        let a = Tensor::randn(g.rng(), &shape);
        let b = Tensor::randn(g.rng(), &shape);
        let alpha = g.f64_in(-3.0, 3.0);
        let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);
        let fcs = FastCountSketch::new(mh);
        let lhs = fcs.apply_dense(&a.scaled(alpha).add(&b));
        let ra = fcs.apply_dense(&a);
        let rb = fcs.apply_dense(&b);
        for (k, &l) in lhs.iter().enumerate() {
            assert!((l - (alpha * ra[k] + rb[k])).abs() < 1e-9, "case {}", g.case);
        }
    });
}

#[test]
fn frobenius_preserved_in_expectation_fcs() {
    // Consistency backbone of Proposition 1: E‖FCS(T)‖² = ‖T‖².
    let mut errs = Vec::new();
    qcheck(6, |g| {
        let shape = g.shape(3, 3, 5);
        let t = Tensor::randn(g.rng(), &shape);
        let t2 = t.frob_norm().powi(2);
        let trials = 300;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(g.rng(), &shape, 24);
            acc += fcs::linalg::norm2(&FastCountSketch::new(mh).apply_dense(&t)).powi(2);
        }
        errs.push(((acc / trials as f64) - t2).abs() / t2);
    });
    let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mean_err < 0.12, "mean rel err {mean_err}");
}

#[test]
fn j_tilde_formula_random() {
    qcheck(40, |g| {
        let order = g.usize_in(2, 5);
        let shape = g.shape(order, 2, 5);
        let ranges: Vec<usize> = (0..order).map(|_| g.usize_in(2, 9)).collect();
        let mh = ModeHashes::draw(g.rng(), &shape, &ranges);
        let expect: usize = ranges.iter().sum::<usize>() - order + 1;
        assert_eq!(mh.composite_range(), expect);
        // max composite bucket is exactly J̃ − 1-reachable bound
        let maxh: usize = mh.modes.iter().map(|m| m.range - 1).sum();
        assert_eq!(maxh, expect - 1);
    });
}
