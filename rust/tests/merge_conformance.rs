//! Merge ≡ whole conformance: sharded sketching under the shared-seed
//! protocol must be **bit-identical** to whole-tensor sketching.
//!
//! Count sketch is linear, so under shared hash draws the sum of per-slab
//! sketches *is* the whole-tensor sketch — up to IEEE reassociation. The
//! bitwise tests therefore run on integer-valued tensors (every bucket
//! partial sum is exactly dyadic, so any association of the adds yields
//! identical bits), which makes `f64::to_bits` equality a genuine test of
//! the hash draws, bucket indexing, and sign logic rather than a fragile
//! float comparison. Real-valued data is covered tolerance-based by the
//! qcheck suites in `src/sketch/merge.rs`.
//!
//! Layers pinned here:
//! * library: `ShardSketch::tree_merge` over uneven partitions ≡ one shard
//!   absorbing all of `vec(T)`, for FCS and TS, shard counts 1/2/3/8;
//! * service: N× `SketchShard` + `MergeShards` ≡ a single whole-tensor
//!   `SketchShard` of the same merge group (the coordinator draws through
//!   the same `group_rng(seed, group)` stream the library uses);
//! * streaming: a rank-1 absorb stream matches a from-scratch re-sketch of
//!   the materialized tensor (tolerance — the rank-1 path runs through the
//!   spectral pipeline, which is not an integer-exact scatter).

use fcs::coordinator::{Request, Response, Service, ServiceConfig, SketchMethod};
use fcs::sketch::ShardSketch;
use fcs::tensor::Tensor;
use fcs::util::prng::Rng;
use std::time::Duration;

/// Service seed shared with every library-side `ShardSketch::for_group`
/// reference (the shared-seed protocol keys draws on `(seed, group)`).
const SEED: u64 = 17;

fn start(workers: usize, cap: usize) -> Service {
    Service::start(
        ServiceConfig {
            workers,
            queue_capacity: cap,
            batch_deadline: Duration::from_micros(200),
            seed: SEED,
        },
        None,
    )
    .unwrap()
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Integer-valued tensor in [-20, 20] — all partial sums exactly dyadic.
fn integer_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f64> = (0..n).map(|_| rng.below(41) as f64 - 20.0).collect();
    Tensor::from_data(shape, data)
}

/// `k` uneven cut points over `[0, total]`: random interior cuts, sorted.
/// Duplicates are kept — an empty shard is a legal partition member and the
/// scatter must treat it as a no-op.
fn uneven_cuts(rng: &mut Rng, total: usize, k: usize) -> Vec<usize> {
    let mut cuts: Vec<usize> = (0..k - 1).map(|_| rng.below(total as u64 + 1) as usize).collect();
    cuts.push(0);
    cuts.push(total);
    cuts.sort_unstable();
    cuts
}

#[test]
fn library_tree_merge_is_bit_identical_to_whole_sketch() {
    // Both backends × shard counts 1/2/3/8 × several random uneven
    // partitions each: the tree merge must reproduce the whole-tensor
    // sketch bit for bit.
    let mut rng = Rng::seed_from_u64(1);
    let shape = [4usize, 5, 6];
    let j = 7usize;
    let t = integer_tensor(&mut rng, &shape);
    for circular in [true, false] {
        let mut whole = ShardSketch::for_group(SEED, 0, &shape, j, circular);
        whole.absorb_slab(&t.data, 0);
        for k in [1usize, 2, 3, 8] {
            for trial in 0..3 {
                let cuts = uneven_cuts(&mut rng, t.data.len(), k);
                let shards: Vec<ShardSketch> = cuts
                    .windows(2)
                    .map(|w| {
                        let mut sh = ShardSketch::for_group(SEED, 0, &shape, j, circular);
                        sh.absorb_slab(&t.data[w[0]..w[1]], w[0]);
                        sh
                    })
                    .collect();
                let (merged, depth) = ShardSketch::tree_merge(shards);
                assert_eq!(depth, (k as f64).log2().ceil() as usize, "k={k}");
                assert!(
                    bits_eq(merged.sketch(), whole.sketch()),
                    "circular={circular} k={k} trial={trial} cuts={cuts:?}: merge ≠ whole"
                );
            }
        }
    }
}

#[test]
fn service_shard_merge_is_bit_identical_to_whole_request() {
    // End-to-end through the coordinator: k SketchShard requests of one
    // merge group, tree-reduced by a MergeShards request, must equal a
    // single whole-tensor SketchShard of the same group bit for bit — and
    // both must equal the library-side ShardSketch reference (same
    // `group_rng(seed, group)` stream on both sides of the wire).
    let svc = start(3, 1024);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(2);
    let shape = vec![4usize, 5, 3];
    let j = 6usize;
    for (group, method) in [(10u64, SketchMethod::Fcs), (11, SketchMethod::Ts)] {
        let t = integer_tensor(&mut rng, &shape);
        let whole = match h
            .call(Request::SketchShard {
                slab: t.data.clone(),
                offset: 0,
                dims: shape.clone(),
                method,
                j,
                group,
            })
            .unwrap()
        {
            Response::Sketch(v) => v,
            other => panic!("wrong response kind: {other:?}"),
        };
        // Library reference under the same (seed, group) draw.
        let mut lib = ShardSketch::for_group(SEED, group, &shape, j, method == SketchMethod::Ts);
        lib.absorb_slab(&t.data, 0);
        assert!(bits_eq(&whole, lib.sketch()), "service whole ≠ library reference");

        for k in [2usize, 3, 8] {
            let cuts = uneven_cuts(&mut rng, t.data.len(), k);
            let rxs: Vec<_> = cuts
                .windows(2)
                .map(|w| {
                    h.submit(Request::SketchShard {
                        slab: t.data[w[0]..w[1]].to_vec(),
                        offset: w[0],
                        dims: shape.clone(),
                        method,
                        j,
                        group,
                    })
                    .unwrap()
                })
                .collect();
            let parts: Vec<Vec<f64>> = rxs
                .into_iter()
                .map(|rx| match rx.recv().unwrap().unwrap() {
                    Response::Sketch(v) => v,
                    other => panic!("wrong response kind: {other:?}"),
                })
                .collect();
            let merged = match h.call(Request::MergeShards { parts }).unwrap() {
                Response::Sketch(v) => v,
                other => panic!("wrong response kind: {other:?}"),
            };
            assert!(
                bits_eq(&merged, &whole),
                "method={method:?} k={k} cuts={cuts:?}: service merge ≠ whole"
            );
        }
    }
    svc.shutdown();
}

#[test]
fn shard_requests_are_group_deterministic_not_order_dependent() {
    // Two identical SketchShard submissions of the same group must return
    // bit-identical sketches regardless of which worker runs them or what
    // req_id they land on — shard determinism is keyed (seed, group) only.
    let svc = start(3, 256);
    let h = svc.handle();
    let mut rng = Rng::seed_from_u64(3);
    let shape = vec![5usize, 4, 4];
    let t = integer_tensor(&mut rng, &shape);
    let req = || Request::SketchShard {
        slab: t.data.clone(),
        offset: 0,
        dims: shape.clone(),
        method: SketchMethod::Fcs,
        j: 8,
        group: 99,
    };
    // Interleave with unrelated traffic so the two calls see different
    // req_ids and (likely) different workers.
    let rx1 = h.submit(req()).unwrap();
    let _ = h
        .call(Request::SketchDense {
            tensor: integer_tensor(&mut rng, &[3, 3, 3]),
            method: SketchMethod::Ts,
            j: 4,
        })
        .unwrap();
    let rx2 = h.submit(req()).unwrap();
    let (Response::Sketch(a), Response::Sketch(b)) =
        (rx1.recv().unwrap().unwrap(), rx2.recv().unwrap().unwrap())
    else {
        panic!("wrong response kind")
    };
    assert!(bits_eq(&a, &b), "same (seed, group) request not deterministic");
    // A different group must (overwhelmingly) differ: the draw is keyed.
    let other = match h
        .call(Request::SketchShard {
            slab: t.data.clone(),
            offset: 0,
            dims: shape.clone(),
            method: SketchMethod::Fcs,
            j: 8,
            group: 100,
        })
        .unwrap()
    {
        Response::Sketch(v) => v,
        other => panic!("wrong response kind: {other:?}"),
    };
    assert!(!bits_eq(&a, &other), "distinct groups produced identical draws");
    svc.shutdown();
}

#[test]
fn streaming_rank1_matches_from_scratch_resketch() {
    // The streaming path: base slab absorb + a stream of rank-1 absorbs
    // must land within roundoff of re-sketching the materialized tensor
    // from scratch under the same draws (linearity; tolerance-based since
    // the rank-1 update runs through the spectral pipeline).
    let mut rng = Rng::seed_from_u64(4);
    let shape = [4usize, 6, 5];
    let base = Tensor::randn(&mut rng, &shape);
    for circular in [true, false] {
        let mut sh = ShardSketch::for_group(SEED, 7, &shape, 8, circular);
        sh.absorb_dense(&base);
        let mut dense = base.clone();
        for step in 0..4 {
            let vs: Vec<Vec<f64>> = shape.iter().map(|&d| rng.normal_vec(d)).collect();
            let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
            let lambda = 1.0 - 0.4 * step as f64;
            sh.absorb_rank1(lambda, &refs);
            dense = dense.add(&fcs::tensor::outer(&refs).scaled(lambda));
        }
        let mut scratch = ShardSketch::for_group(SEED, 7, &shape, 8, circular);
        scratch.absorb_dense(&dense);
        let scale = scratch.sketch().iter().map(|v| v.abs()).fold(1.0, f64::max);
        for (a, b) in sh.sketch().iter().zip(scratch.sketch()) {
            assert!(
                (a - b).abs() < 1e-9 * scale,
                "circular={circular}: streaming {a} vs scratch {b}"
            );
        }
        assert_eq!(sh.updates(), 5, "base absorb + 4 rank-1 absorbs");
    }
}

#[test]
fn shard_validation_rejects_hostile_requests() {
    use fcs::coordinator::ServiceError;
    let svc = start(1, 64);
    let h = svc.handle();
    // Slab window past the end of vec(T).
    let r = h.call(Request::SketchShard {
        slab: vec![1.0; 10],
        offset: 20,
        dims: vec![3, 3, 3],
        method: SketchMethod::Fcs,
        j: 4,
        group: 0,
    });
    assert!(matches!(r, Err(ServiceError::BadRequest(_))), "oversized slab accepted: {r:?}");
    // Overflowing dims product must be a BadRequest, not a panic.
    let r = h.call(Request::SketchShard {
        slab: vec![],
        offset: 0,
        dims: vec![usize::MAX, 2],
        method: SketchMethod::Ts,
        j: 4,
        group: 0,
    });
    assert!(matches!(r, Err(ServiceError::BadRequest(_))), "overflow dims accepted: {r:?}");
    // Degenerate requests.
    for req in [
        Request::SketchShard {
            slab: vec![],
            offset: 0,
            dims: vec![],
            method: SketchMethod::Fcs,
            j: 4,
            group: 0,
        },
        Request::SketchShard {
            slab: vec![],
            offset: 0,
            dims: vec![3, 0],
            method: SketchMethod::Fcs,
            j: 4,
            group: 0,
        },
        Request::SketchShard {
            slab: vec![],
            offset: 0,
            dims: vec![3, 3],
            method: SketchMethod::Fcs,
            j: 0,
            group: 0,
        },
        Request::MergeShards { parts: vec![] },
    ] {
        let r = h.call(req);
        assert!(matches!(r, Err(ServiceError::BadRequest(_))), "degenerate accepted: {r:?}");
    }
    // An empty slab with valid dims is legal: it sketches to all zeros.
    let r = h
        .call(Request::SketchShard {
            slab: vec![],
            offset: 5,
            dims: vec![3, 3],
            method: SketchMethod::Ts,
            j: 4,
            group: 0,
        })
        .unwrap();
    let Response::Sketch(v) = r else { panic!("wrong response kind") };
    assert!(v.iter().all(|&x| x == 0.0) && v.len() == 4);
    svc.shutdown();
}
