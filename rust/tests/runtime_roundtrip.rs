//! Integration: AOT HLO-text artifacts load, compile, and execute through
//! the PJRT runtime with numerics matching the pure-Rust implementation.

use fcs::hash::ModeHashes;
use fcs::runtime::{spawn_runtime, TensorArg};
use fcs::sketch::{CountSketch, FastCountSketch};
use fcs::tensor::CpTensor;
use fcs::util::prng::Rng;

fn runtime() -> Option<fcs::runtime::RuntimeHandle> {
    match spawn_runtime(None) {
        Ok(h) => Some(h),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn cs_batch_artifact_matches_rust_kernel() {
    let Some(rt) = runtime() else { return };
    let entry = rt.manifest().entries.get("cs_batch").expect("cs_batch in manifest").clone();
    let b = entry.meta_usize("batch").unwrap();
    let i = entry.meta_usize("in_dim").unwrap();
    let j = entry.meta_usize("out_dim").unwrap();

    let mut rng = Rng::seed_from_u64(42);
    let pair = fcs::hash::HashPair::draw(&mut rng, i, j);
    let table = pair.materialize();
    let cs = CountSketch::new(table.clone());

    let x: Vec<f64> = rng.normal_vec(b * i);
    // row-major [B, I] for XLA; rust side sketches each row
    let args = vec![
        TensorArg::f32_from_f64(&[b, i], &x),
        TensorArg::i32(&[i], table.h.iter().map(|&v| v as i32).collect()),
        TensorArg::f32(&[i], table.s.iter().map(|&v| v as f32).collect()),
    ];
    let out = rt.run("cs_batch", args).expect("execute cs_batch");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![b, j]);
    for row in 0..b {
        let xrow: Vec<f64> = x[row * i..(row + 1) * i].to_vec();
        let expect = cs.apply(&xrow);
        for col in 0..j {
            let got = out[0].data[row * j + col] as f64;
            assert!(
                (got - expect[col]).abs() < 1e-3 * (1.0 + expect[col].abs()),
                "row {row} col {col}: {got} vs {}",
                expect[col]
            );
        }
    }
}

#[test]
fn fcs_rank1_artifact_matches_rust_fft_path() {
    let Some(rt) = runtime() else { return };
    let entry = rt.manifest().entries.get("fcs_rank1").expect("fcs_rank1").clone();
    let dim = entry.meta_usize("dim").unwrap();
    let rank = entry.meta_usize("rank").unwrap();
    let j = entry.meta_usize("j").unwrap();

    let mut rng = Rng::seed_from_u64(7);
    let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
    let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], j);
    let fcs = FastCountSketch::new(mh.clone());
    let expect = fcs.apply_cp(&cp);

    // XLA factor matrices are row-major [I, R]; our Matrix is col-major.
    let to_rowmajor = |m: &fcs::linalg::Matrix| -> Vec<f32> {
        let mut v = Vec::with_capacity(m.rows * m.cols);
        for r in 0..m.rows {
            for c in 0..m.cols {
                v.push(m.get(r, c) as f32);
            }
        }
        v
    };
    let mut args = Vec::new();
    for f in &cp.factors {
        args.push(TensorArg::f32(&[dim, rank], to_rowmajor(f)));
    }
    args.push(TensorArg::f32(&[rank], cp.lambda.iter().map(|&l| l as f32).collect()));
    for m in &mh.modes {
        args.push(TensorArg::i32(&[dim], m.h.iter().map(|&v| v as i32).collect()));
        args.push(TensorArg::f32(&[dim], m.s.iter().map(|&v| v as f32).collect()));
    }
    let out = rt.run("fcs_rank1", args).expect("execute fcs_rank1");
    assert_eq!(out[0].shape, vec![3 * j - 2]);
    let scale = fcs::linalg::norm2(&expect).max(1.0);
    for (k, (&got, &want)) in out[0].data.iter().zip(&expect).enumerate() {
        assert!(
            ((got as f64) - want).abs() < 2e-4 * scale,
            "k={k}: {got} vs {want}"
        );
    }
}

#[test]
fn runtime_handle_is_cloneable_and_concurrent() {
    let Some(rt) = runtime() else { return };
    let entry = rt.manifest().entries.get("cs_batch").unwrap().clone();
    let b = entry.meta_usize("batch").unwrap();
    let i = entry.meta_usize("in_dim").unwrap();
    let j = entry.meta_usize("out_dim").unwrap();
    rt.warm("cs_batch").unwrap();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(t);
                let pair = fcs::hash::HashPair::draw(&mut rng, i, j);
                let table = pair.materialize();
                let x: Vec<f64> = rng.normal_vec(b * i);
                let args = vec![
                    TensorArg::f32_from_f64(&[b, i], &x),
                    TensorArg::i32(&[i], table.h.iter().map(|&v| v as i32).collect()),
                    TensorArg::f32(&[i], table.s.iter().map(|&v| v as f32).collect()),
                ];
                let out = rt.run("cs_batch", args).unwrap();
                assert_eq!(out[0].shape, vec![b, j]);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn unknown_artifact_is_clean_error() {
    let Some(rt) = runtime() else { return };
    let err = rt.run("no_such_artifact", vec![]).unwrap_err();
    assert!(err.to_string().contains("no_such_artifact"));
}
