//! Steady-state allocation discipline for the spectral hot paths.
//!
//! A counting global allocator wraps `System`; after a warmup pass that
//! populates workspace pools, plan caches, and output capacities, the
//! FFT/convolution `_into` kernels, the FCS CP fast path, and the estimator
//! `t_mode`/`t_iuu` inner-loop paths (what sketched ALS/RTPM iterate on)
//! must perform **zero** heap allocations per call.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fcs::coordinator::{SketchMethod, WorkerState};
use fcs::fft::FftWorkspace;
use fcs::hash::ModeHashes;
use fcs::sketch::{ContractionEstimator, FastCountSketch, FcsEstimator, TensorSketch};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — single-threaded test tally on the allocator
        // hot path; no cross-thread reads race the counted section.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — see alloc.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // ordering: Relaxed — see alloc.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn count() -> u64 {
    // ordering: Relaxed — same-thread read of the tally above.
    ALLOCS.load(Ordering::Relaxed)
}

/// Run `f` once and return how many allocations it performed.
fn allocs_of(mut f: impl FnMut()) -> u64 {
    let before = count();
    f();
    count() - before
}

/// One test function (not several) so no other test thread in this binary
/// can pollute the global counter mid-measurement.
#[test]
fn hot_paths_are_allocation_free_in_steady_state() {
    let mut rng = Rng::seed_from_u64(99);

    // --- convolution kernels ------------------------------------------------
    {
        let a = rng.normal_vec(23);
        let b = rng.normal_vec(17);
        let c = rng.normal_vec(9);
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();
        for _ in 0..2 {
            fcs::fft::conv_linear_many_into(&[&a, &b, &c], &mut ws, &mut out);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                fcs::fft::conv_linear_many_into(&[&a, &b, &c], &mut ws, &mut out);
            }
        });
        assert_eq!(n, 0, "conv_linear_many_into allocated {n} times in steady state");

        // Bluestein (odd length) path with workspace-owned scratch.
        let d = rng.normal_vec(21);
        let e = rng.normal_vec(21);
        for _ in 0..2 {
            fcs::fft::conv_circular_many_into(&[&d, &e], &mut ws, &mut out);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                fcs::fft::conv_circular_many_into(&[&d, &e], &mut ws, &mut out);
            }
        });
        assert_eq!(n, 0, "conv_circular_many_into (Bluestein) allocated {n} times");
    }

    // --- batched multi-spectrum transforms (the split-plane kernel's
    // --- *_many_into entry points: forward packed batch → lane-major
    // --- spectra → batched inverse) -----------------------------------------
    {
        let mut ws = FftWorkspace::new();
        let stride = 11usize;
        let batch = 6usize;
        let xs: Vec<f64> = rng.normal_vec(stride * batch);
        let mut sre = Vec::new();
        let mut sim = Vec::new();
        let mut back = Vec::new();
        // Power-of-two transform length (the FCS path) …
        for _ in 0..2 {
            fcs::fft::fft_real_many_into(&xs, stride, batch, 32, &mut ws, &mut sre, &mut sim);
            fcs::fft::inverse_real_many_into(&mut sre, &mut sim, batch, &mut ws, &mut back);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                fcs::fft::fft_real_many_into(&xs, stride, batch, 32, &mut ws, &mut sre, &mut sim);
                fcs::fft::inverse_real_many_into(&mut sre, &mut sim, batch, &mut ws, &mut back);
            }
        });
        assert_eq!(n, 0, "batched *_many_into (pow2) allocated {n} times in steady state");
        // … and a Bluestein length (odd n: the TS circular path).
        for _ in 0..2 {
            fcs::fft::fft_real_many_into(&xs, stride, batch, 21, &mut ws, &mut sre, &mut sim);
            fcs::fft::inverse_real_many_into(&mut sre, &mut sim, batch, &mut ws, &mut back);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                fcs::fft::fft_real_many_into(&xs, stride, batch, 21, &mut ws, &mut sre, &mut sim);
                fcs::fft::inverse_real_many_into(&mut sre, &mut sim, batch, &mut ws, &mut back);
            }
        });
        assert_eq!(n, 0, "batched *_many_into (Bluestein) allocated {n} times in steady state");
    }

    // --- FCS / TS CP fast paths (one IFFT, spectral accumulation) ----------
    {
        let shape = [8usize, 9, 7];
        let cp = CpTensor::randn(&mut rng, &shape, 4);
        let mh = ModeHashes::draw(&mut rng, &shape, &[8, 16, 5]);
        let fcs_op = FastCountSketch::new(mh);
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();
        for _ in 0..2 {
            fcs_op.apply_cp_into(&cp, &mut ws, &mut out);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                fcs_op.apply_cp_into(&cp, &mut ws, &mut out);
            }
        });
        assert_eq!(n, 0, "FastCountSketch::apply_cp_into allocated {n} times");

        let mh2 = ModeHashes::draw_uniform(&mut rng, &shape, 11);
        let ts_op = TensorSketch::new(mh2);
        for _ in 0..2 {
            ts_op.apply_cp_into(&cp, &mut ws, &mut out);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                ts_op.apply_cp_into(&cp, &mut ws, &mut out);
            }
        });
        assert_eq!(n, 0, "TensorSketch::apply_cp_into allocated {n} times");

        let u = rng.normal_vec(8);
        let v = rng.normal_vec(9);
        let w = rng.normal_vec(7);
        for _ in 0..2 {
            fcs_op.apply_rank1_into(&[&u, &v, &w], &mut ws, &mut out);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                fcs_op.apply_rank1_into(&[&u, &v, &w], &mut ws, &mut out);
            }
        });
        assert_eq!(n, 0, "FastCountSketch::apply_rank1_into allocated {n} times");
    }

    // --- estimator inner loop (what sketched ALS/RTPM hammer) -------------
    {
        let dim = 10usize;
        let t = Tensor::randn(&mut rng, &[dim, dim, dim]);
        let mut est = FcsEstimator::build(&t, 3, 16, &mut rng);
        let u = rng.normal_vec(dim);
        let v = rng.normal_vec(dim);
        let w = rng.normal_vec(dim);
        let vs: [&[f64]; 3] = [&u, &v, &w];
        let mut col = Vec::new();
        for _ in 0..3 {
            est.t_mode_into(0, &vs, &mut col);
            est.t_mode_into(1, &vs, &mut col);
            est.t_iuu_into(&u, &mut col);
            let _ = est.t_uuu(&u);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                est.t_mode_into(0, &vs, &mut col);
                est.t_mode_into(1, &vs, &mut col);
                est.t_iuu_into(&u, &mut col);
                let _ = est.t_uuu(&u);
            }
        });
        assert_eq!(
            n, 0,
            "FcsEstimator t_mode_into/t_iuu_into/t_uuu allocated {n} times in steady state"
        );
        // Sketch-domain deflation (the RTPM outer loop): one SpectralDriver
        // convolution pass + the batched F(st) coherency sweep — zero
        // allocations once the workspace pools are warm.
        for _ in 0..3 {
            est.deflate(1e-3, &vs);
        }
        let n = allocs_of(|| {
            for _ in 0..5 {
                est.deflate(1e-3, &vs);
            }
        });
        assert_eq!(n, 0, "FcsEstimator deflate allocated {n} times in steady state");
    }

    // --- coordinator WorkerState: the service's sketch_dense / sketch_cp /
    // --- inner_estimate compute paths (response envelope excluded — the
    // --- test reuses `out` exactly as a steady-shape client stream reuses
    // --- the worker's arenas) ------------------------------------------------
    {
        let mut state = WorkerState::new();
        let t = Tensor::randn(&mut rng, &[6, 7, 5]);
        let cp = CpTensor::randn(&mut rng, &[6, 7, 5], 3);
        let a = Tensor::randn(&mut rng, &[4, 4, 4]);
        let b = Tensor::randn(&mut rng, &[4, 4, 4]);
        let mut out = Vec::new();
        for i in 0..3u64 {
            let mut r = Rng::seed_from_u64(100 + i);
            state.sketch_dense_into(&t, SketchMethod::Fcs, 16, &mut r, &mut out);
            state.sketch_dense_into(&t, SketchMethod::Ts, 16, &mut r, &mut out);
            state.sketch_cp_into(&cp, 16, &mut r, &mut out);
            let _ = state.inner_estimate(&a, &b, SketchMethod::Fcs, 32, 3, &mut r);
        }
        let n = allocs_of(|| {
            for i in 0..5u64 {
                let mut r = Rng::seed_from_u64(200 + i);
                state.sketch_dense_into(&t, SketchMethod::Fcs, 16, &mut r, &mut out);
                state.sketch_dense_into(&t, SketchMethod::Ts, 16, &mut r, &mut out);
                state.sketch_cp_into(&cp, 16, &mut r, &mut out);
                let _ = state.inner_estimate(&a, &b, SketchMethod::Fcs, 32, 3, &mut r);
            }
        });
        assert_eq!(n, 0, "WorkerState service paths allocated {n} times in steady state");
    }

    // --- observability: steady-state metric recording is zero-alloc --------
    // The registry hands out `Arc`s to fixed-shape atomics at registration
    // time; after that, every counter inc / gauge move / histogram observe is
    // a relaxed atomic RMW. Warm the global registry (first call registers
    // every family), then prove the recording paths — including a LIVE
    // per-stage timer on the sketch_cp hot path — never touch the heap.
    {
        fcs::obs::init();
        let m = fcs::obs::metrics();
        m.rejected_busy.inc();
        m.queue_depth_worker.inc();
        m.queue_depth_worker.dec();
        m.flight_width.observe(4);
        m.op("sketch_cp").latency_us.observe(10);
        let n = allocs_of(|| {
            for i in 0..100u64 {
                m.rejected_busy.inc();
                m.queue_depth_worker.inc();
                m.queue_depth_worker.dec();
                m.flight_width.observe(1 + (i % 16));
                m.op("sketch_cp").latency_us.observe(10 + i);
                m.op("cs_vec").queue_wait_us.observe(i);
            }
        });
        assert_eq!(n, 0, "registry recording allocated {n} times in steady state");

        // Force the stage sampler so the very next `StageTimer::sample()`
        // inside the driver goes live: it reads the clock around each
        // pack/fft/fold/inverse stage and observes `fcs_stage_ns` on drop.
        // None of that may allocate on the warmed sketch_cp path.
        let mut state = WorkerState::new();
        let cp = CpTensor::randn(&mut rng, &[6, 7, 5], 3);
        let mut out = Vec::new();
        for i in 0..3u64 {
            let mut r = Rng::seed_from_u64(300 + i);
            state.sketch_cp_into(&cp, 16, &mut r, &mut out);
        }
        let n = allocs_of(|| {
            for i in 0..5u64 {
                fcs::obs::force_next_stage_sample();
                let mut r = Rng::seed_from_u64(400 + i);
                state.sketch_cp_into(&cp, 16, &mut r, &mut out);
            }
        });
        assert_eq!(n, 0, "sketch_cp with live stage timer allocated {n} times");
    }

    // --- FFT plan caches: steady state must be all hits, no rebuilds --------
    {
        let planner = fcs::fft::global_planner();
        let p1 = planner.plan(64);
        let p2 = planner.plan(64);
        assert!(std::sync::Arc::ptr_eq(&p1, &p2), "plan(64) must be cached");
        let r1 = planner.real_plan(64);
        let r2 = planner.real_plan(64);
        assert!(std::sync::Arc::ptr_eq(&r1, &r2), "real_plan(64) must be cached");
        // Warm every plan length this workload touches (64 and its
        // half-length 32), then assert the steady state is all cache hits.
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();
        let x: Vec<f64> = (0..48).map(|i| i as f64).collect();
        let mut spec = Vec::new();
        fcs::fft::fft_real_into(&x, 64, &mut ws, &mut spec);
        fcs::fft::inverse_real_into(&mut spec, &mut ws, &mut out);
        let (h0, m0) = planner.cache_counters();
        for _ in 0..4 {
            let mut ws2 = FftWorkspace::new();
            fcs::fft::fft_real_into(&x, 64, &mut ws2, &mut spec);
            fcs::fft::inverse_real_into(&mut spec, &mut ws2, &mut out);
        }
        let (h1, m1) = planner.cache_counters();
        // Each of the 4 rounds resolves real_plan(64) and plan(32) at least
        // twice through a cold workspace — all of them global-cache hits.
        assert!(h1 >= h0 + 8, "expected ≥8 plan-cache hits, got {}", h1 - h0);
        assert_eq!(m1, m0, "steady-state transforms must not rebuild plans (misses grew)");
        // The batched entry points resolve the same per-length plans: after
        // the warmup above, a cold workspace running *_many at length 64 is
        // all cache hits too.
        let xs: Vec<f64> = (0..3 * 48).map(|i| i as f64).collect();
        let (mut sre, mut sim, mut back) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..2 {
            let mut ws3 = FftWorkspace::new();
            fcs::fft::fft_real_many_into(&xs, 48, 3, 64, &mut ws3, &mut sre, &mut sim);
            fcs::fft::inverse_real_many_into(&mut sre, &mut sim, 3, &mut ws3, &mut back);
        }
        let (h2, m2) = planner.cache_counters();
        assert!(h2 >= h1 + 4, "expected ≥4 batched plan-cache hits, got {}", h2 - h1);
        assert_eq!(m2, m1, "batched *_many_into rebuilt plans (misses grew)");
    }
}
