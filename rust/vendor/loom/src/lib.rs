//! Offline, API-compatible subset of the [`loom`] model checker.
//!
//! The real loom crate exhaustively enumerates thread interleavings under the
//! C11 memory model. This container builds fully offline, so the crate cannot
//! be fetched; this facade keeps the *same API surface* (`loom::model`,
//! `loom::thread`, `loom::sync::*`, `loom::sync::atomic::*`) backed by std
//! primitives plus a **randomized-preemption explorer**: every atomic
//! operation and every `Mutex::lock` passes through [`sched::point`], which —
//! while a `model()` run is active — yields the OS scheduler with a
//! seed-derived probability. Each `model()` invocation replays the closure
//! across many seeds (default 64, `FCS_LOOM_ITERS` overrides), so a suite run
//! explores a broad sample of interleavings rather than the single lucky one
//! an unperturbed std run would see.
//!
//! Divergences from real loom, chosen deliberately:
//!
//! * Exploration is probabilistic, not exhaustive — assertions hold over the
//!   sampled schedules, not a proof over all of them. Swapping this facade
//!   for `loom = "0.7"` on a networked host upgrades the same test file to a
//!   real exhaustive check with zero source changes.
//! * Atomic constructors are `const fn` (real loom's are not), so the crate's
//!   `static` atomics keep working untouched under `--cfg loom`.
//! * There is no modeled memory order — operations execute with the ordering
//!   the caller requested on real hardware. TSan (see CI `analysis` jobs)
//!   covers the ordering-bug class this facade cannot.

/// Maximum threads a single model may spawn (matches real loom's default).
pub const MAX_THREADS: usize = 4;

pub mod sched {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

    /// Nonzero while a `model()` run is active (count of live models; models
    /// never nest, but keeping a count makes the facade panic-safe).
    pub(crate) static ACTIVE: AtomicUsize = AtomicUsize::new(0);
    /// Seed for the current model iteration; mixed into every thread's local
    /// preemption stream so different iterations explore different schedules.
    pub(crate) static ITER_SEED: AtomicU32 = AtomicU32::new(0);

    thread_local! {
        static LOCAL_RNG: Cell<u32> = const { Cell::new(0) };
    }

    /// A possible preemption point. Called before every facade atomic op and
    /// mutex acquisition. No-op unless a model is running.
    pub fn point() {
        if ACTIVE.load(Ordering::Relaxed) == 0 {
            return;
        }
        let yielded = LOCAL_RNG.with(|cell| {
            let mut x = cell.get();
            if x == 0 {
                // Lazily mix the iteration seed with a per-thread component so
                // sibling threads in one iteration don't preempt in lockstep.
                let tid = std::thread::current().id();
                let mut h = std::collections::hash_map::DefaultHasher::new();
                std::hash::Hash::hash(&tid, &mut h);
                x = ((std::hash::Hasher::finish(&h) as u32)
                    ^ ITER_SEED.load(Ordering::Relaxed))
                    | 1;
            }
            // xorshift32 keeps this dependency-free and deterministic per seed.
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            cell.set(x);
            // Preempt roughly 1-in-4 points: frequent enough to shake out
            // windows a straight run never opens, rare enough to keep a
            // 64-iteration model suite fast.
            x % 4 == 0
        });
        if yielded {
            std::thread::yield_now();
        }
    }
}

/// Run `f` under the explorer. The closure is executed once per iteration
/// (default 64; `FCS_LOOM_ITERS` overrides) with a fresh preemption seed, so
/// spawned threads interleave differently every pass. Panics propagate,
/// failing the surrounding `#[test]` exactly as real loom does.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let iters: u32 = std::env::var("FCS_LOOM_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    for iter in 0..iters.max(1) {
        sched::ITER_SEED.store(0x9E37_79B9_u32.wrapping_mul(iter + 1), std::sync::atomic::Ordering::Relaxed);
        sched::ACTIVE.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(&f));
        sched::ACTIVE.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
        if let Err(payload) = result {
            eprintln!("loom facade: model failed on iteration {iter}/{iters}");
            std::panic::resume_unwind(payload);
        }
    }
}

pub mod thread {
    //! Thread spawning inside a model. Re-exports std; `spawn` adds a
    //! preemption point at thread start so child bodies don't all begin with
    //! the same phase relative to the parent.
    pub use std::thread::{current, park, sleep, yield_now, JoinHandle, Thread, ThreadId};

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::spawn(move || {
            super::sched::point();
            f()
        })
    }
}

pub mod sync {
    use std::fmt;
    use std::sync::LockResult;
    pub use std::sync::{Arc, MutexGuard, OnceLock};

    /// Mutex with a preemption point before every acquisition, so lock
    /// hand-off order varies across model iterations. API-compatible with
    /// `std::sync::Mutex` for the subset the crate uses (`new`, `lock`,
    /// `into_inner`, poisoning via `LockResult`).
    pub struct Mutex<T: ?Sized> {
        inner: std::sync::Mutex<T>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Self { inner: std::sync::Mutex::new(t) }
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }
    }

    impl<T: ?Sized> Mutex<T> {
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            super::sched::point();
            self.inner.lock()
        }

        pub fn try_lock(&self) -> std::sync::TryLockResult<MutexGuard<'_, T>> {
            super::sched::point();
            self.inner.try_lock()
        }
    }

    impl<T: Default> Default for Mutex<T> {
        fn default() -> Self {
            Self::new(T::default())
        }
    }

    impl<T: ?Sized + fmt::Debug> fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            self.inner.fmt(f)
        }
    }

    pub mod atomic {
        //! Atomic newtypes: every operation is bracketed by a scheduling
        //! point. Constructors stay `const fn` (unlike real loom) so the
        //! crate's `static` atomics compile unchanged under `--cfg loom`.
        pub use std::sync::atomic::Ordering;

        macro_rules! atomic_facade {
            ($name:ident, $std:ty, $val:ty) => {
                #[derive(Default)]
                pub struct $name {
                    inner: $std,
                }

                impl $name {
                    pub const fn new(v: $val) -> Self {
                        Self { inner: <$std>::new(v) }
                    }

                    pub fn load(&self, order: Ordering) -> $val {
                        super::super::sched::point();
                        self.inner.load(order)
                    }

                    pub fn store(&self, v: $val, order: Ordering) {
                        super::super::sched::point();
                        self.inner.store(v, order);
                    }

                    pub fn swap(&self, v: $val, order: Ordering) -> $val {
                        super::super::sched::point();
                        self.inner.swap(v, order)
                    }

                    pub fn compare_exchange(
                        &self,
                        current: $val,
                        new: $val,
                        success: Ordering,
                        failure: Ordering,
                    ) -> Result<$val, $val> {
                        super::super::sched::point();
                        self.inner.compare_exchange(current, new, success, failure)
                    }
                }

                impl std::fmt::Debug for $name {
                    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                        self.inner.fmt(f)
                    }
                }
            };
        }

        macro_rules! atomic_facade_int {
            ($name:ident, $std:ty, $val:ty) => {
                atomic_facade!($name, $std, $val);

                impl $name {
                    pub fn fetch_add(&self, v: $val, order: Ordering) -> $val {
                        super::super::sched::point();
                        let prev = self.inner.fetch_add(v, order);
                        super::super::sched::point();
                        prev
                    }

                    pub fn fetch_sub(&self, v: $val, order: Ordering) -> $val {
                        super::super::sched::point();
                        let prev = self.inner.fetch_sub(v, order);
                        super::super::sched::point();
                        prev
                    }

                    pub fn fetch_max(&self, v: $val, order: Ordering) -> $val {
                        super::super::sched::point();
                        self.inner.fetch_max(v, order)
                    }

                    pub fn fetch_min(&self, v: $val, order: Ordering) -> $val {
                        super::super::sched::point();
                        self.inner.fetch_min(v, order)
                    }
                }
            };
        }

        atomic_facade!(AtomicBool, std::sync::atomic::AtomicBool, bool);
        atomic_facade_int!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        atomic_facade_int!(AtomicI64, std::sync::atomic::AtomicI64, i64);
        atomic_facade_int!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    }
}
