//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libpjrt and is only present on hosts with the XLA
//! toolchain. This stub keeps `fcs::runtime` compiling everywhere with the
//! same API surface; every entry point fails with a clear "PJRT unavailable"
//! error, which the callers (runtime tests, coordinator XLA path, TRN
//! pipeline) already treat as "skip the XLA path".

use std::fmt;

/// Error type; implements `std::error::Error` so `?` converts into
/// `anyhow::Error` at call sites.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Self {
        Error("PJRT runtime unavailable in this build (offline xla stub)".into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    F64,
    S32,
}

/// Stub PJRT client. `cpu()` always fails — by design, before any executable
/// or literal can be constructed.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable())
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
    }
}
