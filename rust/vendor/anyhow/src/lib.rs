//! Minimal, offline, API-compatible subset of the `anyhow` crate: a string-y
//! dynamic [`Error`], the [`anyhow!`] macro, [`Result`], and the [`Context`]
//! extension trait. Only the surface this repository actually uses is
//! provided; semantics match upstream for that surface (notably: `Error`
//! deliberately does *not* implement `std::error::Error`, which is what makes
//! the blanket `From` conversion coherent).

use std::fmt;

/// Dynamic error type: a message plus an optional chain of causes (rendered
/// into the message eagerly — we never need to walk the chain).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Wrap with context, upstream-style `"{context}: {cause}"` rendering.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Format-string error constructor.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Context extension for `Result` (and `Option`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_macro() {
        let e = anyhow!("thing {} failed", 7);
        assert_eq!(e.to_string(), "thing 7 failed");
    }

    #[test]
    fn context_chains() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            let _ = "zzz".parse::<i32>()?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
