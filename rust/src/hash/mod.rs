//! 2-wise independent hash families — the randomness substrate of every
//! sketch in the paper.
//!
//! `h : [I] → [J]` and `s : [I] → {±1}` are drawn from the classic
//! degree-1 polynomial family over the Mersenne prime `p = 2^61 − 1`:
//! `h(x) = ((a·x + b) mod p) mod J` with `a ∈ [1,p)`, `b ∈ [0,p)`. This is
//! 2-wise independent, which is exactly the assumption of Definition 1 and
//! Proposition 1.
//!
//! Two representations:
//! * [`HashPair`] — coefficients only (16 B), evaluates on the fly.
//! * [`HashTable`] — materialized `(h, s)` tables, the form the paper's
//!   memory accounting counts (`O(I)` per mode for TS/HCS/FCS vs `O(Π I_n)`
//!   for CS on the vectorized tensor; Figs. 5–6 "memory for Hash functions").
//!
//! [`ModeHashes`] bundles the `N` per-mode pairs and builds the *composite*
//! pair of Eq. 7: `s̃(l) = Π s_n(i_n)`, `h̃(l) = Σ h_n(i_n) − N + 1` (no
//! modulo — hence the output length `J̃ = Σ J_n − N + 1`).

use crate::util::prng::Rng;

/// Mersenne prime 2^61 − 1.
pub const MERSENNE_P: u64 = (1u64 << 61) - 1;

/// Reduce a 128-bit product modulo 2^61 − 1 (two folds suffice).
#[inline]
pub fn mod_mersenne(x: u128) -> u64 {
    let lo = (x & MERSENNE_P as u128) as u64;
    let hi = (x >> 61) as u64;
    let mut r = lo.wrapping_add(hi & MERSENNE_P).wrapping_add(hi >> 61);
    while r >= MERSENNE_P {
        r -= MERSENNE_P;
    }
    r
}

/// One 2-wise independent `(h, s)` pair, coefficient form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashPair {
    /// h coefficients
    a: u64,
    b: u64,
    /// s coefficients (independent draw)
    c: u64,
    d: u64,
    /// domain size I (h, s defined on [0, I))
    pub domain: usize,
    /// range size J (h maps into [0, J))
    pub range: usize,
}

impl HashPair {
    pub fn draw(rng: &mut Rng, domain: usize, range: usize) -> Self {
        assert!(domain > 0 && range > 0);
        Self {
            a: 1 + rng.below(MERSENNE_P - 1),
            b: rng.below(MERSENNE_P),
            c: 1 + rng.below(MERSENNE_P - 1),
            d: rng.below(MERSENNE_P),
            domain,
            range,
        }
    }

    /// Bucket for index `i` (0-based, in `[0, range)`).
    #[inline]
    pub fn h(&self, i: usize) -> usize {
        debug_assert!(i < self.domain);
        let v = mod_mersenne(self.a as u128 * i as u128 + self.b as u128);
        (v % self.range as u64) as usize
    }

    /// Sign for index `i` (±1).
    #[inline]
    pub fn s(&self, i: usize) -> f64 {
        debug_assert!(i < self.domain);
        let v = mod_mersenne(self.c as u128 * i as u128 + self.d as u128);
        if v & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Materialize into lookup tables (the hot-path representation).
    pub fn materialize(&self) -> HashTable {
        let mut t = HashTable {
            h: Vec::with_capacity(self.domain),
            s: Vec::with_capacity(self.domain),
            range: self.range,
        };
        self.materialize_into(&mut t);
        t
    }

    /// Materialize into an existing table, reusing its storage — zero heap
    /// allocations once `out`'s capacity covers `domain` (the coordinator
    /// redraws per-request hashes into per-worker arenas this way).
    pub fn materialize_into(&self, out: &mut HashTable) {
        out.h.clear();
        out.s.clear();
        out.h.reserve(self.domain);
        out.s.reserve(self.domain);
        for i in 0..self.domain {
            out.h.push(self.h(i) as u32);
            out.s.push(if self.s(i) > 0.0 { 1i8 } else { -1i8 });
        }
        out.range = self.range;
    }
}

/// Materialized `(h, s)` tables.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HashTable {
    pub h: Vec<u32>,
    pub s: Vec<i8>,
    pub range: usize,
}

impl HashTable {
    #[inline]
    pub fn domain(&self) -> usize {
        self.h.len()
    }

    #[inline]
    pub fn h(&self, i: usize) -> usize {
        self.h[i] as usize
    }

    #[inline]
    pub fn s(&self, i: usize) -> f64 {
        self.s[i] as f64
    }

    /// Bytes of storage — the paper's "memory for Hash functions" metric.
    /// One `u32` bucket + one `i8` sign per domain element.
    pub fn memory_bytes(&self) -> usize {
        self.h.len() * std::mem::size_of::<u32>() + self.s.len() * std::mem::size_of::<i8>()
    }

    /// Build directly from explicit tables (used by tests and the python
    /// parity harness, which shares hash tables across the FFI boundary).
    pub fn from_tables(h: Vec<u32>, s: Vec<i8>, range: usize) -> Self {
        assert_eq!(h.len(), s.len());
        assert!(h.iter().all(|&b| (b as usize) < range));
        assert!(s.iter().all(|&v| v == 1 || v == -1));
        Self { h, s, range }
    }
}

/// The `N` per-mode hash pairs for an order-`N` tensor, plus the composite
/// pair of Eq. 7.
#[derive(Debug, Clone)]
pub struct ModeHashes {
    pub modes: Vec<HashTable>,
    /// dims[n] = I_n
    pub dims: Vec<usize>,
}

impl ModeHashes {
    /// Draw one pair per mode. `ranges[n] = J_n`.
    pub fn draw(rng: &mut Rng, dims: &[usize], ranges: &[usize]) -> Self {
        assert_eq!(dims.len(), ranges.len());
        let modes = dims
            .iter()
            .zip(ranges)
            .map(|(&i, &j)| HashPair::draw(rng, i, j).materialize())
            .collect();
        Self { modes, dims: dims.to_vec() }
    }

    /// Draw with a single shared range `J` for all modes (the common setup in
    /// the paper's experiments).
    pub fn draw_uniform(rng: &mut Rng, dims: &[usize], j: usize) -> Self {
        let ranges = vec![j; dims.len()];
        Self::draw(rng, dims, &ranges)
    }

    /// Empty arena for later [`Self::redraw_uniform`] calls (the
    /// coordinator's per-worker reusable hash storage).
    pub fn empty() -> Self {
        Self { modes: Vec::new(), dims: Vec::new() }
    }

    /// In-place uniform redraw, reusing table storage. Consumes the same
    /// RNG stream as [`Self::draw_uniform`] (one [`HashPair`] per mode, in
    /// mode order — see [`redraw_tables_uniform`]), so a redraw is
    /// draw-for-draw identical to a fresh `draw_uniform` with the same
    /// generator state. Zero heap allocations once the arena's order and
    /// per-mode domains cover `dims` (the coordinator's same-shape request
    /// streams).
    pub fn redraw_uniform(&mut self, rng: &mut Rng, dims: &[usize], j: usize) {
        self.dims.clear();
        self.dims.extend_from_slice(dims);
        self.modes.truncate(dims.len());
        while self.modes.len() < dims.len() {
            self.modes.push(HashTable { h: Vec::new(), s: Vec::new(), range: 0 });
        }
        redraw_tables_uniform(rng, j, self.modes.iter_mut().zip(dims.iter().copied()));
    }

    pub fn order(&self) -> usize {
        self.modes.len()
    }

    /// Composite output length `J̃ = Σ J_n − N + 1` (Definition 4).
    pub fn composite_range(&self) -> usize {
        self.modes.iter().map(|m| m.range).sum::<usize>() - self.order() + 1
    }

    /// Total vectorized domain `Ĩ = Π I_n`.
    pub fn composite_domain(&self) -> usize {
        self.dims.iter().product()
    }

    /// Composite bucket for a multi-index (Eq. 7, 0-based:
    /// `h̃ = Σ h_n(i_n)` which lies in `[0, J̃)`).
    #[inline]
    pub fn composite_h(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.order());
        idx.iter().zip(&self.modes).map(|(&i, m)| m.h(i)).sum()
    }

    /// Composite sign for a multi-index (Eq. 7).
    #[inline]
    pub fn composite_s(&self, idx: &[usize]) -> f64 {
        let neg = idx
            .iter()
            .zip(&self.modes)
            .filter(|(&i, m)| m.s[i] < 0)
            .count();
        if neg & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Materialize the full composite pair over `[0, Ĩ)` — this is what a
    /// *plain CS on vec(T)* would have to store, and is exactly the memory
    /// gap the paper highlights (point (1) of §3.2). Column-major (first
    /// index fastest) to match `vec(T)` in the paper.
    pub fn materialize_composite(&self) -> HashTable {
        let total = self.composite_domain();
        let n = self.order();
        let mut h = Vec::with_capacity(total);
        let mut s = Vec::with_capacity(total);
        let mut idx = vec![0usize; n];
        for _ in 0..total {
            h.push(self.composite_h(&idx) as u32);
            s.push(if self.composite_s(&idx) > 0.0 { 1i8 } else { -1i8 });
            // increment column-major multi-index (first mode fastest)
            for d in 0..n {
                idx[d] += 1;
                if idx[d] < self.dims[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        HashTable::from_tables(h, s, self.composite_range())
    }

    /// TS-style bucket: `(Σ h_n(i_n)) mod J` — only valid when all mode
    /// ranges are equal. Kept here so TS and FCS provably share hash draws
    /// ("the Hash functions for TS and FCS are equalized", §4.1).
    #[inline]
    pub fn ts_h(&self, idx: &[usize]) -> usize {
        let j = self.modes[0].range;
        debug_assert!(self.modes.iter().all(|m| m.range == j));
        self.composite_h(idx) % j
    }

    /// Memory of the stored per-mode tables, `O(Σ I_n)`.
    pub fn memory_bytes(&self) -> usize {
        self.modes.iter().map(|m| m.memory_bytes()).sum()
    }
}

/// Redraw one uniform `(h, s)` pair per `(table, domain)` item, in order,
/// reusing each table's storage. This is the **single home** of the
/// redraw-stream invariant: exactly one [`HashPair::draw`] per mode, in mode
/// order, which is what keeps every arena path (the [`ModeHashes`] redraw
/// and the coordinator's per-mode [`HashTable`] arenas) draw-for-draw
/// identical to a fresh [`ModeHashes::draw_uniform`].
pub fn redraw_tables_uniform<'t>(
    rng: &mut Rng,
    j: usize,
    tables: impl Iterator<Item = (&'t mut HashTable, usize)>,
) {
    for (table, dim) in tables {
        HashPair::draw(rng, dim, j).materialize_into(table);
    }
}

/// Decompose a column-major linear index into a multi-index.
#[inline]
pub fn unravel_colmajor(mut l: usize, dims: &[usize], out: &mut [usize]) {
    for (o, &d) in out.iter_mut().zip(dims) {
        *o = l % d;
        l /= d;
    }
    debug_assert_eq!(l, 0);
}

/// Compose a column-major linear index from a multi-index.
#[inline]
pub fn ravel_colmajor(idx: &[usize], dims: &[usize]) -> usize {
    let mut l = 0usize;
    let mut stride = 1usize;
    for (&i, &d) in idx.iter().zip(dims) {
        debug_assert!(i < d);
        l += i * stride;
        stride *= d;
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qcheck::qcheck;

    #[test]
    fn hash_in_range() {
        let mut rng = Rng::seed_from_u64(1);
        let p = HashPair::draw(&mut rng, 1000, 37);
        for i in 0..1000 {
            assert!(p.h(i) < 37);
            assert!(p.s(i) == 1.0 || p.s(i) == -1.0);
        }
    }

    #[test]
    fn materialize_matches_eval() {
        let mut rng = Rng::seed_from_u64(2);
        let p = HashPair::draw(&mut rng, 500, 64);
        let t = p.materialize();
        for i in 0..500 {
            assert_eq!(t.h(i), p.h(i));
            assert_eq!(t.s(i), p.s(i));
        }
    }

    #[test]
    fn two_wise_collision_rate() {
        // Pr[h(x) = h(y)] ≈ 1/J for x ≠ y over independent draws.
        let mut rng = Rng::seed_from_u64(3);
        let j = 32;
        let trials = 20_000;
        let mut collisions = 0;
        for _ in 0..trials {
            let p = HashPair::draw(&mut rng, 100, j);
            if p.h(17) == p.h(59) {
                collisions += 1;
            }
        }
        let rate = collisions as f64 / trials as f64;
        assert!((rate - 1.0 / j as f64).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn sign_product_unbiased() {
        // E[s(x) s(y)] = 0 for x ≠ y.
        let mut rng = Rng::seed_from_u64(4);
        let mut acc = 0.0;
        let trials = 20_000;
        for _ in 0..trials {
            let p = HashPair::draw(&mut rng, 100, 8);
            acc += p.s(3) * p.s(77);
        }
        assert!((acc / trials as f64).abs() < 0.03);
    }

    #[test]
    fn redraw_matches_fresh_draw() {
        // redraw_uniform must be draw-for-draw identical to draw_uniform
        // with the same generator state, even after the arena held a
        // different shape.
        let mut a = Rng::seed_from_u64(10);
        let mut b = a.clone();
        let fresh = ModeHashes::draw_uniform(&mut a, &[6, 5, 4], 7);
        let mut arena = ModeHashes::empty();
        let mut warm = b.clone();
        arena.redraw_uniform(&mut warm, &[3, 3], 4);
        arena.redraw_uniform(&mut b, &[6, 5, 4], 7);
        assert_eq!(arena.dims, fresh.dims);
        assert_eq!(arena.modes.len(), fresh.modes.len());
        for (x, y) in arena.modes.iter().zip(&fresh.modes) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn composite_range_formula() {
        let mut rng = Rng::seed_from_u64(5);
        let m = ModeHashes::draw(&mut rng, &[10, 20, 30], &[5, 6, 7]);
        assert_eq!(m.composite_range(), 5 + 6 + 7 - 3 + 1);
        assert_eq!(m.composite_domain(), 6000);
    }

    #[test]
    fn composite_h_bounds() {
        let mut rng = Rng::seed_from_u64(6);
        let m = ModeHashes::draw_uniform(&mut rng, &[9, 9, 9], 11);
        for i in 0..9 {
            for jj in 0..9 {
                for k in 0..9 {
                    let h = m.composite_h(&[i, jj, k]);
                    assert!(h < m.composite_range());
                }
            }
        }
    }

    #[test]
    fn materialized_composite_matches_formula() {
        let mut rng = Rng::seed_from_u64(7);
        let dims = [4usize, 3, 5];
        let m = ModeHashes::draw_uniform(&mut rng, &dims, 6);
        let comp = m.materialize_composite();
        let mut idx = [0usize; 3];
        for l in 0..m.composite_domain() {
            unravel_colmajor(l, &dims, &mut idx);
            assert_eq!(comp.h(l), m.composite_h(&idx), "l={l}");
            assert_eq!(comp.s(l), m.composite_s(&idx), "l={l}");
        }
    }

    #[test]
    fn ravel_unravel_roundtrip() {
        qcheck(50, |g| {
            let order = g.usize_in(1, 4);
            let dims: Vec<usize> = (0..order).map(|_| g.usize_in(1, 9)).collect();
            let total: usize = dims.iter().product();
            let l = g.usize_in(0, total - 1);
            let mut idx = vec![0usize; order];
            unravel_colmajor(l, &dims, &mut idx);
            assert_eq!(ravel_colmajor(&idx, &dims), l);
        });
    }

    #[test]
    fn memory_accounting_gap() {
        // FCS per-mode storage must be much smaller than the composite
        // (CS-on-vec) storage — the paper's point (1).
        let mut rng = Rng::seed_from_u64(8);
        let m = ModeHashes::draw_uniform(&mut rng, &[50, 50, 50], 100);
        let fcs_mem = m.memory_bytes();
        let cs_mem = m.materialize_composite().memory_bytes();
        assert_eq!(fcs_mem, 3 * 50 * 5);
        assert_eq!(cs_mem, 50 * 50 * 50 * 5);
        assert!(cs_mem > 100 * fcs_mem);
    }

    #[test]
    fn composite_sign_is_product() {
        let mut rng = Rng::seed_from_u64(9);
        let m = ModeHashes::draw_uniform(&mut rng, &[7, 8], 5);
        for i in 0..7 {
            for j in 0..8 {
                let prod = m.modes[0].s(i) * m.modes[1].s(j);
                assert_eq!(m.composite_s(&[i, j]), prod);
            }
        }
    }
}
