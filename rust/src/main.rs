//! `fcs` — the leader binary: serve the sketch service, run CPD /
//! compression workloads, train the sketched TRN, inspect artifacts.

use fcs::coordinator::{Service, ServiceConfig};
use fcs::cpd::{als_plain, als_sketched, rtpm_symmetric, AlsConfig, RtpmConfig};
use fcs::data::synthetic_cp;
use fcs::metrics::residual_norm;
use fcs::sketch::Method;
use fcs::util::cli::Args;
use fcs::util::prng::Rng;
use fcs::util::timing::Stopwatch;

const USAGE: &str = "\
fcs — Efficient Tensor Contraction via Fast Count Sketch (full reproduction)

USAGE: fcs <command> [options]

COMMANDS:
  rtpm       sketched RTPM on a synthetic tensor
             --dim 100 --rank 10 --j 5000 --d 10 --sigma 0.01 --method fcs
  als        sketched ALS on a synthetic asymmetric tensor
             --dim 200 --rank 10 --j 4000 --d 10 --sigma 0.01 --method fcs
  trn        train the sketched TRN through the XLA artifacts
             --method fcs --cr 20 --steps 300
  serve      start the coordinator and print serving stats on Ctrl-D
             --workers 8 --seconds 5
  artifacts  list compiled artifacts in the manifest
  help       this text

Benchmarks (one per paper table/figure): `cargo bench --bench fig1_rtpm_synthetic`, …
";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("rtpm") => cmd_rtpm(&args),
        Some("als") => cmd_als(&args),
        Some("trn") => cmd_trn(&args),
        Some("serve") => cmd_serve(&args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_rtpm(args: &Args) -> anyhow::Result<()> {
    let dim = args.get_usize("dim", 100);
    let rank = args.get_usize("rank", 10);
    let j = args.get_usize("j", 5000);
    let d = args.get_usize("d", 10);
    let sigma = args.get_f64("sigma", 0.01);
    let method = Method::parse(&args.get_or("method", "fcs")).expect("bad --method");
    let mut rng = Rng::seed_from_u64(args.get_usize("seed", 0) as u64);
    println!("generating {dim}³ rank-{rank} symmetric tensor (σ={sigma})…");
    let (t, _) = synthetic_cp(&mut rng, &[dim, dim, dim], rank, sigma, true);
    let cfg = RtpmConfig {
        rank,
        n_init: args.get_usize("inits", 15),
        n_iter: args.get_usize("iters", 20),
        seed: 7,
    };
    let sw = Stopwatch::start();
    let mut est = method.build(&t, d, j, &mut rng);
    let cp = rtpm_symmetric(est.as_mut(), dim, &cfg);
    println!(
        "{}-RTPM: residual {:.4} in {:.2}s (hash memory {} B)",
        method.name(),
        residual_norm(&cp, &t),
        sw.elapsed_secs(),
        est.hash_bytes()
    );
    Ok(())
}

fn cmd_als(args: &Args) -> anyhow::Result<()> {
    let dim = args.get_usize("dim", 200);
    let rank = args.get_usize("rank", 10);
    let j = args.get_usize("j", 4000);
    let d = args.get_usize("d", 10);
    let sigma = args.get_f64("sigma", 0.01);
    let method = Method::parse(&args.get_or("method", "fcs")).expect("bad --method");
    let mut rng = Rng::seed_from_u64(args.get_usize("seed", 0) as u64);
    println!("generating {dim}³ rank-{rank} asymmetric tensor (σ={sigma})…");
    let (t, _) = synthetic_cp(&mut rng, &[dim, dim, dim], rank, sigma, false);
    let cfg = AlsConfig { rank, n_iter: args.get_usize("iters", 20), seed: 11 };
    let sw = Stopwatch::start();
    let cp = if method == Method::Plain {
        als_plain(&t, &cfg)
    } else {
        let est = method.build(&t, d, j, &mut rng);
        als_sketched(&t.shape, est.as_ref(), &t, &cfg)
    };
    println!(
        "{}-ALS: residual {:.4} in {:.2}s",
        method.name(),
        residual_norm(&cp, &t),
        sw.elapsed_secs()
    );
    Ok(())
}

fn cmd_trn(args: &Args) -> anyhow::Result<()> {
    let rt = fcs::runtime::spawn_runtime(None)?;
    let method =
        fcs::trn::TrnMethod::parse(&args.get_or("method", "fcs")).expect("bad --method");
    let cfg = fcs::trn::TrnRunConfig {
        method,
        cr_tag: args.get_or("cr", "20").replace('.', "p"),
        steps: args.get_usize("steps", 300),
        lr: args.get_f64("lr", 0.05) as f32,
        train_size: args.get_usize("train-size", 6400),
        test_size: args.get_usize("test-size", 1024),
        seed: args.get_usize("seed", 1234) as u64,
        log_every: args.get_usize("log-every", 20),
    };
    let res = fcs::trn::train_and_eval(&rt, &cfg)?;
    println!(
        "{}-TRN @ CR {}: accuracy {:.4}, final loss {:.4}, {:.1}s",
        res.method,
        res.cr,
        res.accuracy,
        res.losses.last().unwrap(),
        res.train_secs
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let runtime = fcs::runtime::spawn_runtime(None).ok();
    println!(
        "starting coordinator ({} backend)…",
        if runtime.is_some() { "XLA" } else { "pure-Rust" }
    );
    let cfg = ServiceConfig {
        workers: args.get_usize("workers", fcs::util::parallel::default_threads().min(8)),
        ..Default::default()
    };
    let svc = Service::start(cfg, runtime)?;
    let h = svc.handle();
    let seconds = args.get_usize("seconds", 5);
    println!("self-driving load for {seconds}s (dim {} → {})…", h.cs_in_dim, h.cs_out_dim);
    let sw = Stopwatch::start();
    let mut rng = Rng::seed_from_u64(0);
    let x = rng.normal_vec(h.cs_in_dim);
    let mut n = 0u64;
    while sw.elapsed_secs() < seconds as f64 {
        let mut pend = Vec::with_capacity(64);
        for _ in 0..64 {
            if let Ok(rx) = h.submit(fcs::coordinator::Request::CsVec { x: x.clone() }) {
                pend.push(rx);
            }
        }
        for rx in pend {
            if rx.recv().is_ok() {
                n += 1;
            }
        }
    }
    let report = svc.stats();
    println!("served {n} requests → {:.0} req/s", n as f64 / sw.elapsed_secs());
    for op in &report.per_op {
        println!(
            "  {:<12} n={:<8} p50 {:>7.0}µs p95 {:>7.0}µs p99 {:>7.0}µs",
            op.op, op.completed, op.p50_us, op.p95_us, op.p99_us
        );
    }
    println!("  batches {} (mean fill {:.1}), rejected {}", report.batches, report.mean_batch_fill, report.rejected_busy);
    svc.shutdown();
    Ok(())
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let rt = fcs::runtime::spawn_runtime(None)?;
    println!("artifacts at {}:", rt.dir.display());
    let mut names: Vec<_> = rt.manifest().entries.keys().collect();
    names.sort();
    for name in names {
        let e = &rt.manifest().entries[name];
        println!("  {:<28} {} inputs  {}", name, e.inputs.len(), e.file);
    }
    Ok(())
}
