//! Column-major dense matrix. Column-major matches the paper's MATLAB
//! conventions (`vec`, mode-n matricization, factor matrices `U^{(n)}` whose
//! columns are the rank-1 factors), so sketch/CPD code reads like the paper.

use crate::util::prng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    /// Column-major storage: element (i, j) at `data[j * rows + i]`.
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.data[j * rows + i] = f(i, j);
            }
        }
        m
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        Self::from_data(rows, cols, rng.normal_vec(rows * cols))
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Immutable view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        self.col_mut(j).copy_from_slice(v);
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                t.data[i * self.cols + j] = self.data[j * self.rows + i];
            }
        }
        t
    }

    /// `self * other` — blocked column-major matmul.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for j in 0..n {
            let oc = &mut out.data[j * m..(j + 1) * m];
            for l in 0..k {
                let b = other.data[j * k + l];
                if b == 0.0 {
                    continue;
                }
                let ac = &self.data[l * m..(l + 1) * m];
                for (o, a) in oc.iter_mut().zip(ac) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self^T * other` without forming the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for j in 0..n {
            let bc = &other.data[j * k..(j + 1) * k];
            for i in 0..m {
                let ac = &self.data[i * k..(i + 1) * k];
                out.data[j * m + i] = super::dot(ac, bc);
            }
        }
        out
    }

    /// Matrix-vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            super::axpy(xj, self.col(j), &mut out);
        }
        out
    }

    /// `self^T * x`.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        (0..self.cols).map(|j| super::dot(self.col(j), x)).collect()
    }

    /// Hadamard (elementwise) product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix::from_data(self.rows, self.cols, data)
    }

    pub fn frob_norm(&self) -> f64 {
        super::norm2(&self.data)
    }

    pub fn scaled(&self, k: f64) -> Matrix {
        Matrix::from_data(self.rows, self.cols, self.data.iter().map(|v| v * k).collect())
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix::from_data(self.rows, self.cols, data)
    }

    /// Kronecker product `self ⊗ other`
    /// ((A ⊗ B)(p, q) with p = i·rB + k, q = j·cB + l = A(i,j)·B(k,l)).
    pub fn kron(&self, other: &Matrix) -> Matrix {
        let (ra, ca) = (self.rows, self.cols);
        let (rb, cb) = (other.rows, other.cols);
        let mut out = Matrix::zeros(ra * rb, ca * cb);
        for j in 0..ca {
            for i in 0..ra {
                let a = self.get(i, j);
                if a == 0.0 {
                    continue;
                }
                for l in 0..cb {
                    for k in 0..rb {
                        out.set(i * rb + k, j * cb + l, a * other.get(k, l));
                    }
                }
            }
        }
        out
    }

    /// Khatri-Rao (column-wise Kronecker) product: columns `a_r ⊗ b_r`,
    /// i.e. `(A ⊙ B)(i·rB + k, r) = A(i,r)·B(k,r)` — the MATLAB `kr` used in
    /// ALS (Eq. 18 context).
    pub fn khatri_rao(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "khatri_rao needs equal column counts");
        let (ra, rb, c) = (self.rows, other.rows, self.cols);
        let mut out = Matrix::zeros(ra * rb, c);
        for r in 0..c {
            let (a, b) = (self.col(r), other.col(r));
            let oc = out.col_mut(r);
            for (i, &av) in a.iter().enumerate() {
                for (k, &bv) in b.iter().enumerate() {
                    oc[i * rb + k] = av * bv;
                }
            }
        }
        out
    }

    /// `vec(self)` — column-major flattening (paper convention). The storage
    /// already is column-major, so this is a copy of `data`.
    pub fn vec(&self) -> Vec<f64> {
        self.data.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f64); // [[0,1,2],[3,4,5]]
        let b = Matrix::from_fn(3, 2, |i, j| (i * 2 + j) as f64); // [[0,1],[2,3],[4,5]]
        let c = a.matmul(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 2);
        assert_eq!(c.get(0, 0), 10.0);
        assert_eq!(c.get(0, 1), 13.0);
        assert_eq!(c.get(1, 0), 28.0);
        assert_eq!(c.get(1, 1), 40.0);
    }

    #[test]
    fn t_matmul_matches_transpose() {
        let mut rng = Rng::seed_from_u64(1);
        let a = Matrix::randn(&mut rng, 7, 4);
        let b = Matrix::randn(&mut rng, 7, 5);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.sub(&slow).frob_norm() < 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Matrix::randn(&mut rng, 6, 4);
        let x = rng.normal_vec(4);
        let y = a.matvec(&x);
        let xm = Matrix::from_data(4, 1, x);
        let ym = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn kron_shape_and_values() {
        let a = Matrix::from_fn(2, 2, |i, j| (i * 2 + j + 1) as f64);
        let b = Matrix::identity(2);
        let k = a.kron(&b);
        assert_eq!((k.rows, k.cols), (4, 4));
        assert_eq!(k.get(0, 0), 1.0);
        assert_eq!(k.get(1, 1), 1.0);
        assert_eq!(k.get(0, 2), 2.0);
        assert_eq!(k.get(2, 0), 3.0);
        assert_eq!(k.get(2, 2), 4.0);
        assert_eq!(k.get(0, 1), 0.0);
    }

    #[test]
    fn khatri_rao_is_columnwise_kron() {
        let mut rng = Rng::seed_from_u64(3);
        let a = Matrix::randn(&mut rng, 3, 2);
        let b = Matrix::randn(&mut rng, 4, 2);
        let kr = a.khatri_rao(&b);
        assert_eq!((kr.rows, kr.cols), (12, 2));
        for r in 0..2 {
            for i in 0..3 {
                for k in 0..4 {
                    let expect = a.get(i, r) * b.get(k, r);
                    assert!((kr.get(i * 4 + k, r) - expect).abs() < 1e-15);
                }
            }
        }
    }

    #[test]
    fn vec_is_column_major() {
        let m = Matrix::from_fn(2, 2, |i, j| (10 * i + j) as f64);
        // columns: [0, 10], [1, 11]
        assert_eq!(m.vec(), vec![0.0, 10.0, 1.0, 11.0]);
    }
}
