//! Small dense linear algebra: column-major `Matrix`, matmul, QR
//! (Householder), Cholesky solves, and random orthonormal bases. Everything
//! the CPD algorithms need — no external BLAS available offline.

pub mod matrix;
pub mod decomp;

pub use decomp::{cholesky_solve, householder_qr, random_orthonormal, solve_spd_systems};
pub use matrix::Matrix;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Normalize to unit norm in place; returns the original norm.
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm2(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn normalize_unit() {
        let mut x = vec![3.0, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-15);
        assert!((norm2(&x) - 1.0).abs() < 1e-15);
    }
}
