//! Factorizations: Householder QR (for random orthonormal bases and least
//! squares) and Cholesky (for the small `R×R` normal equations in ALS).

use super::matrix::Matrix;
use crate::util::prng::Rng;

/// Householder QR: returns (Q, R) with `Q` m×n (thin) orthonormal columns
/// and `R` n×n upper triangular, for m ≥ n.
pub fn householder_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_qr expects tall matrix");
    let mut r = a.clone();
    // Store the Householder vectors.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let col = r.col(k);
        let mut v: Vec<f64> = col[k..].to_vec();
        let alpha = -v[0].signum() * super::norm2(&v);
        if alpha.abs() < f64::EPSILON {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm = super::norm2(&v);
        if vnorm > 0.0 {
            for x in v.iter_mut() {
                *x /= vnorm;
            }
        }
        // Apply H = I - 2vv^T to the trailing submatrix of R.
        for j in k..n {
            let cj = r.col_mut(j);
            let tail = &mut cj[k..];
            let proj = 2.0 * super::dot(&v, tail);
            for (t, &vi) in tail.iter_mut().zip(&v) {
                *t -= proj * vi;
            }
        }
        vs.push(v);
    }
    // Form thin Q by applying the Householder reflections to I (backwards).
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..n {
            let cj = q.col_mut(j);
            let tail = &mut cj[k..];
            let proj = 2.0 * super::dot(v, tail);
            for (t, &vi) in tail.iter_mut().zip(v) {
                *t -= proj * vi;
            }
        }
    }
    // Zero out sub-diagonal of R and truncate to n×n.
    let mut rr = Matrix::zeros(n, n);
    for j in 0..n {
        for i in 0..=j.min(n - 1) {
            rr.set(i, j, r.get(i, j));
        }
    }
    (q, rr)
}

/// Random matrix with orthonormal columns (QR of a Gaussian), `rows ≥ cols`.
/// Used to build the synthetic CP tensors with orthonormal factors (§4.1).
pub fn random_orthonormal(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let g = Matrix::randn(rng, rows, cols);
    let (q, _r) = householder_qr(&g);
    q
}

/// Cholesky factorization of an SPD matrix (lower triangular L, A = L·L^T).
/// Adds `ridge` to the diagonal for numerical safety (ALS normal equations
/// can be near-singular when factors are correlated).
pub fn cholesky(a: &Matrix, ridge: f64) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        for i in j..n {
            let mut sum = a.get(i, j) + if i == j { ridge } else { 0.0 };
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(j, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky with automatic ridge escalation.
pub fn cholesky_solve(a: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = a.rows;
    assert_eq!(b.len(), n);
    let scale = a.frob_norm().max(1.0);
    let mut ridge = 0.0;
    let l = loop {
        if let Some(l) = cholesky(a, ridge) {
            break l;
        }
        ridge = if ridge == 0.0 { 1e-12 * scale } else { ridge * 100.0 };
        assert!(ridge < scale, "cholesky_solve: matrix is badly indefinite");
    };
    // Forward substitution L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.get(i, k) * y[k];
        }
        y[i] = sum / l.get(i, i);
    }
    // Back substitution L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.get(k, i) * x[k];
        }
        x[i] = sum / l.get(i, i);
    }
    x
}

/// Solve `A X = B` column by column for SPD `A` (shared factorization would
/// be nicer; the `R×R` systems in ALS are tiny so this is fine).
pub fn solve_spd_systems(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.cols, b.cols);
    for j in 0..b.cols {
        let x = cholesky_solve(a, b.col(j));
        out.set_col(j, &x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::seed_from_u64(1);
        let a = Matrix::randn(&mut rng, 8, 5);
        let (q, r) = householder_qr(&a);
        let qr = q.matmul(&r);
        assert!(qr.sub(&a).frob_norm() < 1e-10);
    }

    #[test]
    fn qr_orthonormal_columns() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Matrix::randn(&mut rng, 10, 6);
        let (q, _) = householder_qr(&a);
        let g = q.t_matmul(&q);
        let eye = Matrix::identity(6);
        assert!(g.sub(&eye).frob_norm() < 1e-10);
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Rng::seed_from_u64(3);
        let q = random_orthonormal(&mut rng, 20, 10);
        let g = q.t_matmul(&q);
        assert!(g.sub(&Matrix::identity(10)).frob_norm() < 1e-10);
    }

    #[test]
    fn cholesky_solve_spd() {
        let mut rng = Rng::seed_from_u64(4);
        let g = Matrix::randn(&mut rng, 12, 6);
        let a = g.t_matmul(&g); // SPD
        let x_true = rng.normal_vec(6);
        let b = a.matvec(&x_true);
        let x = cholesky_solve(&a, &b);
        let err: f64 = x.iter().zip(&x_true).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn solve_systems_matches_single() {
        let mut rng = Rng::seed_from_u64(5);
        let g = Matrix::randn(&mut rng, 9, 4);
        let a = g.t_matmul(&g);
        let b = Matrix::randn(&mut rng, 4, 3);
        let x = solve_spd_systems(&a, &b);
        for j in 0..3 {
            let xj = cholesky_solve(&a, b.col(j));
            for i in 0..4 {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-12);
            }
        }
    }
}
