//! Deterministic fault injection ("failpoints").
//!
//! A *site* is a named call to [`act`] or [`check`] placed on a failure-prone
//! path — the worker loop, the spectral driver, the shard merge, the obs
//! exporter. A *schedule* ([`FaultSpec`]) arms a site with an action, a
//! trigger probability drawn from the site's own seeded RNG, and an optional
//! hit cap — so an injection run is a pure function of its specs and the
//! evaluation order, replayable bit-for-bit. The chaos suite
//! (`rust/tests/chaos.rs`) floods a pool under such schedules and proves the
//! resilience contracts: zero lost replies, confined failures, books that
//! reconcile, and supervisor self-healing.
//!
//! Zero-cost when disabled: without the `failpoints` cargo feature, [`act`]
//! and [`check`] compile to empty `#[inline(always)]` bodies (a constant
//! `None`), so production builds carry no registry, no lock, and no branch
//! beyond what the optimizer deletes. With the feature on but nothing armed,
//! every evaluation is one relaxed atomic load.
//!
//! Action semantics at an armed site:
//! * [`FaultAction::Panic`] and [`FaultAction::Delay`] execute *inside*
//!   [`check`] (the site needs no handling code for them);
//! * [`FaultAction::Error`] and [`FaultAction::TruncateSlab`] are returned
//!   for the site to map onto its local failure path (an `Exec` reply, a
//!   torn shard part, a 500 response).
//!
//! Every firing increments `fcs_faults_injected_total{site=...}` (see
//! [`crate::obs`]), so a chaos run's injection count is scrapeable next to
//! the shed/retry/respawn counters it provokes.

use std::time::Duration;

/// What an armed failpoint does when its schedule fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// `panic!` at the site — exercises the `catch_unwind` isolation layers
    /// and (in the worker loop, outside any catch) thread death + respawn.
    Panic,
    /// Sleep in place for the given duration, then continue normally —
    /// manufactures queue backlog and deadline expiry on demand.
    Delay(Duration),
    /// Returned to the site, which maps it onto its local error path.
    Error,
    /// Returned to the site, which tears one element off a shard part the
    /// way a corrupted merge reply would arrive (exercises the
    /// execution-time length assert's confinement contract).
    TruncateSlab,
}

/// Injection schedule for one site. The site evaluates its private
/// `Rng::seed_from_u64(seed)` stream once per [`check`]; it fires when the
/// draw lands under `prob` and fewer than `max_hits` firings have happened.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    pub action: FaultAction,
    /// Trigger probability per evaluation, in `[0, 1]` (`1.0` = always).
    pub prob: f64,
    /// Stop firing after this many hits (`None` = unbounded).
    pub max_hits: Option<u64>,
    /// Seed of the site's private RNG — the schedule is deterministic in
    /// `(spec, evaluation order)`.
    pub seed: u64,
}

#[cfg(feature = "failpoints")]
mod armed {
    use super::{FaultAction, FaultSpec};
    use crate::sync::atomic::{AtomicUsize, Ordering};
    use crate::sync::{Mutex, MutexGuard, OnceLock};
    use crate::util::prng::Rng;
    use std::collections::HashMap;

    struct Site {
        spec: FaultSpec,
        rng: Rng,
        hits: u64,
    }

    /// Count of configured sites — the lock-free "anything armed at all?"
    /// fast path every [`check`] takes before touching the registry lock.
    ///
    /// ARMED is purely advisory: the registry mutex is the real
    /// synchronization, and a stale zero read only means a site armed
    /// concurrently is first observed one evaluation later (the chaos
    /// suites arm sites before spawning load, so nothing depends on
    /// same-instant visibility). All operations are therefore Relaxed —
    /// PR 10 normalized the previous unexplained SeqCst/Relaxed mix
    /// (loom model: `fault_armed_counter_consistent`).
    static ARMED: AtomicUsize = AtomicUsize::new(0);

    fn registry() -> &'static Mutex<HashMap<&'static str, Site>> {
        static REG: OnceLock<Mutex<HashMap<&'static str, Site>>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(HashMap::new()))
    }

    fn lock() -> MutexGuard<'static, HashMap<&'static str, Site>> {
        // The injected Panic action fires *after* the lock is released, so
        // our own panics never poison this mutex — but a test that panics
        // for unrelated reasons while configuring must not wedge the
        // registry for the rest of the process.
        registry().lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Arm (or re-arm, resetting RNG and hit count) a site's schedule.
    pub fn configure(site: &'static str, spec: FaultSpec) {
        let fresh = Site { rng: Rng::seed_from_u64(spec.seed), spec, hits: 0 };
        if lock().insert(site, fresh).is_none() {
            // ordering: Relaxed — advisory fast-path count; the registry
            // mutex (held here) is the real synchronization. See ARMED doc.
            ARMED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Disarm one site.
    pub fn clear(site: &'static str) {
        if lock().remove(site).is_some() {
            // ordering: Relaxed — advisory fast-path count. See ARMED doc.
            ARMED.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Disarm every site (chaos tests bracket themselves with this).
    pub fn clear_all() {
        let mut g = lock();
        let n = g.len();
        g.clear();
        drop(g);
        // ordering: Relaxed — advisory fast-path count. See ARMED doc.
        ARMED.fetch_sub(n, Ordering::Relaxed);
    }

    /// How many times `site`'s schedule has actually fired.
    pub fn hits(site: &'static str) -> u64 {
        lock().get(site).map_or(0, |s| s.hits)
    }

    /// Evaluate a site. `Panic`/`Delay` execute here; `Error`/`TruncateSlab`
    /// are returned for the caller to map onto its local failure path.
    pub fn check(site: &'static str) -> Option<FaultAction> {
        // ordering: Relaxed — advisory fast path; a stale zero defers the
        // first observation of a concurrent arm by one evaluation, and any
        // nonzero read falls through to the mutex for the real answer.
        if ARMED.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let action = {
            let mut g = lock();
            let s = g.get_mut(site)?;
            if s.spec.max_hits.is_some_and(|m| s.hits >= m) {
                return None;
            }
            if s.rng.uniform() >= s.spec.prob {
                return None;
            }
            s.hits += 1;
            s.spec.action
            // Lock released here: the panic/sleep below must never hold it.
        };
        crate::obs::metrics().fault_injected(site).inc();
        match action {
            FaultAction::Panic => panic!("failpoint {site}: injected panic"),
            FaultAction::Delay(d) => {
                std::thread::sleep(d);
                None
            }
            other => Some(other),
        }
    }
}

#[cfg(feature = "failpoints")]
pub use armed::{check, clear, clear_all, configure, hits};

/// Evaluate a site, discarding action-carrying results (`Panic`/`Delay`
/// still execute in place). For sites with no local error mapping.
#[cfg(feature = "failpoints")]
#[inline]
pub fn act(site: &'static str) {
    let _ = check(site);
}

/// Failpoints disabled: a constant `None` the optimizer deletes.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn check(_site: &'static str) -> Option<FaultAction> {
    None
}

/// Failpoints disabled: an empty body the optimizer deletes.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn act(_site: &'static str) {}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    // Each test arms its own uniquely named sites, so the process-global
    // registry needs no cross-test serialization here.

    #[test]
    fn unarmed_site_is_silent() {
        assert_eq!(check("fault_test_unarmed"), None);
        assert_eq!(hits("fault_test_unarmed"), 0);
    }

    #[test]
    fn max_hits_bounds_the_schedule() {
        configure(
            "fault_test_max",
            FaultSpec { action: FaultAction::Error, prob: 1.0, max_hits: Some(2), seed: 1 },
        );
        assert_eq!(check("fault_test_max"), Some(FaultAction::Error));
        assert_eq!(check("fault_test_max"), Some(FaultAction::Error));
        assert_eq!(check("fault_test_max"), None);
        assert_eq!(hits("fault_test_max"), 2);
        clear("fault_test_max");
        assert_eq!(check("fault_test_max"), None, "cleared site is unarmed");
    }

    #[test]
    fn zero_probability_never_fires() {
        configure(
            "fault_test_p0",
            FaultSpec { action: FaultAction::Error, prob: 0.0, max_hits: None, seed: 7 },
        );
        for _ in 0..100 {
            assert_eq!(check("fault_test_p0"), None);
        }
        assert_eq!(hits("fault_test_p0"), 0);
        clear("fault_test_p0");
    }

    #[test]
    fn schedule_is_deterministic_in_the_seed() {
        let run = || -> Vec<bool> {
            configure(
                "fault_test_det",
                FaultSpec { action: FaultAction::Error, prob: 0.5, max_hits: None, seed: 42 },
            );
            let v = (0..64).map(|_| check("fault_test_det").is_some()).collect();
            clear("fault_test_det");
            v
        };
        let a = run();
        assert_eq!(a, run(), "same seed must replay the same schedule");
        assert!(
            a.iter().any(|&x| x) && a.iter().any(|&x| !x),
            "p=0.5 over 64 draws should both fire and skip"
        );
    }

    #[test]
    fn panic_action_panics_at_the_site_and_consumes_a_hit() {
        configure(
            "fault_test_panic",
            FaultSpec { action: FaultAction::Panic, prob: 1.0, max_hits: Some(1), seed: 3 },
        );
        let caught = std::panic::catch_unwind(|| act("fault_test_panic"));
        assert!(caught.is_err(), "Panic action must unwind");
        assert_eq!(hits("fault_test_panic"), 1);
        assert_eq!(check("fault_test_panic"), None, "max_hits consumed by the panic");
        clear("fault_test_panic");
    }

    #[test]
    fn delay_action_sleeps_then_continues() {
        configure(
            "fault_test_delay",
            FaultSpec {
                action: FaultAction::Delay(Duration::from_millis(20)),
                prob: 1.0,
                max_hits: Some(1),
                seed: 5,
            },
        );
        let t0 = Instant::now();
        assert_eq!(check("fault_test_delay"), None, "delay executes in place");
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert_eq!(hits("fault_test_delay"), 1);
        clear("fault_test_delay");
    }
}
