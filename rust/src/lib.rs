//! # fcs — Efficient Tensor Contraction via Fast Count Sketch
//!
//! A full reproduction of Cao & Liu (2021): the FCS sketching operator, the
//! CS / TS / HCS baselines, sketched CP decomposition (RTPM + ALS), tensor
//! regression network compression, and Kronecker-product / tensor-contraction
//! compression — implemented as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas)**: the count-sketch scatter kernel and spectral
//!   multiply, authored in `python/compile/kernels/`, lowered AOT.
//! * **Layer 2 (JAX)**: TRN forward/backward and batched FCS graphs,
//!   lowered to HLO text artifacts by `python/compile/aot.py`.
//! * **Layer 3 (this crate)**: the sketch library, CPD algorithms,
//!   compression pipelines, PJRT runtime, and the serving coordinator —
//!   Python is never on the request path.

// The serving stack's concurrency story is machine-checked (loom models,
// Miri, TSan — see EXPERIMENTS.md §Static analysis); both locks hold today
// with zero fallout and `scripts/lint_invariants.py` fails CI if the forbid
// ever disappears.
#![forbid(unsafe_code)]
#![deny(unreachable_pub)]

pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod cpd;
pub mod data;
pub mod fault;
pub mod fft;
pub mod hash;
pub mod linalg;
pub mod tensor;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sketch;
pub mod sync;
pub mod trn;
pub mod util;
