//! TRN training driver (Table 4 + the end-to-end example): owns parameter
//! state in Rust, feeds the AOT-compiled XLA train-step in a loop, and
//! evaluates accuracy with the infer artifact. Python never runs here.

use crate::data::fmnist::{FmnistLike, IMG};
use crate::hash::ModeHashes;
use crate::runtime::{RuntimeHandle, TensorArg};
use crate::util::prng::Rng;
use anyhow::{anyhow, Result};

/// Activation tensor shape fed to the TRL (mirrors python model.ACT_SHAPE).
pub const ACT_SHAPE: [usize; 3] = [7, 7, 32];
pub const ACT_DIM: usize = 7 * 7 * 32;
pub const NUM_CLASSES: usize = 10;

/// Which sketched head a TRN artifact uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrnMethod {
    Cs,
    Ts,
    Fcs,
}

impl TrnMethod {
    pub fn name(&self) -> &'static str {
        match self {
            TrnMethod::Cs => "cs",
            TrnMethod::Ts => "ts",
            TrnMethod::Fcs => "fcs",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cs" => Some(TrnMethod::Cs),
            "ts" => Some(TrnMethod::Ts),
            "fcs" => Some(TrnMethod::Fcs),
            _ => None,
        }
    }
}

/// The hash tables an artifact expects (per-mode + composite).
pub struct TrnTables {
    pub args: Vec<TensorArg>,
}

/// Build the eight table inputs for a (method, j, sketch_dim) artifact.
/// `j` is the per-mode hash length; `sketch_dim` the sketch length.
pub fn build_tables(rng: &mut Rng, method: TrnMethod, j: usize, sketch_dim: usize) -> TrnTables {
    let mh = ModeHashes::draw_uniform(rng, &ACT_SHAPE, j);
    let comp = mh.materialize_composite(); // col-major, buckets = Σ h_n
    let (hx, sx): (Vec<i32>, Vec<f32>) = match method {
        TrnMethod::Fcs => (
            comp.h.iter().map(|&v| v as i32).collect(),
            comp.s.iter().map(|&v| v as f32).collect(),
        ),
        TrnMethod::Ts => (
            comp.h.iter().map(|&v| (v as usize % j) as i32).collect(),
            comp.s.iter().map(|&v| v as f32).collect(),
        ),
        TrnMethod::Cs => {
            // independent long hash pair over vec(act)
            let pair = crate::hash::HashPair::draw(rng, ACT_DIM, sketch_dim);
            let t = pair.materialize();
            (
                t.h.iter().map(|&v| v as i32).collect(),
                t.s.iter().map(|&v| v as f32).collect(),
            )
        }
    };
    let mut args = Vec::with_capacity(8);
    for m in &mh.modes {
        args.push(TensorArg::i32(
            &[m.domain()],
            m.h.iter().map(|&v| v as i32).collect(),
        ));
        args.push(TensorArg::f32(
            &[m.domain()],
            m.s.iter().map(|&v| v as f32).collect(),
        ));
    }
    args.push(TensorArg::i32(&[ACT_DIM], hx));
    args.push(TensorArg::f32(&[ACT_DIM], sx));
    TrnTables { args }
}

/// Initialize parameters to match the artifact's first 9 inputs
/// (He-style init for conv kernels, small Gaussians for factors).
pub fn init_params(rng: &mut Rng, shapes: &[(Vec<usize>, String)]) -> Vec<TensorArg> {
    assert!(shapes.len() >= 9, "artifact should begin with 9 params");
    shapes[..9]
        .iter()
        .map(|(shape, _)| {
            let n: usize = shape.iter().product();
            let fan_in: usize = if shape.len() == 4 {
                shape[0] * shape[1] * shape[2] // HWIO conv kernel
            } else {
                shape.first().copied().unwrap_or(1)
            };
            let std = (2.0 / fan_in.max(1) as f64).sqrt() * 0.5;
            let data: Vec<f32> = (0..n).map(|_| (rng.normal() * std) as f32).collect();
            TensorArg::f32(shape, data)
        })
        .collect()
}

/// Configuration for one training run.
#[derive(Debug, Clone)]
pub struct TrnRunConfig {
    pub method: TrnMethod,
    /// CR tag as used in artifact names, e.g. "20", "33p33".
    pub cr_tag: String,
    pub steps: usize,
    pub lr: f32,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
    /// Print loss every `log_every` steps (0 = silent).
    pub log_every: usize,
}

/// Result of a run.
#[derive(Debug, Clone)]
pub struct TrnRunResult {
    pub method: &'static str,
    pub cr: f64,
    pub losses: Vec<f64>,
    pub accuracy: f64,
    pub train_secs: f64,
}

/// Train a sketched TRN end-to-end through the XLA artifacts and report
/// test accuracy.
pub fn train_and_eval(rt: &RuntimeHandle, cfg: &TrnRunConfig) -> Result<TrnRunResult> {
    let train_name = format!("trn_train_{}_cr{}", cfg.method.name(), cfg.cr_tag);
    let infer_name = format!("trn_infer_{}_cr{}", cfg.method.name(), cfg.cr_tag);
    let entry = rt
        .manifest()
        .entries
        .get(&train_name)
        .ok_or_else(|| anyhow!("artifact {train_name} missing — run `make artifacts`"))?
        .clone();
    let batch = entry.meta_usize("batch").unwrap_or(64);
    let j = entry
        .meta_usize("j")
        .ok_or_else(|| anyhow!("{train_name}: missing j"))?;
    let sketch_dim = entry.meta_usize("sketch_dim").unwrap_or(j);
    let cr = entry.meta_f64("cr").unwrap_or(0.0);

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut params = init_params(&mut rng, &entry.inputs);
    let tables = build_tables(&mut rng, cfg.method, j, sketch_dim);
    let train = FmnistLike::generate(&mut rng, cfg.train_size);
    let test = FmnistLike::generate(&mut rng, cfg.test_size);

    rt.warm(&train_name)?;
    let sw = crate::util::timing::Stopwatch::start();
    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let (x, y) = train.batch(step * batch, batch);
        let mut args = params.clone();
        args.push(TensorArg::f32(&[batch, IMG, IMG, 1], x));
        args.push(TensorArg::i32(&[batch], y));
        args.push(TensorArg::scalar_f32(cfg.lr));
        args.extend(tables.args.iter().cloned());
        let outs = rt.run(&train_name, args)?;
        // outputs: 9 updated params + loss
        if outs.len() != 10 {
            return Err(anyhow!("{train_name}: expected 10 outputs, got {}", outs.len()));
        }
        let loss = outs[9].data[0] as f64;
        losses.push(loss);
        params = outs[..9]
            .iter()
            .map(|t| TensorArg::f32(&t.shape, t.data.clone()))
            .collect();
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            log::info!("{} step {step}: loss {loss:.4}", cfg.method.name());
            println!("  [{}] step {step:4}: loss {loss:.4}", cfg.method.name());
        }
    }
    let train_secs = sw.elapsed_secs();

    // Evaluation.
    rt.warm(&infer_name)?;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let nbatches = cfg.test_size / batch;
    for bi in 0..nbatches.max(1) {
        let (x, y) = test.batch(bi * batch, batch);
        let mut args = params.clone();
        args.push(TensorArg::f32(&[batch, IMG, IMG, 1], x));
        args.extend(tables.args.iter().cloned());
        let outs = rt.run(&infer_name, args)?;
        let logits = &outs[0];
        for row in 0..batch {
            let pred = (0..NUM_CLASSES)
                .max_by(|&a, &b| {
                    // total_cmp: a NaN logit (diverged training) must not
                    // panic the evaluation loop.
                    logits.data[row * NUM_CLASSES + a]
                        .total_cmp(&logits.data[row * NUM_CLASSES + b])
                })
                .unwrap();
            if pred as i32 == y[row] {
                correct += 1;
            }
            seen += 1;
        }
    }
    Ok(TrnRunResult {
        method: cfg.method.name(),
        cr,
        losses,
        accuracy: correct as f64 / seen as f64,
        train_secs,
    })
}

/// All CR tags present in the manifest for a given method, sorted ascending
/// by CR value.
pub fn available_cr_tags(rt: &RuntimeHandle, method: TrnMethod) -> Vec<(f64, String)> {
    let prefix = format!("trn_train_{}_cr", method.name());
    let mut out: Vec<(f64, String)> = rt
        .manifest()
        .entries
        .iter()
        .filter_map(|(name, e)| {
            name.strip_prefix(&prefix)
                .map(|tag| (e.meta_f64("cr").unwrap_or(0.0), tag.to_string()))
        })
        .collect();
    out.sort_by(|a, b| a.0.total_cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_have_right_shapes_and_ranges() {
        let mut rng = Rng::seed_from_u64(1);
        for method in [TrnMethod::Cs, TrnMethod::Ts, TrnMethod::Fcs] {
            let j = 11;
            let sdim = match method {
                TrnMethod::Fcs => 3 * j - 2,
                _ => j,
            };
            let t = build_tables(&mut rng, method, j, sdim);
            assert_eq!(t.args.len(), 8);
            // composite bucket range check
            let TensorArg::I32 { data, .. } = &t.args[6] else { panic!() };
            assert_eq!(data.len(), ACT_DIM);
            assert!(data.iter().all(|&v| (v as usize) < sdim), "{method:?}");
            let TensorArg::F32 { data: s, .. } = &t.args[7] else { panic!() };
            assert!(s.iter().all(|&v| v == 1.0 || v == -1.0));
        }
    }

    #[test]
    fn ts_composite_is_fcs_mod_j() {
        let mut rng1 = Rng::seed_from_u64(5);
        let mut rng2 = Rng::seed_from_u64(5);
        let j = 9;
        let f = build_tables(&mut rng1, TrnMethod::Fcs, j, 3 * j - 2);
        let t = build_tables(&mut rng2, TrnMethod::Ts, j, j);
        let TensorArg::I32 { data: hf, .. } = &f.args[6] else { panic!() };
        let TensorArg::I32 { data: ht, .. } = &t.args[6] else { panic!() };
        for (a, b) in hf.iter().zip(ht) {
            assert_eq!((a % j as i32), *b);
        }
    }

    #[test]
    fn init_params_match_shapes() {
        let mut rng = Rng::seed_from_u64(2);
        let shapes: Vec<(Vec<usize>, String)> = vec![
            (vec![3, 3, 1, 16], "float32".into()),
            (vec![16], "float32".into()),
            (vec![3, 3, 16, 32], "float32".into()),
            (vec![32], "float32".into()),
            (vec![7, 5], "float32".into()),
            (vec![7, 5], "float32".into()),
            (vec![32, 5], "float32".into()),
            (vec![10, 5], "float32".into()),
            (vec![10], "float32".into()),
        ];
        let params = init_params(&mut rng, &shapes);
        assert_eq!(params.len(), 9);
        for (p, (s, _)) in params.iter().zip(&shapes) {
            assert_eq!(p.shape(), s.as_slice());
        }
    }
}
