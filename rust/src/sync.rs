//! Concurrency-primitive switchboard for the lock-free serving stack.
//!
//! Every lock-free component in the crate (`obs::registry`, `obs::trace`,
//! `coordinator::stats`, `coordinator::retry`, `fault`, the `fft` plan-cache
//! counters, and the service's queue-depth/stop-latch atomics) imports its
//! primitives from here instead of `std::sync`:
//!
//! * Normal builds re-export `std::sync` — zero-cost, identical codegen.
//! * Under `RUSTFLAGS="--cfg loom"` the same names resolve to the vendored
//!   loom facade (`rust/vendor/loom`), whose atomics and mutexes insert
//!   scheduling points so `tests/loom_models.rs` can replay each component's
//!   critical interleavings across many explored schedules. On a networked
//!   host the facade can be swapped for the real `loom = "0.7"` model checker
//!   without touching this module's consumers.
//!
//! Only the types the crate actually uses are re-exported; additions should
//! land in both arms so the loom build never drifts from the std one.

#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{
        AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering,
    };
}

#[cfg(loom)]
pub use loom::sync::{Arc, Mutex, MutexGuard, OnceLock};

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{
        AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering,
    };
}
