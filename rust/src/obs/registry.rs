//! Lock-free metric primitives and the process-wide registry.
//!
//! Counters, gauges, and log₂-bucket histograms are plain atomics behind
//! `Arc`s. Instruments are registered once (at startup, or lazily on first
//! use of [`crate::obs::metrics`]) and recorded through shared handles, so
//! a hot-path update is a single relaxed `fetch_add` — no locks, no
//! allocation. The registry's internal `Mutex<Vec<Entry>>` is touched only
//! at registration and render time, never while recording.
//!
//! Histogram buckets are powers of two: finite upper bounds `2^0 .. 2^26`
//! plus `+Inf`. That covers one nanosecond-to-67ms span for stage timers
//! and one microsecond-to-67s span for latencies with a fixed 28-slot
//! array, which keeps `observe` branch-free apart from the leading-zeros
//! bucket index.

use crate::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use crate::sync::{Arc, Mutex, OnceLock};

/// Number of histogram buckets: 27 finite power-of-two bounds plus `+Inf`.
pub const HIST_BUCKETS: usize = 28;

/// Upper bound (`le`) of finite bucket `i`, i.e. `2^i` for `i < 27`.
#[inline]
pub fn bucket_bound(i: usize) -> u64 {
    1u64 << i
}

/// Index of the first bucket whose upper bound is `>= v`.
///
/// `v = 0` and `v = 1` land in bucket 0 (`le = 1`); values above `2^26`
/// land in the `+Inf` bucket (index 27).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    // ceil(log2(v)) via leading zeros of v-1; saturating_sub keeps v=0 sane.
    ((64 - v.saturating_sub(1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self { value: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        // ordering: Relaxed — independent monotone tally; scrapes tolerate lag.
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        // ordering: Relaxed — independent monotone tally; scrapes tolerate lag.
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        // ordering: Relaxed — point-in-time read; no cross-metric consistency claimed.
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depths, in-flight counts).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self { value: AtomicI64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: i64) {
        // ordering: Relaxed — last-writer-wins snapshot value; no ordering consumers.
        self.value.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        // ordering: Relaxed — atomic RMW keeps the sum exact; publication order irrelevant.
        self.value.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn dec(&self) {
        self.add(-1);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        // ordering: Relaxed — point-in-time read; no cross-metric consistency claimed.
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log₂ histogram over `u64` observations.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        // ordering: Relaxed — bucket and sum are each exact under RMW; a scrape
        // between the two updates sees count/sum skewed by one observation,
        // which Prometheus semantics explicitly permit.
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        // ordering: Relaxed — see bucket update above.
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Non-cumulative per-bucket counts (index 27 is `+Inf`).
    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        // ordering: Relaxed — render-time sample; buckets are independently exact.
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    pub fn sum(&self) -> u64 {
        // ordering: Relaxed — render-time sample.
        self.sum.load(Ordering::Relaxed)
    }

    pub fn count(&self) -> u64 {
        self.bucket_counts().iter().sum()
    }
}

/// A registered instrument, tagged with its exposition metadata.
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// One registry row: family name, help text, optional label set, instrument.
///
/// `labels` is the rendered label body without braces (e.g.
/// `op="sketch_cp"`), or `""` for an unlabeled series. Entries sharing a
/// family `name` must be registered adjacently and with the same metric
/// kind — the renderer emits `# HELP`/`# TYPE` once per family in
/// registration order.
pub struct Entry {
    pub name: &'static str,
    pub help: &'static str,
    pub labels: &'static str,
    pub metric: Metric,
}

/// Metric registry: registration + render-time enumeration.
///
/// Independent instances can be created for tests; production code uses
/// [`global`].
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Self {
        Self { entries: Mutex::new(Vec::new()) }
    }

    pub fn counter(&self, name: &'static str, help: &'static str, labels: &'static str) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.entries.lock().unwrap().push(Entry {
            name,
            help,
            labels,
            metric: Metric::Counter(c.clone()),
        });
        c
    }

    pub fn gauge(&self, name: &'static str, help: &'static str, labels: &'static str) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.entries.lock().unwrap().push(Entry {
            name,
            help,
            labels,
            metric: Metric::Gauge(g.clone()),
        });
        g
    }

    pub fn histogram(&self, name: &'static str, help: &'static str, labels: &'static str) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.entries.lock().unwrap().push(Entry {
            name,
            help,
            labels,
            metric: Metric::Histogram(h.clone()),
        });
        h
    }

    /// Run `f` over the registered entries (render-time only).
    pub fn with_entries<R>(&self, f: impl FnOnce(&[Entry]) -> R) -> R {
        let g = self.entries.lock().unwrap();
        f(&g)
    }
}

/// The process-wide registry backing [`crate::obs::metrics`].
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 26), 26);
        assert_eq!(bucket_index((1 << 26) + 1), 27);
        assert_eq!(bucket_index(u64::MAX), 27);
        // Every finite bound lands in its own bucket, one past it spills over.
        for i in 0..27 {
            assert_eq!(bucket_index(bucket_bound(i)), i);
        }
    }

    #[test]
    fn histogram_counts_and_sum() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 1000, 1 << 30] {
            h.observe(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 1 + 2 + 3 + 1000 + (1 << 30));
        let b = h.bucket_counts();
        assert_eq!(b[0], 2); // 0, 1
        assert_eq!(b[1], 1); // 2
        assert_eq!(b[2], 1); // 3
        assert_eq!(b[10], 1); // 1000 <= 1024
        assert_eq!(b[27], 1); // 2^30 -> +Inf
    }

    #[test]
    fn gauge_tracks_depth() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }
}
