//! Minimal HTTP exporter thread: `GET /metrics`, `GET /healthz`, and
//! `GET /traces` over a blocking `std::net::TcpListener`.
//!
//! This is deliberately not a web server — one accept loop, one request
//! per connection, `Connection: close`. It is the seed of the ROADMAP's
//! async gateway front-end: the scrape path a Prometheus agent needs today,
//! with the real gateway free to absorb it later.

use super::export::render_global;
use crate::fault::FaultAction;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Arc;
use std::time::{Duration, Instant};

/// Spans returned by `GET /traces`.
const TRACE_DUMP_N: usize = 64;

/// Request-line cap. A peer that sends this much without a newline is not a
/// scraper — the connection gets a 400 instead of unbounded buffering.
const MAX_REQUEST_LINE: usize = 1024;

/// Hard wall-clock bound on reading one request line. The per-`read`
/// timeout alone would let a slowloris peer trickle one byte per 499ms
/// forever; this caps the *sum*.
const READ_DEADLINE: Duration = Duration::from_secs(2);

/// Handle to a running exporter thread. Dropping it (or calling
/// [`Exporter::shutdown`]) stops the accept loop and joins the thread.
pub struct Exporter {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Exporter {
    /// Bind `addr` (e.g. `"127.0.0.1:9898"`, or port `0` for an ephemeral
    /// port) and start serving on a background thread named `fcs-metrics`.
    pub fn bind(addr: &str) -> std::io::Result<Exporter> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fcs-metrics".into())
            .spawn(move || accept_loop(listener, stop2))
            .expect("spawn fcs-metrics thread");
        Ok(Exporter { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the serving thread. Idempotent.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            // ordering: SeqCst — must be globally visible before the wakeup
            // connection below lands, or the accept loop could consume the
            // wakeup, miss the flag, and block on accept forever.
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the (blocking) accept with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
        }
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        // ordering: SeqCst — pairs with the shutdown store; the accept that
        // delivered the wakeup connection must observe the flag set.
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if let Ok(stream) = conn {
            // Serve inline: scrapes are rare and cheap, and a single-threaded
            // loop cannot be wedged open by a slow peer thanks to the timeouts.
            let _ = serve_one(stream);
        }
    }
}

/// Outcome of parsing one request line.
enum RequestLine {
    Get(String),
    NotGet,
    Malformed,
}

/// Strict parse of `"GET /path HTTP/x.y"`: exactly three tokens, a
/// `/`-rooted path, an `HTTP/` version. Anything else — binary garbage, a
/// proxy CONNECT probe, a request smuggled onto extra tokens — is
/// `Malformed` and answered 400 without touching the render paths.
fn parse_request_line(line: &[u8]) -> RequestLine {
    let Ok(text) = std::str::from_utf8(line) else {
        return RequestLine::Malformed;
    };
    let mut tokens = text.trim_end_matches('\r').split_whitespace();
    let (Some(method), Some(path), Some(version), None) =
        (tokens.next(), tokens.next(), tokens.next(), tokens.next())
    else {
        return RequestLine::Malformed;
    };
    if !path.starts_with('/') || !version.starts_with("HTTP/") {
        return RequestLine::Malformed;
    }
    if method != "GET" {
        return RequestLine::NotGet;
    }
    RequestLine::Get(path.to_string())
}

fn serve_one(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // Read until the first LF, bounded in both bytes (MAX_REQUEST_LINE) and
    // wall clock (READ_DEADLINE). A single `read` is not enough — a
    // legitimate client's request line may arrive in several segments — but
    // unbounded buffering would hand a hostile peer our memory and this
    // (single-threaded) accept loop's time.
    let started = Instant::now();
    let mut buf = [0u8; MAX_REQUEST_LINE];
    let mut n = 0usize;
    let line_end: Option<usize> = loop {
        if let Some(pos) = buf[..n].iter().position(|&b| b == b'\n') {
            break Some(pos);
        }
        if n == buf.len() || started.elapsed() >= READ_DEADLINE {
            break None;
        }
        match stream.read(&mut buf[n..]) {
            Ok(0) | Err(_) => break None,
            Ok(m) => n += m,
        }
    };

    let (status, content_type, body) = match line_end.map(|end| parse_request_line(&buf[..end])) {
        None | Some(RequestLine::Malformed) => (
            "400 Bad Request",
            "text/plain; charset=utf-8",
            "bad request\n".to_string(),
        ),
        Some(RequestLine::NotGet) => (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "method not allowed\n".to_string(),
        ),
        Some(RequestLine::Get(path)) => route(&path),
    };

    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

fn route(path: &str) -> (&'static str, &'static str, String) {
    // Failpoint: Error maps onto a 500 (the exporter's local failure path).
    // This site runs on the accept-loop thread, so schedules must stick to
    // Error/Delay — an injected Panic would kill the exporter itself.
    if matches!(crate::fault::check("exporter"), Some(FaultAction::Error)) {
        return (
            "500 Internal Server Error",
            "text/plain; charset=utf-8",
            "injected fault\n".to_string(),
        );
    }
    match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            render_global(),
        ),
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        "/traces" => (
            "200 OK",
            "application/json; charset=utf-8",
            super::trace::global().dump_json(TRACE_DUMP_N),
        ),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found\n".to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        let req = format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n");
        stream.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_healthz_traces_and_404() {
        let mut exporter = Exporter::bind("127.0.0.1:0").unwrap();
        let addr = exporter.local_addr();

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let metrics = get(addr, "/metrics");
        assert!(metrics.contains("text/plain; version=0.0.4"), "{metrics}");
        assert!(metrics.contains("# TYPE fcs_plan_cache_hits_total counter"));
        assert!(metrics.contains("# TYPE fcs_flight_width histogram"));

        let traces = get(addr, "/traces");
        assert!(traces.contains("application/json"), "{traces}");
        assert!(traces.contains("\"spans\":["), "{traces}");

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404 Not Found\r\n"), "{missing}");

        exporter.shutdown();
        // Shut down: new connections must not be served.
        assert!(
            TcpStream::connect(addr).map(|mut s| {
                let _ = s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
                let mut out = String::new();
                s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
                s.read_to_string(&mut out).unwrap_or(0) == 0
            }).unwrap_or(true),
            "exporter served a request after shutdown"
        );
    }

    fn send_raw(addr: SocketAddr, payload: &[u8]) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(payload).unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn hardened_against_malformed_and_slow_input() {
        let mut exporter = Exporter::bind("127.0.0.1:0").unwrap();
        let addr = exporter.local_addr();

        // Binary garbage on the request line: 400, not a panic or a 404
        // from a lossy-decoded phantom path.
        let garbage = send_raw(addr, b"\x00\xffBLARG\r\n");
        assert!(garbage.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{garbage}");

        // A request line at exactly the cap with no newline: the server
        // must refuse rather than buffer forever. (Exactly MAX_REQUEST_LINE
        // bytes, so nothing is left unread to trigger a connection reset.)
        let oversize = send_raw(addr, &[b'A'; MAX_REQUEST_LINE]);
        assert!(oversize.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{oversize}");

        // Non-GET methods are refused explicitly.
        let post = send_raw(addr, b"POST /metrics HTTP/1.1\r\n\r\n");
        assert!(post.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"), "{post}");

        // A request line split across TCP segments must still parse — the
        // pre-hardening single-read parser would have answered 400 here.
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /heal").unwrap();
        std::thread::sleep(Duration::from_millis(50));
        stream.write_all(b"thz HTTP/1.1\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "{out}");

        // The loop is still healthy after the abuse.
        let health = get(addr, "/healthz");
        assert!(health.ends_with("ok\n"), "{health}");

        exporter.shutdown();
        assert!(
            TcpStream::connect(addr).map(|mut s| {
                let _ = s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
                let mut out = String::new();
                s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
                s.read_to_string(&mut out).unwrap_or(0) == 0
            }).unwrap_or(true),
            "exporter served a request after shutdown"
        );
    }
}
