//! Minimal HTTP exporter thread: `GET /metrics`, `GET /healthz`, and
//! `GET /traces` over a blocking `std::net::TcpListener`.
//!
//! This is deliberately not a web server — one accept loop, one request
//! per connection, `Connection: close`. It is the seed of the ROADMAP's
//! async gateway front-end: the scrape path a Prometheus agent needs today,
//! with the real gateway free to absorb it later.

use super::export::render_global;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Spans returned by `GET /traces`.
const TRACE_DUMP_N: usize = 64;

/// Handle to a running exporter thread. Dropping it (or calling
/// [`Exporter::shutdown`]) stops the accept loop and joins the thread.
pub struct Exporter {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Exporter {
    /// Bind `addr` (e.g. `"127.0.0.1:9898"`, or port `0` for an ephemeral
    /// port) and start serving on a background thread named `fcs-metrics`.
    pub fn bind(addr: &str) -> std::io::Result<Exporter> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fcs-metrics".into())
            .spawn(move || accept_loop(listener, stop2))
            .expect("spawn fcs-metrics thread");
        Ok(Exporter { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the serving thread. Idempotent.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the (blocking) accept with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
        }
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if let Ok(stream) = conn {
            // Serve inline: scrapes are rare and cheap, and a single-threaded
            // loop cannot be wedged open by a slow peer thanks to the timeouts.
            let _ = serve_one(stream);
        }
    }
}

fn serve_one(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let head = String::from_utf8_lossy(&buf[..n]);
    // "GET /path HTTP/1.1" — the path is the second whitespace token.
    let path = head.split_whitespace().nth(1).unwrap_or("/");

    let (status, content_type, body) = match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            render_global(),
        ),
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        "/traces" => (
            "200 OK",
            "application/json; charset=utf-8",
            super::trace::global().dump_json(TRACE_DUMP_N),
        ),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found\n".to_string(),
        ),
    };

    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        let req = format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n");
        stream.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_healthz_traces_and_404() {
        let mut exporter = Exporter::bind("127.0.0.1:0").unwrap();
        let addr = exporter.local_addr();

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let metrics = get(addr, "/metrics");
        assert!(metrics.contains("text/plain; version=0.0.4"), "{metrics}");
        assert!(metrics.contains("# TYPE fcs_plan_cache_hits_total counter"));
        assert!(metrics.contains("# TYPE fcs_flight_width histogram"));

        let traces = get(addr, "/traces");
        assert!(traces.contains("application/json"), "{traces}");
        assert!(traces.contains("\"spans\":["), "{traces}");

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404 Not Found\r\n"), "{missing}");

        exporter.shutdown();
        // Shut down: new connections must not be served.
        assert!(
            TcpStream::connect(addr).map(|mut s| {
                let _ = s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
                let mut out = String::new();
                s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
                s.read_to_string(&mut out).unwrap_or(0) == 0
            }).unwrap_or(true),
            "exporter served a request after shutdown"
        );
    }
}
