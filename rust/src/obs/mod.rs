//! Crate-wide observability: zero-alloc metrics registry, Prometheus
//! text exporter, and per-request flight tracing.
//!
//! Layout:
//! * [`registry`] — atomic counters / gauges / log₂ histograms behind a
//!   registration-order registry; recording is a relaxed `fetch_add`.
//! * [`export`] — Prometheus text exposition (format 0.0.4), golden-tested.
//! * [`exporter`] — `std::net::TcpListener` thread serving `GET /metrics`,
//!   `GET /healthz`, and `GET /traces`.
//! * [`trace`] — bounded per-worker ring buffers of
//!   `(req_id, submit → queue → flight-start → reply)` spans.
//!
//! All crate instruments live in one [`CrateMetrics`] struct built lazily
//! against the global registry; call [`metrics`] for the `&'static`
//! handles. Metric names are a **stable API** once scraped — the protocol
//! is recorded in EXPERIMENTS.md §Observability.

pub mod export;
pub mod exporter;
pub mod registry;
pub mod trace;

use registry::{Counter, Gauge, Histogram};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, OnceLock};
use std::time::Instant;

/// Known coordinator operations, in registration order. `"other"` is the
/// catch-all for names outside the coordinator's `Request::op_name` set.
pub const OPS: [&str; 7] = [
    "cs_vec",
    "sketch_dense",
    "sketch_cp",
    "inner_estimate",
    "sketch_shard",
    "merge_shards",
    "other",
];

const OP_LABELS: [&str; 7] = [
    "op=\"cs_vec\"",
    "op=\"sketch_dense\"",
    "op=\"sketch_cp\"",
    "op=\"inner_estimate\"",
    "op=\"sketch_shard\"",
    "op=\"merge_shards\"",
    "op=\"other\"",
];

/// SpectralDriver stages, in `fcs_stage_ns` label order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Pack = 0,
    Fft = 1,
    Fold = 2,
    Inverse = 3,
}

pub const STAGES: [&str; 4] = ["pack", "fft", "fold", "inverse"];

const STAGE_LABELS: [&str; 4] = [
    "stage=\"pack\"",
    "stage=\"fft\"",
    "stage=\"fold\"",
    "stage=\"inverse\"",
];

/// Deadline-shed stages, in `fcs_deadline_shed_total` label order (indexed
/// by `coordinator::stats::ShedStage as usize`).
pub const SHED_STAGES: [&str; 3] = ["submit", "dequeue", "flight"];

const SHED_STAGE_LABELS: [&str; 3] = ["stage=\"submit\"", "stage=\"dequeue\"", "stage=\"flight\""];

/// Failpoint sites with a dedicated `fcs_faults_injected_total` series, in
/// label order. `"other"` is the catch-all for sites added without a label.
pub const FAULT_SITES: [&str; 7] = [
    "worker_loop",
    "worker_job",
    "spectral_driver",
    "shard_scatter",
    "merge_shards",
    "exporter",
    "other",
];

const FAULT_SITE_LABELS: [&str; 7] = [
    "site=\"worker_loop\"",
    "site=\"worker_job\"",
    "site=\"spectral_driver\"",
    "site=\"shard_scatter\"",
    "site=\"merge_shards\"",
    "site=\"exporter\"",
    "site=\"other\"",
];

/// Per-operation instruments (one set per entry of [`OPS`]).
pub struct OpMetrics {
    /// `fcs_requests_completed_total{op=...}`
    pub completed: Arc<Counter>,
    /// `fcs_request_latency_us{op=...}` — submit → reply.
    pub latency_us: Arc<Histogram>,
    /// `fcs_queue_wait_us{op=...}` — submit → flight start.
    pub queue_wait_us: Arc<Histogram>,
    /// `fcs_exec_us{op=...}` — flight start → reply.
    pub exec_us: Arc<Histogram>,
}

/// Every instrument the crate records into, registered once against the
/// global registry. Obtain via [`metrics`]; handles are `&'static`.
pub struct CrateMetrics {
    /// `fcs_plan_cache_hits_total{cache="forward"|"real"}`
    pub plan_cache_hits_forward: Arc<Counter>,
    pub plan_cache_hits_real: Arc<Counter>,
    /// `fcs_plan_cache_misses_total{cache="forward"|"real"}`
    pub plan_cache_misses_forward: Arc<Counter>,
    pub plan_cache_misses_real: Arc<Counter>,

    ops: [OpMetrics; 7],

    /// `fcs_flight_width` — jobs per executed flight (1 = serial).
    pub flight_width: Arc<Histogram>,
    /// `fcs_flight_exec_us` — wall time per flight.
    pub flight_exec_us: Arc<Histogram>,

    /// `fcs_queue_depth{queue="worker"|"batcher"}`
    pub queue_depth_worker: Arc<Gauge>,
    pub queue_depth_batcher: Arc<Gauge>,

    /// `fcs_rejected_busy_total` — submits refused on a full queue.
    pub rejected_busy: Arc<Counter>,
    /// `fcs_poisoned_jobs_total` — jobs that panicked under `catch_unwind`.
    pub poisoned_jobs: Arc<Counter>,
    /// `fcs_fused_flight_aborts_total` — fused flights that fell back to
    /// the per-job serial retry after an unwind.
    pub fused_flight_aborts: Arc<Counter>,
    /// `fcs_batches_total` / `fcs_batched_jobs_total` — cs_vec batcher.
    pub batches: Arc<Counter>,
    pub batched_jobs: Arc<Counter>,

    /// `fcs_stage_ns{stage=...}` — sampled SpectralDriver stage timings.
    pub stage_ns: [Arc<Histogram>; 4],

    /// `fcs_shard_width` — slab elements per `sketch_shard` request.
    pub shard_width: Arc<Histogram>,
    /// `fcs_merge_depth` — pairwise tree-reduce levels per `merge_shards`.
    pub merge_depth: Arc<Histogram>,

    /// `fcs_estimator_queries_total{kind="t_mode"|"deflate"}`
    pub estimator_t_mode: Arc<Counter>,
    pub estimator_deflate: Arc<Counter>,

    /// `fcs_traces_recorded_total`
    pub traces_recorded: Arc<Counter>,

    /// `fcs_deadline_shed_total{stage="submit"|"dequeue"|"flight"}` — jobs
    /// refused or shed because their deadline expired (or the admission
    /// controller's queue-wait estimate exceeded the remaining budget).
    /// Indexed by `coordinator::stats::ShedStage as usize`.
    pub deadline_shed: [Arc<Counter>; 3],
    /// `fcs_retries_total` — client-handle retry attempts actually slept
    /// for and re-submitted.
    pub retries: Arc<Counter>,
    /// `fcs_retry_budget_exhausted_total` — retries refused because the
    /// shared retry budget was broke (overload anti-amplification).
    pub retry_budget_exhausted: Arc<Counter>,
    /// `fcs_worker_respawns_total` — dead (panicked) worker threads
    /// replaced by the pool supervisor.
    pub worker_respawns: Arc<Counter>,
    /// `fcs_faults_injected_total{site=...}` — failpoint firings. Always
    /// registered (stable names); stays zero unless the `failpoints`
    /// feature is compiled in and a schedule is armed.
    faults_injected: [Arc<Counter>; 7],
}

impl CrateMetrics {
    fn register(reg: &registry::Registry) -> CrateMetrics {
        // Entries of one family must be registered adjacently (the renderer
        // emits HELP/TYPE on family-name change), so build family by family.
        let plan_cache_hits_forward = reg.counter(
            "fcs_plan_cache_hits_total",
            "FFT plan cache hits, by cache.",
            "cache=\"forward\"",
        );
        let plan_cache_hits_real = reg.counter(
            "fcs_plan_cache_hits_total",
            "FFT plan cache hits, by cache.",
            "cache=\"real\"",
        );
        let plan_cache_misses_forward = reg.counter(
            "fcs_plan_cache_misses_total",
            "FFT plan cache misses (plan builds), by cache.",
            "cache=\"forward\"",
        );
        let plan_cache_misses_real = reg.counter(
            "fcs_plan_cache_misses_total",
            "FFT plan cache misses (plan builds), by cache.",
            "cache=\"real\"",
        );

        let completed: [Arc<Counter>; 7] = std::array::from_fn(|i| {
            reg.counter(
                "fcs_requests_completed_total",
                "Coordinator requests answered, by operation.",
                OP_LABELS[i],
            )
        });
        let latency: [Arc<Histogram>; 7] = std::array::from_fn(|i| {
            reg.histogram(
                "fcs_request_latency_us",
                "Submit-to-reply latency in microseconds, by operation.",
                OP_LABELS[i],
            )
        });
        let queue_wait: [Arc<Histogram>; 7] = std::array::from_fn(|i| {
            reg.histogram(
                "fcs_queue_wait_us",
                "Submit-to-flight-start wait in microseconds, by operation.",
                OP_LABELS[i],
            )
        });
        let exec: [Arc<Histogram>; 7] = std::array::from_fn(|i| {
            reg.histogram(
                "fcs_exec_us",
                "Flight-start-to-reply execution time in microseconds, by operation.",
                OP_LABELS[i],
            )
        });
        let ops: [OpMetrics; 7] = std::array::from_fn(|i| OpMetrics {
            completed: completed[i].clone(),
            latency_us: latency[i].clone(),
            queue_wait_us: queue_wait[i].clone(),
            exec_us: exec[i].clone(),
        });

        let flight_width = reg.histogram(
            "fcs_flight_width",
            "Jobs per executed worker flight (1 = serial).",
            "",
        );
        let flight_exec_us = reg.histogram(
            "fcs_flight_exec_us",
            "Wall time per worker flight in microseconds.",
            "",
        );

        let queue_depth_worker = reg.gauge(
            "fcs_queue_depth",
            "Jobs currently enqueued, by queue.",
            "queue=\"worker\"",
        );
        let queue_depth_batcher = reg.gauge(
            "fcs_queue_depth",
            "Jobs currently enqueued, by queue.",
            "queue=\"batcher\"",
        );

        let rejected_busy = reg.counter(
            "fcs_rejected_busy_total",
            "Submissions rejected because a bounded queue was full.",
            "",
        );
        let poisoned_jobs = reg.counter(
            "fcs_poisoned_jobs_total",
            "Jobs that panicked inside a worker (caught; reply was an error).",
            "",
        );
        let fused_flight_aborts = reg.counter(
            "fcs_fused_flight_aborts_total",
            "Fused flights that unwound and fell back to per-job serial retry.",
            "",
        );
        let batches = reg.counter(
            "fcs_batches_total",
            "cs_vec batches flushed by the batcher.",
            "",
        );
        let batched_jobs = reg.counter(
            "fcs_batched_jobs_total",
            "cs_vec jobs flushed inside batches.",
            "",
        );

        let stage_ns: [Arc<Histogram>; 4] = std::array::from_fn(|i| {
            reg.histogram(
                "fcs_stage_ns",
                "Sampled SpectralDriver stage time in nanoseconds, by stage.",
                STAGE_LABELS[i],
            )
        });

        let shard_width = reg.histogram(
            "fcs_shard_width",
            "Slab elements per sketch_shard request.",
            "",
        );
        let merge_depth = reg.histogram(
            "fcs_merge_depth",
            "Pairwise tree-reduce levels per merge_shards request.",
            "",
        );

        let estimator_t_mode = reg.counter(
            "fcs_estimator_queries_total",
            "Estimator spectral queries, by kind.",
            "kind=\"t_mode\"",
        );
        let estimator_deflate = reg.counter(
            "fcs_estimator_queries_total",
            "Estimator spectral queries, by kind.",
            "kind=\"deflate\"",
        );

        let traces_recorded = reg.counter(
            "fcs_traces_recorded_total",
            "Request trace spans recorded into the ring buffers.",
            "",
        );

        let deadline_shed: [Arc<Counter>; 3] = std::array::from_fn(|i| {
            reg.counter(
                "fcs_deadline_shed_total",
                "Jobs refused or shed on an expired/unmeetable deadline, by stage.",
                SHED_STAGE_LABELS[i],
            )
        });
        let retries = reg.counter(
            "fcs_retries_total",
            "Client-handle retry attempts performed (budgeted, jittered).",
            "",
        );
        let retry_budget_exhausted = reg.counter(
            "fcs_retry_budget_exhausted_total",
            "Retries refused because the shared retry budget was exhausted.",
            "",
        );
        let worker_respawns = reg.counter(
            "fcs_worker_respawns_total",
            "Dead worker threads replaced by the pool supervisor.",
            "",
        );
        let faults_injected: [Arc<Counter>; 7] = std::array::from_fn(|i| {
            reg.counter(
                "fcs_faults_injected_total",
                "Failpoint firings (failpoints feature only), by site.",
                FAULT_SITE_LABELS[i],
            )
        });

        CrateMetrics {
            plan_cache_hits_forward,
            plan_cache_hits_real,
            plan_cache_misses_forward,
            plan_cache_misses_real,
            ops,
            flight_width,
            flight_exec_us,
            queue_depth_worker,
            queue_depth_batcher,
            rejected_busy,
            poisoned_jobs,
            fused_flight_aborts,
            batches,
            batched_jobs,
            stage_ns,
            shard_width,
            merge_depth,
            estimator_t_mode,
            estimator_deflate,
            traces_recorded,
            deadline_shed,
            retries,
            retry_budget_exhausted,
            worker_respawns,
            faults_injected,
        }
    }

    /// Per-op instruments for `name` (`Request::op_name`); unknown names
    /// fall into the `"other"` series rather than allocating a new one.
    #[inline]
    pub fn op(&self, name: &str) -> &OpMetrics {
        let i = OPS.iter().position(|&o| o == name).unwrap_or(OPS.len() - 1);
        &self.ops[i]
    }

    /// The `fcs_faults_injected_total` series for a failpoint site; sites
    /// outside [`FAULT_SITES`] fall into the `"other"` series.
    #[inline]
    pub fn fault_injected(&self, site: &str) -> &Counter {
        let i = FAULT_SITES.iter().position(|&s| s == site).unwrap_or(FAULT_SITES.len() - 1);
        &self.faults_injected[i]
    }
}

/// The crate's instruments, registered once against the global registry.
pub fn metrics() -> &'static CrateMetrics {
    static METRICS: OnceLock<CrateMetrics> = OnceLock::new();
    METRICS.get_or_init(|| CrateMetrics::register(registry::global()))
}

/// Eagerly build the instrument set and pin the trace epoch. Call at
/// service startup so (a) hot-path `metrics()` lookups never hit the
/// registration slow path, and (b) trace timestamps share one epoch that
/// precedes every job's `enqueued` instant.
pub fn init() {
    let _ = metrics();
    let _ = trace::epoch();
}

/// Record a stage timing on one in every `STAGE_SAMPLE_EVERY` driver
/// dispatches (see EXPERIMENTS.md §Observability for the overhead budget).
pub const STAGE_SAMPLE_EVERY: u64 = 32;

static STAGE_TICK: AtomicU64 = AtomicU64::new(0);
static STAGE_FORCE: AtomicBool = AtomicBool::new(false);

/// Force the next [`StageTimer::sample`] to be live regardless of the
/// sampling tick — test hook for deterministic coverage.
pub fn force_next_stage_sample() {
    // ordering: Relaxed — advisory test hook; the consuming swap is atomic,
    // and it is fine for the forced sample to land on any nearby dispatch.
    STAGE_FORCE.store(true, Ordering::Relaxed);
}

/// Sampled per-stage accumulator for one driver dispatch.
///
/// A live timer (one per [`STAGE_SAMPLE_EVERY`] dispatches) accumulates
/// nanoseconds per [`Stage`] and observes them into `fcs_stage_ns` on
/// `Drop` (so timings land even if the dispatch unwinds). A dead timer is
/// a `None` and every call on it is a branch on a register — no clock
/// reads, no atomics, no allocation either way.
pub struct StageTimer {
    acc: Option<[u64; 4]>,
}

impl StageTimer {
    /// Tick the global sample counter; live on every k-th call (or when
    /// forced by [`force_next_stage_sample`]).
    #[inline]
    pub fn sample() -> StageTimer {
        // ordering: Relaxed — atomic swap guarantees exactly one timer
        // consumes a force; which dispatch wins is deliberately unspecified.
        let forced = STAGE_FORCE.swap(false, Ordering::Relaxed);
        // ordering: Relaxed — sampling tick; exact interleaving of ticks
        // across threads only perturbs which dispatches are sampled.
        let tick = STAGE_TICK.fetch_add(1, Ordering::Relaxed);
        if forced || tick % STAGE_SAMPLE_EVERY == 0 {
            StageTimer { acc: Some([0; 4]) }
        } else {
            StageTimer { acc: None }
        }
    }

    /// A timer that never records (for paths that opt out).
    #[inline]
    pub fn off() -> StageTimer {
        StageTimer { acc: None }
    }

    #[inline]
    pub fn is_live(&self) -> bool {
        self.acc.is_some()
    }

    /// Start of a stage: a clock read only when live.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.acc.is_some() { Some(Instant::now()) } else { None }
    }

    /// End of a stage: accumulate elapsed nanos since the matching
    /// [`StageTimer::start`].
    #[inline]
    pub fn lap(&mut self, stage: Stage, from: Option<Instant>) {
        if let (Some(acc), Some(t0)) = (self.acc.as_mut(), from) {
            acc[stage as usize] += t0.elapsed().as_nanos() as u64;
        }
    }
}

impl Drop for StageTimer {
    fn drop(&mut self) {
        if let Some(acc) = self.acc {
            let m = metrics();
            for (i, ns) in acc.iter().enumerate() {
                if *ns > 0 {
                    m.stage_ns[i].observe(*ns);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_lookup_maps_known_and_unknown() {
        let m = metrics();
        assert!(std::ptr::eq(m.op("sketch_cp"), &m.ops[2]));
        assert!(std::ptr::eq(m.op("sketch_shard"), &m.ops[4]));
        assert!(std::ptr::eq(m.op("merge_shards"), &m.ops[5]));
        assert!(std::ptr::eq(m.op("no_such_op"), &m.ops[6]));
    }

    /// Obtain a live timer even if a concurrent test steals the force flag
    /// (the tick counter and force flag are process-global).
    fn live_timer() -> StageTimer {
        loop {
            force_next_stage_sample();
            let t = StageTimer::sample();
            if t.is_live() {
                return t;
            }
        }
    }

    #[test]
    fn forced_stage_timer_records_on_drop() {
        let m = metrics();
        let before = m.stage_ns[Stage::Fold as usize].count();
        let mut t = live_timer();
        let s = t.start();
        std::thread::sleep(std::time::Duration::from_micros(50));
        t.lap(Stage::Fold, s);
        drop(t);
        assert!(m.stage_ns[Stage::Fold as usize].count() > before);
    }

    #[test]
    fn dead_timer_reads_no_clock() {
        let mut t = StageTimer::off();
        assert!(!t.is_live());
        let s = t.start();
        assert!(s.is_none());
        t.lap(Stage::Pack, s); // no-op on a dead timer
    }

    #[test]
    fn sampling_is_sparse_but_nonempty() {
        // Exact 1-in-k counts are racy under the parallel test harness
        // (every driver dispatch in the binary shares the tick), so pin the
        // two properties that matter: some samples fire, most do not.
        let total = 10 * STAGE_SAMPLE_EVERY;
        let mut live = 0;
        drop(live_timer()); // guarantees >= 1 live sample was reachable
        for _ in 0..total {
            if StageTimer::sample().is_live() {
                live += 1;
            }
        }
        assert!(live < total / 2, "sampling not sparse: {live}/{total}");
    }
}
