//! Prometheus text exposition (format 0.0.4) over a [`Registry`].
//!
//! Metric names and label sets are a **stable API** once scraped — see
//! EXPERIMENTS.md §Observability for the naming protocol. The exact output
//! shape (HELP/TYPE once per family, cumulative `_bucket` lines with
//! power-of-two `le` bounds, `_sum`/`_count` per histogram series) is
//! pinned by the golden test below; renaming a series is a breaking change
//! to every dashboard scraping it.

use super::registry::{bucket_bound, Entry, Metric, Registry, HIST_BUCKETS};
use std::fmt::Write as _;

fn kind(metric: &Metric) -> &'static str {
    match metric {
        Metric::Counter(_) => "counter",
        Metric::Gauge(_) => "gauge",
        Metric::Histogram(_) => "histogram",
    }
}

fn write_header(out: &mut String, e: &Entry) {
    let _ = writeln!(out, "# HELP {} {}", e.name, e.help);
    let _ = writeln!(out, "# TYPE {} {}", e.name, kind(&e.metric));
}

fn write_series(out: &mut String, e: &Entry) {
    match &e.metric {
        Metric::Counter(c) => {
            if e.labels.is_empty() {
                let _ = writeln!(out, "{} {}", e.name, c.get());
            } else {
                let _ = writeln!(out, "{}{{{}}} {}", e.name, e.labels, c.get());
            }
        }
        Metric::Gauge(g) => {
            if e.labels.is_empty() {
                let _ = writeln!(out, "{} {}", e.name, g.get());
            } else {
                let _ = writeln!(out, "{}{{{}}} {}", e.name, e.labels, g.get());
            }
        }
        Metric::Histogram(h) => {
            let counts = h.bucket_counts();
            let mut cum = 0u64;
            for (i, c) in counts.iter().enumerate() {
                cum += c;
                let le = if i == HIST_BUCKETS - 1 {
                    "+Inf".to_string()
                } else {
                    bucket_bound(i).to_string()
                };
                if e.labels.is_empty() {
                    let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", e.name, le, cum);
                } else {
                    let _ = writeln!(out, "{}_bucket{{{},le=\"{}\"}} {}", e.name, e.labels, le, cum);
                }
            }
            if e.labels.is_empty() {
                let _ = writeln!(out, "{}_sum {}", e.name, h.sum());
                let _ = writeln!(out, "{}_count {}", e.name, cum);
            } else {
                let _ = writeln!(out, "{}_sum{{{}}} {}", e.name, e.labels, h.sum());
                let _ = writeln!(out, "{}_count{{{}}} {}", e.name, e.labels, cum);
            }
        }
    }
}

/// Render a registry as Prometheus text. `# HELP`/`# TYPE` are emitted once
/// per family, on the first entry bearing that family name (entries of one
/// family are registered adjacently, so registration order groups them).
pub fn render(reg: &Registry) -> String {
    reg.with_entries(|entries| {
        let mut out = String::with_capacity(4096);
        let mut last_family: Option<&'static str> = None;
        for e in entries {
            if last_family != Some(e.name) {
                write_header(&mut out, e);
                last_family = Some(e.name);
            }
            write_series(&mut out, e);
        }
        out
    })
}

/// Render the process-wide registry (forces [`crate::obs::metrics`] so the
/// crate families exist even if nothing has recorded yet).
pub fn render_global() -> String {
    let _ = crate::obs::metrics();
    render(super::registry::global())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden test: the exposition format is pinned byte-for-byte on a
    /// small local registry. If this test changes, every scraper breaks.
    #[test]
    fn prometheus_format_golden() {
        let reg = Registry::new();
        let hits = reg.counter("t_hits_total", "Cache hits.", "cache=\"forward\"");
        let miss = reg.counter("t_hits_total", "Cache hits.", "cache=\"real\"");
        let depth = reg.gauge("t_queue_depth", "Jobs queued.", "");
        let lat = reg.histogram("t_latency_us", "Latency.", "op=\"cs_vec\"");

        hits.add(3);
        miss.inc();
        depth.set(2);
        lat.observe(1); // bucket le=1
        lat.observe(5); // bucket le=8
        lat.observe(1 << 30); // +Inf

        let text = render(&reg);
        let expected = "\
# HELP t_hits_total Cache hits.
# TYPE t_hits_total counter
t_hits_total{cache=\"forward\"} 3
t_hits_total{cache=\"real\"} 1
# HELP t_queue_depth Jobs queued.
# TYPE t_queue_depth gauge
t_queue_depth 2
# HELP t_latency_us Latency.
# TYPE t_latency_us histogram
t_latency_us_bucket{op=\"cs_vec\",le=\"1\"} 1
t_latency_us_bucket{op=\"cs_vec\",le=\"2\"} 1
t_latency_us_bucket{op=\"cs_vec\",le=\"4\"} 1
t_latency_us_bucket{op=\"cs_vec\",le=\"8\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"16\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"32\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"64\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"128\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"256\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"512\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"1024\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"2048\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"4096\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"8192\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"16384\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"32768\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"65536\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"131072\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"262144\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"524288\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"1048576\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"2097152\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"4194304\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"8388608\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"16777216\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"33554432\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"67108864\"} 2
t_latency_us_bucket{op=\"cs_vec\",le=\"+Inf\"} 3
t_latency_us_sum{op=\"cs_vec\"} 1073741830
t_latency_us_count{op=\"cs_vec\"} 3
";
        assert_eq!(text, expected);
    }

    /// The global render always carries the crate's core families, even on
    /// a process that has served no traffic.
    #[test]
    fn global_render_has_core_families() {
        let text = render_global();
        for family in [
            "# TYPE fcs_plan_cache_hits_total counter",
            "# TYPE fcs_plan_cache_misses_total counter",
            "# TYPE fcs_requests_completed_total counter",
            "# TYPE fcs_request_latency_us histogram",
            "# TYPE fcs_flight_width histogram",
            "# TYPE fcs_stage_ns histogram",
            "# TYPE fcs_queue_depth gauge",
            "# TYPE fcs_rejected_busy_total counter",
            "# TYPE fcs_poisoned_jobs_total counter",
            "# TYPE fcs_shard_width histogram",
            "# TYPE fcs_merge_depth histogram",
        ] {
            assert!(text.contains(family), "missing {family:?} in:\n{text}");
        }
    }
}
