//! Per-request flight tracing: bounded per-worker ring buffers of
//! `(req_id, submit → queue → flight-start → reply)` spans.
//!
//! Req ids are the coordinator's pre-drawn [`crate::coordinator::job_rng`]
//! ids, so a span can be joined offline against the exact RNG stream that
//! produced its sketch. Timestamps are microseconds since a process-local
//! epoch pinned by [`crate::obs::init`]; they are derived from monotone
//! `Instant`s with saturating subtraction, so ordering within a span
//! (`submit_us <= queue_us <= flight_start_us <= reply_us`) always holds
//! even for jobs enqueued before the epoch was pinned.
//!
//! Recording takes one shard mutex (shard = worker index mod
//! [`TRACE_SHARDS`]) and writes into a preallocated ring — no allocation
//! after the ring's first fill, and contention only between workers that
//! share a shard.

use crate::sync::{Mutex, OnceLock};
use crate::util::json::Json;
use std::time::Instant;

/// Number of ring shards; workers map onto shards by `worker % TRACE_SHARDS`.
pub const TRACE_SHARDS: usize = 8;

/// Spans retained per shard (newest overwrite oldest). Under `--cfg loom`
/// the ring shrinks so `tests/loom_models.rs` exercises wraparound within a
/// tractable schedule budget; the ring arithmetic is cap-independent.
pub const TRACE_RING_CAP: usize = if cfg!(loom) { 8 } else { 512 };

/// One completed request, as seen from the worker that replied to it.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpan {
    /// Pre-drawn req id (the `job_rng` key).
    pub req_id: u64,
    /// Operation name (`Request::op_name`).
    pub op: &'static str,
    /// Client-side submit time (job creation), µs since process epoch.
    pub submit_us: u64,
    /// When the worker pulled the job off its queue, µs since epoch.
    pub queue_us: u64,
    /// When the job's flight began executing, µs since epoch.
    pub flight_start_us: u64,
    /// When the reply was sent, µs since epoch.
    pub reply_us: u64,
    /// Width of the flight this job executed in (1 = serial).
    pub width: u16,
    /// Whether the reply was `Ok`.
    pub ok: bool,
}

struct Ring {
    buf: Vec<TraceSpan>,
    /// Total spans ever written; `written % TRACE_RING_CAP` is the next slot.
    written: u64,
}

impl Ring {
    fn new() -> Self {
        Self { buf: Vec::with_capacity(TRACE_RING_CAP), written: 0 }
    }

    fn push(&mut self, span: TraceSpan) {
        let slot = (self.written % TRACE_RING_CAP as u64) as usize;
        if slot == self.buf.len() {
            self.buf.push(span); // filling phase: capacity preallocated
        } else {
            self.buf[slot] = span; // steady state: overwrite oldest
        }
        self.written += 1;
    }
}

/// Sharded trace store.
pub struct TraceBook {
    shards: [Mutex<Ring>; TRACE_SHARDS],
}

impl Default for TraceBook {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceBook {
    pub fn new() -> Self {
        Self { shards: std::array::from_fn(|_| Mutex::new(Ring::new())) }
    }

    /// Record a completed span from worker `worker`.
    pub fn record(&self, worker: usize, span: TraceSpan) {
        self.shards[worker % TRACE_SHARDS].lock().unwrap().push(span);
        crate::obs::metrics().traces_recorded.inc();
    }

    /// The most recent `n` spans across all shards, oldest first.
    pub fn recent(&self, n: usize) -> Vec<TraceSpan> {
        let mut all: Vec<TraceSpan> = Vec::new();
        for shard in &self.shards {
            let g = shard.lock().unwrap();
            all.extend_from_slice(&g.buf);
        }
        all.sort_by_key(|s| s.reply_us);
        let keep = all.len().saturating_sub(n);
        all.split_off(keep)
    }

    /// JSON dump of the most recent `n` spans (the `/traces` payload).
    pub fn dump_json(&self, n: usize) -> String {
        let spans: Vec<Json> = self
            .recent(n)
            .into_iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("req_id", (s.req_id as f64).into())
                    .set("op", s.op.into())
                    .set("submit_us", (s.submit_us as f64).into())
                    .set("queue_us", (s.queue_us as f64).into())
                    .set("flight_start_us", (s.flight_start_us as f64).into())
                    .set("reply_us", (s.reply_us as f64).into())
                    .set("width", (s.width as usize).into())
                    .set("ok", s.ok.into());
                o
            })
            .collect();
        let mut root = Json::obj();
        root.set("spans", Json::Arr(spans));
        root.to_string()
    }
}

/// The process-wide trace book fed by coordinator workers.
pub fn global() -> &'static TraceBook {
    static GLOBAL: OnceLock<TraceBook> = OnceLock::new();
    GLOBAL.get_or_init(TraceBook::new)
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process trace epoch; pinned on first call (see [`crate::obs::init`]).
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process epoch. Saturates at 0 for instants taken
/// before the epoch was pinned, which preserves within-span ordering.
pub fn epoch_us(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(req_id: u64, reply_us: u64) -> TraceSpan {
        TraceSpan {
            req_id,
            op: "sketch_dense",
            submit_us: reply_us.saturating_sub(30),
            queue_us: reply_us.saturating_sub(20),
            flight_start_us: reply_us.saturating_sub(10),
            reply_us,
            width: 1,
            ok: true,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_recent_sorts() {
        let book = TraceBook::new();
        // Overfill one shard: 512-cap ring sees 600 spans, keeps the last 512.
        for i in 0..600u64 {
            book.record(0, span(i, i + 100));
        }
        let recent = book.recent(10);
        assert_eq!(recent.len(), 10);
        // Oldest-first ordering, and only the newest survive the ring.
        for w in recent.windows(2) {
            assert!(w[0].reply_us <= w[1].reply_us);
        }
        assert_eq!(recent.last().unwrap().req_id, 599);
        assert_eq!(recent.first().unwrap().req_id, 590);
    }

    #[test]
    fn spans_spread_across_shards() {
        let book = TraceBook::new();
        for w in 0..TRACE_SHARDS {
            book.record(w, span(w as u64, 1000 + w as u64));
        }
        assert_eq!(book.recent(TRACE_SHARDS).len(), TRACE_SHARDS);
    }

    #[test]
    fn dump_json_parses_back() {
        let book = TraceBook::new();
        book.record(3, span(42, 500));
        let text = book.dump_json(8);
        let j = Json::parse(&text).unwrap();
        let spans = j.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("req_id").unwrap().as_f64(), Some(42.0));
        assert_eq!(spans[0].get("op").unwrap().as_str(), Some("sketch_dense"));
        assert_eq!(spans[0].get("width").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn epoch_us_is_monotone() {
        let a = Instant::now();
        let ua = epoch_us(a);
        let b = Instant::now();
        assert!(epoch_us(b) >= ua);
    }
}
