//! Tensor contractions — the operations the paper accelerates.
//!
//! Includes the RTPM contractions `T(u,u,u)` / `T(I,u,u)` (§2.1), the general
//! multilinear form, mode-wise contractions for ALS (Eq. 18), the pairwise
//! contraction `A ⊙_{p,q} B` (§4.3.2), and Kronecker/outer products.

use super::dense::Tensor;
use crate::linalg::Matrix;

/// `T(u, u, u) = ⟨T, u ∘ u ∘ u⟩` for a 3rd-order cubical tensor — the RTPM
/// eigenvalue evaluation.
pub fn t_uuu(t: &Tensor, u: &[f64]) -> f64 {
    crate::linalg::dot(&t_iuu(t, u), u)
}

/// `T(I, u, u)_i = Σ_{j,k} T_{ijk} u_j u_k` — the RTPM power-iteration map.
/// Column-major fibers `T[:, j, k]` are contiguous, so this runs at memory
/// bandwidth.
pub fn t_iuu(t: &Tensor, u: &[f64]) -> Vec<f64> {
    assert_eq!(t.order(), 3);
    let (i1, i2, i3) = (t.shape[0], t.shape[1], t.shape[2]);
    assert_eq!(u.len(), i2.max(i3));
    assert_eq!(i2, i3, "t_iuu expects T with equal mode-2/3 dims");
    let mut out = vec![0.0; i1];
    for k in 0..i3 {
        let uk = u[k];
        if uk == 0.0 {
            continue;
        }
        for j in 0..i2 {
            let c = u[j] * uk;
            if c == 0.0 {
                continue;
            }
            let fiber = &t.data[(k * i2 + j) * i1..(k * i2 + j + 1) * i1];
            crate::linalg::axpy(c, fiber, &mut out);
        }
    }
    out
}

/// General multilinear form `T(v^{(1)}, …, v^{(N)}) = ⟨T, v^{(1)} ∘ … ⟩`.
pub fn multilinear_form(t: &Tensor, vs: &[&[f64]]) -> f64 {
    assert_eq!(vs.len(), t.order());
    for (v, &d) in vs.iter().zip(&t.shape) {
        assert_eq!(v.len(), d);
    }
    // Contract modes from last to first; each step reduces the trailing mode.
    let mut cur = t.data.clone();
    let mut shape = t.shape.clone();
    while let Some(&last_dim) = shape.last() {
        if shape.len() == 1 {
            return crate::linalg::dot(&cur, vs[0]);
        }
        let v = vs[shape.len() - 1];
        let inner: usize = shape[..shape.len() - 1].iter().product();
        let mut next = vec![0.0; inner];
        for k in 0..last_dim {
            let c = v[k];
            if c == 0.0 {
                continue;
            }
            crate::linalg::axpy(c, &cur[k * inner..(k + 1) * inner], &mut next);
        }
        cur = next;
        shape.pop();
    }
    unreachable!("empty tensor shape")
}

/// Contract every mode except `free_mode` with the given vectors:
/// `out_j = Σ_{i_d, d≠free} T_{…} Π_{d≠free} v_d(i_d)`.
/// `vs` has one entry per mode; `vs[free_mode]` is ignored.
pub fn contract_all_but(t: &Tensor, free_mode: usize, vs: &[&[f64]]) -> Vec<f64> {
    let n = t.order();
    assert!(free_mode < n);
    assert_eq!(vs.len(), n);
    // Contract trailing modes down to free_mode, then leading modes.
    let mut cur = t.data.clone();
    let mut shape = t.shape.clone();
    // Fold trailing modes (> free_mode), last first.
    while shape.len() - 1 > free_mode {
        let last = shape.len() - 1;
        let v = vs[last];
        assert_eq!(v.len(), shape[last]);
        let inner: usize = shape[..last].iter().product();
        let mut next = vec![0.0; inner];
        for k in 0..shape[last] {
            let c = v[k];
            if c == 0.0 {
                continue;
            }
            crate::linalg::axpy(c, &cur[k * inner..(k + 1) * inner], &mut next);
        }
        cur = next;
        shape.pop();
    }
    // Fold leading modes (< free_mode), first mode fastest ⇒ contract mode 0
    // repeatedly.
    for d in 0..free_mode {
        let v = vs[d];
        let first = shape[0];
        assert_eq!(v.len(), first);
        let outer: usize = shape[1..].iter().product();
        let mut next = vec![0.0; outer];
        for (o, onext) in next.iter_mut().enumerate() {
            let base = o * first;
            *onext = crate::linalg::dot(&cur[base..base + first], v);
        }
        cur = next;
        shape.remove(0);
        let _ = d;
    }
    assert_eq!(shape.len(), 1);
    cur
}

/// Multilinear (Tucker-style) transform `T(M_1, …, M_N)` with
/// `M_n ∈ R^{I_n × J_n}` (§2.1). Implemented as successive mode-n products.
pub fn multilinear_transform(t: &Tensor, mats: &[&Matrix]) -> Tensor {
    assert_eq!(mats.len(), t.order());
    let mut cur = t.clone();
    for (mode, m) in mats.iter().enumerate() {
        assert_eq!(m.rows, cur.shape[mode], "mode-{mode} dim mismatch");
        cur = mode_product_t(&cur, mode, m);
    }
    cur
}

/// Mode-n product with `M^T`: replaces mode `n` of size `I_n` by size `J_n`
/// where `M ∈ R^{I_n × J_n}` (i.e. contracts over the first index of `M`,
/// matching the paper's `T(M_1, …, M_N)` convention).
pub fn mode_product_t(t: &Tensor, mode: usize, m: &Matrix) -> Tensor {
    let unfolded = t.matricize(mode); // I_n × rest
    let new_unfolded = m.t_matmul(&unfolded); // J_n × rest
    let mut new_shape = t.shape.clone();
    new_shape[mode] = m.cols;
    Tensor::fold(&new_unfolded, mode, &new_shape)
}

/// Outer product of vectors into a dense tensor (`u ∘ v ∘ …`).
pub fn outer(vs: &[&[f64]]) -> Tensor {
    let shape: Vec<usize> = vs.iter().map(|v| v.len()).collect();
    // vec(u ∘ v ∘ w) = w ⊗ v ⊗ u; build iteratively.
    let mut data = vs[0].to_vec();
    for v in &vs[1..] {
        let mut next = Vec::with_capacity(data.len() * v.len());
        for &b in v.iter() {
            for &a in data.iter() {
                next.push(a * b);
            }
        }
        data = next;
    }
    Tensor::from_data(&shape, data)
}

/// Kronecker product of vectors `⊗_{n=N}^{1} v_n = v_N ⊗ … ⊗ v_1` (which
/// equals `vec(v_1 ∘ … ∘ v_N)`).
pub fn kron_vecs_rev(vs: &[&[f64]]) -> Vec<f64> {
    outer(vs).data
}

/// Pairwise contraction `A ⊙_{p,q} B`: contracts mode `p` of `A` with mode
/// `q` of `B` (0-based), producing a tensor whose shape is A's other modes
/// followed by B's other modes (§4.3.2 uses p = last, q = first).
pub fn contract_pair(a: &Tensor, p: usize, b: &Tensor, q: usize) -> Tensor {
    assert_eq!(a.shape[p], b.shape[q], "contraction dim mismatch");
    let l = a.shape[p];
    let ma = a.matricize(p); // L × (rest of A)
    let mb = b.matricize(q); // L × (rest of B)
    let prod = ma.t_matmul(&mb); // (rest A) × (rest B)
    let mut shape: Vec<usize> = Vec::new();
    for (d, &s) in a.shape.iter().enumerate() {
        if d != p {
            shape.push(s);
        }
    }
    for (d, &s) in b.shape.iter().enumerate() {
        if d != q {
            shape.push(s);
        }
    }
    let _ = l;
    Tensor::from_data(&shape, prod.data)
}

/// Dense Kronecker product of two matrices as a `Tensor` of shape
/// `[I1·I3, I2·I4]` (paper §4.3.1 compresses `A ⊗ B`).
pub fn kron_matrix(a: &Matrix, b: &Matrix) -> Matrix {
    a.kron(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::qcheck::qcheck;

    fn naive_t_iuu(t: &Tensor, u: &[f64]) -> Vec<f64> {
        let (i1, i2, i3) = (t.shape[0], t.shape[1], t.shape[2]);
        let mut out = vec![0.0; i1];
        for i in 0..i1 {
            for j in 0..i2 {
                for k in 0..i3 {
                    out[i] += t.get(&[i, j, k]) * u[j] * u[k];
                }
            }
        }
        out
    }

    #[test]
    fn t_iuu_matches_naive() {
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&mut rng, &[6, 5, 5]);
        let u = rng.normal_vec(5);
        let fast = t_iuu(&t, &u);
        let slow = naive_t_iuu(&t, &u);
        for (a, b) in fast.iter().zip(&slow) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn t_uuu_matches_inner_with_outer() {
        let mut rng = Rng::seed_from_u64(2);
        let t = Tensor::randn(&mut rng, &[5, 5, 5]);
        let u = rng.normal_vec(5);
        let cube = outer(&[&u, &u, &u]);
        assert!((t_uuu(&t, &u) - t.inner(&cube)).abs() < 1e-10);
    }

    #[test]
    fn multilinear_form_matches_outer_inner() {
        qcheck(20, |g| {
            let shape = g.shape(3, 2, 6);
            let t = Tensor::randn(g.rng(), &shape);
            let vs: Vec<Vec<f64>> = shape.iter().map(|&d| g.normal_vec(d)).collect();
            let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
            let direct = multilinear_form(&t, &refs);
            let viaouter = t.inner(&outer(&refs));
            assert!((direct - viaouter).abs() < 1e-9, "{direct} vs {viaouter}");
        });
    }

    #[test]
    fn contract_all_but_matches_basis_trick() {
        // contract_all_but(t, m, vs)[i] == multilinear_form with e_i at mode m
        let mut rng = Rng::seed_from_u64(3);
        let t = Tensor::randn(&mut rng, &[4, 3, 5]);
        let v0 = rng.normal_vec(4);
        let v1 = rng.normal_vec(3);
        let v2 = rng.normal_vec(5);
        for mode in 0..3 {
            let out = contract_all_but(&t, mode, &[&v0, &v1, &v2]);
            assert_eq!(out.len(), t.shape[mode]);
            for i in 0..t.shape[mode] {
                let mut basis = vec![0.0; t.shape[mode]];
                basis[i] = 1.0;
                let mut vs: Vec<&[f64]> = vec![&v0, &v1, &v2];
                vs[mode] = &basis;
                let expect = multilinear_form(&t, &vs);
                assert!((out[i] - expect).abs() < 1e-9, "mode={mode} i={i}");
            }
        }
    }

    #[test]
    fn t_iuu_equals_contract_all_but() {
        let mut rng = Rng::seed_from_u64(4);
        let t = Tensor::randn(&mut rng, &[5, 5, 5]);
        let u = rng.normal_vec(5);
        let a = t_iuu(&t, &u);
        let b = contract_all_but(&t, 0, &[&u, &u, &u]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn outer_vec_is_reversed_kron() {
        // vec(u ∘ v) = v ⊗ u
        let u = [1.0, 2.0];
        let v = [3.0, 4.0, 5.0];
        let t = outer(&[&u, &v]);
        assert_eq!(t.data, vec![3.0, 6.0, 4.0, 8.0, 5.0, 10.0]);
    }

    #[test]
    fn contract_pair_matches_naive() {
        let mut rng = Rng::seed_from_u64(5);
        let a = Tensor::randn(&mut rng, &[3, 4, 6]);
        let b = Tensor::randn(&mut rng, &[6, 2, 5]);
        let c = contract_pair(&a, 2, &b, 0);
        assert_eq!(c.shape, vec![3, 4, 2, 5]);
        for i1 in 0..3 {
            for i2 in 0..4 {
                for i3 in 0..2 {
                    for i4 in 0..5 {
                        let mut expect = 0.0;
                        for l in 0..6 {
                            expect += a.get(&[i1, i2, l]) * b.get(&[l, i3, i4]);
                        }
                        assert!((c.get(&[i1, i2, i3, i4]) - expect).abs() < 1e-10);
                    }
                }
            }
        }
    }

    #[test]
    fn multilinear_transform_identity_is_noop() {
        let mut rng = Rng::seed_from_u64(6);
        let t = Tensor::randn(&mut rng, &[3, 4, 5]);
        let i3 = Matrix::identity(3);
        let i4 = Matrix::identity(4);
        let i5 = Matrix::identity(5);
        let out = multilinear_transform(&t, &[&i3, &i4, &i5]);
        assert!(out.sub(&t).frob_norm() < 1e-12);
    }

    #[test]
    fn multilinear_transform_rank1_check() {
        // T = u∘v, T(a, b) = (u·a)(v·b) for column "matrices"
        let u = [1.0, 2.0];
        let v = [1.0, -1.0, 0.5];
        let t = outer(&[&u, &v]);
        let a = Matrix::from_data(2, 1, vec![3.0, 4.0]);
        let b = Matrix::from_data(3, 1, vec![1.0, 1.0, 2.0]);
        let out = multilinear_transform(&t, &[&a, &b]);
        let expect = (1.0 * 3.0 + 2.0 * 4.0) * (1.0 - 1.0 + 1.0);
        assert_eq!(out.shape, vec![1, 1]);
        assert!((out.data[0] - expect).abs() < 1e-12);
    }
}
