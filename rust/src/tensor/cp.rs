//! CP (CANDECOMP/PARAFAC) tensor: `T ≈ Σ_r λ_r u_r^{(1)} ∘ … ∘ u_r^{(N)}
//! = [λ; U^{(1)}, …, U^{(N)}]`.

use super::dense::Tensor;
use crate::linalg::Matrix;
use crate::util::prng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct CpTensor {
    pub lambda: Vec<f64>,
    /// factors[n] is `U^{(n)} ∈ R^{I_n × R}`.
    pub factors: Vec<Matrix>,
}

impl CpTensor {
    pub fn new(lambda: Vec<f64>, factors: Vec<Matrix>) -> Self {
        let r = lambda.len();
        assert!(!factors.is_empty());
        for f in &factors {
            assert_eq!(f.cols, r, "factor rank mismatch");
        }
        Self { lambda, factors }
    }

    pub fn rank(&self) -> usize {
        self.lambda.len()
    }

    pub fn order(&self) -> usize {
        self.factors.len()
    }

    pub fn shape(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.rows).collect()
    }

    /// Random CP tensor with iid Gaussian factors.
    pub fn randn(rng: &mut Rng, shape: &[usize], rank: usize) -> Self {
        let factors = shape.iter().map(|&d| Matrix::randn(rng, d, rank)).collect();
        Self::new(vec![1.0; rank], factors)
    }

    /// Symmetric CP tensor `Σ_r u_r ∘ u_r ∘ u_r` with orthonormal `{u_r}`
    /// (the paper's synthetic setup, §4.1.1).
    pub fn random_orthogonal_symmetric(rng: &mut Rng, dim: usize, rank: usize, order: usize) -> Self {
        let u = crate::linalg::random_orthonormal(rng, dim, rank);
        Self::new(vec![1.0; rank], vec![u; order])
    }

    /// Asymmetric CP tensor with per-mode random orthonormal factors
    /// (§4.1.2 synthetic setup).
    pub fn random_orthogonal(rng: &mut Rng, shape: &[usize], rank: usize) -> Self {
        let factors = shape
            .iter()
            .map(|&d| crate::linalg::random_orthonormal(rng, d, rank))
            .collect();
        Self::new(vec![1.0; rank], factors)
    }

    /// `vec(T) = (U^{(N)} ⊙ … ⊙ U^{(1)}) λ` (column-major Khatri-Rao chain).
    pub fn to_vec(&self) -> Vec<f64> {
        let mut acc = self.factors[0].clone();
        for f in &self.factors[1..] {
            acc = f.khatri_rao(&acc);
        }
        acc.matvec(&self.lambda)
    }

    /// Materialize to a dense tensor.
    pub fn to_dense(&self) -> Tensor {
        Tensor::from_data(&self.shape(), self.to_vec())
    }

    /// Frobenius norm via the Gram trick:
    /// `‖T‖² = λ^T (⊛_n U^{(n)T} U^{(n)}) λ` — no materialization.
    pub fn frob_norm(&self) -> f64 {
        let r = self.rank();
        let mut g = Matrix::from_fn(r, r, |_, _| 1.0);
        for f in &self.factors {
            g = g.hadamard(&f.t_matmul(f));
        }
        let gl = g.matvec(&self.lambda);
        crate::linalg::dot(&self.lambda, &gl).max(0.0).sqrt()
    }

    /// Inner product with a dense tensor without materializing `self`:
    /// `⟨T, X⟩ = Σ_r λ_r X(u_r^{(1)}, …, u_r^{(N)})`.
    pub fn inner_dense(&self, x: &Tensor) -> f64 {
        assert_eq!(self.shape(), x.shape);
        let mut acc = 0.0;
        for r in 0..self.rank() {
            let vs: Vec<&[f64]> = self.factors.iter().map(|f| f.col(r)).collect();
            acc += self.lambda[r] * super::ops::multilinear_form(x, &vs);
        }
        acc
    }

    /// Normalize each factor column to unit norm, absorbing magnitudes into
    /// `lambda`. Standard CPD post-processing.
    pub fn normalize(&mut self) {
        for r in 0..self.rank() {
            let mut mag = 1.0;
            for f in self.factors.iter_mut() {
                let n = crate::linalg::normalize(f.col_mut(r));
                mag *= n;
            }
            self.lambda[r] *= mag;
        }
    }

    /// Residual `‖X − T̂‖ / ‖X‖` against a dense reference.
    pub fn residual(&self, x: &Tensor) -> f64 {
        // ‖X − T‖² = ‖X‖² − 2⟨T, X⟩ + ‖T‖² — avoids materializing T for
        // large X... but for numerical safety at small residuals we
        // materialize when modest size.
        if x.numel() <= 1 << 24 {
            self.to_dense().sub(x).frob_norm() / x.frob_norm()
        } else {
            let t2 = self.frob_norm().powi(2);
            let x2 = x.frob_norm().powi(2);
            let tx = self.inner_dense(x);
            ((x2 - 2.0 * tx + t2).max(0.0)).sqrt() / x2.sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_dense_matches_elementwise() {
        let mut rng = Rng::seed_from_u64(1);
        let cp = CpTensor::randn(&mut rng, &[3, 4, 5], 2);
        let t = cp.to_dense();
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    let mut expect = 0.0;
                    for r in 0..2 {
                        expect += cp.lambda[r]
                            * cp.factors[0].get(i, r)
                            * cp.factors[1].get(j, r)
                            * cp.factors[2].get(k, r);
                    }
                    assert!((t.get(&[i, j, k]) - expect).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn gram_norm_matches_dense() {
        let mut rng = Rng::seed_from_u64(2);
        let mut cp = CpTensor::randn(&mut rng, &[4, 5, 6], 3);
        cp.lambda = vec![0.5, -2.0, 1.5];
        let dense_norm = cp.to_dense().frob_norm();
        assert!((cp.frob_norm() - dense_norm).abs() < 1e-10);
    }

    #[test]
    fn symmetric_orthogonal_unit_lambda_norm() {
        let mut rng = Rng::seed_from_u64(3);
        let cp = CpTensor::random_orthogonal_symmetric(&mut rng, 10, 4, 3);
        // orthonormal factors => ‖T‖² = Σ λ_r² = R
        assert!((cp.frob_norm() - 2.0).abs() < 1e-10);
    }

    #[test]
    fn inner_dense_matches_materialized() {
        let mut rng = Rng::seed_from_u64(4);
        let cp = CpTensor::randn(&mut rng, &[3, 3, 3], 2);
        let x = Tensor::randn(&mut rng, &[3, 3, 3]);
        let direct = cp.to_dense().inner(&x);
        assert!((cp.inner_dense(&x) - direct).abs() < 1e-10);
    }

    #[test]
    fn normalize_preserves_tensor() {
        let mut rng = Rng::seed_from_u64(5);
        let mut cp = CpTensor::randn(&mut rng, &[4, 4, 4], 3);
        let before = cp.to_dense();
        cp.normalize();
        let after = cp.to_dense();
        assert!(before.sub(&after).frob_norm() < 1e-10);
        for f in &cp.factors {
            for r in 0..3 {
                assert!((crate::linalg::norm2(f.col(r)) - 1.0).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn residual_zero_for_exact() {
        let mut rng = Rng::seed_from_u64(6);
        let cp = CpTensor::randn(&mut rng, &[5, 5, 5], 2);
        let x = cp.to_dense();
        assert!(cp.residual(&x) < 1e-12);
    }
}
