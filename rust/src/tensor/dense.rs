//! Dense tensor in **column-major** (MATLAB / paper) layout: the first index
//! varies fastest, so `data` *is* `vec(T)` in the paper's sense
//! (`l = Σ_n (i_n − 1) Π_{j<n} I_j + 1`, 0-based here).

use crate::hash::{ravel_colmajor, unravel_colmajor};
use crate::linalg::Matrix;
use crate::util::prng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    /// Column-major flattened entries — equal to `vec(T)`.
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_data(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> f64) -> Self {
        let mut t = Self::zeros(shape);
        let mut idx = vec![0usize; shape.len()];
        for l in 0..t.numel() {
            unravel_colmajor(l, shape, &mut idx);
            t.data[l] = f(&idx);
        }
        t
    }

    #[inline]
    pub fn order(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn get(&self, idx: &[usize]) -> f64 {
        self.data[ravel_colmajor(idx, &self.shape)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f64) {
        let l = ravel_colmajor(idx, &self.shape);
        self.data[l] = v;
    }

    /// `vec(T)` — a borrow of the column-major data.
    #[inline]
    pub fn as_vec(&self) -> &[f64] {
        &self.data
    }

    pub fn frob_norm(&self) -> f64 {
        crate::linalg::norm2(&self.data)
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor::from_data(&self.shape, data)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor::from_data(&self.shape, data)
    }

    pub fn scaled(&self, k: f64) -> Tensor {
        Tensor::from_data(&self.shape, self.data.iter().map(|v| v * k).collect())
    }

    /// Add iid Gaussian noise with std `sigma` in place.
    pub fn add_noise(&mut self, rng: &mut Rng, sigma: f64) {
        for v in self.data.iter_mut() {
            *v += sigma * rng.normal();
        }
    }

    /// Mode-n matricization `T_(n) ∈ R^{I_n × Π_{i≠n} I_i}` with the other
    /// modes flattened column-major in increasing mode order (MATLAB
    /// convention, as used by the paper's ALS Eq. 18).
    pub fn matricize(&self, mode: usize) -> Matrix {
        let n = self.order();
        assert!(mode < n);
        let rows = self.shape[mode];
        let cols = self.numel() / rows;
        let mut m = Matrix::zeros(rows, cols);
        let mut idx = vec![0usize; n];
        for l in 0..self.numel() {
            unravel_colmajor(l, &self.shape, &mut idx);
            let i = idx[mode];
            // column index: flatten remaining modes in increasing order
            let mut col = 0usize;
            let mut stride = 1usize;
            for d in 0..n {
                if d == mode {
                    continue;
                }
                col += idx[d] * stride;
                stride *= self.shape[d];
            }
            m.set(i, col, self.data[l]);
        }
        m
    }

    /// Inverse of `matricize`: fold a matrix back along `mode`.
    pub fn fold(m: &Matrix, mode: usize, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let n = shape.len();
        let mut idx = vec![0usize; n];
        for l in 0..t.numel() {
            unravel_colmajor(l, shape, &mut idx);
            let i = idx[mode];
            let mut col = 0usize;
            let mut stride = 1usize;
            for d in 0..n {
                if d == mode {
                    continue;
                }
                col += idx[d] * stride;
                stride *= shape[d];
            }
            t.data[l] = m.get(i, col);
        }
        t
    }

    /// Tensor inner product `⟨M, N⟩ = vec(M)^T vec(N)`.
    pub fn inner(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        crate::linalg::dot(&self.data, &other.data)
    }

    /// Relative Frobenius error `‖self − other‖ / ‖other‖`.
    pub fn rel_error(&self, reference: &Tensor) -> f64 {
        self.sub(reference).frob_norm() / reference.frob_norm()
    }

    /// A random dense tensor with iid uniform entries.
    pub fn rand_uniform(rng: &mut Rng, shape: &[usize], lo: f64, hi: f64) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_data(shape, rng.uniform_vec(n, lo, hi))
    }

    /// A random dense tensor with iid standard normal entries.
    pub fn randn(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_data(shape, rng.normal_vec(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colmajor_layout() {
        // 2x3 tensor: vec order is (0,0),(1,0),(0,1),(1,1),(0,2),(1,2)
        let t = Tensor::from_fn(&[2, 3], |idx| (idx[0] * 10 + idx[1]) as f64);
        assert_eq!(t.data, vec![0.0, 10.0, 1.0, 11.0, 2.0, 12.0]);
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = Tensor::zeros(&[3, 4, 5]);
        t.set(&[2, 1, 4], 7.0);
        assert_eq!(t.get(&[2, 1, 4]), 7.0);
        assert_eq!(t.nnz(), 1);
    }

    #[test]
    fn matricize_mode0_of_matrix_is_identityish() {
        let t = Tensor::from_fn(&[3, 4], |idx| (idx[0] * 4 + idx[1]) as f64);
        let m = t.matricize(0);
        assert_eq!((m.rows, m.cols), (3, 4));
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(m.get(i, j), t.get(&[i, j]));
            }
        }
    }

    #[test]
    fn matricize_fold_roundtrip() {
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&mut rng, &[3, 4, 5]);
        for mode in 0..3 {
            let m = t.matricize(mode);
            let back = Tensor::fold(&m, mode, &t.shape);
            assert_eq!(back, t, "mode {mode}");
        }
    }

    #[test]
    fn matricize_shapes() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!((t.matricize(0).rows, t.matricize(0).cols), (2, 12));
        assert_eq!((t.matricize(1).rows, t.matricize(1).cols), (3, 8));
        assert_eq!((t.matricize(2).rows, t.matricize(2).cols), (4, 6));
    }

    #[test]
    fn inner_product_is_vec_dot() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Tensor::randn(&mut rng, &[4, 4, 4]);
        let b = Tensor::randn(&mut rng, &[4, 4, 4]);
        let byhand: f64 = a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum();
        assert!((a.inner(&b) - byhand).abs() < 1e-12);
    }

    #[test]
    fn frob_matches_vec_norm() {
        let mut rng = Rng::seed_from_u64(3);
        let t = Tensor::randn(&mut rng, &[5, 6]);
        assert!((t.frob_norm() - crate::linalg::norm2(t.as_vec())).abs() < 1e-14);
    }

    #[test]
    fn noise_changes_entries() {
        let mut rng = Rng::seed_from_u64(4);
        let mut t = Tensor::zeros(&[10, 10]);
        t.add_noise(&mut rng, 0.5);
        assert!(t.frob_norm() > 0.0);
        let std = t.frob_norm() / (t.numel() as f64).sqrt();
        assert!((std - 0.5).abs() < 0.1, "std={std}");
    }
}
