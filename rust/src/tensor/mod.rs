//! Tensor substrate: dense column-major tensors, CP decomposed tensors, and
//! the contraction operations the paper accelerates.

pub mod cp;
pub mod dense;
pub mod ops;

pub use cp::CpTensor;
pub use dense::Tensor;
pub use ops::{
    contract_all_but, contract_pair, kron_vecs_rev, mode_product_t, multilinear_form,
    multilinear_transform, outer, t_iuu, t_uuu,
};
