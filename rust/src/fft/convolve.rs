//! Convolutions on top of the FFT plans.
//!
//! The distinction between **linear** (zero-padded) and **circular**
//! (mod-J wraparound) convolution is the heart of the paper: TS (Eq. 3) uses
//! circular convolution of the per-mode count sketches; FCS (Eq. 8) uses
//! linear convolution, which preserves the composite hash `Σ h_n(i_n) − N + 1`
//! without the modulo that destroys spatial structure.
//!
//! Every kernel has a `_into` variant taking a caller-owned
//! [`FftWorkspace`]: the hot loops (ALS/RTPM inner iterations, the
//! coordinator workers) rent scratch from the workspace and perform zero
//! heap allocations in steady state. The classic allocating signatures
//! remain as thin wrappers over the thread-local workspace.

use super::complex::{C64, ZERO};
use super::plan::Dir;
use super::workspace::{
    fft_real_into, fft_real_many_into, inverse_real_into, mul_lane_run, with_thread_workspace,
    FftWorkspace,
};

/// Product spectrum `F(a)·F(b)` of two real signals at length `n`, computed
/// with **one** complex FFT via the real-pair packing identity: with
/// `Z = F(a + i·b)`, Hermitian symmetry gives
/// `F(a)[k]·F(b)[k] = (Z[k]² − conj(Z[n−k])²) · (−i/4)` (§Perf: halves the
/// forward-FFT work in every convolution).
pub fn packed_product_spectrum_into(
    a: &[f64],
    b: &[f64],
    n: usize,
    ws: &mut FftWorkspace,
    out: &mut Vec<C64>,
) {
    debug_assert!(a.len() <= n && b.len() <= n);
    // Native split planes: `a` is the real plane, `b` the imaginary one —
    // the batch=1 plane entry runs the kernel with no interleaved staging.
    let mut zre = ws.take_f64(n);
    let mut zim = ws.take_f64(n);
    zre[..a.len()].copy_from_slice(a);
    zim[..b.len()].copy_from_slice(b);
    ws.process_planes(&mut zre, &mut zim, Dir::Forward);
    out.clear();
    out.resize(n, ZERO);
    let quarter_negi = C64::new(0.0, -0.25);
    for (k, o) in out.iter_mut().enumerate() {
        let zk = C64::new(zre[k], zim[k]);
        let mk = (n - k) % n;
        let zmk = C64::new(zre[mk], -zim[mk]);
        *o = (zk * zk - zmk * zmk) * quarter_negi;
    }
    ws.give_f64(zim);
    ws.give_f64(zre);
}

/// Allocating wrapper over [`packed_product_spectrum_into`].
pub fn packed_product_spectrum(a: &[f64], b: &[f64], n: usize) -> Vec<C64> {
    with_thread_workspace(|ws| {
        let mut out = Vec::with_capacity(n);
        packed_product_spectrum_into(a, b, n, ws, &mut out);
        out
    })
}

/// Product spectrum `Π_i F(signals[i])` at length `n`, written into `out`.
///
/// All signals are packed at a uniform stride and transformed by **one**
/// batched real-input call ([`fft_real_many_into`], half-length complex
/// kernel, batch innermost), then each bin's lanes are folded pointwise —
/// one blocked plan dispatch instead of one packed-pair transform per two
/// signals (the pre-PR 5 chain this replaced).
pub fn product_spectrum_into(
    signals: &[&[f64]],
    n: usize,
    ws: &mut FftWorkspace,
    out: &mut Vec<C64>,
) {
    assert!(!signals.is_empty());
    if signals.len() == 1 {
        fft_real_into(signals[0], n, ws, out);
        return;
    }
    let m = signals.len();
    let stride = signals.iter().map(|s| s.len()).max().unwrap().max(1);
    assert!(stride <= n, "product_spectrum_into: signal longer than transform");
    let mut xs = ws.take_f64(m * stride);
    for (b, s) in signals.iter().enumerate() {
        xs[b * stride..b * stride + s.len()].copy_from_slice(s);
    }
    let mut sre = ws.take_f64(0);
    let mut sim = ws.take_f64(0);
    fft_real_many_into(&xs, stride, m, n, ws, &mut sre, &mut sim);
    out.clear();
    out.resize(n, ZERO);
    for (k, o) in out.iter_mut().enumerate() {
        let row = k * m;
        let mut pr = sre[row];
        let mut pi = sim[row];
        mul_lane_run(&sre, &sim, row + 1, m - 1, false, &mut pr, &mut pi);
        o.re = pr;
        o.im = pi;
    }
    ws.give_f64(sim);
    ws.give_f64(sre);
    ws.give_f64(xs);
}

/// Linear convolution of real signals into `out`, output length
/// `a.len() + b.len() - 1`, via zero-padded FFT (one packed forward + one
/// half-length inverse).
pub fn conv_linear_into(a: &[f64], b: &[f64], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
    out.clear();
    if a.is_empty() || b.is_empty() {
        return;
    }
    let out_len = a.len() + b.len() - 1;
    let n = out_len.next_power_of_two();
    let mut spec = ws.take_c64(n);
    packed_product_spectrum_into(a, b, n, ws, &mut spec);
    inverse_real_into(&mut spec, ws, out);
    out.truncate(out_len);
    ws.give_c64(spec);
}

/// Allocating wrapper over [`conv_linear_into`].
pub fn conv_linear(a: &[f64], b: &[f64]) -> Vec<f64> {
    with_thread_workspace(|ws| {
        let mut out = Vec::new();
        conv_linear_into(a, b, ws, &mut out);
        out
    })
}

/// Linear convolution of several real signals, all zero-padded to the final
/// output length `Σ len − (k−1)` before a single pointwise product in the
/// spectral domain (this is exactly Eq. 8 of the paper with `J̃`-point FFTs).
pub fn conv_linear_many_into(signals: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
    assert!(!signals.is_empty());
    if signals.len() == 1 {
        out.clear();
        out.extend_from_slice(signals[0]);
        return;
    }
    let out_len = signals.iter().map(|s| s.len()).sum::<usize>() - (signals.len() - 1);
    let n = out_len.next_power_of_two();
    let mut acc = ws.take_c64(n);
    product_spectrum_into(signals, n, ws, &mut acc);
    inverse_real_into(&mut acc, ws, out);
    out.truncate(out_len);
    ws.give_c64(acc);
}

/// Allocating wrapper over [`conv_linear_many_into`].
pub fn conv_linear_many(signals: &[&[f64]]) -> Vec<f64> {
    with_thread_workspace(|ws| {
        let mut out = Vec::new();
        conv_linear_many_into(signals, ws, &mut out);
        out
    })
}

/// Circular convolution of real signals of identical length `J`
/// (the TS mode-J convolution, Eq. 3).
pub fn conv_circular(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "circular convolution needs equal lengths");
    conv_circular_many(&[a, b])
}

/// Circular convolution of several equal-length real signals into `out`.
pub fn conv_circular_many_into(signals: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
    assert!(!signals.is_empty());
    let j = signals[0].len();
    for s in signals {
        assert_eq!(s.len(), j, "circular convolution needs equal lengths");
    }
    if signals.len() == 1 {
        out.clear();
        out.extend_from_slice(signals[0]);
        return;
    }
    let mut acc = ws.take_c64(j);
    product_spectrum_into(signals, j, ws, &mut acc);
    inverse_real_into(&mut acc, ws, out);
    ws.give_c64(acc);
}

/// Allocating wrapper over [`conv_circular_many_into`].
pub fn conv_circular_many(signals: &[&[f64]]) -> Vec<f64> {
    with_thread_workspace(|ws| {
        let mut out = Vec::new();
        conv_circular_many_into(signals, ws, &mut out);
        out
    })
}

/// Cross-correlation style product used in Eq. 17:
/// `F^{-1}( F(z) * conj(F(a)) * conj(F(b)) )` over a common length `n`
/// (signals zero-padded). Writes real parts, length `n`, into `out`.
pub fn spectral_corr_into(
    z: &[f64],
    conj_with: &[&[f64]],
    n: usize,
    ws: &mut FftWorkspace,
    out: &mut Vec<f64>,
) {
    let mut fz = ws.take_c64(n);
    fft_real_into(z, n, ws, &mut fz);
    let mut fs = ws.take_c64(n);
    for s in conj_with {
        fft_real_into(s, n, ws, &mut fs);
        for (x, y) in fz.iter_mut().zip(fs.iter()) {
            *x = *x * y.conj();
        }
    }
    inverse_real_into(&mut fz, ws, out);
    ws.give_c64(fs);
    ws.give_c64(fz);
}

/// Allocating wrapper over [`spectral_corr_into`].
pub fn spectral_corr(z: &[f64], conj_with: &[&[f64]], n: usize) -> Vec<f64> {
    with_thread_workspace(|ws| {
        let mut out = Vec::with_capacity(n);
        spectral_corr_into(z, conj_with, n, ws, &mut out);
        out
    })
}

/// Naive O(n·m) linear convolution — oracle for tests.
pub fn conv_linear_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let mut out = vec![0.0; a.len() + b.len() - 1];
    for (i, &x) in a.iter().enumerate() {
        for (j, &y) in b.iter().enumerate() {
            out[i + j] += x * y;
        }
    }
    out
}

/// Naive circular convolution — oracle for tests.
pub fn conv_circular_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
    let j = a.len();
    let mut out = vec![0.0; j];
    for (i, &x) in a.iter().enumerate() {
        for (k, &y) in b.iter().enumerate() {
            out[(i + k) % j] += x * y;
        }
    }
    out
}

/// Pointwise complex product of two spectra (exported for the L1 kernel
/// parity tests against `python/compile/kernels/conv_mult.py`).
pub fn spectra_mul(a: &[C64], b: &[C64]) -> Vec<C64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x * *y).collect()
}

/// Forward FFT of a real signal at its own length (no padding), exposed for
/// parity tests with the python reference.
pub fn spectrum(x: &[f64]) -> Vec<C64> {
    super::plan::fft_real(x, x.len())
}

/// Inverse of `spectrum` — unified with `ifft_to_real` (both delegate to
/// [`inverse_real_into`], which debug-asserts the imaginary residue instead
/// of silently discarding it).
pub fn inverse_spectrum(spec: Vec<C64>) -> Vec<f64> {
    super::plan::ifft_to_real(spec)
}

/// Zero-pad helper.
pub fn zero_pad(x: &[f64], n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    v[..x.len()].copy_from_slice(x);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::qcheck::qcheck;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn linear_matches_naive() {
        let mut rng = Rng::seed_from_u64(10);
        for &(n, m) in &[(1usize, 1usize), (3, 5), (17, 9), (100, 57), (255, 255)] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(m);
            let fast = conv_linear(&a, &b);
            let slow = conv_linear_naive(&a, &b);
            assert!(max_err(&fast, &slow) < 1e-8 * (n + m) as f64);
        }
    }

    #[test]
    fn circular_matches_naive() {
        let mut rng = Rng::seed_from_u64(11);
        for &n in &[1usize, 2, 5, 16, 100, 243] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let fast = conv_circular(&a, &b);
            let slow = conv_circular_naive(&a, &b);
            assert!(max_err(&fast, &slow) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn many_equals_pairwise_chain() {
        let mut rng = Rng::seed_from_u64(12);
        let a = rng.normal_vec(13);
        let b = rng.normal_vec(7);
        let c = rng.normal_vec(9);
        let chained = conv_linear(&conv_linear(&a, &b), &c);
        let many = conv_linear_many(&[&a, &b, &c]);
        assert_eq!(chained.len(), many.len());
        assert!(max_err(&chained, &many) < 1e-8);
    }

    #[test]
    fn into_variants_match_allocating_and_reuse_workspace() {
        let mut rng = Rng::seed_from_u64(15);
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();
        for _ in 0..3 {
            let a = rng.normal_vec(21);
            let b = rng.normal_vec(33);
            let c = rng.normal_vec(5);
            conv_linear_many_into(&[&a, &b, &c], &mut ws, &mut out);
            assert!(max_err(&out, &conv_linear_many(&[&a, &b, &c])) < 1e-10);
            conv_linear_into(&a, &b, &mut ws, &mut out);
            assert!(max_err(&out, &conv_linear_naive(&a, &b)) < 1e-8);
            let d = rng.normal_vec(21);
            conv_circular_many_into(&[&a, &d], &mut ws, &mut out);
            assert!(max_err(&out, &conv_circular_naive(&a, &d)) < 1e-8);
            let z = rng.normal_vec(16);
            spectral_corr_into(&z, &[&c], 16, &mut ws, &mut out);
            assert!(max_err(&out, &spectral_corr(&z, &[&c], 16)) < 1e-10);
        }
    }

    #[test]
    fn circular_is_linear_mod_j() {
        // circular(a,b)[k] = Σ_{k' ≡ k mod J} linear(a,b)[k'] — the exact
        // relation between TS and FCS outputs (paper §3, point 2).
        let mut rng = Rng::seed_from_u64(13);
        let j = 11;
        let a = rng.normal_vec(j);
        let b = rng.normal_vec(j);
        let lin = conv_linear(&a, &b);
        let circ = conv_circular(&a, &b);
        let mut folded = vec![0.0; j];
        for (k, &v) in lin.iter().enumerate() {
            folded[k % j] += v;
        }
        assert!(max_err(&folded, &circ) < 1e-9);
    }

    #[test]
    fn conv_commutative_property() {
        qcheck(25, |g| {
            let n = g.usize_in(1, 60);
            let m = g.usize_in(1, 60);
            let a = g.f64_vec(n, -1.0, 1.0);
            let b = g.f64_vec(m, -1.0, 1.0);
            let ab = conv_linear(&a, &b);
            let ba = conv_linear(&b, &a);
            assert!(max_err(&ab, &ba) < 1e-9);
        });
    }

    #[test]
    fn spectral_corr_matches_definition() {
        // <z ⊛ reverse-correlation> check: spectral_corr(z,[a],n)[i] should
        // equal Σ_k z[(i+k) mod n] a[k] for zero-padded a, z.
        let mut rng = Rng::seed_from_u64(14);
        let n = 16;
        let z = rng.normal_vec(n);
        let a = rng.normal_vec(5);
        let out = spectral_corr(&z, &[&a], n);
        let apad = zero_pad(&a, n);
        for i in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += z[(i + k) % n] * apad[k];
            }
            assert!((out[i] - acc).abs() < 1e-9, "i={i}");
        }
    }
}
