//! Convolutions on top of the FFT plans.
//!
//! The distinction between **linear** (zero-padded) and **circular**
//! (mod-J wraparound) convolution is the heart of the paper: TS (Eq. 3) uses
//! circular convolution of the per-mode count sketches; FCS (Eq. 8) uses
//! linear convolution, which preserves the composite hash `Σ h_n(i_n) − N + 1`
//! without the modulo that destroys spatial structure.

use super::complex::{C64, ZERO};
use super::plan::{fft_inplace, fft_real, ifft_inplace, ifft_to_real};

/// Product spectrum `F(a)·F(b)` of two real signals at length `n`, computed
/// with **one** complex FFT via the real-pair packing identity: with
/// `Z = F(a + i·b)`, Hermitian symmetry gives
/// `F(a)[k]·F(b)[k] = (Z[k]² − conj(Z[n−k])²) · (−i/4)` (§Perf: halves the
/// forward-FFT work in every convolution).
pub fn packed_product_spectrum(a: &[f64], b: &[f64], n: usize) -> Vec<C64> {
    debug_assert!(a.len() <= n && b.len() <= n);
    let mut z = vec![ZERO; n];
    for (i, &v) in a.iter().enumerate() {
        z[i].re = v;
    }
    for (i, &v) in b.iter().enumerate() {
        z[i].im = v;
    }
    fft_inplace(&mut z);
    let quarter_negi = C64::new(0.0, -0.25);
    let mut out = vec![ZERO; n];
    for k in 0..n {
        let zk = z[k];
        let zmk = z[(n - k) % n].conj();
        out[k] = (zk * zk - zmk * zmk) * quarter_negi;
    }
    out
}

/// Linear convolution of real signals, output length `a.len() + b.len() - 1`,
/// computed via zero-padded FFT (one packed forward + one inverse).
pub fn conv_linear(a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let out_len = a.len() + b.len() - 1;
    let n = out_len.next_power_of_two();
    let spec = packed_product_spectrum(a, b, n);
    let mut out = ifft_to_real(spec);
    out.truncate(out_len);
    out
}

/// Linear convolution of several real signals, all zero-padded to the final
/// output length `Σ len − (k−1)` before a single pointwise product in the
/// spectral domain (this is exactly Eq. 8 of the paper with `J̃`-point FFTs).
pub fn conv_linear_many(signals: &[&[f64]]) -> Vec<f64> {
    assert!(!signals.is_empty());
    if signals.len() == 1 {
        return signals[0].to_vec();
    }
    let out_len = signals.iter().map(|s| s.len()).sum::<usize>() - (signals.len() - 1);
    let n = out_len.next_power_of_two();
    // Consume signals pairwise through the packing trick.
    let mut acc = packed_product_spectrum(signals[0], signals[1], n);
    let mut rest = &signals[2..];
    while rest.len() >= 2 {
        let spec = packed_product_spectrum(rest[0], rest[1], n);
        for (x, y) in acc.iter_mut().zip(&spec) {
            *x = *x * *y;
        }
        rest = &rest[2..];
    }
    if let Some(s) = rest.first() {
        let fs = fft_real(s, n);
        for (x, y) in acc.iter_mut().zip(fs.iter()) {
            *x = *x * *y;
        }
    }
    let mut out = ifft_to_real(acc);
    out.truncate(out_len);
    out
}

/// Circular convolution of real signals of identical length `J`
/// (the TS mode-J convolution, Eq. 3).
pub fn conv_circular(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "circular convolution needs equal lengths");
    let j = a.len();
    let mut fa = fft_real(a, j);
    let fb = fft_real(b, j);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = *x * *y;
    }
    ifft_to_real(fa)
}

/// Circular convolution of several equal-length real signals.
pub fn conv_circular_many(signals: &[&[f64]]) -> Vec<f64> {
    assert!(!signals.is_empty());
    let j = signals[0].len();
    let mut acc = fft_real(signals[0], j);
    for s in &signals[1..] {
        assert_eq!(s.len(), j);
        let fs = fft_real(s, j);
        for (x, y) in acc.iter_mut().zip(fs.iter()) {
            *x = *x * *y;
        }
    }
    ifft_to_real(acc)
}

/// Cross-correlation style product used in Eq. 17:
/// `F^{-1}( F(z) * conj(F(a)) * conj(F(b)) )` over a common length `n`
/// (signals zero-padded). Returns real parts, length `n`.
pub fn spectral_corr(z: &[f64], conj_with: &[&[f64]], n: usize) -> Vec<f64> {
    let mut fz = fft_real(z, n);
    for s in conj_with {
        let fs = fft_real(s, n);
        for (x, y) in fz.iter_mut().zip(fs.iter()) {
            *x = *x * y.conj();
        }
    }
    ifft_to_real(fz)
}

/// Naive O(n·m) linear convolution — oracle for tests.
pub fn conv_linear_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let mut out = vec![0.0; a.len() + b.len() - 1];
    for (i, &x) in a.iter().enumerate() {
        for (j, &y) in b.iter().enumerate() {
            out[i + j] += x * y;
        }
    }
    out
}

/// Naive circular convolution — oracle for tests.
pub fn conv_circular_naive(a: &[f64], b: &[f64]) -> Vec<f64> {
    let j = a.len();
    let mut out = vec![0.0; j];
    for (i, &x) in a.iter().enumerate() {
        for (k, &y) in b.iter().enumerate() {
            out[(i + k) % j] += x * y;
        }
    }
    out
}

/// Pointwise complex product of two spectra (exported for the L1 kernel
/// parity tests against `python/compile/kernels/conv_mult.py`).
pub fn spectra_mul(a: &[C64], b: &[C64]) -> Vec<C64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x * *y).collect()
}

/// Forward FFT of a real signal at its own length (no padding), exposed for
/// parity tests with the python reference.
pub fn spectrum(x: &[f64]) -> Vec<C64> {
    fft_real(x, x.len())
}

/// Inverse of `spectrum`.
pub fn inverse_spectrum(mut s: Vec<C64>) -> Vec<f64> {
    ifft_inplace(&mut s);
    s.into_iter().map(|z| z.re).collect()
}

/// Zero-pad helper.
pub fn zero_pad(x: &[f64], n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    v[..x.len()].copy_from_slice(x);
    v
}

#[allow(dead_code)]
fn _unused(_: C64) {
    let _ = ZERO;
    let mut v = vec![ZERO; 2];
    fft_inplace(&mut v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::qcheck::qcheck;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn linear_matches_naive() {
        let mut rng = Rng::seed_from_u64(10);
        for &(n, m) in &[(1usize, 1usize), (3, 5), (17, 9), (100, 57), (255, 255)] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(m);
            let fast = conv_linear(&a, &b);
            let slow = conv_linear_naive(&a, &b);
            assert!(max_err(&fast, &slow) < 1e-8 * (n + m) as f64);
        }
    }

    #[test]
    fn circular_matches_naive() {
        let mut rng = Rng::seed_from_u64(11);
        for &n in &[1usize, 2, 5, 16, 100, 243] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let fast = conv_circular(&a, &b);
            let slow = conv_circular_naive(&a, &b);
            assert!(max_err(&fast, &slow) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn many_equals_pairwise_chain() {
        let mut rng = Rng::seed_from_u64(12);
        let a = rng.normal_vec(13);
        let b = rng.normal_vec(7);
        let c = rng.normal_vec(9);
        let chained = conv_linear(&conv_linear(&a, &b), &c);
        let many = conv_linear_many(&[&a, &b, &c]);
        assert_eq!(chained.len(), many.len());
        assert!(max_err(&chained, &many) < 1e-8);
    }

    #[test]
    fn circular_is_linear_mod_j() {
        // circular(a,b)[k] = Σ_{k' ≡ k mod J} linear(a,b)[k'] — the exact
        // relation between TS and FCS outputs (paper §3, point 2).
        let mut rng = Rng::seed_from_u64(13);
        let j = 11;
        let a = rng.normal_vec(j);
        let b = rng.normal_vec(j);
        let lin = conv_linear(&a, &b);
        let circ = conv_circular(&a, &b);
        let mut folded = vec![0.0; j];
        for (k, &v) in lin.iter().enumerate() {
            folded[k % j] += v;
        }
        assert!(max_err(&folded, &circ) < 1e-9);
    }

    #[test]
    fn conv_commutative_property() {
        qcheck(25, |g| {
            let n = g.usize_in(1, 60);
            let m = g.usize_in(1, 60);
            let a = g.f64_vec(n, -1.0, 1.0);
            let b = g.f64_vec(m, -1.0, 1.0);
            let ab = conv_linear(&a, &b);
            let ba = conv_linear(&b, &a);
            assert!(max_err(&ab, &ba) < 1e-9);
        });
    }

    #[test]
    fn spectral_corr_matches_definition() {
        // <z ⊛ reverse-correlation> check: spectral_corr(z,[a],n)[i] should
        // equal Σ_k z[(i+k) mod n] a[k] for zero-padded a, z.
        let mut rng = Rng::seed_from_u64(14);
        let n = 16;
        let z = rng.normal_vec(n);
        let a = rng.normal_vec(5);
        let out = spectral_corr(&z, &[&a], n);
        let apad = zero_pad(&a, n);
        for i in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += z[(i + k) % n] * apad[k];
            }
            assert!((out[i] - acc).abs() < 1e-9, "i={i}");
        }
    }
}
