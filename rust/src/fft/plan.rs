//! FFT plans: iterative radix-2 Cooley-Tukey for power-of-two lengths and
//! Bluestein's algorithm (chirp-z) for arbitrary lengths. Plans cache
//! twiddle factors and bit-reversal tables; the planner memoizes plans per
//! length so repeated transforms (the FCS hot path runs thousands at the
//! same `J̃`) pay setup once.

use super::complex::{C64, ONE, ZERO};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Direction of the transform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Forward,
    Inverse,
}

/// A radix-2 plan for power-of-two `n`.
#[derive(Debug)]
struct Radix2Plan {
    n: usize,
    /// Bit-reversal permutation.
    rev: Vec<u32>,
    /// Twiddles for the forward transform, grouped per stage:
    /// stage with half-size `m` uses `twiddle[m + k]` = e^{-i pi k / m}.
    twiddles: Vec<C64>,
}

impl Radix2Plan {
    fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n > 0);
        let bits = n.trailing_zeros();
        let mut rev = vec![0u32; n];
        for i in 0..n {
            rev[i] = (i as u32).reverse_bits() >> (32 - bits.max(1));
            if n == 1 {
                rev[i] = 0;
            }
        }
        // Twiddle table indexed like a binary heap: for each half-size m
        // (1, 2, 4, ..., n/2) store m roots at offset m.
        let mut twiddles = vec![ZERO; n.max(2)];
        let mut m = 1usize;
        while m < n {
            for k in 0..m {
                twiddles[m + k] = C64::cis(-std::f64::consts::PI * k as f64 / m as f64);
            }
            m <<= 1;
        }
        Self { n, rev, twiddles }
    }

    fn process(&self, data: &mut [C64], dir: Dir) {
        let n = self.n;
        debug_assert_eq!(data.len(), n);
        if n == 1 {
            return;
        }
        // Inverse via conjugation: F⁻¹(x) = conj(F(conj(x)))/n — keeps the
        // butterfly loop branch-free (§Perf).
        if dir == Dir::Inverse {
            for x in data.iter_mut() {
                x.im = -x.im;
            }
            self.process(data, Dir::Forward);
            let inv = 1.0 / n as f64;
            for x in data.iter_mut() {
                x.re *= inv;
                x.im *= -inv;
            }
            return;
        }
        // Bit-reversal permutation.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        // Stage m=1 specialized: w = 1 for every butterfly.
        {
            let mut base = 0usize;
            while base < n {
                let a = data[base];
                let b = data[base + 1];
                data[base] = a + b;
                data[base + 1] = a - b;
                base += 2;
            }
        }
        // Stage m=2 specialized: w ∈ {1, −i}.
        if n >= 4 {
            let mut base = 0usize;
            while base < n {
                let a0 = data[base];
                let b0 = data[base + 2];
                data[base] = a0 + b0;
                data[base + 2] = a0 - b0;
                let a1 = data[base + 1];
                let b1 = data[base + 3];
                let rb = C64::new(b1.im, -b1.re); // b · (−i)
                data[base + 1] = a1 + rb;
                data[base + 3] = a1 - rb;
                base += 4;
            }
        }
        // Remaining stages: forward twiddles, branch-free.
        let mut m = 4usize;
        while m < n {
            let stride = m << 1;
            let tw = &self.twiddles[m..m + m];
            let mut base = 0usize;
            while base < n {
                let (lo, hi) = data[base..base + stride].split_at_mut(m);
                for k in 0..m {
                    let w = tw[k];
                    let a = lo[k];
                    let b = hi[k] * w;
                    lo[k] = a + b;
                    hi[k] = a - b;
                }
                base += stride;
            }
            m = stride;
        }
    }
}

/// Bluestein plan for arbitrary `n`: expresses the length-`n` DFT as a
/// convolution of length `m >= 2n-1`, `m` a power of two.
#[derive(Debug)]
struct BluesteinPlan {
    n: usize,
    m: usize,
    inner: Radix2Plan,
    /// chirp[k] = e^{-i pi k^2 / n} for k in [0, n)
    chirp: Vec<C64>,
    /// FFT of the (conjugated, wrapped) chirp kernel, length m.
    kernel_fft: Vec<C64>,
}

impl BluesteinPlan {
    fn new(n: usize) -> Self {
        assert!(n > 0);
        let m = (2 * n - 1).next_power_of_two();
        let inner = Radix2Plan::new(m);
        let mut chirp = vec![ZERO; n];
        for k in 0..n {
            // k^2 mod 2n keeps the angle argument small & exact.
            let kk = (k as u128 * k as u128 % (2 * n as u128)) as f64;
            chirp[k] = C64::cis(-std::f64::consts::PI * kk / n as f64);
        }
        let mut kernel = vec![ZERO; m];
        kernel[0] = chirp[0].conj();
        for k in 1..n {
            kernel[k] = chirp[k].conj();
            kernel[m - k] = chirp[k].conj();
        }
        inner.process(&mut kernel, Dir::Forward);
        Self { n, m, inner, chirp, kernel_fft: kernel }
    }

    /// `scratch` is the length-`m` convolution buffer — caller-owned so hot
    /// loops (via [`super::workspace::FftWorkspace`]) reuse it instead of
    /// allocating per transform.
    fn process_scratch(&self, data: &mut [C64], dir: Dir, scratch: &mut Vec<C64>) {
        let n = self.n;
        debug_assert_eq!(data.len(), n);
        scratch.clear();
        scratch.resize(self.m, ZERO);
        let a = scratch;
        match dir {
            Dir::Forward => {
                for k in 0..n {
                    a[k] = data[k] * self.chirp[k];
                }
            }
            Dir::Inverse => {
                // inverse DFT = conj(forward DFT of conj(x))/n
                for k in 0..n {
                    a[k] = data[k].conj() * self.chirp[k];
                }
            }
        }
        self.inner.process(a, Dir::Forward);
        for (x, k) in a.iter_mut().zip(self.kernel_fft.iter()) {
            *x = *x * *k;
        }
        self.inner.process(a, Dir::Inverse);
        match dir {
            Dir::Forward => {
                for k in 0..n {
                    data[k] = a[k] * self.chirp[k];
                }
            }
            Dir::Inverse => {
                let inv = 1.0 / n as f64;
                for k in 0..n {
                    data[k] = (a[k] * self.chirp[k]).conj().scale(inv);
                }
            }
        }
    }
}

/// A plan for one transform length.
#[derive(Debug)]
enum PlanKind {
    Radix2(Radix2Plan),
    Bluestein(BluesteinPlan),
}

/// Shareable FFT plan for a fixed length.
#[derive(Debug)]
pub struct Plan {
    kind: PlanKind,
    pub n: usize,
}

impl Plan {
    pub fn new(n: usize) -> Self {
        let kind = if n.is_power_of_two() {
            PlanKind::Radix2(Radix2Plan::new(n))
        } else {
            PlanKind::Bluestein(BluesteinPlan::new(n))
        };
        Self { kind, n }
    }

    /// In-place transform. `data.len()` must equal `self.n`.
    pub fn process(&self, data: &mut [C64], dir: Dir) {
        let mut scratch = Vec::new();
        self.process_scratch(data, dir, &mut scratch);
    }

    /// In-place transform with caller-owned Bluestein scratch (unused for
    /// power-of-two lengths). Zero-allocation when `scratch` has capacity.
    pub fn process_scratch(&self, data: &mut [C64], dir: Dir, scratch: &mut Vec<C64>) {
        assert_eq!(data.len(), self.n, "FFT plan length mismatch");
        match &self.kind {
            PlanKind::Radix2(p) => p.process(data, dir),
            PlanKind::Bluestein(p) => p.process_scratch(data, dir, scratch),
        }
    }
}

/// Recombination twiddles for the packed real-input transform of even
/// length `n = 2m`: `twiddles[k] = e^{-iπk/m}` for `k ∈ [0, m)`. The forward
/// split-spectrum step multiplies by `twiddles[k]`, the inverse by its
/// conjugate — previously both recomputed a `sin_cos` per point per call
/// (ROADMAP follow-up: "cache rfft twiddles per length").
#[derive(Debug)]
pub struct RealPlan {
    /// Transform length `n` (even).
    pub n: usize,
    /// `e^{-iπk/m}`, `m = n/2`.
    pub twiddles: Vec<C64>,
}

impl RealPlan {
    fn new(n: usize) -> Self {
        assert!(n > 0 && n % 2 == 0, "RealPlan requires even n");
        let m = n / 2;
        let twiddles = (0..m)
            .map(|k| C64::cis(-std::f64::consts::PI * k as f64 / m as f64))
            .collect();
        Self { n, twiddles }
    }
}

/// Process-wide plan cache. The FCS hot loop transforms many vectors of the
/// same length; building twiddles once matters (§Perf).
#[derive(Default)]
pub struct Planner {
    plans: Mutex<HashMap<usize, Arc<Plan>>>,
    real_plans: Mutex<HashMap<usize, Arc<RealPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Planner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Double-checked cache lookup shared by both plan maps: the (possibly
    /// expensive — Bluestein builds a 2×-padded kernel FFT) construction
    /// happens **outside** the mutex, so a large build never blocks
    /// concurrent sketching threads that want already-cached lengths. Also
    /// the single home of the hit/miss accounting the alloc-discipline test
    /// asserts on.
    fn cached<P>(
        &self,
        map: &Mutex<HashMap<usize, Arc<P>>>,
        n: usize,
        build: impl FnOnce(usize) -> P,
    ) -> Arc<P> {
        if let Some(p) = map.lock().unwrap().get(&n) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return p.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build(n));
        let mut guard = map.lock().unwrap();
        guard.entry(n).or_insert(built).clone()
    }

    /// Plan lookup (see [`Self::cached`] for the insert discipline).
    pub fn plan(&self, n: usize) -> Arc<Plan> {
        self.cached(&self.plans, n, Plan::new)
    }

    /// Cached recombination twiddles for the even-length packed real
    /// transform (same discipline as [`Self::plan`]).
    pub fn real_plan(&self, n: usize) -> Arc<RealPlan> {
        self.cached(&self.real_plans, n, RealPlan::new)
    }

    /// `(hits, misses)` across both plan caches — lets tests assert that
    /// steady-state transforms are served from cache (hits grow, misses
    /// stay flat).
    pub fn cache_counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

/// Global planner instance.
pub fn global_planner() -> &'static Planner {
    static PLANNER: std::sync::OnceLock<Planner> = std::sync::OnceLock::new();
    PLANNER.get_or_init(Planner::new)
}

/// Convenience: forward FFT of a complex buffer (in place).
pub fn fft_inplace(data: &mut [C64]) {
    super::workspace::with_thread_workspace(|ws| ws.process(data, Dir::Forward));
}

/// Convenience: inverse FFT of a complex buffer (in place).
pub fn ifft_inplace(data: &mut [C64]) {
    super::workspace::with_thread_workspace(|ws| ws.process(data, Dir::Inverse));
}

/// Forward FFT of a real signal zero-padded to length `n` (allocating
/// wrapper over [`super::workspace::fft_real_into`] — even `n` runs as a
/// half-length complex transform).
pub fn fft_real(x: &[f64], n: usize) -> Vec<C64> {
    super::workspace::with_thread_workspace(|ws| {
        let mut out = Vec::with_capacity(n);
        super::workspace::fft_real_into(x, n, ws, &mut out);
        out
    })
}

/// Inverse FFT of a Hermitian spectrum, returning the real signal
/// (allocating wrapper over [`super::workspace::inverse_real_into`], which
/// debug-asserts the discarded imaginary residue is below tolerance).
pub fn ifft_to_real(mut spec: Vec<C64>) -> Vec<f64> {
    super::workspace::with_thread_workspace(|ws| {
        let mut out = Vec::with_capacity(spec.len());
        super::workspace::inverse_real_into(&mut spec, ws, &mut out);
        out
    })
}

/// Naive O(n^2) DFT — oracle for tests.
pub fn dft_naive(x: &[C64], dir: Dir) -> Vec<C64> {
    let n = x.len();
    let sign = if dir == Dir::Forward { -1.0 } else { 1.0 };
    let mut out = vec![ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = ZERO;
        for (j, &v) in x.iter().enumerate() {
            let ang = sign * 2.0 * std::f64::consts::PI * (k as u128 * j as u128 % n as u128) as f64
                / n as f64;
            acc += v * C64::cis(ang);
        }
        *o = if dir == Dir::Inverse { acc.scale(1.0 / n as f64) } else { acc };
    }
    let _ = ONE;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_signal(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn radix2_matches_naive() {
        let mut rng = Rng::seed_from_u64(1);
        for &n in &[1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(&mut rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            let z = dft_naive(&x, Dir::Forward);
            assert!(max_err(&y, &z) < 1e-9 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        let mut rng = Rng::seed_from_u64(2);
        for &n in &[3usize, 5, 6, 7, 12, 100, 299, 997] {
            let x = rand_signal(&mut rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            let z = dft_naive(&x, Dir::Forward);
            assert!(max_err(&y, &z) < 1e-8 * (n as f64), "n={n} err={}", max_err(&y, &z));
        }
    }

    #[test]
    fn planner_caches_plans_and_real_plans() {
        let p = Planner::new();
        assert_eq!(p.cache_counters(), (0, 0));
        let a = p.plan(16);
        let b = p.plan(16);
        assert!(Arc::ptr_eq(&a, &b));
        let ra = p.real_plan(16);
        let rb = p.real_plan(16);
        assert!(Arc::ptr_eq(&ra, &rb));
        let (h, m) = p.cache_counters();
        assert_eq!((h, m), (2, 2));
        for (k, w) in ra.twiddles.iter().enumerate() {
            let expect = C64::cis(-std::f64::consts::PI * k as f64 / 8.0);
            assert!((*w - expect).abs() < 1e-15, "k={k}");
        }
    }

    #[test]
    fn roundtrip_forward_inverse() {
        let mut rng = Rng::seed_from_u64(3);
        for &n in &[2usize, 17, 128, 1000, 4093] {
            let x = rand_signal(&mut rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            ifft_inplace(&mut y);
            assert!(max_err(&x, &y) < 1e-9 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn real_transform_is_hermitian() {
        let mut rng = Rng::seed_from_u64(4);
        let x: Vec<f64> = rng.normal_vec(37);
        let spec = fft_real(&x, 64);
        for k in 1..64 {
            let err = (spec[k] - spec[64 - k].conj()).abs();
            assert!(err < 1e-10, "k={k}");
        }
    }

    #[test]
    fn linearity_property() {
        use crate::util::qcheck::qcheck;
        qcheck(30, |g| {
            let n = g.usize_in(2, 200);
            let a: Vec<C64> = (0..n).map(|_| C64::new(g.f64_in(-1.0, 1.0), g.f64_in(-1.0, 1.0))).collect();
            let b: Vec<C64> = (0..n).map(|_| C64::new(g.f64_in(-1.0, 1.0), g.f64_in(-1.0, 1.0))).collect();
            let alpha = g.f64_in(-2.0, 2.0);
            let mut lhs: Vec<C64> = a.iter().zip(&b).map(|(x, y)| *x + y.scale(alpha)).collect();
            fft_inplace(&mut lhs);
            let mut fa = a.clone();
            fft_inplace(&mut fa);
            let mut fb = b.clone();
            fft_inplace(&mut fb);
            let rhs: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| *x + y.scale(alpha)).collect();
            let err = lhs.iter().zip(&rhs).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max);
            assert!(err < 1e-8 * n as f64);
        });
    }
}
