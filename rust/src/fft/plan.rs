//! FFT plans: an iterative, batch-capable radix-4 kernel on **split re/im
//! planes** (structure-of-arrays) for power-of-two lengths, and Bluestein's
//! algorithm (chirp-z) for arbitrary lengths, composed over the same kernel.
//!
//! The radix-4 stages are fused pairs of radix-2 stages (3 complex multiplies
//! per 4 outputs instead of 4, and half the passes over the data), driven off
//! a precomputed bit-reversal permutation and per-stage twiddle tables stored
//! contiguously in the plan. Because the planes are plain `f64` arrays and
//! [`Plan::process_many`] keeps the batch as the innermost axis, the butterfly
//! inner loops autovectorize without explicit intrinsics.
//!
//! The planner memoizes plans per length so repeated transforms (the FCS hot
//! path runs thousands at the same `J̃`) pay setup once. The pre-existing
//! scalar interleaved radix-2 kernel survives as [`ScalarRadix2Plan`], an
//! independent oracle for the conformance tests and the §Perf baseline.

use super::complex::{C64, ONE, ZERO};
use std::collections::HashMap;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};

/// Direction of the transform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Forward,
    Inverse,
}

/// Reusable scratch planes for the split-plane kernel: the interleaved-`C64`
/// entry points stage data through `re`/`im`, and Bluestein's inner
/// convolution runs in `conv_re`/`conv_im`. Caller-owned so hot loops (via
/// [`super::workspace::FftWorkspace`]) reuse the planes instead of
/// allocating per transform.
#[derive(Debug, Default)]
pub struct FftScratch {
    re: Vec<f64>,
    im: Vec<f64>,
    conv_re: Vec<f64>,
    conv_im: Vec<f64>,
}

impl FftScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

// ---------------------------------------------------------------------------
// Split-plane radix-4 kernel (power-of-two lengths)
// ---------------------------------------------------------------------------

/// One fused pair of radix-2 stages (half-sizes `m` and `2m`) — i.e. one
/// radix-4 stage. Its twiddles live at `tw[off..off+m]` (`w1[k] = e^{-iπk/m}`,
/// the inner radix-2 stage) and `tw[off+m..off+2m]` (`w2[k] = e^{-iπk/2m}`,
/// the outer one; the upper half `w2[m+k] = -i·w2[k]` is folded into the
/// butterfly instead of being stored).
#[derive(Debug, Clone, Copy)]
struct Stage {
    m: usize,
    off: usize,
}

/// Iterative DIT radix-4 kernel for power-of-two `n`, operating on split
/// re/im planes with an arbitrary batch as the innermost axis. Derived by
/// fusing consecutive stages of the classic radix-2 flow graph, so it shares
/// its bit-reversal permutation; an odd `log2(n)` runs one leading radix-2
/// stage (all twiddles 1) before the radix-4 sweep.
#[derive(Debug)]
struct Radix4Plan {
    n: usize,
    /// Bit-reversal permutation.
    rev: Vec<u32>,
    /// `log2(n)` odd ⇒ one leading half-size-1 radix-2 stage.
    head_radix2: bool,
    stages: Vec<Stage>,
    /// Per-stage twiddles, contiguous split planes (see [`Stage`]).
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
}

impl Radix4Plan {
    fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n > 0);
        let bits = n.trailing_zeros();
        let mut rev = vec![0u32; n];
        if n > 1 {
            for (i, r) in rev.iter_mut().enumerate() {
                *r = (i as u32).reverse_bits() >> (32 - bits);
            }
        }
        let head_radix2 = bits % 2 == 1;
        let mut stages = Vec::new();
        let mut tw_re = Vec::new();
        let mut tw_im = Vec::new();
        let mut m = if head_radix2 { 2usize } else { 1usize };
        while m < n {
            let off = tw_re.len();
            for k in 0..m {
                let w = C64::cis(-std::f64::consts::PI * k as f64 / m as f64);
                tw_re.push(w.re);
                tw_im.push(w.im);
            }
            for k in 0..m {
                let w = C64::cis(-std::f64::consts::PI * k as f64 / (2 * m) as f64);
                tw_re.push(w.re);
                tw_im.push(w.im);
            }
            stages.push(Stage { m, off });
            m *= 4;
        }
        Self { n, rev, head_radix2, stages, tw_re, tw_im }
    }

    /// In-place batched transform: `re`/`im` hold `batch` signals lane-major
    /// (`re[k*batch + b]` is element `k` of signal `b`).
    fn process(&self, re: &mut [f64], im: &mut [f64], batch: usize, dir: Dir) {
        let n = self.n;
        debug_assert_eq!(re.len(), n * batch);
        debug_assert_eq!(im.len(), n * batch);
        if n == 1 || batch == 0 {
            return;
        }
        // Inverse via conjugation: F⁻¹(x) = conj(F(conj(x)))/n — keeps the
        // butterfly loops branch-free (§Perf).
        if dir == Dir::Inverse {
            for v in im.iter_mut() {
                *v = -*v;
            }
            self.process(re, im, batch, Dir::Forward);
            let inv = 1.0 / n as f64;
            for v in re.iter_mut() {
                *v *= inv;
            }
            for v in im.iter_mut() {
                *v *= -inv;
            }
            return;
        }
        // Bit-reversal permutation, whole rows of `batch` lanes.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                for l in 0..batch {
                    re.swap(i * batch + l, j * batch + l);
                    im.swap(i * batch + l, j * batch + l);
                }
            }
        }
        // Leading radix-2 stage for odd log2(n): half-size 1, w = 1.
        if self.head_radix2 {
            let pair = 2 * batch;
            for (bre, bim) in re.chunks_exact_mut(pair).zip(im.chunks_exact_mut(pair)) {
                let (ar, br) = bre.split_at_mut(batch);
                let (ai, bi) = bim.split_at_mut(batch);
                for l in 0..batch {
                    let (xr, xi) = (ar[l], ai[l]);
                    let (yr, yi) = (br[l], bi[l]);
                    ar[l] = xr + yr;
                    ai[l] = xi + yi;
                    br[l] = xr - yr;
                    bi[l] = xi - yi;
                }
            }
        }
        // Radix-4 sweep. Per block of 4m rows [A | B | C | D] and twiddle
        // index k, the fused butterflies are
        //   t0 = A + w1·B   t1 = A − w1·B   t2 = C + w1·D   t3 = C − w1·D
        //   A' = t0 + w2·t2          C' = t0 − w2·t2
        //   B' = t1 − i·w2·t3        D' = t1 + i·w2·t3
        // (exactly radix-2 stages m then 2m of the standard flow graph).
        for st in &self.stages {
            let m = st.m;
            let tw1_re = &self.tw_re[st.off..st.off + m];
            let tw1_im = &self.tw_im[st.off..st.off + m];
            let tw2_re = &self.tw_re[st.off + m..st.off + 2 * m];
            let tw2_im = &self.tw_im[st.off + m..st.off + 2 * m];
            let quarter = m * batch;
            for (blk_re, blk_im) in
                re.chunks_exact_mut(4 * quarter).zip(im.chunks_exact_mut(4 * quarter))
            {
                let (a_re, rest) = blk_re.split_at_mut(quarter);
                let (b_re, rest) = rest.split_at_mut(quarter);
                let (c_re, d_re) = rest.split_at_mut(quarter);
                let (a_im, rest) = blk_im.split_at_mut(quarter);
                let (b_im, rest) = rest.split_at_mut(quarter);
                let (c_im, d_im) = rest.split_at_mut(quarter);
                for k in 0..m {
                    let (w1r, w1i) = (tw1_re[k], tw1_im[k]);
                    let (w2r, w2i) = (tw2_re[k], tw2_im[k]);
                    let off = k * batch;
                    let ar = &mut a_re[off..off + batch];
                    let ai = &mut a_im[off..off + batch];
                    let br = &mut b_re[off..off + batch];
                    let bi = &mut b_im[off..off + batch];
                    let cr = &mut c_re[off..off + batch];
                    let ci = &mut c_im[off..off + batch];
                    let dr = &mut d_re[off..off + batch];
                    let di = &mut d_im[off..off + batch];
                    for l in 0..batch {
                        let bwr = br[l] * w1r - bi[l] * w1i;
                        let bwi = br[l] * w1i + bi[l] * w1r;
                        let dwr = dr[l] * w1r - di[l] * w1i;
                        let dwi = dr[l] * w1i + di[l] * w1r;
                        let t0r = ar[l] + bwr;
                        let t0i = ai[l] + bwi;
                        let t1r = ar[l] - bwr;
                        let t1i = ai[l] - bwi;
                        let t2r = cr[l] + dwr;
                        let t2i = ci[l] + dwi;
                        let t3r = cr[l] - dwr;
                        let t3i = ci[l] - dwi;
                        let u2r = t2r * w2r - t2i * w2i;
                        let u2i = t2r * w2i + t2i * w2r;
                        // −i·w2·t3: compute v = w2·t3, then (−i)·v = (v.im, −v.re)
                        let vr = t3r * w2r - t3i * w2i;
                        let vi = t3r * w2i + t3i * w2r;
                        ar[l] = t0r + u2r;
                        ai[l] = t0i + u2i;
                        cr[l] = t0r - u2r;
                        ci[l] = t0i - u2i;
                        br[l] = t1r + vi;
                        bi[l] = t1i - vr;
                        dr[l] = t1r - vi;
                        di[l] = t1i + vr;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Bluestein (arbitrary lengths), composed over the radix-4 kernel
// ---------------------------------------------------------------------------

/// Bluestein plan for arbitrary `n`: expresses the length-`n` DFT as a
/// convolution of length `len >= 2n-1`, `len` a power of two, run on the
/// split-plane radix-4 kernel. Every loop keeps the batch innermost, so the
/// batched entry point vectorizes the chirp multiplies too.
#[derive(Debug)]
struct BluesteinPlan {
    n: usize,
    /// Inner power-of-two convolution length.
    len: usize,
    inner: Radix4Plan,
    /// chirp[k] = e^{-i pi k^2 / n} for k in [0, n), split planes.
    chirp_re: Vec<f64>,
    chirp_im: Vec<f64>,
    /// FFT of the (conjugated, wrapped) chirp kernel, length `len`.
    kernel_re: Vec<f64>,
    kernel_im: Vec<f64>,
}

impl BluesteinPlan {
    fn new(n: usize) -> Self {
        assert!(n > 0);
        let len = (2 * n - 1).next_power_of_two();
        let inner = Radix4Plan::new(len);
        let mut chirp_re = vec![0.0; n];
        let mut chirp_im = vec![0.0; n];
        for k in 0..n {
            // k^2 mod 2n keeps the angle argument small & exact.
            let kk = (k as u128 * k as u128 % (2 * n as u128)) as f64;
            let w = C64::cis(-std::f64::consts::PI * kk / n as f64);
            chirp_re[k] = w.re;
            chirp_im[k] = w.im;
        }
        let mut kernel_re = vec![0.0; len];
        let mut kernel_im = vec![0.0; len];
        kernel_re[0] = chirp_re[0];
        kernel_im[0] = -chirp_im[0];
        for k in 1..n {
            kernel_re[k] = chirp_re[k];
            kernel_im[k] = -chirp_im[k];
            kernel_re[len - k] = chirp_re[k];
            kernel_im[len - k] = -chirp_im[k];
        }
        inner.process(&mut kernel_re, &mut kernel_im, 1, Dir::Forward);
        Self { n, len, inner, chirp_re, chirp_im, kernel_re, kernel_im }
    }

    /// Batched in-place transform; `scratch` provides the length-`len·batch`
    /// convolution planes (caller-owned so hot loops reuse them).
    fn process_many(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        batch: usize,
        dir: Dir,
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        debug_assert_eq!(re.len(), n * batch);
        debug_assert_eq!(im.len(), n * batch);
        if batch == 0 {
            return;
        }
        let (are, aim) = (&mut scratch.conv_re, &mut scratch.conv_im);
        are.clear();
        aim.clear();
        are.resize(self.len * batch, 0.0);
        aim.resize(self.len * batch, 0.0);
        // a[k] = x[k]·chirp[k] (inverse runs on conj(x): F⁻¹ = conj∘F∘conj/n).
        let in_sign = if dir == Dir::Inverse { -1.0 } else { 1.0 };
        for k in 0..n {
            let (cr, ci) = (self.chirp_re[k], self.chirp_im[k]);
            let row = k * batch;
            for l in 0..batch {
                let xr = re[row + l];
                let xi = in_sign * im[row + l];
                are[row + l] = xr * cr - xi * ci;
                aim[row + l] = xr * ci + xi * cr;
            }
        }
        self.inner.process(are, aim, batch, Dir::Forward);
        for k in 0..self.len {
            let (kr, ki) = (self.kernel_re[k], self.kernel_im[k]);
            let row = k * batch;
            for l in 0..batch {
                let (xr, xi) = (are[row + l], aim[row + l]);
                are[row + l] = xr * kr - xi * ki;
                aim[row + l] = xr * ki + xi * kr;
            }
        }
        self.inner.process(are, aim, batch, Dir::Inverse);
        match dir {
            Dir::Forward => {
                for k in 0..n {
                    let (cr, ci) = (self.chirp_re[k], self.chirp_im[k]);
                    let row = k * batch;
                    for l in 0..batch {
                        let (xr, xi) = (are[row + l], aim[row + l]);
                        re[row + l] = xr * cr - xi * ci;
                        im[row + l] = xr * ci + xi * cr;
                    }
                }
            }
            Dir::Inverse => {
                let inv = 1.0 / n as f64;
                for k in 0..n {
                    let (cr, ci) = (self.chirp_re[k], self.chirp_im[k]);
                    let row = k * batch;
                    for l in 0..batch {
                        let (xr, xi) = (are[row + l], aim[row + l]);
                        re[row + l] = (xr * cr - xi * ci) * inv;
                        im[row + l] = -(xr * ci + xi * cr) * inv;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public plan type
// ---------------------------------------------------------------------------

/// A plan for one transform length.
#[derive(Debug)]
enum PlanKind {
    Radix4(Radix4Plan),
    Bluestein(BluesteinPlan),
}

/// Shareable FFT plan for a fixed length.
#[derive(Debug)]
pub struct Plan {
    kind: PlanKind,
    pub n: usize,
}

impl Plan {
    pub fn new(n: usize) -> Self {
        let kind = if n.is_power_of_two() {
            PlanKind::Radix4(Radix4Plan::new(n))
        } else {
            PlanKind::Bluestein(BluesteinPlan::new(n))
        };
        Self { kind, n }
    }

    /// In-place transform. `data.len()` must equal `self.n`.
    pub fn process(&self, data: &mut [C64], dir: Dir) {
        let mut scratch = FftScratch::new();
        self.process_scratch(data, dir, &mut scratch);
    }

    /// In-place transform of interleaved complex data, staged through the
    /// caller-owned split-plane scratch. Zero-allocation when `scratch` has
    /// capacity.
    pub fn process_scratch(&self, data: &mut [C64], dir: Dir, scratch: &mut FftScratch) {
        assert_eq!(data.len(), self.n, "FFT plan length mismatch");
        let mut re = std::mem::take(&mut scratch.re);
        let mut im = std::mem::take(&mut scratch.im);
        re.clear();
        im.clear();
        re.extend(data.iter().map(|z| z.re));
        im.extend(data.iter().map(|z| z.im));
        self.process_many(&mut re, &mut im, 1, dir, scratch);
        for ((z, r), i) in data.iter_mut().zip(&re).zip(&im) {
            z.re = *r;
            z.im = *i;
        }
        scratch.re = re;
        scratch.im = im;
    }

    /// Native batch=1 transform on **caller-owned** split re/im planes —
    /// the single-signal plan entry the ROADMAP follow-up called for: callers
    /// that already hold split planes skip the O(n) interleaved-`C64`
    /// pack/unpack staging [`Self::process_scratch`] pays. `scratch` is only
    /// touched for Bluestein lengths.
    pub fn process_planes(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        dir: Dir,
        scratch: &mut FftScratch,
    ) {
        self.process_many(re, im, 1, dir, scratch)
    }

    /// Batched in-place transform of `batch` same-length signals on split
    /// re/im planes, stored with the frequency index major and the **batch
    /// as the innermost (SIMD) axis**: element `k` of signal `b` lives at
    /// `re[k*batch + b]`. Twiddles are loaded once per butterfly row and
    /// applied across the whole batch, so one blocked pass transforms all
    /// signals. `scratch` is only touched for Bluestein lengths.
    pub fn process_many(
        &self,
        re: &mut [f64],
        im: &mut [f64],
        batch: usize,
        dir: Dir,
        scratch: &mut FftScratch,
    ) {
        assert_eq!(re.len(), self.n * batch, "FFT plan length mismatch");
        assert_eq!(im.len(), self.n * batch, "FFT plan length mismatch");
        match &self.kind {
            PlanKind::Radix4(p) => p.process(re, im, batch, dir),
            PlanKind::Bluestein(p) => p.process_many(re, im, batch, dir, scratch),
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar radix-2 oracle (the pre-split-radix kernel, kept for conformance)
// ---------------------------------------------------------------------------

/// The scalar, interleaved-complex radix-2 kernel that predates the
/// split-plane radix-4 core — kept as an independent oracle for the kernel
/// conformance tests and as the §Perf baseline the split-radix speedup is
/// measured against. Not used by [`Plan`].
#[derive(Debug)]
pub struct ScalarRadix2Plan {
    n: usize,
    /// Bit-reversal permutation.
    rev: Vec<u32>,
    /// Twiddles for the forward transform, grouped per stage:
    /// stage with half-size `m` uses `twiddle[m + k]` = e^{-i pi k / m}.
    twiddles: Vec<C64>,
}

impl ScalarRadix2Plan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n > 0);
        let bits = n.trailing_zeros();
        let mut rev = vec![0u32; n];
        for i in 0..n {
            rev[i] = (i as u32).reverse_bits() >> (32 - bits.max(1));
            if n == 1 {
                rev[i] = 0;
            }
        }
        // Twiddle table indexed like a binary heap: for each half-size m
        // (1, 2, 4, ..., n/2) store m roots at offset m.
        let mut twiddles = vec![ZERO; n.max(2)];
        let mut m = 1usize;
        while m < n {
            for k in 0..m {
                twiddles[m + k] = C64::cis(-std::f64::consts::PI * k as f64 / m as f64);
            }
            m <<= 1;
        }
        Self { n, rev, twiddles }
    }

    pub fn process(&self, data: &mut [C64], dir: Dir) {
        let n = self.n;
        assert_eq!(data.len(), n, "FFT plan length mismatch");
        if n == 1 {
            return;
        }
        if dir == Dir::Inverse {
            for x in data.iter_mut() {
                x.im = -x.im;
            }
            self.process(data, Dir::Forward);
            let inv = 1.0 / n as f64;
            for x in data.iter_mut() {
                x.re *= inv;
                x.im *= -inv;
            }
            return;
        }
        // Bit-reversal permutation.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        // Stage m=1 specialized: w = 1 for every butterfly.
        {
            let mut base = 0usize;
            while base < n {
                let a = data[base];
                let b = data[base + 1];
                data[base] = a + b;
                data[base + 1] = a - b;
                base += 2;
            }
        }
        // Stage m=2 specialized: w ∈ {1, −i}.
        if n >= 4 {
            let mut base = 0usize;
            while base < n {
                let a0 = data[base];
                let b0 = data[base + 2];
                data[base] = a0 + b0;
                data[base + 2] = a0 - b0;
                let a1 = data[base + 1];
                let b1 = data[base + 3];
                let rb = C64::new(b1.im, -b1.re); // b · (−i)
                data[base + 1] = a1 + rb;
                data[base + 3] = a1 - rb;
                base += 4;
            }
        }
        // Remaining stages: forward twiddles, branch-free.
        let mut m = 4usize;
        while m < n {
            let stride = m << 1;
            let tw = &self.twiddles[m..m + m];
            let mut base = 0usize;
            while base < n {
                let (lo, hi) = data[base..base + stride].split_at_mut(m);
                for k in 0..m {
                    let w = tw[k];
                    let a = lo[k];
                    let b = hi[k] * w;
                    lo[k] = a + b;
                    hi[k] = a - b;
                }
                base += stride;
            }
            m = stride;
        }
    }
}

// ---------------------------------------------------------------------------
// Real-transform recombination twiddles
// ---------------------------------------------------------------------------

/// Recombination twiddles for the packed real-input transform of even
/// length `n = 2m`: `twiddles[k] = e^{-iπk/m}` for `k ∈ [0, m)`. The forward
/// split-spectrum step multiplies by `twiddles[k]`, the inverse by its
/// conjugate — previously both recomputed a `sin_cos` per point per call
/// (ROADMAP follow-up: "cache rfft twiddles per length").
#[derive(Debug)]
pub struct RealPlan {
    /// Transform length `n` (even).
    pub n: usize,
    /// `e^{-iπk/m}`, `m = n/2`.
    pub twiddles: Vec<C64>,
}

impl RealPlan {
    fn new(n: usize) -> Self {
        assert!(n > 0 && n % 2 == 0, "RealPlan requires even n");
        let m = n / 2;
        let twiddles = (0..m)
            .map(|k| C64::cis(-std::f64::consts::PI * k as f64 / m as f64))
            .collect();
        Self { n, twiddles }
    }
}

// ---------------------------------------------------------------------------
// Planner (process-wide plan cache)
// ---------------------------------------------------------------------------

/// Per-cache `(hits, misses)` split of the planner's accounting: the
/// forward complex-plan cache vs the real-recombination-twiddle cache. A
/// cold real cache is *not* the same operational signal as a cold complex
/// cache (the latter implies full twiddle/bit-reversal rebuilds), so the
/// split is surfaced both here and as the `cache="forward"|"real"` label on
/// `fcs_plan_cache_{hits,misses}_total`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheCounters {
    /// `(hits, misses)` of the complex forward/inverse [`Plan`] cache.
    pub forward: (u64, u64),
    /// `(hits, misses)` of the packed-real [`RealPlan`] cache.
    pub real: (u64, u64),
}

impl PlanCacheCounters {
    fn rate(h: u64, m: u64) -> f64 {
        if h + m == 0 { f64::NAN } else { h as f64 / (h + m) as f64 }
    }

    /// Hit rate of the forward cache in `[0, 1]` (`NaN` when untouched).
    pub fn forward_hit_rate(&self) -> f64 {
        Self::rate(self.forward.0, self.forward.1)
    }

    /// Hit rate of the real-plan cache in `[0, 1]` (`NaN` when untouched).
    pub fn real_hit_rate(&self) -> f64 {
        Self::rate(self.real.0, self.real.1)
    }
}

/// Which plan map a [`Planner::cached`] lookup is serving — selects both
/// the per-instance counters and the registry series to feed.
#[derive(Clone, Copy)]
enum PlanCache {
    Forward,
    Real,
}

/// Process-wide plan cache. The FCS hot loop transforms many vectors of the
/// same length; building twiddles once matters (§Perf).
#[derive(Default)]
pub struct Planner {
    plans: Mutex<HashMap<usize, Arc<Plan>>>,
    real_plans: Mutex<HashMap<usize, Arc<RealPlan>>>,
    fwd_hits: AtomicU64,
    fwd_misses: AtomicU64,
    real_hits: AtomicU64,
    real_misses: AtomicU64,
}

impl Planner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Double-checked cache lookup shared by both plan maps: the (possibly
    /// expensive — Bluestein builds a 2×-padded kernel FFT) construction
    /// happens **outside** the mutex, so a large build never blocks
    /// concurrent sketching threads that want already-cached lengths. Also
    /// the single home of the hit/miss accounting: per-instance atomics
    /// (what [`Self::cache_counters`] reads) and the crate-wide
    /// `fcs_plan_cache_*` registry series advance from the same branch, so
    /// they can never disagree. Every `Planner` instance feeds the global
    /// series; in production only [`global_planner`] exists.
    fn cached<P>(
        &self,
        map: &Mutex<HashMap<usize, Arc<P>>>,
        which: PlanCache,
        n: usize,
        build: impl FnOnce(usize) -> P,
    ) -> Arc<P> {
        let obs = crate::obs::metrics();
        let (hits, misses, obs_hits, obs_misses) = match which {
            PlanCache::Forward => (
                &self.fwd_hits,
                &self.fwd_misses,
                &*obs.plan_cache_hits_forward,
                &*obs.plan_cache_misses_forward,
            ),
            PlanCache::Real => (
                &self.real_hits,
                &self.real_misses,
                &*obs.plan_cache_hits_real,
                &*obs.plan_cache_misses_real,
            ),
        };
        if let Some(p) = map.lock().unwrap().get(&n) {
            // ordering: Relaxed — pure tally; the cache itself is guarded by
            // the map mutex, so the counter orders nothing (PR 10 audit:
            // counters were already weakest-correct, now documented).
            hits.fetch_add(1, Ordering::Relaxed);
            obs_hits.inc();
            return p.clone();
        }
        // ordering: Relaxed — pure tally; see hit counter above. Two racing
        // builders of one length each book a miss (both did build), even
        // though `or_insert` keeps only one plan.
        misses.fetch_add(1, Ordering::Relaxed);
        obs_misses.inc();
        let built = Arc::new(build(n));
        let mut guard = map.lock().unwrap();
        guard.entry(n).or_insert(built).clone()
    }

    /// Plan lookup (see [`Self::cached`] for the insert discipline).
    pub fn plan(&self, n: usize) -> Arc<Plan> {
        self.cached(&self.plans, PlanCache::Forward, n, Plan::new)
    }

    /// Cached recombination twiddles for the even-length packed real
    /// transform (same discipline as [`Self::plan`]).
    pub fn real_plan(&self, n: usize) -> Arc<RealPlan> {
        self.cached(&self.real_plans, PlanCache::Real, n, RealPlan::new)
    }

    /// `(hits, misses)` summed across both plan caches — lets tests assert
    /// that steady-state transforms are served from cache (hits grow,
    /// misses stay flat). See [`Self::cache_counters_by_cache`] for the
    /// per-cache split.
    pub fn cache_counters(&self) -> (u64, u64) {
        let c = self.cache_counters_by_cache();
        (c.forward.0 + c.real.0, c.forward.1 + c.real.1)
    }

    /// Per-cache `(hits, misses)`, forward vs real.
    pub fn cache_counters_by_cache(&self) -> PlanCacheCounters {
        // ordering: Relaxed (all four) — snapshot of independent tallies; a
        // scrape racing a lookup may skew hits/misses by one, acceptable
        // for rate reporting.
        PlanCacheCounters {
            forward: (
                self.fwd_hits.load(Ordering::Relaxed),
                self.fwd_misses.load(Ordering::Relaxed),
            ),
            real: (
                self.real_hits.load(Ordering::Relaxed),
                self.real_misses.load(Ordering::Relaxed),
            ),
        }
    }
}

/// Global planner instance.
pub fn global_planner() -> &'static Planner {
    static PLANNER: crate::sync::OnceLock<Planner> = crate::sync::OnceLock::new();
    PLANNER.get_or_init(Planner::new)
}

/// Convenience: forward FFT of a complex buffer (in place).
pub fn fft_inplace(data: &mut [C64]) {
    super::workspace::with_thread_workspace(|ws| ws.process(data, Dir::Forward));
}

/// Convenience: inverse FFT of a complex buffer (in place).
pub fn ifft_inplace(data: &mut [C64]) {
    super::workspace::with_thread_workspace(|ws| ws.process(data, Dir::Inverse));
}

/// Forward FFT of a real signal zero-padded to length `n` (allocating
/// wrapper over [`super::workspace::fft_real_into`] — even `n` runs as a
/// half-length complex transform).
pub fn fft_real(x: &[f64], n: usize) -> Vec<C64> {
    super::workspace::with_thread_workspace(|ws| {
        let mut out = Vec::with_capacity(n);
        super::workspace::fft_real_into(x, n, ws, &mut out);
        out
    })
}

/// Inverse FFT of a Hermitian spectrum, returning the real signal
/// (allocating wrapper over [`super::workspace::inverse_real_into`], which
/// debug-asserts the discarded imaginary residue is below tolerance).
pub fn ifft_to_real(mut spec: Vec<C64>) -> Vec<f64> {
    super::workspace::with_thread_workspace(|ws| {
        let mut out = Vec::with_capacity(spec.len());
        super::workspace::inverse_real_into(&mut spec, ws, &mut out);
        out
    })
}

/// Naive O(n^2) DFT — oracle for tests.
pub fn dft_naive(x: &[C64], dir: Dir) -> Vec<C64> {
    let n = x.len();
    let sign = if dir == Dir::Forward { -1.0 } else { 1.0 };
    let mut out = vec![ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = ZERO;
        for (j, &v) in x.iter().enumerate() {
            let ang = sign * 2.0 * std::f64::consts::PI * (k as u128 * j as u128 % n as u128) as f64
                / n as f64;
            acc += v * C64::cis(ang);
        }
        *o = if dir == Dir::Inverse { acc.scale(1.0 / n as f64) } else { acc };
    }
    let _ = ONE;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_signal(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn radix4_matches_naive() {
        let mut rng = Rng::seed_from_u64(1);
        for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let x = rand_signal(&mut rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            let z = dft_naive(&x, Dir::Forward);
            assert!(max_err(&y, &z) < 1e-9 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn radix4_matches_scalar_radix2_oracle() {
        let mut rng = Rng::seed_from_u64(11);
        for &n in &[2usize, 4, 8, 64, 512, 1024] {
            let x = rand_signal(&mut rng, n);
            for dir in [Dir::Forward, Dir::Inverse] {
                let mut y = x.clone();
                Plan::new(n).process(&mut y, dir);
                let mut z = x.clone();
                ScalarRadix2Plan::new(n).process(&mut z, dir);
                assert!(max_err(&y, &z) < 1e-10 * (n as f64), "n={n} dir={dir:?}");
            }
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        let mut rng = Rng::seed_from_u64(2);
        for &n in &[3usize, 5, 6, 7, 12, 100, 299, 997] {
            let x = rand_signal(&mut rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            let z = dft_naive(&x, Dir::Forward);
            assert!(max_err(&y, &z) < 1e-8 * (n as f64), "n={n} err={}", max_err(&y, &z));
        }
    }

    #[test]
    fn process_many_matches_single_lane_process() {
        let mut rng = Rng::seed_from_u64(12);
        for &(n, batch) in &[(8usize, 3usize), (16, 1), (21, 4), (64, 5), (100, 2)] {
            let lanes: Vec<Vec<C64>> = (0..batch).map(|_| rand_signal(&mut rng, n)).collect();
            let mut re = vec![0.0; n * batch];
            let mut im = vec![0.0; n * batch];
            for (b, lane) in lanes.iter().enumerate() {
                for (k, z) in lane.iter().enumerate() {
                    re[k * batch + b] = z.re;
                    im[k * batch + b] = z.im;
                }
            }
            let plan = Plan::new(n);
            let mut scratch = FftScratch::new();
            plan.process_many(&mut re, &mut im, batch, Dir::Forward, &mut scratch);
            for (b, lane) in lanes.iter().enumerate() {
                let mut single = lane.clone();
                plan.process(&mut single, Dir::Forward);
                for (k, z) in single.iter().enumerate() {
                    let d = (re[k * batch + b] - z.re).abs() + (im[k * batch + b] - z.im).abs();
                    assert!(d < 1e-10 * n as f64, "n={n} batch={batch} lane={b} k={k}");
                }
            }
        }
    }

    #[test]
    fn process_planes_matches_interleaved_process() {
        // The native batch=1 plane entry must agree with the staged
        // interleaved path for pow2 and Bluestein lengths, both directions.
        let mut rng = Rng::seed_from_u64(13);
        for &n in &[1usize, 2, 8, 64, 100, 243] {
            let x = rand_signal(&mut rng, n);
            let plan = Plan::new(n);
            let mut scratch = FftScratch::new();
            for dir in [Dir::Forward, Dir::Inverse] {
                let mut re: Vec<f64> = x.iter().map(|z| z.re).collect();
                let mut im: Vec<f64> = x.iter().map(|z| z.im).collect();
                plan.process_planes(&mut re, &mut im, dir, &mut scratch);
                let mut y = x.clone();
                plan.process(&mut y, dir);
                for k in 0..n {
                    let d = (re[k] - y[k].re).abs() + (im[k] - y[k].im).abs();
                    assert!(d < 1e-10 * (n as f64).max(1.0), "n={n} dir={dir:?} k={k}");
                }
            }
        }
    }

    #[test]
    fn planner_caches_plans_and_real_plans() {
        let p = Planner::new();
        assert_eq!(p.cache_counters(), (0, 0));
        let a = p.plan(16);
        let b = p.plan(16);
        assert!(Arc::ptr_eq(&a, &b));
        let ra = p.real_plan(16);
        let rb = p.real_plan(16);
        assert!(Arc::ptr_eq(&ra, &rb));
        let (h, m) = p.cache_counters();
        assert_eq!((h, m), (2, 2));
        for (k, w) in ra.twiddles.iter().enumerate() {
            let expect = C64::cis(-std::f64::consts::PI * k as f64 / 8.0);
            assert!((*w - expect).abs() < 1e-15, "k={k}");
        }
    }

    #[test]
    fn planner_splits_counters_per_cache() {
        let p = Planner::new();
        assert_eq!(p.cache_counters_by_cache(), PlanCacheCounters::default());
        let _ = p.plan(16); // forward miss
        let _ = p.plan(16); // forward hit
        let _ = p.plan(32); // forward miss
        let _ = p.real_plan(16); // real miss
        let _ = p.real_plan(16); // real hit
        let _ = p.real_plan(16); // real hit
        let c = p.cache_counters_by_cache();
        assert_eq!(c.forward, (1, 2));
        assert_eq!(c.real, (2, 1));
        // Summed view stays consistent for back-compat callers.
        assert_eq!(p.cache_counters(), (3, 3));
        assert!((c.forward_hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert!((c.real_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!(PlanCacheCounters::default().forward_hit_rate().is_nan());
    }

    #[test]
    fn roundtrip_forward_inverse() {
        let mut rng = Rng::seed_from_u64(3);
        for &n in &[2usize, 17, 128, 1000, 4093] {
            let x = rand_signal(&mut rng, n);
            let mut y = x.clone();
            fft_inplace(&mut y);
            ifft_inplace(&mut y);
            assert!(max_err(&x, &y) < 1e-9 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn real_transform_is_hermitian() {
        let mut rng = Rng::seed_from_u64(4);
        let x: Vec<f64> = rng.normal_vec(37);
        let spec = fft_real(&x, 64);
        for k in 1..64 {
            let err = (spec[k] - spec[64 - k].conj()).abs();
            assert!(err < 1e-10, "k={k}");
        }
    }

    #[test]
    fn linearity_property() {
        use crate::util::qcheck::qcheck;
        qcheck(30, |g| {
            let n = g.usize_in(2, 200);
            let a: Vec<C64> = (0..n).map(|_| C64::new(g.f64_in(-1.0, 1.0), g.f64_in(-1.0, 1.0))).collect();
            let b: Vec<C64> = (0..n).map(|_| C64::new(g.f64_in(-1.0, 1.0), g.f64_in(-1.0, 1.0))).collect();
            let alpha = g.f64_in(-2.0, 2.0);
            let mut lhs: Vec<C64> = a.iter().zip(&b).map(|(x, y)| *x + y.scale(alpha)).collect();
            fft_inplace(&mut lhs);
            let mut fa = a.clone();
            fft_inplace(&mut fa);
            let mut fb = b.clone();
            fft_inplace(&mut fb);
            let rhs: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| *x + y.scale(alpha)).collect();
            let err = lhs.iter().zip(&rhs).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max);
            assert!(err < 1e-8 * n as f64);
        });
    }
}
