//! Minimal complex arithmetic (num-complex is not vendored; num-traits is,
//! but a bespoke `c64` keeps the FFT inner loops transparent to the
//! optimizer).

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// 64-bit complex number.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

impl C64 {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    #[inline]
    pub fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    /// e^{i theta}
    #[inline]
    pub fn cis(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        Self { re: c, im: s }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    #[inline]
    pub fn scale(self, k: f64) -> Self {
        Self { re: self.re * k, im: self.im * k }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline]
    fn div(self, o: C64) -> C64 {
        let d = o.norm_sqr();
        C64::new(
            (self.re * o.re + self.im * o.im) / d,
            (self.im * o.re - self.re * o.im) / d,
        )
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64::new(-self.re, -self.im)
    }
}

impl AddAssign for C64 {
    #[inline]
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for C64 {
    #[inline]
    fn sub_assign(&mut self, o: C64) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for C64 {
    #[inline]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert_eq!(a + b, C64::new(4.0, 1.0));
        assert_eq!(a - b, C64::new(-2.0, 3.0));
        assert_eq!(a * b, C64::new(5.0, 5.0));
        let q = (a / b) * b;
        assert!((q - a).abs() < 1e-12);
    }

    #[test]
    fn cis_unit_circle() {
        for k in 0..8 {
            let z = C64::cis(k as f64 * std::f64::consts::FRAC_PI_4);
            assert!((z.abs() - 1.0).abs() < 1e-12);
        }
        let z = C64::cis(std::f64::consts::FRAC_PI_2);
        assert!((z - C64::new(0.0, 1.0)).abs() < 1e-12);
    }
}
