//! Caller-owned FFT workspaces: reusable `C64`/`f64` scratch arenas plus
//! per-length plan handles, so steady-state hot loops (the ALS/RTPM inner
//! loops call the spectral kernels thousands of times at a fixed `J̃`)
//! perform **zero heap allocations** after warmup.
//!
//! Also home of the packed **real-input FFT**: a length-`n` transform of a
//! real signal runs as one length-`n/2` complex transform (Hermitian
//! symmetry), halving butterfly work for every convolution in the crate.
//! `fft_real_into` / `inverse_real_into` are the workspace-based primitives;
//! the allocating wrappers in [`super::plan`] route through them.

use super::complex::{C64, ZERO};
use super::plan::{global_planner, Dir, Plan, RealPlan};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

/// Reusable transform scratch + plan cache. Buffers are rented with
/// `take_*` and returned with `give_*`; in steady state (same call sequence
/// each iteration) every rental is served from the pool without allocating.
#[derive(Default)]
pub struct FftWorkspace {
    /// Per-length plan handles, resolved once from the global planner so hot
    /// loops never touch the planner mutex.
    plans: HashMap<usize, Arc<Plan>>,
    /// Per-length recombination twiddles for the packed real transform.
    real_plans: HashMap<usize, Arc<RealPlan>>,
    c64_pool: Vec<Vec<C64>>,
    f64_pool: Vec<Vec<f64>>,
    /// Scratch for Bluestein's inner convolution, kept out of the pools so a
    /// transform can run while rented buffers are outstanding.
    bluestein: Vec<C64>,
}

impl FftWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Plan handle for length `n`, cached locally (mutex-free after first
    /// use of each length).
    pub fn plan(&mut self, n: usize) -> Arc<Plan> {
        if let Some(p) = self.plans.get(&n) {
            return p.clone();
        }
        let p = global_planner().plan(n);
        self.plans.insert(n, p.clone());
        p
    }

    /// Real-transform twiddle table for even length `n`, cached locally
    /// (mutex-free after first use of each length).
    pub fn real_plan(&mut self, n: usize) -> Arc<RealPlan> {
        if let Some(p) = self.real_plans.get(&n) {
            return p.clone();
        }
        let p = global_planner().real_plan(n);
        self.real_plans.insert(n, p.clone());
        p
    }

    /// In-place transform using cached plans and reusable Bluestein scratch.
    pub fn process(&mut self, data: &mut [C64], dir: Dir) {
        let plan = self.plan(data.len());
        let mut scratch = std::mem::take(&mut self.bluestein);
        plan.process_scratch(data, dir, &mut scratch);
        self.bluestein = scratch;
    }

    /// Rent a zeroed complex buffer of length `n`.
    pub fn take_c64(&mut self, n: usize) -> Vec<C64> {
        let mut b = self.c64_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(n, ZERO);
        b
    }

    /// Return a complex buffer to the pool.
    pub fn give_c64(&mut self, b: Vec<C64>) {
        self.c64_pool.push(b);
    }

    /// Rent a zeroed real buffer of length `n`.
    pub fn take_f64(&mut self, n: usize) -> Vec<f64> {
        let mut b = self.f64_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(n, 0.0);
        b
    }

    /// Return a real buffer to the pool.
    pub fn give_f64(&mut self, b: Vec<f64>) {
        self.f64_pool.push(b);
    }
}

thread_local! {
    static THREAD_WS: RefCell<FftWorkspace> = RefCell::new(FftWorkspace::new());
}

/// Run `f` with this thread's shared workspace. Re-entrant calls (a
/// workspace user calling an allocating wrapper that grabs the workspace
/// again) fall back to a fresh arena instead of panicking on the RefCell.
pub fn with_thread_workspace<R>(f: impl FnOnce(&mut FftWorkspace) -> R) -> R {
    THREAD_WS.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ws) => f(&mut ws),
        Err(_) => f(&mut FftWorkspace::new()),
    })
}

/// Forward FFT of a real signal zero-padded to length `n`, written as the
/// full length-`n` (Hermitian) spectrum into `out`.
///
/// Even `n` runs as a single length-`n/2` complex transform: pack
/// `z[j] = x[2j] + i·x[2j+1]`, transform, then split even/odd spectra via
/// `E[k] = (Z[k] + conj(Z[m−k]))/2`, `O[k] = (Z[k] − conj(Z[m−k]))·(−i/2)`
/// and recombine `X[k] = E[k] + e^{−2πik/n}·O[k]`, mirroring the rest by
/// conjugate symmetry. Odd `n` falls back to the full complex transform.
pub fn fft_real_into(x: &[f64], n: usize, ws: &mut FftWorkspace, out: &mut Vec<C64>) {
    assert!(
        x.len() <= n,
        "fft_real_into: signal longer than transform ({} > {n})",
        x.len()
    );
    out.clear();
    if n == 0 {
        return;
    }
    if n % 2 != 0 {
        out.resize(n, ZERO);
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            o.re = v;
        }
        ws.process(out, Dir::Forward);
        return;
    }
    let m = n / 2;
    let rp = ws.real_plan(n);
    let mut z = ws.take_c64(m);
    for (j, zj) in z.iter_mut().enumerate() {
        let re = if 2 * j < x.len() { x[2 * j] } else { 0.0 };
        let im = if 2 * j + 1 < x.len() { x[2 * j + 1] } else { 0.0 };
        *zj = C64::new(re, im);
    }
    ws.process(&mut z, Dir::Forward);
    out.resize(n, ZERO);
    for k in 0..m {
        let zk = z[k];
        let zmk = z[(m - k) % m].conj();
        let e = (zk + zmk).scale(0.5);
        let o = (zk - zmk) * C64::new(0.0, -0.5);
        // Cached e^{-iπk/m} (ROADMAP follow-up: no per-point sin_cos).
        out[k] = e + rp.twiddles[k] * o;
    }
    // X[m] = E[0] − O[0] (both real: Re(Z[0]) and Im(Z[0])).
    out[m] = C64::real(z[0].re - z[0].im);
    for k in 1..m {
        out[n - k] = out[k].conj();
    }
    ws.give_c64(z);
}

/// Inverse FFT of a Hermitian spectrum, returning the real signal in `out`.
/// `spec` is consumed as scratch (its contents are destroyed).
///
/// This is the single unification point for the old `ifft_to_real` /
/// `inverse_spectrum` pair: even `n` runs one length-`n/2` complex inverse
/// (`E[k] = (X[k]+X[k+m])/2`, `O[k] = (X[k]−X[k+m])·e^{2πik/n}/2`,
/// `z = F⁻¹(E + iO)`, de-interleave), odd `n` runs the full inverse. Debug
/// builds assert the spectrum really is (numerically) Hermitian — i.e. that
/// the imaginary residue being discarded is below tolerance — instead of
/// silently dropping it.
pub fn inverse_real_into(spec: &mut [C64], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
    let n = spec.len();
    out.clear();
    if n == 0 {
        return;
    }
    #[cfg(debug_assertions)]
    {
        let scale2 = spec
            .iter()
            .map(|v| v.norm_sqr())
            .fold(0.0f64, f64::max)
            .max(1.0);
        for k in 0..n {
            let resid2 = (spec[k] - spec[(n - k) % n].conj()).norm_sqr();
            debug_assert!(
                resid2 <= 1e-14 * scale2,
                "inverse_real_into: non-Hermitian spectrum at k={k}/{n} \
                 (|residue|²={resid2:.3e}, max|X|²={scale2:.3e}) — a nonzero \
                 imaginary output would be silently discarded"
            );
        }
    }
    if n % 2 != 0 {
        ws.process(spec, Dir::Inverse);
        out.extend(spec.iter().map(|v| v.re));
        return;
    }
    let m = n / 2;
    let rp = ws.real_plan(n);
    let mut z = ws.take_c64(m);
    for (k, zk) in z.iter_mut().enumerate() {
        let a = spec[k];
        let b = spec[k + m];
        let e = (a + b).scale(0.5);
        // e^{+iπk/m} = conj of the cached forward twiddle.
        let o = ((a - b).scale(0.5)) * rp.twiddles[k].conj();
        // z[k] = E[k] + i·O[k]
        *zk = C64::new(e.re - o.im, e.im + o.re);
    }
    ws.process(&mut z, Dir::Inverse);
    out.resize(n, 0.0);
    for (j, zj) in z.iter().enumerate() {
        out[2 * j] = zj.re;
        out[2 * j + 1] = zj.im;
    }
    ws.give_c64(z);
}

#[cfg(test)]
mod tests {
    use super::super::plan::{dft_naive, fft_real, ifft_to_real};
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn rfft_matches_naive_dft() {
        let mut rng = Rng::seed_from_u64(21);
        for &n in &[2usize, 4, 6, 8, 10, 16, 34, 64, 100, 128, 250, 3, 7, 25] {
            let x: Vec<f64> = rng.normal_vec(n);
            let spec = fft_real(&x, n);
            let full: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
            let naive = dft_naive(&full, Dir::Forward);
            let err = spec
                .iter()
                .zip(&naive)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-8 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn rfft_zero_padding_matches_naive() {
        let mut rng = Rng::seed_from_u64(22);
        for &(len, n) in &[(5usize, 16usize), (7, 8), (1, 2), (13, 40), (9, 27)] {
            let x: Vec<f64> = rng.normal_vec(len);
            let spec = fft_real(&x, n);
            let mut full = vec![ZERO; n];
            for (f, &v) in full.iter_mut().zip(&x) {
                f.re = v;
            }
            let naive = dft_naive(&full, Dir::Forward);
            let err = spec
                .iter()
                .zip(&naive)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-9 * n as f64, "len={len} n={n} err={err}");
        }
    }

    #[test]
    fn rfft_irfft_roundtrip() {
        let mut rng = Rng::seed_from_u64(23);
        for &n in &[2usize, 6, 16, 64, 100, 256, 1000, 5, 17, 243] {
            let x: Vec<f64> = rng.normal_vec(n);
            let spec = fft_real(&x, n);
            let back = ifft_to_real(spec);
            let err = x
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-10 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn workspace_pool_recycles_buffers() {
        let mut ws = FftWorkspace::new();
        let a = ws.take_c64(64);
        let cap_before = a.capacity();
        ws.give_c64(a);
        let b = ws.take_c64(32);
        assert!(b.capacity() >= cap_before.min(64));
        assert_eq!(b.len(), 32);
        assert!(b.iter().all(|z| z.re == 0.0 && z.im == 0.0));
        ws.give_c64(b);
    }

    #[test]
    fn thread_workspace_is_reentrant_safe() {
        let r = with_thread_workspace(|ws| {
            let buf = ws.take_c64(8);
            // A nested grab must not panic (falls back to a fresh arena).
            let inner = with_thread_workspace(|ws2| ws2.take_c64(4).len());
            ws.give_c64(buf);
            inner
        });
        assert_eq!(r, 4);
    }
}
