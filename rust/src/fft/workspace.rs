//! Caller-owned FFT workspaces: reusable `C64`/`f64` scratch arenas plus
//! per-length plan handles, so steady-state hot loops (the ALS/RTPM inner
//! loops call the spectral kernels thousands of times at a fixed `J̃`)
//! perform **zero heap allocations** after warmup.
//!
//! Also home of the packed **real-input FFT**: a length-`n` transform of a
//! real signal runs as one length-`n/2` complex transform (Hermitian
//! symmetry), halving butterfly work for every convolution in the crate.
//! `fft_real_into` / `inverse_real_into` are the single-signal primitives;
//! `fft_real_many_into` / `inverse_real_many_into` transform a strided batch
//! of same-length signals in one blocked pass over the split-plane kernel
//! (twiddles loaded once per stage, batch innermost — the rank-R spectral
//! paths route every mode spectrum of a rank batch through one such call).
//! The allocating wrappers in [`super::plan`] route through them.

use super::complex::{C64, ZERO};
use super::plan::{global_planner, Dir, FftScratch, Plan, RealPlan};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

/// Multiply the complex product of `count` consecutive lanes
/// `(sre, sim)[s..s+count]` of one lane-major frequency row into the
/// accumulator `(pr, pi)`; with `conj` each lane enters conjugated (spectral
/// correlation rather than convolution). The single home of the batched
/// pointwise-product inner loop every spectral fold runs — the sketch-layer
/// [`crate::sketch::common::SpectralDriver`] and the convolution layer's
/// [`super::convolve::product_spectrum_into`] both fold through it.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn mul_lane_run(
    sre: &[f64],
    sim: &[f64],
    s: usize,
    count: usize,
    conj: bool,
    pr: &mut f64,
    pi: &mut f64,
) {
    for d in 0..count {
        let qr = sre[s + d];
        let qi = if conj { -sim[s + d] } else { sim[s + d] };
        let t = *pr * qr - *pi * qi;
        *pi = *pr * qi + *pi * qr;
        *pr = t;
    }
}

/// Reusable transform scratch + plan cache. Buffers are rented with
/// `take_*` and returned with `give_*`; in steady state (same call sequence
/// each iteration) every rental is served from the pool without allocating.
#[derive(Default)]
pub struct FftWorkspace {
    /// Per-length plan handles, resolved once from the global planner so hot
    /// loops never touch the planner mutex.
    plans: HashMap<usize, Arc<Plan>>,
    /// Per-length recombination twiddles for the packed real transform.
    real_plans: HashMap<usize, Arc<RealPlan>>,
    c64_pool: Vec<Vec<C64>>,
    f64_pool: Vec<Vec<f64>>,
    /// Split-plane staging + Bluestein convolution scratch, kept out of the
    /// pools so a transform can run while rented buffers are outstanding.
    scratch: FftScratch,
}

impl FftWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Plan handle for length `n`, cached locally (mutex-free after first
    /// use of each length).
    pub fn plan(&mut self, n: usize) -> Arc<Plan> {
        if let Some(p) = self.plans.get(&n) {
            return p.clone();
        }
        let p = global_planner().plan(n);
        self.plans.insert(n, p.clone());
        p
    }

    /// Real-transform twiddle table for even length `n`, cached locally
    /// (mutex-free after first use of each length).
    pub fn real_plan(&mut self, n: usize) -> Arc<RealPlan> {
        if let Some(p) = self.real_plans.get(&n) {
            return p.clone();
        }
        let p = global_planner().real_plan(n);
        self.real_plans.insert(n, p.clone());
        p
    }

    /// In-place transform using cached plans and reusable scratch planes.
    pub fn process(&mut self, data: &mut [C64], dir: Dir) {
        let plan = self.plan(data.len());
        let mut scratch = std::mem::take(&mut self.scratch);
        plan.process_scratch(data, dir, &mut scratch);
        self.scratch = scratch;
    }

    /// Native batch=1 transform on caller-owned split re/im planes: the
    /// signal goes straight into the split-plane kernel with **no**
    /// interleaved-`C64` staging (the O(n) pack/unpack [`Self::process`]
    /// pays) — the ROADMAP follow-up's "native batch=1 plane entry". Plans
    /// cached locally, Bluestein scratch reused.
    pub fn process_planes(&mut self, re: &mut [f64], im: &mut [f64], dir: Dir) {
        assert_eq!(re.len(), im.len(), "process_planes: plane length mismatch");
        let plan = self.plan(re.len());
        let mut scratch = std::mem::take(&mut self.scratch);
        plan.process_planes(re, im, dir, &mut scratch);
        self.scratch = scratch;
    }

    /// Batched in-place transform on split re/im planes (lane-major, batch
    /// innermost — see [`Plan::process_many`]) using cached plans and
    /// reusable Bluestein scratch.
    pub fn process_many(
        &mut self,
        re: &mut [f64],
        im: &mut [f64],
        n: usize,
        batch: usize,
        dir: Dir,
    ) {
        let plan = self.plan(n);
        let mut scratch = std::mem::take(&mut self.scratch);
        plan.process_many(re, im, batch, dir, &mut scratch);
        self.scratch = scratch;
    }

    /// Rent a zeroed complex buffer of length `n`.
    pub fn take_c64(&mut self, n: usize) -> Vec<C64> {
        let mut b = self.c64_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(n, ZERO);
        b
    }

    /// Return a complex buffer to the pool.
    pub fn give_c64(&mut self, b: Vec<C64>) {
        self.c64_pool.push(b);
    }

    /// Rent a zeroed real buffer of length `n`.
    pub fn take_f64(&mut self, n: usize) -> Vec<f64> {
        let mut b = self.f64_pool.pop().unwrap_or_default();
        b.clear();
        b.resize(n, 0.0);
        b
    }

    /// Return a real buffer to the pool.
    pub fn give_f64(&mut self, b: Vec<f64>) {
        self.f64_pool.push(b);
    }
}

thread_local! {
    static THREAD_WS: RefCell<FftWorkspace> = RefCell::new(FftWorkspace::new());
}

/// Run `f` with this thread's shared workspace. Re-entrant calls (a
/// workspace user calling an allocating wrapper that grabs the workspace
/// again) fall back to a fresh arena instead of panicking on the RefCell.
pub fn with_thread_workspace<R>(f: impl FnOnce(&mut FftWorkspace) -> R) -> R {
    THREAD_WS.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ws) => f(&mut ws),
        Err(_) => f(&mut FftWorkspace::new()),
    })
}

/// Forward FFT of a real signal zero-padded to length `n`, written as the
/// full length-`n` (Hermitian) spectrum into `out`.
///
/// Even `n` runs as a single length-`n/2` complex transform: pack
/// `z[j] = x[2j] + i·x[2j+1]`, transform, then split even/odd spectra via
/// `E[k] = (Z[k] + conj(Z[m−k]))/2`, `O[k] = (Z[k] − conj(Z[m−k]))·(−i/2)`
/// and recombine `X[k] = E[k] + e^{−2πik/n}·O[k]`, mirroring the rest by
/// conjugate symmetry. Odd `n` falls back to the full complex transform.
pub fn fft_real_into(x: &[f64], n: usize, ws: &mut FftWorkspace, out: &mut Vec<C64>) {
    assert!(
        x.len() <= n,
        "fft_real_into: signal longer than transform ({} > {n})",
        x.len()
    );
    out.clear();
    if n == 0 {
        return;
    }
    if n % 2 != 0 {
        out.resize(n, ZERO);
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            o.re = v;
        }
        ws.process(out, Dir::Forward);
        return;
    }
    let m = n / 2;
    let rp = ws.real_plan(n);
    // Native split-plane packing: the half-length complex signal is built
    // directly in two f64 planes and transformed through the batch=1 plane
    // entry — no interleaved-C64 staging round-trip.
    let mut zre = ws.take_f64(m);
    let mut zim = ws.take_f64(m);
    for j in 0..m {
        if 2 * j < x.len() {
            zre[j] = x[2 * j];
        }
        if 2 * j + 1 < x.len() {
            zim[j] = x[2 * j + 1];
        }
    }
    ws.process_planes(&mut zre, &mut zim, Dir::Forward);
    out.resize(n, ZERO);
    for k in 0..m {
        let zk = C64::new(zre[k], zim[k]);
        let mk = (m - k) % m;
        let zmk = C64::new(zre[mk], -zim[mk]);
        let e = (zk + zmk).scale(0.5);
        let o = (zk - zmk) * C64::new(0.0, -0.5);
        // Cached e^{-iπk/m} (ROADMAP follow-up: no per-point sin_cos).
        out[k] = e + rp.twiddles[k] * o;
    }
    // X[m] = E[0] − O[0] (both real: Re(Z[0]) and Im(Z[0])).
    out[m] = C64::real(zre[0] - zim[0]);
    for k in 1..m {
        out[n - k] = out[k].conj();
    }
    ws.give_f64(zim);
    ws.give_f64(zre);
}

/// Batched forward real FFT: `batch` signals packed **signal-major** in `xs`
/// at uniform `stride` (`xs[b*stride..(b+1)*stride]` is signal `b`,
/// zero-padded within its slot by the caller), each transformed at length
/// `n ≥ stride`. The full Hermitian spectra are written **lane-major** into
/// the split planes: `out_re[k*batch + b] + i·out_im[k*batch + b]` is
/// `X_b[k]` — the layout the spectral-product consumers iterate (fixed `k`,
/// batch innermost) and the layout [`inverse_real_many_into`] accepts back.
///
/// Even `n` runs one batched length-`n/2` complex transform (Hermitian
/// packing, exactly as [`fft_real_into`]); odd `n` falls back to the full
/// batched complex transform. Zero heap allocations in steady state.
pub fn fft_real_many_into(
    xs: &[f64],
    stride: usize,
    batch: usize,
    n: usize,
    ws: &mut FftWorkspace,
    out_re: &mut Vec<f64>,
    out_im: &mut Vec<f64>,
) {
    assert_eq!(xs.len(), stride * batch, "fft_real_many_into: xs/stride/batch mismatch");
    assert!(
        stride <= n,
        "fft_real_many_into: signal stride longer than transform ({stride} > {n})"
    );
    out_re.clear();
    out_im.clear();
    out_re.resize(n * batch, 0.0);
    out_im.resize(n * batch, 0.0);
    if n == 0 || batch == 0 {
        return;
    }
    if n % 2 != 0 {
        // Odd length: full complex transform directly in the output planes.
        for (b, sig) in xs.chunks_exact(stride).enumerate() {
            for (j, &v) in sig.iter().enumerate() {
                out_re[j * batch + b] = v;
            }
        }
        ws.process_many(out_re, out_im, n, batch, Dir::Forward);
        return;
    }
    let m = n / 2;
    let rp = ws.real_plan(n);
    let mut zre = ws.take_f64(m * batch);
    let mut zim = ws.take_f64(m * batch);
    // Pack z[j] = x[2j] + i·x[2j+1] per lane (slot tails beyond `stride`
    // stay zero from the rental).
    for (b, sig) in xs.chunks_exact(stride).enumerate() {
        let mut pairs = sig.chunks_exact(2);
        for (j, pair) in pairs.by_ref().enumerate() {
            zre[j * batch + b] = pair[0];
            zim[j * batch + b] = pair[1];
        }
        if let [last] = pairs.remainder() {
            zre[(stride / 2) * batch + b] = *last;
        }
    }
    ws.process_many(&mut zre, &mut zim, m, batch, Dir::Forward);
    // Recombine — same identity as fft_real_into, batch innermost.
    for k in 0..m {
        let w = rp.twiddles[k];
        let krow = k * batch;
        let mrow = ((m - k) % m) * batch;
        for l in 0..batch {
            let (zkr, zki) = (zre[krow + l], zim[krow + l]);
            let (zmr, zmi) = (zre[mrow + l], -zim[mrow + l]);
            let er = 0.5 * (zkr + zmr);
            let ei = 0.5 * (zki + zmi);
            // o = (zk − zmk)·(−i/2)
            let odr = 0.5 * (zki - zmi);
            let odi = -0.5 * (zkr - zmr);
            out_re[krow + l] = er + (w.re * odr - w.im * odi);
            out_im[krow + l] = ei + (w.re * odi + w.im * odr);
        }
    }
    // X[m] = Re(Z[0]) − Im(Z[0]) (real); the mirror below fills k > m.
    let mrow = m * batch;
    for l in 0..batch {
        out_re[mrow + l] = zre[l] - zim[l];
    }
    for k in 1..m {
        let (src, dst) = (k * batch, (n - k) * batch);
        for l in 0..batch {
            out_re[dst + l] = out_re[src + l];
            out_im[dst + l] = -out_im[src + l];
        }
    }
    ws.give_f64(zim);
    ws.give_f64(zre);
}

/// Inverse FFT of a Hermitian spectrum, returning the real signal in `out`.
/// `spec` is consumed as scratch (its contents are destroyed).
///
/// This is the single unification point for the old `ifft_to_real` /
/// `inverse_spectrum` pair: even `n` runs one length-`n/2` complex inverse
/// (`E[k] = (X[k]+X[k+m])/2`, `O[k] = (X[k]−X[k+m])·e^{2πik/n}/2`,
/// `z = F⁻¹(E + iO)`, de-interleave), odd `n` runs the full inverse. Debug
/// builds assert the spectrum really is (numerically) Hermitian — i.e. that
/// the imaginary residue being discarded is below tolerance — instead of
/// silently dropping it.
pub fn inverse_real_into(spec: &mut [C64], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
    let n = spec.len();
    out.clear();
    if n == 0 {
        return;
    }
    #[cfg(debug_assertions)]
    {
        let scale2 = spec
            .iter()
            .map(|v| v.norm_sqr())
            .fold(0.0f64, f64::max)
            .max(1.0);
        for k in 0..n {
            let resid2 = (spec[k] - spec[(n - k) % n].conj()).norm_sqr();
            debug_assert!(
                resid2 <= 1e-14 * scale2,
                "inverse_real_into: non-Hermitian spectrum at k={k}/{n} \
                 (|residue|²={resid2:.3e}, max|X|²={scale2:.3e}) — a nonzero \
                 imaginary output would be silently discarded"
            );
        }
    }
    if n % 2 != 0 {
        ws.process(spec, Dir::Inverse);
        out.extend(spec.iter().map(|v| v.re));
        return;
    }
    let m = n / 2;
    let rp = ws.real_plan(n);
    // Native split planes, as in `fft_real_into`: build the half-length
    // signal directly in f64 planes and run the batch=1 plane entry.
    let mut zre = ws.take_f64(m);
    let mut zim = ws.take_f64(m);
    for k in 0..m {
        let a = spec[k];
        let b = spec[k + m];
        let e = (a + b).scale(0.5);
        // e^{+iπk/m} = conj of the cached forward twiddle.
        let o = ((a - b).scale(0.5)) * rp.twiddles[k].conj();
        // z[k] = E[k] + i·O[k]
        zre[k] = e.re - o.im;
        zim[k] = e.im + o.re;
    }
    ws.process_planes(&mut zre, &mut zim, Dir::Inverse);
    out.resize(n, 0.0);
    for j in 0..m {
        out[2 * j] = zre[j];
        out[2 * j + 1] = zim[j];
    }
    ws.give_f64(zim);
    ws.give_f64(zre);
}

/// Batched inverse of [`fft_real_many_into`]: `batch` Hermitian spectra in
/// **lane-major** split planes (consumed as scratch), real signals written
/// **signal-major** into `out` (`out[b*n..(b+1)*n]` is signal `b` — the
/// layout per-repetition consumers slice apart). Debug builds assert each
/// lane's spectrum is numerically Hermitian, as [`inverse_real_into`] does.
pub fn inverse_real_many_into(
    spec_re: &mut [f64],
    spec_im: &mut [f64],
    batch: usize,
    ws: &mut FftWorkspace,
    out: &mut Vec<f64>,
) {
    assert!(batch > 0, "inverse_real_many_into: empty batch");
    assert_eq!(spec_re.len(), spec_im.len(), "inverse_real_many_into: plane length mismatch");
    assert_eq!(spec_re.len() % batch, 0, "inverse_real_many_into: planes not a lane multiple");
    let n = spec_re.len() / batch;
    out.clear();
    if n == 0 {
        return;
    }
    #[cfg(debug_assertions)]
    for l in 0..batch {
        let mut scale2 = 1.0f64;
        for k in 0..n {
            let (r, i) = (spec_re[k * batch + l], spec_im[k * batch + l]);
            scale2 = scale2.max(r * r + i * i);
        }
        for k in 0..n {
            let kc = (n - k) % n;
            let dr = spec_re[k * batch + l] - spec_re[kc * batch + l];
            let di = spec_im[k * batch + l] + spec_im[kc * batch + l];
            let resid2 = dr * dr + di * di;
            debug_assert!(
                resid2 <= 1e-14 * scale2,
                "inverse_real_many_into: non-Hermitian spectrum in lane {l} at k={k}/{n} \
                 (|residue|²={resid2:.3e}, max|X|²={scale2:.3e})"
            );
        }
    }
    if n % 2 != 0 {
        ws.process_many(spec_re, spec_im, n, batch, Dir::Inverse);
        out.resize(n * batch, 0.0);
        for j in 0..n {
            let row = j * batch;
            for l in 0..batch {
                out[l * n + j] = spec_re[row + l];
            }
        }
        return;
    }
    let m = n / 2;
    let rp = ws.real_plan(n);
    let mut zre = ws.take_f64(m * batch);
    let mut zim = ws.take_f64(m * batch);
    for k in 0..m {
        let w = rp.twiddles[k];
        let krow = k * batch;
        let hrow = (k + m) * batch;
        for l in 0..batch {
            let (ar, ai) = (spec_re[krow + l], spec_im[krow + l]);
            let (br, bi) = (spec_re[hrow + l], spec_im[hrow + l]);
            let er = 0.5 * (ar + br);
            let ei = 0.5 * (ai + bi);
            // o = ((a − b)/2)·conj(w)
            let hr = 0.5 * (ar - br);
            let hi = 0.5 * (ai - bi);
            let our = hr * w.re + hi * w.im;
            let oui = hi * w.re - hr * w.im;
            // z[k] = E[k] + i·O[k]
            zre[krow + l] = er - oui;
            zim[krow + l] = ei + our;
        }
    }
    ws.process_many(&mut zre, &mut zim, m, batch, Dir::Inverse);
    out.resize(n * batch, 0.0);
    for j in 0..m {
        let row = j * batch;
        for l in 0..batch {
            out[l * n + 2 * j] = zre[row + l];
            out[l * n + 2 * j + 1] = zim[row + l];
        }
    }
    ws.give_f64(zim);
    ws.give_f64(zre);
}

#[cfg(test)]
mod tests {
    use super::super::plan::{dft_naive, fft_real, ifft_to_real};
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn rfft_matches_naive_dft() {
        let mut rng = Rng::seed_from_u64(21);
        for &n in &[2usize, 4, 6, 8, 10, 16, 34, 64, 100, 128, 250, 3, 7, 25] {
            let x: Vec<f64> = rng.normal_vec(n);
            let spec = fft_real(&x, n);
            let full: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
            let naive = dft_naive(&full, Dir::Forward);
            let err = spec
                .iter()
                .zip(&naive)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-8 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn rfft_zero_padding_matches_naive() {
        let mut rng = Rng::seed_from_u64(22);
        for &(len, n) in &[(5usize, 16usize), (7, 8), (1, 2), (13, 40), (9, 27)] {
            let x: Vec<f64> = rng.normal_vec(len);
            let spec = fft_real(&x, n);
            let mut full = vec![ZERO; n];
            for (f, &v) in full.iter_mut().zip(&x) {
                f.re = v;
            }
            let naive = dft_naive(&full, Dir::Forward);
            let err = spec
                .iter()
                .zip(&naive)
                .map(|(a, b)| (*a - *b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-9 * n as f64, "len={len} n={n} err={err}");
        }
    }

    #[test]
    fn rfft_irfft_roundtrip() {
        let mut rng = Rng::seed_from_u64(23);
        for &n in &[2usize, 6, 16, 64, 100, 256, 1000, 5, 17, 243] {
            let x: Vec<f64> = rng.normal_vec(n);
            let spec = fft_real(&x, n);
            let back = ifft_to_real(spec);
            let err = x
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-10 * n as f64, "n={n} err={err}");
        }
    }

    #[test]
    fn batched_real_transforms_match_single_lane() {
        // fft_real_many_into ≡ a loop of fft_real_into, and the batched
        // inverse returns each lane's signal — for even, odd, and padded
        // lengths (the qcheck property lives in tests/fft_kernel.rs; this is
        // the in-module smoke check).
        let mut rng = Rng::seed_from_u64(24);
        let mut ws = FftWorkspace::new();
        for &(stride, n, batch) in &[(8usize, 8usize, 3usize), (5, 12, 2), (7, 7, 4), (9, 16, 1)] {
            let xs: Vec<f64> = rng.normal_vec(stride * batch);
            let mut sre = Vec::new();
            let mut sim = Vec::new();
            fft_real_many_into(&xs, stride, batch, n, &mut ws, &mut sre, &mut sim);
            let mut single = Vec::new();
            for b in 0..batch {
                fft_real_into(&xs[b * stride..(b + 1) * stride], n, &mut ws, &mut single);
                for k in 0..n {
                    let dr = (sre[k * batch + b] - single[k].re).abs();
                    let di = (sim[k * batch + b] - single[k].im).abs();
                    assert!(dr + di < 1e-10 * n as f64, "stride={stride} n={n} b={b} k={k}");
                }
            }
            let mut back = Vec::new();
            inverse_real_many_into(&mut sre, &mut sim, batch, &mut ws, &mut back);
            for b in 0..batch {
                for j in 0..stride {
                    assert!(
                        (back[b * n + j] - xs[b * stride + j]).abs() < 1e-10 * n as f64,
                        "roundtrip stride={stride} n={n} b={b} j={j}"
                    );
                }
                for j in stride..n {
                    assert!(back[b * n + j].abs() < 1e-10 * n as f64, "pad residue b={b} j={j}");
                }
            }
        }
    }

    #[test]
    fn workspace_pool_recycles_buffers() {
        let mut ws = FftWorkspace::new();
        let a = ws.take_c64(64);
        let cap_before = a.capacity();
        ws.give_c64(a);
        let b = ws.take_c64(32);
        assert!(b.capacity() >= cap_before.min(64));
        assert_eq!(b.len(), 32);
        assert!(b.iter().all(|z| z.re == 0.0 && z.im == 0.0));
        ws.give_c64(b);
    }

    #[test]
    fn thread_workspace_is_reentrant_safe() {
        let r = with_thread_workspace(|ws| {
            let buf = ws.take_c64(8);
            // A nested grab must not panic (falls back to a fresh arena).
            let inner = with_thread_workspace(|ws2| ws2.take_c64(4).len());
            ws.give_c64(buf);
            inner
        });
        assert_eq!(r, 4);
    }
}
