//! From-scratch FFT library (rustfft is not available offline): complex
//! arithmetic, a split-plane (structure-of-arrays) radix-4 kernel with
//! batched multi-spectrum transforms, Bluestein plans composed over it, a
//! global plan cache, a packed real-input transform, caller-owned
//! zero-allocation workspaces, and the linear/circular convolutions that
//! implement Eq. 3 (TS) and Eq. 8 (FCS). `dft_naive` and the scalar
//! interleaved radix-2 kernel (`ScalarRadix2Plan`) are kept as oracles.

pub mod complex;
pub mod convolve;
pub mod plan;
pub mod workspace;

pub use complex::C64;
pub use convolve::{
    conv_circular, conv_circular_many, conv_circular_many_into, conv_linear, conv_linear_into,
    conv_linear_many, conv_linear_many_into, packed_product_spectrum, packed_product_spectrum_into,
    product_spectrum_into, spectral_corr, spectral_corr_into, zero_pad,
};
pub use plan::{
    dft_naive, fft_inplace, fft_real, global_planner, ifft_inplace, ifft_to_real, Dir, FftScratch,
    Plan, PlanCacheCounters, Planner, RealPlan, ScalarRadix2Plan,
};
pub use workspace::{
    fft_real_into, fft_real_many_into, inverse_real_into, inverse_real_many_into,
    with_thread_workspace, FftWorkspace,
};
