//! From-scratch FFT library (rustfft is not available offline): complex
//! arithmetic, radix-2 + Bluestein plans with a global plan cache, a packed
//! real-input transform, caller-owned zero-allocation workspaces, and the
//! linear/circular convolutions that implement Eq. 3 (TS) and Eq. 8 (FCS).

pub mod complex;
pub mod convolve;
pub mod plan;
pub mod workspace;

pub use complex::C64;
pub use convolve::{
    conv_circular, conv_circular_many, conv_circular_many_into, conv_linear, conv_linear_into,
    conv_linear_many, conv_linear_many_into, packed_product_spectrum, packed_product_spectrum_into,
    product_spectrum_into, spectral_corr, spectral_corr_into, zero_pad,
};
pub use plan::{
    fft_inplace, fft_real, global_planner, ifft_inplace, ifft_to_real, Dir, Plan, Planner,
    RealPlan,
};
pub use workspace::{fft_real_into, inverse_real_into, with_thread_workspace, FftWorkspace};
