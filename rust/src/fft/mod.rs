//! From-scratch FFT library (rustfft is not available offline): complex
//! arithmetic, radix-2 + Bluestein plans with a global plan cache, and the
//! linear/circular convolutions that implement Eq. 3 (TS) and Eq. 8 (FCS).

pub mod complex;
pub mod convolve;
pub mod plan;

pub use complex::C64;
pub use convolve::{
    conv_circular, conv_circular_many, conv_linear, conv_linear_many, spectral_corr, zero_pad,
};
pub use plan::{fft_inplace, fft_real, global_planner, ifft_inplace, ifft_to_real, Dir, Plan};
