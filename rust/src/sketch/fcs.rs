//! Fast count sketch (Definition 4, the paper's contribution).
//!
//! `FCS(T) := CS(vec(T); h̃, s̃)` with the composite hash pair of Eq. 7 —
//! equivalently, for CP tensors, the zero-padded **linear** convolution of
//! the per-mode count sketches (Eq. 8). Output length `J̃ = Σ J_n − N + 1`.
//!
//! All frequency-domain work delegates to the shared
//! [`SpectralSketchCore`] (linear parameterization): TS and FCS differ only
//! in the two lengths handed to the core.

use super::common::{sketch_dense, sketch_dense_into, SpectralSketchCore, SpectralSketchOp};
use super::cs::CountSketch;
use crate::fft::FftWorkspace;
use crate::hash::ModeHashes;
use crate::tensor::{CpTensor, Tensor};

#[derive(Debug, Clone)]
pub struct FastCountSketch {
    pub hashes: ModeHashes,
    pub modes: Vec<CountSketch>,
    /// `J̃ = Σ J_n − N + 1`
    pub j_tilde: usize,
}

impl FastCountSketch {
    pub fn new(hashes: ModeHashes) -> Self {
        let j_tilde = hashes.composite_range();
        let modes = hashes.modes.iter().map(|t| CountSketch::new(t.clone())).collect();
        Self { hashes, modes, j_tilde }
    }

    pub fn order(&self) -> usize {
        self.modes.len()
    }

    /// The linear spectral-pipeline view (`sketch_len = J̃`,
    /// `fft_len = next_power_of_two(J̃)`).
    pub fn core(&self) -> SpectralSketchCore<'_> {
        SpectralSketchCore::linear(&self.modes, self.j_tilde)
    }

    /// Sketch a general dense tensor — `O(nnz(T))` (Eq. 13).
    pub fn apply_dense(&self, t: &Tensor) -> Vec<f64> {
        sketch_dense(t, &self.hashes, None)
    }

    /// In-place variant for the hot path.
    pub fn apply_dense_into(&self, t: &Tensor, out: &mut [f64]) {
        sketch_dense_into(t, &self.hashes, None, out);
    }

    /// FFT length for the CP fast path: FCS's linear (non-modular) structure
    /// means any `n ≥ J̃` is exact, so round up to a power of two and skip
    /// Bluestein entirely.
    #[inline]
    pub fn fft_len(&self) -> usize {
        self.j_tilde.next_power_of_two()
    }

    /// Sketch a CP tensor by **linear** convolution of per-mode count
    /// sketches (Eq. 8) — `O(max_n nnz(U^{(n)}) + R·J̃ log J̃)`.
    ///
    /// The rank sum `Σ_r λ_r · Π_n F(CS_n(u_r))` is accumulated in the
    /// **spectral domain**, so the whole call runs a single inverse FFT
    /// (R IFFTs → 1, §Perf). Above a size threshold the ranks fan out over
    /// worker threads.
    pub fn apply_cp(&self, cp: &CpTensor) -> Vec<f64> {
        assert!(
            super::common::cp_shape_matches(cp, &self.hashes.dims),
            "CP/hash shape mismatch"
        );
        self.core().apply_cp(cp)
    }

    /// Serial workspace variant of [`Self::apply_cp`]: zero heap allocations
    /// in steady state (all scratch rented from `ws`, `out` reused).
    pub fn apply_cp_into(&self, cp: &CpTensor, ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        assert!(
            super::common::cp_shape_matches(cp, &self.hashes.dims),
            "CP/hash shape mismatch"
        );
        self.core().apply_cp_into(cp, ws, out);
    }

    /// Pre-spectral-accumulation reference (one linear convolution and one
    /// inverse FFT **per rank**). Kept as the oracle for property tests and
    /// as the baseline the §Perf rank-R speedup is measured against.
    /// Deliberately *not* routed through [`SpectralSketchCore`] so it stays
    /// an independent check on the shared pipeline.
    pub fn apply_cp_per_rank(&self, cp: &CpTensor) -> Vec<f64> {
        assert!(
            super::common::cp_shape_matches(cp, &self.hashes.dims),
            "CP/hash shape mismatch"
        );
        let mut out = vec![0.0; self.j_tilde];
        for r in 0..cp.rank() {
            let sketched: Vec<Vec<f64>> = self
                .modes
                .iter()
                .zip(&cp.factors)
                .map(|(cs, u)| cs.apply(u.col(r)))
                .collect();
            let refs: Vec<&[f64]> = sketched.iter().map(|v| v.as_slice()).collect();
            let conv = crate::fft::conv_linear_many(&refs);
            debug_assert_eq!(conv.len(), self.j_tilde);
            crate::linalg::axpy(cp.lambda[r], &conv, &mut out);
        }
        out
    }

    /// Sketch of a rank-1 tensor `v_1 ∘ … ∘ v_N` (used by Eq. 16).
    pub fn apply_rank1(&self, vs: &[&[f64]]) -> Vec<f64> {
        crate::fft::with_thread_workspace(|ws| {
            let mut out = Vec::with_capacity(self.fft_len());
            self.apply_rank1_into(vs, ws, &mut out);
            out
        })
    }

    /// Workspace variant of [`Self::apply_rank1`] — zero allocations in
    /// steady state.
    pub fn apply_rank1_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        assert_eq!(vs.len(), self.order());
        self.core().apply_rank1_into(vs, ws, out);
    }

    /// The defining equivalence (Eq. 6): CS of `vec(T)` under the
    /// *materialized* composite hash pair. O(Ĩ) memory — used by tests and
    /// by the CS baseline comparison, never by the fast path.
    pub fn apply_via_composite_cs(&self, t: &Tensor) -> Vec<f64> {
        let comp = CountSketch::new(self.hashes.materialize_composite());
        comp.apply(t.as_vec())
    }

    /// Elementwise decompression (§4.3 rule):
    /// `T̂[i_1..i_N] = Π s_n(i_n) · FCS(T)[Σ h_n(i_n)]`.
    pub fn decode(&self, sketch: &[f64], idx: &[usize]) -> f64 {
        debug_assert_eq!(sketch.len(), self.j_tilde);
        self.hashes.composite_s(idx) * sketch[self.hashes.composite_h(idx)]
    }

    /// Memory of the stored hash functions (bytes) — `O(Σ I_n)`.
    pub fn hash_memory_bytes(&self) -> usize {
        self.hashes.memory_bytes()
    }
}

impl SpectralSketchOp for FastCountSketch {
    const NAME: &'static str = "fcs";

    fn from_hashes(hashes: ModeHashes) -> Self {
        FastCountSketch::new(hashes)
    }

    fn hashes(&self) -> &ModeHashes {
        &self.hashes
    }

    fn core(&self) -> SpectralSketchCore<'_> {
        FastCountSketch::core(self)
    }

    fn apply_dense(&self, t: &Tensor) -> Vec<f64> {
        FastCountSketch::apply_dense(self, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn definition_equivalence_eq6() {
        // FCS(T) (fast path) == CS(vec(T); composite hashes) (Definition 4).
        let mut rng = Rng::seed_from_u64(1);
        let shape = [5usize, 4, 6];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 7);
        let fcs = FastCountSketch::new(mh);
        let fast = fcs.apply_dense(&t);
        let def = fcs.apply_via_composite_cs(&t);
        for (a, b) in fast.iter().zip(&def) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn cp_fft_path_matches_dense_path_eq8() {
        // Eq. 8 (FFT linear convolution) == Eq. 13 on the materialized CP.
        let mut rng = Rng::seed_from_u64(2);
        let mut cp = CpTensor::randn(&mut rng, &[6, 5, 4], 3);
        cp.lambda = vec![1.0, -0.5, 2.0];
        let mh = ModeHashes::draw_uniform(&mut rng, &[6, 5, 4], 8);
        let fcs = FastCountSketch::new(mh);
        let via_cp = fcs.apply_cp(&cp);
        let via_dense = fcs.apply_dense(&cp.to_dense());
        let via_per_rank = fcs.apply_cp_per_rank(&cp);
        assert_eq!(via_cp.len(), 3 * 8 - 3 + 1);
        for (a, b) in via_cp.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        for (a, b) in via_cp.iter().zip(&via_per_rank) {
            assert!((a - b).abs() < 1e-9, "spectral {a} vs per-rank {b}");
        }
    }

    #[test]
    fn qcheck_spectral_cp_matches_reference_and_dense() {
        // Property: the one-IFFT spectral-accumulation path ≡ the per-rank
        // reference ≡ apply_dense on the materialized CP tensor, across
        // random orders, heterogeneous mode ranges, and non-power-of-two J̃.
        use crate::util::qcheck::qcheck;
        qcheck(12, |g| {
            let order = g.usize_in(2, 4);
            let shape = g.shape(order, 2, 5);
            let ranges: Vec<usize> = (0..order).map(|_| g.usize_in(2, 9)).collect();
            let rank = g.usize_in(1, 4);
            let cp = CpTensor::randn(g.rng(), &shape, rank);
            let mh = ModeHashes::draw(g.rng(), &shape, &ranges);
            let fcs = FastCountSketch::new(mh);
            let spectral = fcs.apply_cp(&cp);
            let per_rank = fcs.apply_cp_per_rank(&cp);
            let dense = fcs.apply_dense(&cp.to_dense());
            assert_eq!(spectral.len(), fcs.j_tilde);
            let scale = dense.iter().map(|v| v.abs()).fold(1.0, f64::max);
            for k in 0..fcs.j_tilde {
                assert!(
                    (spectral[k] - per_rank[k]).abs() < 1e-9 * scale,
                    "case {}: k={k} spectral {} vs per-rank {}",
                    g.case,
                    spectral[k],
                    per_rank[k]
                );
                assert!(
                    (spectral[k] - dense[k]).abs() < 1e-8 * scale,
                    "case {}: k={k} spectral {} vs dense {}",
                    g.case,
                    spectral[k],
                    dense[k]
                );
            }
        });
    }

    #[test]
    fn qcheck_rank1_into_matches_dense() {
        use crate::fft::FftWorkspace;
        use crate::util::qcheck::qcheck;
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();
        qcheck(10, |g| {
            let shape = g.shape(3, 2, 6);
            let ranges: Vec<usize> = (0..3).map(|_| g.usize_in(2, 8)).collect();
            let vs: Vec<Vec<f64>> = shape.iter().map(|&d| g.normal_vec(d)).collect();
            let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
            let mh = ModeHashes::draw(g.rng(), &shape, &ranges);
            let fcs = FastCountSketch::new(mh);
            fcs.apply_rank1_into(&refs, &mut ws, &mut out);
            let dense = fcs.apply_dense(&crate::tensor::outer(&refs));
            assert_eq!(out.len(), dense.len());
            for (a, b) in out.iter().zip(&dense) {
                assert!((a - b).abs() < 1e-9, "case {}: {a} vs {b}", g.case);
            }
        });
    }

    #[test]
    fn heterogeneous_ranges_supported() {
        // FCS (unlike TS) allows J_n to differ per mode.
        let mut rng = Rng::seed_from_u64(3);
        let shape = [5usize, 7, 3];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw(&mut rng, &shape, &[4, 9, 5]);
        let fcs = FastCountSketch::new(mh);
        let fast = fcs.apply_dense(&t);
        assert_eq!(fast.len(), 4 + 9 + 5 - 3 + 1);
        let def = fcs.apply_via_composite_cs(&t);
        for (a, b) in fast.iter().zip(&def) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn rank1_matches_dense() {
        let mut rng = Rng::seed_from_u64(4);
        let u = rng.normal_vec(5);
        let v = rng.normal_vec(6);
        let w = rng.normal_vec(4);
        let mh = ModeHashes::draw_uniform(&mut rng, &[5, 6, 4], 9);
        let fcs = FastCountSketch::new(mh);
        let fast = fcs.apply_rank1(&[&u, &v, &w]);
        let dense = fcs.apply_dense(&crate::tensor::outer(&[&u, &v, &w]));
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn inner_product_unbiased() {
        let mut rng = Rng::seed_from_u64(5);
        let m = Tensor::randn(&mut rng, &[5, 5, 5]);
        let n = Tensor::randn(&mut rng, &[5, 5, 5]);
        let truth = m.inner(&n);
        let trials = 1500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &[5, 5, 5], 24);
            let f = FastCountSketch::new(mh);
            acc += crate::linalg::dot(&f.apply_dense(&m), &f.apply_dense(&n));
        }
        let mean = acc / trials as f64;
        assert!((mean - truth).abs() < 0.75, "mean={mean} truth={truth}");
    }

    #[test]
    fn fcs_variance_not_worse_than_ts() {
        // Empirical check of Proposition 1: with equalized hashes the FCS
        // inner-product estimator has variance ≤ the TS one.
        let mut rng = Rng::seed_from_u64(6);
        let m = Tensor::randn(&mut rng, &[6, 6, 6]);
        let n = Tensor::randn(&mut rng, &[6, 6, 6]);
        let trials = 800;
        let mut fcs_est = Vec::with_capacity(trials);
        let mut ts_est = Vec::with_capacity(trials);
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &[6, 6, 6], 16);
            let f = FastCountSketch::new(mh.clone());
            let t = super::super::ts::TensorSketch::new(mh);
            fcs_est.push(crate::linalg::dot(&f.apply_dense(&m), &f.apply_dense(&n)));
            ts_est.push(crate::linalg::dot(&t.apply_dense(&m), &t.apply_dense(&n)));
        }
        let var = |xs: &[f64]| {
            let mu = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / xs.len() as f64
        };
        let (vf, vt) = (var(&fcs_est), var(&ts_est));
        assert!(
            vf <= vt * 1.15, // sampling slack; systematic relation is ≤
            "Var[FCS]={vf} should be ≤ Var[TS]={vt}"
        );
    }

    #[test]
    fn decode_roundtrip_expectation() {
        // E[decode] = entry value.
        let mut rng = Rng::seed_from_u64(7);
        let shape = [4usize, 4, 4];
        let mut t = Tensor::zeros(&shape);
        t.set(&[1, 2, 3], 5.0);
        t.set(&[0, 0, 0], -2.0);
        let trials = 3000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 16);
            let f = FastCountSketch::new(mh);
            let sk = f.apply_dense(&t);
            acc += f.decode(&sk, &[1, 2, 3]);
        }
        let mean = acc / trials as f64;
        assert!((mean - 5.0).abs() < 0.25, "mean={mean}");
    }
}
