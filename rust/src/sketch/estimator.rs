//! Contraction estimators — the interface RTPM/ALS program against.
//!
//! Each method (plain/CS/TS/HCS/FCS) preprocesses a tensor `T` once into `D`
//! independent sketches, then answers the contraction queries of §4.1:
//!
//! * `t_uuu`   — `T(u, u, u)` (Eq. 16 for FCS),
//! * `t_iuu`   — `T(I, u, u)` (Eq. 17 for FCS),
//! * `t_mode`  — the general "free mode n, contract the rest" form used by
//!   asymmetric RTPM and ALS (Eq. 18).
//!
//! All sketched estimators return the **median over D repetitions** (§4).
//!
//! TS and FCS share one generic implementation, [`SpectralEstimator`]: both
//! are a [`SpectralSketchCore`](super::common::SpectralSketchCore)
//! parameterization (circular vs linear), so every spectral query body —
//! `t_uuu`, the Eq. 17 correlate-and-gather behind `t_mode`, and the
//! sketch-domain `deflate` — is written exactly once.

use super::common::{pack_mode_lane, seed_first_lane, FoldSeed, SpectralDriver, SpectralSketchOp};
use super::cs::CountSketch;
use super::fcs::FastCountSketch;
use super::hcs::HigherOrderCountSketch;
use super::ts::TensorSketch;
use crate::fft::{self, FftWorkspace};
use crate::hash::{HashPair, ModeHashes};
use crate::tensor::{contract_all_but, t_iuu, t_uuu, Tensor};
use crate::util::parallel::par_map;
use crate::util::prng::Rng;

/// Unified estimator interface. Implementations must be `Send + Sync` so the
/// coordinator can serve them from a worker pool.
pub trait ContractionEstimator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Estimate `T(u, u, u)` (cubical 3rd-order `T`).
    fn t_uuu(&self, u: &[f64]) -> f64;

    /// Estimate `T(I, u, u)` (cubical 3rd-order `T`).
    fn t_iuu(&self, u: &[f64]) -> Vec<f64> {
        let vs: Vec<&[f64]> = vec![u, u, u];
        self.t_mode(0, &vs)
    }

    /// Estimate the mode-`mode` contraction with `vs[d]` at every other mode
    /// (`vs[mode]` is ignored). Returns a vector of length `I_mode`.
    fn t_mode(&self, mode: usize, vs: &[&[f64]]) -> Vec<f64>;

    /// Buffer-reusing variant of [`Self::t_mode`]: writes into `out`
    /// (cleared first) so steady-state callers — the ALS/RTPM inner loops —
    /// avoid per-call allocation. Sketched implementations override this
    /// with a zero-allocation workspace path; the default delegates.
    fn t_mode_into(&self, mode: usize, vs: &[&[f64]], out: &mut Vec<f64>) {
        let v = self.t_mode(mode, vs);
        out.clear();
        out.extend_from_slice(&v);
    }

    /// Buffer-reusing variant of [`Self::t_iuu`].
    fn t_iuu_into(&self, u: &[f64], out: &mut Vec<f64>) {
        let vs: [&[f64]; 3] = [u, u, u];
        self.t_mode_into(0, &vs, out);
    }

    /// Estimate of `‖T‖_F` from the sketched representation (median of
    /// per-repetition sketch norms; exact for `plain`). RTPM uses it to cap
    /// eigenvalue estimates: `|λ| = |T(u,v,w)| ≤ ‖T‖_F` for unit vectors, so
    /// clamping prevents a noisy λ from blowing up the deflation.
    fn norm_estimate(&self) -> f64;

    /// Rank-1 deflation `T ← T − λ·v_1 ∘ … ∘ v_N`, applied *in the sketch
    /// domain* for sketched estimators (sketches are linear operators, so
    /// `sketch(T − λ u∘v∘w) = sketch(T) − λ·sketch(u∘v∘w)` — no re-sketching
    /// of the full tensor, the trick RTPM-with-sketching relies on).
    fn deflate(&mut self, lambda: f64, vs: &[&[f64]]);

    /// Bytes held by the sketched representation of `T`.
    fn sketch_bytes(&self) -> usize;

    /// Bytes held by the stored hash functions (the paper's memory metric).
    fn hash_bytes(&self) -> usize;
}

/// Elementwise median across `D` equal-length vectors. NaN-tolerant:
/// `total_cmp` ordering (NaN sorts to the tail) — a degenerate sketch must
/// yield a degenerate *estimate*, never a panic in a serving worker.
pub fn elementwise_median(rows: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rows.is_empty());
    let n = rows[0].len();
    if rows.len() == 1 {
        return rows[0].clone();
    }
    let mut out = vec![0.0; n];
    let mut buf = vec![0.0; rows.len()];
    for i in 0..n {
        for (b, row) in buf.iter_mut().zip(rows) {
            *b = row[i];
        }
        buf.sort_unstable_by(f64::total_cmp);
        out[i] = crate::util::timing::percentile_sorted(&buf, 50.0);
    }
    out
}

/// Flat-buffer variant of [`elementwise_median`]: `rows` is row-major
/// `[d × n]`, `scratch` is the per-column sort buffer. Allocation-free when
/// `scratch`/`out` have capacity (the estimator hot paths rent both from an
/// [`crate::fft::FftWorkspace`]).
pub fn elementwise_median_flat(
    rows: &[f64],
    d: usize,
    n: usize,
    scratch: &mut Vec<f64>,
    out: &mut Vec<f64>,
) {
    assert!(d > 0);
    assert_eq!(rows.len(), d * n);
    out.clear();
    out.resize(n, 0.0);
    if d == 1 {
        out.copy_from_slice(rows);
        return;
    }
    scratch.clear();
    scratch.resize(d, 0.0);
    for i in 0..n {
        for r in 0..d {
            scratch[r] = rows[r * n + i];
        }
        scratch.sort_unstable_by(f64::total_cmp);
        out[i] = crate::util::timing::percentile_sorted(scratch, 50.0);
    }
}

/// Repetition fan-out threshold for estimator queries: enough independent
/// repetitions to chunk, and large enough transforms to amortize thread
/// startup inside a power-iteration step.
fn reps_parallel(reps: usize, fft_len: usize) -> bool {
    reps >= 6 && fft_len >= 16384
}

// ---------------------------------------------------------------------------
// Plain (exact) estimator
// ---------------------------------------------------------------------------

/// Exact contractions on the dense tensor — the "plain" baseline.
pub struct PlainEstimator {
    pub t: Tensor,
}

impl PlainEstimator {
    pub fn new(t: Tensor) -> Self {
        Self { t }
    }
}

impl ContractionEstimator for PlainEstimator {
    fn name(&self) -> &'static str {
        "plain"
    }

    fn t_uuu(&self, u: &[f64]) -> f64 {
        t_uuu(&self.t, u)
    }

    fn t_iuu(&self, u: &[f64]) -> Vec<f64> {
        t_iuu(&self.t, u)
    }

    fn t_iuu_into(&self, u: &[f64], out: &mut Vec<f64>) {
        let v = t_iuu(&self.t, u);
        out.clear();
        out.extend_from_slice(&v);
    }

    fn t_mode(&self, mode: usize, vs: &[&[f64]]) -> Vec<f64> {
        contract_all_but(&self.t, mode, vs)
    }

    fn norm_estimate(&self) -> f64 {
        self.t.frob_norm()
    }

    fn deflate(&mut self, lambda: f64, vs: &[&[f64]]) {
        let rank1 = crate::tensor::outer(vs);
        crate::linalg::axpy(-lambda, &rank1.data, &mut self.t.data);
    }

    fn sketch_bytes(&self) -> usize {
        self.t.numel() * 8
    }

    fn hash_bytes(&self) -> usize {
        0
    }
}

// ---------------------------------------------------------------------------
// CS baseline: one long hash pair over vec(T) (Definition 1 applied naively)
// ---------------------------------------------------------------------------

struct CsRep {
    cs: CountSketch,
    st: Vec<f64>,
}

/// CS on `vec(T)` with an *independent long* hash pair per repetition —
/// the strawman the paper contrasts FCS against: `O(Ĩ)` hash storage, and
/// rank-1 queries must enumerate `nnz(u)^N` entries of `u ∘ u ∘ u`.
pub struct CsEstimator {
    shape: Vec<usize>,
    reps: Vec<CsRep>,
}

impl CsEstimator {
    pub fn build(t: &Tensor, d: usize, j: usize, rng: &mut Rng) -> Self {
        let total = t.numel();
        let seeds: Vec<u64> = (0..d).map(|_| rng.next_u64()).collect();
        let reps = par_map(d, crate::util::parallel::default_threads(), |i| {
            let mut r = Rng::seed_from_u64(seeds[i]);
            let cs = CountSketch::new(HashPair::draw(&mut r, total, j).materialize());
            let st = cs.apply(t.as_vec());
            CsRep { cs, st }
        });
        Self { shape: t.shape.clone(), reps }
    }
}

impl ContractionEstimator for CsEstimator {
    fn name(&self) -> &'static str {
        "cs"
    }

    fn t_uuu(&self, u: &[f64]) -> f64 {
        let i = self.shape[0];
        assert_eq!(u.len(), i);
        let ests: Vec<f64> = self
            .reps
            .iter()
            .map(|rep| {
                // ⟨CS(vec T), CS(vec(u∘u∘u))⟩ without materializing either:
                // Σ_{ijk} s(l) st[h(l)] u_i u_j u_k, l = i + I(j + I k).
                let h = &rep.cs.table.h;
                let s = &rep.cs.table.s;
                let mut acc = 0.0;
                for k in 0..i {
                    let uk = u[k];
                    if uk == 0.0 {
                        continue;
                    }
                    for j in 0..i {
                        let c = u[j] * uk;
                        if c == 0.0 {
                            continue;
                        }
                        let base = (k * i + j) * i;
                        let mut inner = 0.0;
                        for (ii, &ui) in u.iter().enumerate() {
                            let l = base + ii;
                            inner += (s[l] as f64) * rep.st[h[l] as usize] * ui;
                        }
                        acc += c * inner;
                    }
                }
                acc
            })
            .collect();
        crate::util::timing::median(&ests)
    }

    fn t_mode(&self, mode: usize, vs: &[&[f64]]) -> Vec<f64> {
        assert_eq!(self.shape.len(), 3, "CS estimator supports 3rd-order tensors");
        let dims = &self.shape;
        let rows: Vec<Vec<f64>> = self
            .reps
            .iter()
            .map(|rep| {
                let h = &rep.cs.table.h;
                let s = &rep.cs.table.s;
                let mut out = vec![0.0; dims[mode]];
                // iterate the two contracted modes; for each free index read
                // the hashed bucket — O(Ĩ) worst case, O(nnz² I) sparse.
                let (d0, d1, d2) = (dims[0], dims[1], dims[2]);
                for k in 0..d2 {
                    let vk = if mode == 2 { 1.0 } else { vs[2][k] };
                    if vk == 0.0 {
                        continue;
                    }
                    for j in 0..d1 {
                        let vj = if mode == 1 { 1.0 } else { vs[1][j] };
                        if vj == 0.0 {
                            continue;
                        }
                        let base = (k * d1 + j) * d0;
                        match mode {
                            0 => {
                                let c = vj * vk;
                                for (o, ov) in out.iter_mut().enumerate() {
                                    let l = base + o;
                                    *ov += c * (s[l] as f64) * rep.st[h[l] as usize];
                                }
                            }
                            1 => {
                                let mut inner = 0.0;
                                for (ii, &vi) in vs[0].iter().enumerate() {
                                    let l = base + ii;
                                    inner += vi * (s[l] as f64) * rep.st[h[l] as usize];
                                }
                                out[j] += vk * inner;
                            }
                            _ => {
                                let mut inner = 0.0;
                                for (ii, &vi) in vs[0].iter().enumerate() {
                                    let l = base + ii;
                                    inner += vi * (s[l] as f64) * rep.st[h[l] as usize];
                                }
                                out[k] += vj * inner;
                            }
                        }
                    }
                }
                out
            })
            .collect();
        elementwise_median(&rows)
    }

    fn norm_estimate(&self) -> f64 {
        let norms: Vec<f64> = self.reps.iter().map(|r| crate::linalg::norm2(&r.st)).collect();
        crate::util::timing::median(&norms)
    }

    fn deflate(&mut self, lambda: f64, vs: &[&[f64]]) {
        // CS has no structure to exploit: sketch the dense rank-1 tensor
        // entry by entry, O(Ĩ) per repetition.
        assert_eq!(vs.len(), 3);
        let (u, v, w) = (vs[0], vs[1], vs[2]);
        let (d0, d1) = (self.shape[0], self.shape[1]);
        for rep in &mut self.reps {
            let h = &rep.cs.table.h;
            let s = &rep.cs.table.s;
            for (k, &wk) in w.iter().enumerate() {
                if wk == 0.0 {
                    continue;
                }
                for (j, &vj) in v.iter().enumerate() {
                    let c = lambda * vj * wk;
                    if c == 0.0 {
                        continue;
                    }
                    let base = (k * d1 + j) * d0;
                    for (i, &ui) in u.iter().enumerate() {
                        let l = base + i;
                        rep.st[h[l] as usize] -= c * (s[l] as f64) * ui;
                    }
                }
            }
        }
    }

    fn sketch_bytes(&self) -> usize {
        self.reps.iter().map(|r| r.st.len() * 8).sum()
    }

    fn hash_bytes(&self) -> usize {
        self.reps.iter().map(|r| r.cs.table.memory_bytes()).sum()
    }
}

// ---------------------------------------------------------------------------
// Generic spectral estimator — the single implementation behind TS and FCS
// ---------------------------------------------------------------------------

/// One repetition: the sketch operator, the sketched tensor, and the cached
/// forward FFT of the sketch. Fields are crate-private: `st` and `st_fft`
/// must stay coherent (only [`SpectralEstimator::deflate`] may move them),
/// so external mutation would silently corrupt every later `t_mode`.
pub struct SpectralRep<S> {
    pub(crate) op: S,
    pub(crate) st: Vec<f64>,
    /// Cached forward FFT of `st` at the core's `fft_len`. `st` is fixed
    /// between deflations, so `F(st)` is hoisted out of every `t_mode` call
    /// (§Perf); [`SpectralEstimator::deflate`] keeps it coherent.
    st_fft: Vec<crate::fft::C64>,
}

/// Median-of-D estimator over any [`SpectralSketchOp`]. TS instantiates the
/// circular core (Eq. 3 + the TS analogue of Eq. 17), FCS the linear one
/// (Eqs. 8, 16, 17) — every query body below is shared:
///
/// * `t_uuu` — `⟨sketch(T), sketch(u∘u∘u)⟩` (Eq. 16), the rank-1 sketch via
///   the core's product-of-spectra pipeline;
/// * `t_mode` — `z = F⁻¹(F(st) · Π_{d≠mode} conj(F(CS_d(v_d))))` then the
///   mode-`mode` basis gather (Eq. 17 generalized, one repetition per rep);
/// * `deflate` — sketch-domain rank-1 subtraction, keeping the `F(st)`
///   cache coherent by linearity of `F`.
pub struct SpectralEstimator<S> {
    pub(crate) reps: Vec<SpectralRep<S>>,
    /// Sketch length (J for TS, J̃ for FCS).
    sketch_len: usize,
    /// Transform length (J for TS, next_pow2(J̃) for FCS).
    fft_len: usize,
}

/// TS-backed estimator (circular convolution, Eq. 3 + TS analogue of Eq. 17).
pub type TsEstimator = SpectralEstimator<TensorSketch>;

/// FCS-backed estimator (Eqs. 8, 16, 17 — the paper's method).
pub type FcsEstimator = SpectralEstimator<FastCountSketch>;

impl<S: SpectralSketchOp> SpectralEstimator<S> {
    /// Build with freshly drawn hashes.
    pub fn build(t: &Tensor, d: usize, j: usize, rng: &mut Rng) -> Self {
        let hashes: Vec<ModeHashes> = (0..d)
            .map(|_| ModeHashes::draw_uniform(rng, &t.shape, j))
            .collect();
        Self::build_with_hashes(t, &hashes)
    }

    /// Build reusing existing hash draws (for TS/FCS equalization, §4.1).
    ///
    /// Every repetition must share the same per-mode sketch ranges (every
    /// in-crate builder draws them that way): the batched serial
    /// `t_mode_into`/`deflate` paths pack all repetitions' mode sketches
    /// into one uniform-stride arena and index every `st_fft` at the shared
    /// `fft_len`, so a heterogeneous repetition would silently corrupt the
    /// fold — reject it loudly here instead.
    pub fn build_with_hashes(t: &Tensor, hashes: &[ModeHashes]) -> Self {
        assert!(!hashes.is_empty());
        for h in &hashes[1..] {
            assert!(
                h.modes.len() == hashes[0].modes.len()
                    && h.modes.iter().zip(&hashes[0].modes).all(|(a, b)| a.range == b.range),
                "spectral estimator repetitions must share per-mode sketch ranges"
            );
        }
        let reps = par_map(hashes.len(), crate::util::parallel::default_threads(), |i| {
            let op = S::from_hashes(hashes[i].clone());
            let st = op.apply_dense(t);
            let st_fft = op.core().sketch_spectrum(&st);
            SpectralRep { op, st, st_fft }
        });
        let core = reps[0].op.core();
        let (sketch_len, fft_len) = (core.sketch_len, core.fft_len);
        Self { reps, sketch_len, fft_len }
    }

    /// Build directly from a CP representation (uses the Eq. 8/Eq. 3 FFT
    /// path — `O(nnz(U) + R·n log n)` instead of `O(nnz(T))`).
    pub fn build_from_cp(cp: &crate::tensor::CpTensor, d: usize, j: usize, rng: &mut Rng) -> Self {
        let hashes: Vec<ModeHashes> = (0..d)
            .map(|_| ModeHashes::draw_uniform(rng, &cp.shape(), j))
            .collect();
        assert!(!hashes.is_empty());
        let reps = par_map(hashes.len(), crate::util::parallel::default_threads(), |i| {
            let op = S::from_hashes(hashes[i].clone());
            // Serial spectral path per repetition: the repetitions themselves
            // are already fanned out across this par_map.
            let mut ws = FftWorkspace::new();
            let mut st = Vec::new();
            op.apply_cp_into(cp, &mut ws, &mut st);
            let st_fft = op.core().sketch_spectrum(&st);
            SpectralRep { op, st, st_fft }
        });
        let core = reps[0].op.core();
        let (sketch_len, fft_len) = (core.sketch_len, core.fft_len);
        Self { reps, sketch_len, fft_len }
    }

    /// One repetition of the Eq. 17 query: the core's correlate-and-gather
    /// with this repetition's cached `F(st)` (the per-rep body the parallel
    /// fan-out runs; the serial path batches across repetitions instead).
    fn t_mode_one_rep(
        &self,
        rep: &SpectralRep<S>,
        mode: usize,
        vs: &[&[f64]],
        ws: &mut FftWorkspace,
        out: &mut Vec<f64>,
    ) {
        rep.op.core().correlate_gather_into(&rep.st_fft, mode, vs, ws, out);
    }

    /// Largest per-mode sketch range across every repetition — the uniform
    /// slot stride the cross-repetition batched transforms pack at. Derived
    /// from the core's stride rule (its single home), maxed over reps.
    fn mode_stride(&self) -> usize {
        self.reps.iter().map(|r| r.op.core().mode_stride()).max().unwrap_or(0)
    }

    /// Streaming rank-1 absorb: fold `+λ·(v₁ ∘ … ∘ v_N)` into every
    /// repetition's sketch (and cached spectrum) **without** touching the
    /// base tensor — the exact mirror of [`ContractionEstimator::deflate`]
    /// (which subtracts), so by CS linearity the updated state equals a
    /// from-scratch re-sketch of `T + λ·(v₁ ∘ … ∘ v_N)` under the same hash
    /// draws. This is the incremental path for tensors too big to
    /// re-sketch: build on a partial (or merged shard) sketch, then absorb
    /// deltas as they arrive.
    pub fn absorb_rank1(&mut self, lambda: f64, vs: &[&[f64]]) {
        self.deflate(-lambda, vs);
    }
}

impl<S: SpectralSketchOp> ContractionEstimator for SpectralEstimator<S> {
    fn name(&self) -> &'static str {
        S::NAME
    }

    fn t_uuu(&self, u: &[f64]) -> f64 {
        // Eq. 16 / its TS analogue: ⟨sketch(T), sketch(u∘u∘u)⟩, the rank-1
        // sketch via the spectral pipeline, all scratch rented from the
        // thread workspace.
        fft::with_thread_workspace(|ws| {
            let mut ests = ws.take_f64(self.reps.len());
            let mut sk = ws.take_f64(self.sketch_len);
            for (i, rep) in self.reps.iter().enumerate() {
                rep.op.apply_rank1_into(&[u, u, u], ws, &mut sk);
                ests[i] = crate::linalg::dot(&rep.st, &sk);
            }
            let m = crate::util::timing::median_inplace(&mut ests);
            ws.give_f64(sk);
            ws.give_f64(ests);
            m
        })
    }

    fn t_mode(&self, mode: usize, vs: &[&[f64]]) -> Vec<f64> {
        let mut out = Vec::new();
        self.t_mode_into(mode, vs, &mut out);
        out
    }

    fn t_mode_into(&self, mode: usize, vs: &[&[f64]], out: &mut Vec<f64>) {
        crate::obs::metrics().estimator_t_mode.inc();
        let d_reps = self.reps.len();
        let im = self.reps[0].op.core().modes[mode].domain();
        let nm = self.reps[0].op.core().modes.len();
        if reps_parallel(d_reps, self.fft_len) {
            let rows = par_map(d_reps, crate::util::parallel::default_threads(), |ri| {
                let mut ws = FftWorkspace::new();
                let mut row = Vec::new();
                self.t_mode_one_rep(&self.reps[ri], mode, vs, &mut ws, &mut row);
                row
            });
            let med = elementwise_median(&rows);
            out.clear();
            out.extend_from_slice(&med);
            return;
        }
        // Serial path: one cross-repetition SpectralDriver correlation pass.
        // The driver chunks repetitions at its MAX_FFT_LANES cap — per chunk,
        // ONE forward transform for the chunk's D_c·(N−1) contracted-mode
        // sketches, the per-rep Eq. 17 fold seeded with each cached F(st),
        // and ONE batched inverse for the D_c correlation signals — instead
        // of D·N plan dispatches per query.
        let driver =
            SpectralDriver::correlate(self.fft_len, self.mode_stride(), nm.saturating_sub(1));
        let reps = &self.reps;
        fft::with_thread_workspace(|ws| {
            let mut rows = ws.take_f64(d_reps * im);
            driver.fold_inverse(
                d_reps,
                ws,
                |g, l, slot| {
                    let core = reps[g].op.core();
                    let d = if l < mode { l } else { l + 1 };
                    pack_mode_lane(&core.modes[d], vs[d], slot);
                },
                FoldSeed::External(|g: usize, k: usize| {
                    let f = reps[g].st_fft[k];
                    (f.re, f.im)
                }),
                |g, z| {
                    // Per-rep mode-basis gather (Eq. 17's ⟨z, CS(e_i)⟩ trick).
                    let cs_m = &reps[g].op.core().modes[mode];
                    for (i, o) in rows[g * im..g * im + im].iter_mut().enumerate() {
                        let (bk, s) = cs_m.basis(i);
                        *o = s * z[bk];
                    }
                },
            );
            // Elementwise median across all repetitions.
            let mut scratch = ws.take_f64(d_reps);
            elementwise_median_flat(&rows, d_reps, im, &mut scratch, out);
            ws.give_f64(scratch);
            ws.give_f64(rows);
        });
    }

    fn norm_estimate(&self) -> f64 {
        let norms: Vec<f64> = self.reps.iter().map(|r| crate::linalg::norm2(&r.st)).collect();
        crate::util::timing::median(&norms)
    }

    fn deflate(&mut self, lambda: f64, vs: &[&[f64]]) {
        // Batched sketch-domain rank-1 subtraction: one cross-repetition
        // SpectralDriver convolution pass (per chunk, ONE forward for the
        // D_c·N mode sketches and ONE batched inverse for the D_c rank-1
        // sketches), then one batched forward sweep of the truncated
        // signals to keep every F(st) cache coherent (F is linear) —
        // instead of D·(N+1) plan dispatches.
        crate::obs::metrics().estimator_deflate.inc();
        let (sketch_len, n) = (self.sketch_len, self.fft_len);
        let d_reps = self.reps.len();
        let nm = self.reps[0].op.core().modes.len();
        assert_eq!(vs.len(), nm, "deflate: rank-1 arity mismatch");
        let driver = SpectralDriver::convolve(n, self.mode_stride(), nm);
        fft::with_thread_workspace(|ws| {
            // The subtracted rank-1 sketch signals, signal-major — truncated
            // to sketch_len (tails zeroed) so the cache update below sees
            // exactly the signal taken out of each `st`.
            let mut sk_all = ws.take_f64(d_reps * n);
            {
                let reps = &self.reps;
                driver.fold_inverse(
                    d_reps,
                    ws,
                    |g, d, slot| pack_mode_lane(&reps[g].op.core().modes[d], vs[d], slot),
                    seed_first_lane(),
                    |g, z| {
                        for v in z[sketch_len..].iter_mut() {
                            *v = 0.0;
                        }
                        sk_all[g * n..(g + 1) * n].copy_from_slice(z);
                    },
                );
            }
            for (g, rep) in self.reps.iter_mut().enumerate() {
                crate::linalg::axpy(-lambda, &sk_all[g * n..g * n + sketch_len], &mut rep.st);
            }
            // Cache-coherency sweep: F(st) ← F(st) − λ·F(subtracted signal).
            let reps = &mut self.reps;
            driver.forward_each(&sk_all, d_reps, ws, |g, k, fr, fi| {
                let x = &mut reps[g].st_fft[k];
                x.re -= lambda * fr;
                x.im -= lambda * fi;
            });
            ws.give_f64(sk_all);
        });
    }

    fn sketch_bytes(&self) -> usize {
        self.reps.iter().map(|r| r.st.len() * 8).sum()
    }

    fn hash_bytes(&self) -> usize {
        self.reps.iter().map(|r| r.op.hash_memory_bytes()).sum()
    }
}

// ---------------------------------------------------------------------------
// HCS estimator (Eq. 4/5, Shi et al.)
// ---------------------------------------------------------------------------

struct HcsRep {
    hcs: HigherOrderCountSketch,
    st: Tensor,
}

pub struct HcsEstimator {
    reps: Vec<HcsRep>,
}

impl HcsEstimator {
    pub fn build(t: &Tensor, d: usize, j: usize, rng: &mut Rng) -> Self {
        let hashes: Vec<ModeHashes> = (0..d)
            .map(|_| ModeHashes::draw_uniform(rng, &t.shape, j))
            .collect();
        let reps = par_map(hashes.len(), crate::util::parallel::default_threads(), |i| {
            let hcs = HigherOrderCountSketch::new(hashes[i].clone());
            let st = hcs.apply_dense(t);
            HcsRep { hcs, st }
        });
        Self { reps }
    }
}

impl ContractionEstimator for HcsEstimator {
    fn name(&self) -> &'static str {
        "hcs"
    }

    fn t_uuu(&self, u: &[f64]) -> f64 {
        let ests: Vec<f64> = self
            .reps
            .iter()
            .map(|rep| {
                let cs: Vec<Vec<f64>> =
                    rep.hcs.modes.iter().map(|m| m.apply(u)).collect();
                let refs: Vec<&[f64]> = cs.iter().map(|v| v.as_slice()).collect();
                crate::tensor::multilinear_form(&rep.st, &refs)
            })
            .collect();
        crate::util::timing::median(&ests)
    }

    fn t_mode(&self, mode: usize, vs: &[&[f64]]) -> Vec<f64> {
        let rows: Vec<Vec<f64>> = self
            .reps
            .iter()
            .map(|rep| {
                // Contract the sketched tensor with CS_d(v_d) at d ≠ mode
                // (O(Π J_n)), then decode the free sketched mode per index.
                let cs: Vec<Vec<f64>> = (0..rep.hcs.order())
                    .map(|d| {
                        if d == mode {
                            Vec::new()
                        } else {
                            rep.hcs.modes[d].apply(vs[d])
                        }
                    })
                    .collect();
                let dummy = vec![0.0; rep.st.shape[mode]];
                let refs: Vec<&[f64]> = (0..rep.hcs.order())
                    .map(|d| if d == mode { dummy.as_slice() } else { cs[d].as_slice() })
                    .collect();
                let m = contract_all_but(&rep.st, mode, &refs);
                let cs_m = &rep.hcs.modes[mode];
                (0..cs_m.domain())
                    .map(|i| {
                        let (b, s) = cs_m.basis(i);
                        s * m[b]
                    })
                    .collect()
            })
            .collect();
        elementwise_median(&rows)
    }

    fn norm_estimate(&self) -> f64 {
        let norms: Vec<f64> = self.reps.iter().map(|r| r.st.frob_norm()).collect();
        crate::util::timing::median(&norms)
    }

    fn deflate(&mut self, lambda: f64, vs: &[&[f64]]) {
        for rep in &mut self.reps {
            // Materialized outer product of the CS'd vectors (Eq. 5 cost).
            let cs: Vec<Vec<f64>> = rep
                .hcs
                .modes
                .iter()
                .zip(vs)
                .map(|(m, v)| m.apply(v))
                .collect();
            let refs: Vec<&[f64]> = cs.iter().map(|v| v.as_slice()).collect();
            let rank1 = crate::tensor::outer(&refs);
            crate::linalg::axpy(-lambda, &rank1.data, &mut rep.st.data);
        }
    }

    fn sketch_bytes(&self) -> usize {
        self.reps.iter().map(|r| r.st.numel() * 8).sum()
    }

    fn hash_bytes(&self) -> usize {
        self.reps.iter().map(|r| r.hcs.hash_memory_bytes()).sum()
    }
}

// ---------------------------------------------------------------------------
// Method tag + factory (what the CLI / benches select on)
// ---------------------------------------------------------------------------

/// Sketching method selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Plain,
    Cs,
    Ts,
    Hcs,
    Fcs,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "plain" => Some(Method::Plain),
            "cs" => Some(Method::Cs),
            "ts" => Some(Method::Ts),
            "hcs" => Some(Method::Hcs),
            "fcs" => Some(Method::Fcs),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Plain => "plain",
            Method::Cs => "cs",
            Method::Ts => "ts",
            Method::Hcs => "hcs",
            Method::Fcs => "fcs",
        }
    }

    /// Build an estimator for `t` with `d` repetitions and hash length `j`.
    pub fn build(&self, t: &Tensor, d: usize, j: usize, rng: &mut Rng) -> Box<dyn ContractionEstimator> {
        match self {
            Method::Plain => Box::new(PlainEstimator::new(t.clone())),
            Method::Cs => Box::new(CsEstimator::build(t, d, j, rng)),
            Method::Ts => Box::new(TsEstimator::build(t, d, j, rng)),
            Method::Hcs => Box::new(HcsEstimator::build(t, d, j, rng)),
            Method::Fcs => Box::new(FcsEstimator::build(t, d, j, rng)),
        }
    }
}

/// Build TS and FCS estimators sharing the *same* hash draws — the paper's
/// equalized-hash comparison protocol (§4.1).
pub fn build_equalized(
    t: &Tensor,
    d: usize,
    j: usize,
    rng: &mut Rng,
) -> (TsEstimator, FcsEstimator) {
    let hashes: Vec<ModeHashes> = (0..d)
        .map(|_| ModeHashes::draw_uniform(rng, &t.shape, j))
        .collect();
    (
        TsEstimator::build_with_hashes(t, &hashes),
        FcsEstimator::build_with_hashes(t, &hashes),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::CpTensor;

    fn test_tensor(rng: &mut Rng, dim: usize) -> Tensor {
        let cp = CpTensor::random_orthogonal_symmetric(rng, dim, 3, 3);
        let mut t = cp.to_dense();
        t.add_noise(rng, 0.01);
        t
    }

    #[test]
    fn all_methods_approximate_t_uuu() {
        let mut rng = Rng::seed_from_u64(1);
        let t = test_tensor(&mut rng, 20);
        let mut u = rng.normal_vec(20);
        crate::linalg::normalize(&mut u);
        let truth = t_uuu(&t, &u);
        for method in [Method::Cs, Method::Ts, Method::Hcs, Method::Fcs] {
            // hash length: HCS uses per-mode J (sketched dim J³), others J=400
            let j = if method == Method::Hcs { 12 } else { 400 };
            let est = method.build(&t, 9, j, &mut rng);
            let got = est.t_uuu(&u);
            assert!(
                (got - truth).abs() < 0.35 * truth.abs().max(1.0),
                "{}: {got} vs {truth}",
                est.name()
            );
        }
    }

    #[test]
    fn all_methods_approximate_t_iuu() {
        let mut rng = Rng::seed_from_u64(2);
        let t = test_tensor(&mut rng, 16);
        let mut u = rng.normal_vec(16);
        crate::linalg::normalize(&mut u);
        let truth = t_iuu(&t, &u);
        let tn = crate::linalg::norm2(&truth);
        for method in [Method::Cs, Method::Ts, Method::Hcs, Method::Fcs] {
            // CS gets a longer hash: its single-hash estimator has no
            // composite-structure variance reduction (that is the paper's
            // point), so at equal J it is markedly noisier.
            let j = match method {
                Method::Hcs => 14,
                Method::Cs => 3000,
                _ => 1500,
            };
            let est = method.build(&t, 15, j, &mut rng);
            let got = est.t_iuu(&u);
            let err: f64 = got
                .iter()
                .zip(&truth)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            // TS carries the circular-wraparound collision variance
            // (Proposition 1 says it is the worst of TS/FCS) and HCS's
            // sketched dim (J³≈2700) is the smallest here, so both get a
            // looser statistical bound than CS/FCS.
            let bound = match method {
                Method::Ts | Method::Hcs => 1.0,
                _ => 0.5,
            };
            assert!(err / tn < bound, "{}: rel err {}", est.name(), err / tn);
        }
    }

    #[test]
    fn cs_estimator_matches_materialized_sketches() {
        // D=1 CS estimate must equal ⟨CS(vec T), CS(vec(u∘u∘u))⟩ and, per
        // coordinate, ⟨CS(vec T), CS(vec(e_i∘u∘u))⟩ — the literal Def. 1
        // computation with everything materialized.
        let mut rng = Rng::seed_from_u64(42);
        let t = test_tensor(&mut rng, 8);
        let u = rng.normal_vec(8);
        let est = CsEstimator::build(&t, 1, 64, &mut rng);
        let rep = &est.reps[0];
        let cube = crate::tensor::outer(&[&u[..], &u[..], &u[..]]);
        let s_cube = rep.cs.apply(cube.as_vec());
        let expect_uuu = crate::linalg::dot(&rep.st, &s_cube);
        assert!((est.t_uuu(&u) - expect_uuu).abs() < 1e-10);
        let got = est.t_iuu(&u);
        for i in 0..8 {
            let mut e = vec![0.0; 8];
            e[i] = 1.0;
            let t3 = crate::tensor::outer(&[&e[..], &u[..], &u[..]]);
            let s3 = rep.cs.apply(t3.as_vec());
            let expect = crate::linalg::dot(&rep.st, &s3);
            assert!((got[i] - expect).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn plain_is_exact() {
        let mut rng = Rng::seed_from_u64(3);
        let t = test_tensor(&mut rng, 10);
        let u = rng.normal_vec(10);
        let est = PlainEstimator::new(t.clone());
        assert_eq!(est.t_uuu(&u), t_uuu(&t, &u));
        assert_eq!(est.t_iuu(&u), t_iuu(&t, &u));
    }

    #[test]
    fn fcs_t_mode_consistent_with_eq16() {
        // dot(t_mode(0, u), u) should approximate t_uuu ≈ the Eq.16 estimate.
        let mut rng = Rng::seed_from_u64(4);
        let t = test_tensor(&mut rng, 12);
        let mut u = rng.normal_vec(12);
        crate::linalg::normalize(&mut u);
        let est = FcsEstimator::build(&t, 1, 600, &mut rng);
        let via_iuu = crate::linalg::dot(&est.t_iuu(&u), &u);
        let direct = est.t_uuu(&u);
        // Same sketch, same hashes, D=1 ⇒ identical up to FFT roundoff.
        assert!((via_iuu - direct).abs() < 1e-8, "{via_iuu} vs {direct}");
    }

    #[test]
    fn ts_t_mode_consistent_with_sketch_inner() {
        let mut rng = Rng::seed_from_u64(5);
        let t = test_tensor(&mut rng, 12);
        let mut u = rng.normal_vec(12);
        crate::linalg::normalize(&mut u);
        let est = TsEstimator::build(&t, 1, 500, &mut rng);
        let via_iuu = crate::linalg::dot(&est.t_iuu(&u), &u);
        let direct = est.t_uuu(&u);
        assert!((via_iuu - direct).abs() < 1e-8, "{via_iuu} vs {direct}");
    }

    #[test]
    #[should_panic(expected = "share per-mode sketch ranges")]
    fn heterogeneous_rep_ranges_rejected() {
        // The batched cross-repetition paths pack every rep at one uniform
        // stride and fft_len; mixed-range repetitions must fail at build.
        let mut rng = Rng::seed_from_u64(11);
        let t = test_tensor(&mut rng, 8);
        let hashes = vec![
            ModeHashes::draw_uniform(&mut rng, &t.shape, 16),
            ModeHashes::draw_uniform(&mut rng, &t.shape, 8),
        ];
        let _ = FcsEstimator::build_with_hashes(&t, &hashes);
    }

    #[test]
    fn equalized_hashes_share_draws() {
        let mut rng = Rng::seed_from_u64(6);
        let t = test_tensor(&mut rng, 10);
        let (ts, fcs) = build_equalized(&t, 2, 100, &mut rng);
        for (tr, fr) in ts.reps.iter().zip(&fcs.reps) {
            for (tm, fm) in tr.op.hashes.modes.iter().zip(&fr.op.hashes.modes) {
                assert_eq!(tm.h, fm.h);
                assert_eq!(tm.s, fm.s);
            }
        }
    }

    #[test]
    fn asymmetric_t_mode_all_modes() {
        // non-cubical tensor: check each free mode against the exact value.
        let mut rng = Rng::seed_from_u64(7);
        let cp = CpTensor::random_orthogonal(&mut rng, &[10, 14, 12], 2);
        let mut t = cp.to_dense();
        t.add_noise(&mut rng, 0.01);
        let v0 = rng.normal_vec(10);
        let v1 = rng.normal_vec(14);
        let v2 = rng.normal_vec(12);
        let vs: Vec<&[f64]> = vec![&v0, &v1, &v2];
        let est = FcsEstimator::build(&t, 9, 500, &mut rng);
        for mode in 0..3 {
            let truth = contract_all_but(&t, mode, &vs);
            let got = est.t_mode(mode, &vs);
            let err = got
                .iter()
                .zip(&truth)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
                / crate::linalg::norm2(&truth);
            assert!(err < 0.45, "mode {mode}: rel err {err}");
        }
    }

    #[test]
    fn streaming_absorb_matches_rebuild() {
        // absorb_rank1 is deflate's mirror: absorbing +λ·u∘u∘u into an
        // estimator built on T must match building on T + λ·u∘u∘u with the
        // same hash draws — the streaming contract the sharded layer leans
        // on (sketch once, fold deltas in as they arrive).
        let mut rng = Rng::seed_from_u64(31);
        let t = test_tensor(&mut rng, 8);
        let mut u = rng.normal_vec(8);
        crate::linalg::normalize(&mut u);
        let lambda = 0.9;
        let grown = {
            let r1 = crate::tensor::outer(&[&u[..], &u[..], &u[..]]);
            t.add(&r1.scaled(lambda))
        };
        let vs: Vec<&[f64]> = vec![&u, &u, &u];
        let hashes: Vec<ModeHashes> =
            (0..2).map(|_| ModeHashes::draw_uniform(&mut rng, &t.shape, 50)).collect();

        let mut fcs = FcsEstimator::build_with_hashes(&t, &hashes);
        fcs.absorb_rank1(lambda, &vs);
        let fcs2 = FcsEstimator::build_with_hashes(&grown, &hashes);
        for (a, b) in fcs.reps.iter().zip(&fcs2.reps) {
            for (x, y) in a.st.iter().zip(&b.st) {
                assert!((x - y).abs() < 1e-9, "fcs absorb mismatch");
            }
        }

        let mut ts = TsEstimator::build_with_hashes(&t, &hashes);
        ts.absorb_rank1(lambda, &vs);
        let ts2 = TsEstimator::build_with_hashes(&grown, &hashes);
        for (a, b) in ts.reps.iter().zip(&ts2.reps) {
            for (x, y) in a.st.iter().zip(&b.st) {
                assert!((x - y).abs() < 1e-9, "ts absorb mismatch");
            }
        }
    }

    #[test]
    fn deflation_matches_resketching() {
        // For every sketched method, deflating in the sketch domain must
        // equal sketching the deflated tensor with the same hashes.
        let mut rng = Rng::seed_from_u64(9);
        let t = test_tensor(&mut rng, 8);
        let mut u = rng.normal_vec(8);
        crate::linalg::normalize(&mut u);
        let lambda = 1.7;
        let deflated = {
            let r1 = crate::tensor::outer(&[&u[..], &u[..], &u[..]]);
            t.sub(&r1.scaled(lambda))
        };
        let vs: Vec<&[f64]> = vec![&u, &u, &u];

        // FCS
        let hashes: Vec<ModeHashes> =
            (0..2).map(|_| ModeHashes::draw_uniform(&mut rng, &t.shape, 50)).collect();
        let mut fcs = FcsEstimator::build_with_hashes(&t, &hashes);
        fcs.deflate(lambda, &vs);
        let fcs2 = FcsEstimator::build_with_hashes(&deflated, &hashes);
        for (a, b) in fcs.reps.iter().zip(&fcs2.reps) {
            for (x, y) in a.st.iter().zip(&b.st) {
                assert!((x - y).abs() < 1e-9, "fcs sketch mismatch");
            }
        }

        // TS
        let mut ts = TsEstimator::build_with_hashes(&t, &hashes);
        ts.deflate(lambda, &vs);
        let ts2 = TsEstimator::build_with_hashes(&deflated, &hashes);
        for (a, b) in ts.reps.iter().zip(&ts2.reps) {
            for (x, y) in a.st.iter().zip(&b.st) {
                assert!((x - y).abs() < 1e-9, "ts sketch mismatch");
            }
        }

        // Plain
        let mut plain = PlainEstimator::new(t.clone());
        plain.deflate(lambda, &vs);
        assert!(plain.t.sub(&deflated).frob_norm() < 1e-12);

        // CS: deflate then compare t_uuu against an estimator built on the
        // deflated tensor is statistical; instead check the sketch update
        // algebra on a single rep with a fresh build sharing the RNG draw.
        let mut rng2 = Rng::seed_from_u64(77);
        let mut cs1 = CsEstimator::build(&t, 1, 64, &mut rng2.clone());
        let cs2 = CsEstimator::build(&deflated, 1, 64, &mut rng2);
        cs1.deflate(lambda, &vs);
        for (x, y) in cs1.reps[0].st.iter().zip(&cs2.reps[0].st) {
            assert!((x - y).abs() < 1e-9, "cs sketch mismatch");
        }

        // HCS
        let mut rng3 = Rng::seed_from_u64(88);
        let mut h1 = HcsEstimator::build(&t, 2, 5, &mut rng3.clone());
        let h2 = HcsEstimator::build(&deflated, 2, 5, &mut rng3);
        h1.deflate(lambda, &vs);
        for (a, b) in h1.reps.iter().zip(&h2.reps) {
            assert!(a.st.sub(&b.st).frob_norm() < 1e-9, "hcs sketch mismatch");
        }
    }

    #[test]
    fn deflation_keeps_spectral_cache_coherent() {
        // After deflate, the cached F(st) must equal a fresh forward FFT of
        // the updated sketch — for both core parameterizations.
        let mut rng = Rng::seed_from_u64(10);
        let t = test_tensor(&mut rng, 8);
        let mut u = rng.normal_vec(8);
        crate::linalg::normalize(&mut u);
        let vs: Vec<&[f64]> = vec![&u, &u, &u];
        let hashes: Vec<ModeHashes> =
            (0..2).map(|_| ModeHashes::draw_uniform(&mut rng, &t.shape, 40)).collect();
        let mut fcs = FcsEstimator::build_with_hashes(&t, &hashes);
        let mut ts = TsEstimator::build_with_hashes(&t, &hashes);
        fcs.deflate(0.9, &vs);
        ts.deflate(0.9, &vs);
        for rep in &fcs.reps {
            let fresh = rep.op.core().sketch_spectrum(&rep.st);
            for (a, b) in rep.st_fft.iter().zip(&fresh) {
                assert!((*a - *b).abs() < 1e-9, "fcs st_fft drifted");
            }
        }
        for rep in &ts.reps {
            let fresh = rep.op.core().sketch_spectrum(&rep.st);
            for (a, b) in rep.st_fft.iter().zip(&fresh) {
                assert!((*a - *b).abs() < 1e-9, "ts st_fft drifted");
            }
        }
    }

    #[test]
    fn elementwise_median_basic() {
        let rows = vec![
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![100.0, -5.0],
        ];
        assert_eq!(elementwise_median(&rows), vec![2.0, 10.0]);
    }

    #[test]
    fn memory_accounting_ordering() {
        // hash memory: CS >> TS ≈ FCS ≈ HCS (paper Table 1 last row).
        let mut rng = Rng::seed_from_u64(8);
        let t = test_tensor(&mut rng, 12);
        let cs = CsEstimator::build(&t, 2, 100, &mut rng);
        let ts = TsEstimator::build(&t, 2, 100, &mut rng);
        let fcs = FcsEstimator::build(&t, 2, 100, &mut rng);
        assert!(cs.hash_bytes() > 10 * fcs.hash_bytes());
        assert_eq!(ts.hash_bytes(), fcs.hash_bytes());
    }
}
