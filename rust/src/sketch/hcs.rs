//! Higher-order count sketch (Definition 3, Shi et al.): sketches an order-N
//! tensor into a *smaller order-N tensor* `HCS(T) ∈ R^{J_1 × … × J_N}`
//! (Eq. 4); for CP tensors, the outer product of the per-mode count sketches
//! must be materialized (Eq. 5) — the `O(R·Π J_n)` cost FCS avoids.

use super::cs::CountSketch;
use crate::hash::ModeHashes;
use crate::tensor::{CpTensor, Tensor};

#[derive(Debug, Clone)]
pub struct HigherOrderCountSketch {
    pub hashes: ModeHashes,
    pub modes: Vec<CountSketch>,
    pub ranges: Vec<usize>,
}

impl HigherOrderCountSketch {
    pub fn new(hashes: ModeHashes) -> Self {
        let ranges = hashes.modes.iter().map(|m| m.range).collect();
        let modes = hashes.modes.iter().map(|t| CountSketch::new(t.clone())).collect();
        Self { hashes, modes, ranges }
    }

    pub fn order(&self) -> usize {
        self.modes.len()
    }

    /// Sketch a general dense tensor — `O(nnz(T))` (Eq. 4).
    pub fn apply_dense(&self, t: &Tensor) -> Tensor {
        assert_eq!(t.shape, self.hashes.dims);
        let mut out = Tensor::zeros(&self.ranges);
        let n = t.order();
        let i0 = t.shape[0];
        let h0 = &self.hashes.modes[0].h;
        let s0 = &self.hashes.modes[0].s;
        let fibers = t.numel() / i0;
        let mut idx_hi = vec![0usize; n - 1];
        // strides of the output tensor (column-major)
        let mut strides = vec![1usize; n];
        for d in 1..n {
            strides[d] = strides[d - 1] * self.ranges[d - 1];
        }
        let mut l = 0usize;
        for _ in 0..fibers {
            let mut base = 0usize;
            let mut neg = 0usize;
            for (d, &i) in idx_hi.iter().enumerate() {
                let m = &self.hashes.modes[d + 1];
                base += (m.h[i] as usize) * strides[d + 1];
                if m.s[i] < 0 {
                    neg += 1;
                }
            }
            let sbase = if neg & 1 == 0 { 1.0 } else { -1.0 };
            for i in 0..i0 {
                let v = t.data[l];
                l += 1;
                if v != 0.0 {
                    out.data[base + h0[i] as usize] += sbase * (s0[i] as f64) * v;
                }
            }
            for (d, ix) in idx_hi.iter_mut().enumerate() {
                *ix += 1;
                if *ix < t.shape[d + 1] {
                    break;
                }
                *ix = 0;
            }
        }
        out
    }

    /// Sketch a CP tensor via materialized outer products (Eq. 5) —
    /// `O(max_n nnz(U^{(n)}) + R·Π J_n)`.
    pub fn apply_cp(&self, cp: &CpTensor) -> Tensor {
        assert_eq!(cp.shape(), self.hashes.dims);
        let mut out = Tensor::zeros(&self.ranges);
        for r in 0..cp.rank() {
            let sketched: Vec<Vec<f64>> = self
                .modes
                .iter()
                .zip(&cp.factors)
                .map(|(cs, u)| cs.apply(u.col(r)))
                .collect();
            let refs: Vec<&[f64]> = sketched.iter().map(|v| v.as_slice()).collect();
            let rank1 = crate::tensor::outer(&refs); // the unavoidable materialization
            crate::linalg::axpy(cp.lambda[r], &rank1.data, &mut out.data);
        }
        out
    }

    /// Elementwise decompression (Shi et al.):
    /// `T̂[i_1..i_N] = Π s_n(i_n) · HCS(T)[h_1(i_1), …, h_N(i_N)]`.
    pub fn decode(&self, sketch: &Tensor, idx: &[usize]) -> f64 {
        let j: Vec<usize> = idx
            .iter()
            .zip(&self.hashes.modes)
            .map(|(&i, m)| m.h(i))
            .collect();
        self.hashes.composite_s(idx) * sketch.get(&j)
    }

    /// Memory of the stored hash functions (bytes) — `O(Σ I_n)`.
    pub fn hash_memory_bytes(&self) -> usize {
        self.hashes.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn dense_matches_definition() {
        let mut rng = Rng::seed_from_u64(1);
        let shape = [4usize, 5, 3];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw(&mut rng, &shape, &[3, 4, 2]);
        let hcs = HigherOrderCountSketch::new(mh);
        let out = hcs.apply_dense(&t);
        assert_eq!(out.shape, vec![3, 4, 2]);
        // Brute-force Eq. 4.
        let mut expect = Tensor::zeros(&[3, 4, 2]);
        for i in 0..4 {
            for j in 0..5 {
                for k in 0..3 {
                    let idx = [i, j, k];
                    let dst = [
                        hcs.hashes.modes[0].h(i),
                        hcs.hashes.modes[1].h(j),
                        hcs.hashes.modes[2].h(k),
                    ];
                    let s = hcs.hashes.composite_s(&idx);
                    expect.set(&dst, expect.get(&dst) + s * t.get(&idx));
                }
            }
        }
        assert!(out.sub(&expect).frob_norm() < 1e-12);
    }

    #[test]
    fn cp_path_matches_dense_path() {
        let mut rng = Rng::seed_from_u64(2);
        let cp = CpTensor::randn(&mut rng, &[6, 5, 4], 3);
        let mh = ModeHashes::draw_uniform(&mut rng, &[6, 5, 4], 3);
        let hcs = HigherOrderCountSketch::new(mh);
        let via_cp = hcs.apply_cp(&cp);
        let via_dense = hcs.apply_dense(&cp.to_dense());
        assert!(via_cp.sub(&via_dense).frob_norm() < 1e-9);
    }

    #[test]
    fn decode_unbiased() {
        let mut rng = Rng::seed_from_u64(3);
        let shape = [4usize, 4, 4];
        let mut t = Tensor::zeros(&shape);
        t.set(&[2, 1, 3], 4.0);
        let trials = 3000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 3);
            let hcs = HigherOrderCountSketch::new(mh);
            let sk = hcs.apply_dense(&t);
            acc += hcs.decode(&sk, &[2, 1, 3]);
        }
        let mean = acc / trials as f64;
        assert!((mean - 4.0).abs() < 0.35, "mean={mean}");
    }

    #[test]
    fn preserves_frobenius_in_expectation() {
        let mut rng = Rng::seed_from_u64(4);
        let t = Tensor::randn(&mut rng, &[5, 5, 5]);
        let t2 = t.frob_norm().powi(2);
        let trials = 400;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &[5, 5, 5], 4);
            let hcs = HigherOrderCountSketch::new(mh);
            acc += hcs.apply_dense(&t).frob_norm().powi(2);
        }
        let mean = acc / trials as f64;
        assert!((mean - t2).abs() / t2 < 0.15, "mean={mean} t2={t2}");
    }
}
