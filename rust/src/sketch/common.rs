//! Shared sketching cores used by TS (Eq. 2/3) and FCS (Eq. 8/13).
//!
//! Two layers live here:
//!
//! 1. [`sketch_dense_into`] — the `O(nnz(T))` dense-tensor walk, accumulating
//!    under the composite hash `Σ_n h_n(i_n)` (TS folds it `mod J`, FCS keeps
//!    it un-folded).
//! 2. [`SpectralSketchCore`] — the CS-hash → rfft → spectral product →
//!    one-IFFT pipeline every CP/rank-1/estimator fast path is a
//!    parameterization of. TS is the *circular* instantiation
//!    (`fft_len == sketch_len == J`), FCS the *linear* one
//!    (`sketch_len = J̃`, `fft_len = next_pow2(J̃)` — exact because FCS's
//!    non-modular structure leaves the padded tail untouched).
//!
//! The dense hot loop is specialized for the first mode: within a mode-0
//! fiber only `h_0(i_0)` and `s_0(i_0)` change, so the outer-mode
//! contributions are hoisted to a per-fiber `(hbase, sbase)`.

use super::cs::CountSketch;
use crate::fft::complex::ZERO;
use crate::fft::{self, fft_real_many_into, inverse_real_many_into, C64, FftWorkspace};
use crate::hash::ModeHashes;
use crate::linalg::Matrix;
use crate::obs::{Stage, StageTimer};
use crate::tensor::{CpTensor, Tensor};

pub(crate) use crate::fft::workspace::mul_lane_run;

/// Upper bound on simultaneous lanes in the batched spectral transforms:
/// wide enough to keep the batch (innermost SIMD) axis full with headroom,
/// small enough that the lane-major `fft_len × lanes` planes stay cache- and
/// pool-friendly at the largest practical transform lengths.
pub(crate) const MAX_FFT_LANES: usize = 16;

// ---------------------------------------------------------------------------
// SpectralDriver — the one pack → fold → inverse engine
// ---------------------------------------------------------------------------

/// How each group's spectral fold is seeded.
pub(crate) enum FoldSeed<F> {
    /// Start from the group's first packed lane; the fold multiplies the
    /// remaining `lanes − 1` spectra into it (the convolution paths:
    /// CP/rank-1 accumulation, deflation).
    FirstLane,
    /// Start from an external per-group spectrum value `(re, im)` at bin
    /// `(group, k)`; the fold multiplies all `lanes` spectra into it (the
    /// Eq. 17 correlation paths seed with the cached `F(st)`).
    External(F),
}

/// `FoldSeed::FirstLane` with its closure slot pinned to a concrete fn type,
/// so call sites need no turbofish.
pub(crate) fn seed_first_lane() -> FoldSeed<fn(usize, usize) -> (f64, f64)> {
    FoldSeed::FirstLane
}

/// The single batched **pack → `fft_real_many_into` → fold →
/// `inverse_real_many_into`** engine behind every spectral consumer in the
/// crate. Work is organized as *groups* of `lanes` equal-stride real signals
/// (a CP rank's N mode sketches, one repetition's N−1 contracted-mode
/// sketches, …); groups are processed in [`MAX_FFT_LANES`]-bounded chunks,
/// each chunk's `gc·lanes` signals going through **one** batched forward
/// transform, each bin folded batch-innermost via [`mul_lane_run`], and —
/// on the [`Self::fold_inverse`] path — each chunk's `gc` product spectra
/// returning through **one** batched inverse.
///
/// The three lane layouts the callers instantiate (rank-chunk CP
/// accumulation, single-group mode-chunk rank-1/Eq. 17, cross-repetition
/// estimator batching) and the two fold directions (convolution vs
/// conjugated correlation) are all parameters of this one type — the
/// estimator's former private chunk-loop scaffolding is gone.
///
/// Packing contract: `pack(g, lane, slot)` writes into a `stride`-length
/// slot rented zeroed; a given lane index must fill the same prefix length
/// on every chunk (all callers pack a fixed mode per lane position), so slot
/// tails beyond each signal stay zero without per-chunk re-clearing.
#[derive(Clone, Copy)]
pub(crate) struct SpectralDriver {
    /// Transform length.
    pub n: usize,
    /// Uniform per-lane slot stride in the packed input arena (`≤ n`).
    pub stride: usize,
    /// Real signals per group: `N` for convolution folds, `N − 1` for the
    /// Eq. 17 correlation (the free mode contributes no spectrum).
    pub lanes: usize,
    /// Fold direction: `false` ⇒ convolution (plain spectral product),
    /// `true` ⇒ conjugated correlation.
    pub conj: bool,
}

impl SpectralDriver {
    /// Convolution-fold driver (CP accumulation, rank-1 sketches, deflate).
    pub fn convolve(n: usize, stride: usize, lanes: usize) -> Self {
        Self { n, stride, lanes, conj: false }
    }

    /// Conjugated-correlation driver (the Eq. 17 correlate-and-gather).
    pub fn correlate(n: usize, stride: usize, lanes: usize) -> Self {
        Self { n, stride, lanes, conj: true }
    }

    /// Whole groups per batched chunk under the [`MAX_FFT_LANES`] cap.
    #[inline]
    pub fn groups_per_chunk(&self) -> usize {
        (MAX_FFT_LANES / self.lanes.max(1)).max(1)
    }

    /// Multi-request variant of [`Self::accumulate_spectra`]: one flat pass
    /// over the concatenated `(job, group)` pairs of several independent
    /// jobs (`job_groups[jb]` groups for job `jb`, e.g. one CP rank per
    /// group), so a chunk's batched forward transform may span job
    /// boundaries — N small same-shape jobs cost `⌈Σ groups·lanes / 16⌉`
    /// dispatches instead of `Σ ⌈groups·lanes / 16⌉`. Each group's fold is
    /// seeded from its first lane and lands in its *own* job's accumulator:
    /// `accs[jb][k] += weight(jb, g) · fold_{jb,g}[k]`.
    ///
    /// Restricted to one job, the `(group, k)` visit order — and therefore
    /// the IEEE summation order into `accs[jb]` — is identical to a serial
    /// [`Self::accumulate_spectra`] call, and the batched kernels keep every
    /// lane's flop sequence independent of batch width, so each job's
    /// accumulated spectrum is **bit-identical** to its serial run. That is
    /// the invariant the coordinator's cross-request fused flights rely on.
    pub fn accumulate_spectra_multi(
        &self,
        job_groups: &[usize],
        ws: &mut FftWorkspace,
        mut pack: impl FnMut(usize, usize, usize, &mut [f64]),
        mut weight: impl FnMut(usize, usize) -> f64,
        accs: &mut [Vec<C64>],
    ) {
        // Failpoint: a Panic here unwinds a whole fused flight mid-transform
        // — the coordinator's fused-abort → serial-retry path.
        crate::fault::act("spectral_driver");
        debug_assert_eq!(job_groups.len(), accs.len());
        debug_assert!(accs.iter().all(|a| a.len() == self.n));
        let total: usize = job_groups.iter().sum();
        if self.lanes == 0 || total == 0 {
            return;
        }
        let (n, nm, stride) = (self.n, self.lanes, self.stride);
        let per = self.groups_per_chunk().min(total);
        let mut xs = ws.take_f64(per * nm * stride);
        let mut sre = ws.take_f64(0);
        let mut sim = ws.take_f64(0);
        // Flat cursor over (job, group) pairs in job-major order; each chunk
        // records its slots' owners so the fold can scatter per job.
        let (mut job, mut grp) = (0usize, 0usize);
        while job < job_groups.len() && job_groups[job] == 0 {
            job += 1;
        }
        let mut slot_job = [0usize; MAX_FFT_LANES];
        let mut slot_grp = [0usize; MAX_FFT_LANES];
        let mut done = 0usize;
        // Sampled per-dispatch stage accounting (records on drop); a dead
        // timer makes every start/lap a branch — never a clock read.
        let mut timer = StageTimer::sample();
        while done < total {
            let gc = per.min(total - done);
            let t = timer.start();
            for gi in 0..gc {
                slot_job[gi] = job;
                slot_grp[gi] = grp;
                for l in 0..nm {
                    let slot = (gi * nm + l) * stride;
                    pack(job, grp, l, &mut xs[slot..slot + stride]);
                }
                grp += 1;
                while job < job_groups.len() && grp >= job_groups[job] {
                    job += 1;
                    grp = 0;
                }
            }
            timer.lap(Stage::Pack, t);
            let lanes = gc * nm;
            let t = timer.start();
            fft_real_many_into(&xs[..lanes * stride], stride, lanes, n, ws, &mut sre, &mut sim);
            timer.lap(Stage::Fft, t);
            let t = timer.start();
            for k in 0..n {
                let row = k * lanes;
                for gi in 0..gc {
                    let s = row + gi * nm;
                    let mut pr = sre[s];
                    let mut pi = sim[s];
                    mul_lane_run(&sre, &sim, s + 1, nm - 1, self.conj, &mut pr, &mut pi);
                    let w = weight(slot_job[gi], slot_grp[gi]);
                    let a = &mut accs[slot_job[gi]][k];
                    a.re += w * pr;
                    a.im += w * pi;
                }
            }
            timer.lap(Stage::Fold, t);
            done += gc;
        }
        ws.give_f64(sim);
        ws.give_f64(sre);
        ws.give_f64(xs);
    }

    /// Pack → forward → fold into a complex accumulator: for every group
    /// `g ∈ groups`, `acc[k] += weight(g) · fold_g[k]` (fold seeded from the
    /// group's first lane). The caller inverts `acc` once at the end —
    /// that is the R-IFFTs→1 trick of the CP fast path.
    pub fn accumulate_spectra(
        &self,
        groups: std::ops::Range<usize>,
        ws: &mut FftWorkspace,
        mut pack: impl FnMut(usize, usize, &mut [f64]),
        mut weight: impl FnMut(usize) -> f64,
        acc: &mut [C64],
    ) {
        // Same site as the fused entry point: serial spectral passes share it.
        crate::fault::act("spectral_driver");
        debug_assert_eq!(acc.len(), self.n);
        if self.lanes == 0 || groups.is_empty() {
            return;
        }
        let (n, nm, stride) = (self.n, self.lanes, self.stride);
        let per = self.groups_per_chunk().min(groups.end - groups.start);
        // Slot tails beyond each packed signal stay zero: the rental arrives
        // zeroed and every chunk rewrites the same (lane-slot, prefix) layout.
        let mut xs = ws.take_f64(per * nm * stride);
        let mut sre = ws.take_f64(0);
        let mut sim = ws.take_f64(0);
        let mut g0 = groups.start;
        let mut timer = StageTimer::sample();
        while g0 < groups.end {
            let gc = (groups.end - g0).min(per);
            let lanes = gc * nm;
            let t = timer.start();
            for gi in 0..gc {
                for l in 0..nm {
                    let slot = (gi * nm + l) * stride;
                    pack(g0 + gi, l, &mut xs[slot..slot + stride]);
                }
            }
            timer.lap(Stage::Pack, t);
            let t = timer.start();
            fft_real_many_into(&xs[..lanes * stride], stride, lanes, n, ws, &mut sre, &mut sim);
            timer.lap(Stage::Fft, t);
            let t = timer.start();
            for (k, a) in acc.iter_mut().enumerate() {
                let row = k * lanes;
                for gi in 0..gc {
                    let s = row + gi * nm;
                    let mut pr = sre[s];
                    let mut pi = sim[s];
                    mul_lane_run(&sre, &sim, s + 1, nm - 1, self.conj, &mut pr, &mut pi);
                    let w = weight(g0 + gi);
                    a.re += w * pr;
                    a.im += w * pi;
                }
            }
            timer.lap(Stage::Fold, t);
            g0 += gc;
        }
        ws.give_f64(sim);
        ws.give_f64(sre);
        ws.give_f64(xs);
    }

    /// Pack → forward → fold → batched inverse: for every group
    /// `g ∈ 0..groups`, the folded product spectrum (seeded per `seed`) is
    /// inverse-transformed and its length-`n` real signal handed to
    /// `emit(g, signal)` — mutable, so emitters may truncate in place.
    /// Chunks share one forward and one inverse dispatch each.
    pub fn fold_inverse<F: FnMut(usize, usize) -> (f64, f64)>(
        &self,
        groups: usize,
        ws: &mut FftWorkspace,
        mut pack: impl FnMut(usize, usize, &mut [f64]),
        mut seed: FoldSeed<F>,
        mut emit: impl FnMut(usize, &mut [f64]),
    ) {
        if groups == 0 {
            return;
        }
        debug_assert!(
            self.lanes > 0 || matches!(seed, FoldSeed::External(_)),
            "fold_inverse: a first-lane seed needs at least one lane"
        );
        let (n, nm, stride) = (self.n, self.lanes, self.stride);
        let per = self.groups_per_chunk().min(groups);
        let mut xs = ws.take_f64(per * nm * stride);
        let mut sre = ws.take_f64(0);
        let mut sim = ws.take_f64(0);
        let mut izre = ws.take_f64(n * per);
        let mut izim = ws.take_f64(n * per);
        let mut z = ws.take_f64(0);
        let mut g0 = 0usize;
        let mut timer = StageTimer::sample();
        while g0 < groups {
            let gc = (groups - g0).min(per);
            let lanes = gc * nm;
            let t = timer.start();
            for gi in 0..gc {
                for l in 0..nm {
                    let slot = (gi * nm + l) * stride;
                    pack(g0 + gi, l, &mut xs[slot..slot + stride]);
                }
            }
            timer.lap(Stage::Pack, t);
            let t = timer.start();
            fft_real_many_into(&xs[..lanes * stride], stride, lanes, n, ws, &mut sre, &mut sim);
            timer.lap(Stage::Fft, t);
            let t = timer.start();
            for k in 0..n {
                let srow = k * lanes;
                let irow = k * gc;
                for gi in 0..gc {
                    let s = srow + gi * nm;
                    let (mut pr, mut pi, skip) = match &mut seed {
                        FoldSeed::FirstLane => (sre[s], sim[s], 1),
                        FoldSeed::External(f) => {
                            let (r, i) = f(g0 + gi, k);
                            (r, i, 0)
                        }
                    };
                    mul_lane_run(&sre, &sim, s + skip, nm - skip, self.conj, &mut pr, &mut pi);
                    izre[irow + gi] = pr;
                    izim[irow + gi] = pi;
                }
            }
            timer.lap(Stage::Fold, t);
            let t = timer.start();
            inverse_real_many_into(&mut izre[..n * gc], &mut izim[..n * gc], gc, ws, &mut z);
            timer.lap(Stage::Inverse, t);
            for gi in 0..gc {
                emit(g0 + gi, &mut z[gi * n..(gi + 1) * n]);
            }
            g0 += gc;
        }
        ws.give_f64(z);
        ws.give_f64(izim);
        ws.give_f64(izre);
        ws.give_f64(sim);
        ws.give_f64(sre);
        ws.give_f64(xs);
    }

    /// Batched forward sweep over `groups` signal-major length-`n` real
    /// signals (chunked at [`MAX_FFT_LANES`]), handing every spectrum value
    /// to `emit(g, k, re, im)` — the deflation cache-coherency pass that
    /// keeps each repetition's `F(st)` in step with its updated sketch.
    pub fn forward_each(
        &self,
        signals: &[f64],
        groups: usize,
        ws: &mut FftWorkspace,
        mut emit: impl FnMut(usize, usize, f64, f64),
    ) {
        let n = self.n;
        debug_assert_eq!(signals.len(), groups * n);
        let mut fre = ws.take_f64(0);
        let mut fim = ws.take_f64(0);
        let mut g0 = 0usize;
        let mut timer = StageTimer::sample();
        while g0 < groups {
            let gc = (groups - g0).min(MAX_FFT_LANES);
            let t = timer.start();
            fft_real_many_into(&signals[g0 * n..(g0 + gc) * n], n, gc, n, ws, &mut fre, &mut fim);
            timer.lap(Stage::Fft, t);
            for k in 0..n {
                let row = k * gc;
                for gi in 0..gc {
                    emit(g0 + gi, k, fre[row + gi], fim[row + gi]);
                }
            }
            g0 += gc;
        }
        ws.give_f64(fim);
        ws.give_f64(fre);
    }
}

/// Pack one mode sketch into its stride-length driver slot: the `CS_d(v)`
/// scatter every spectral pack closure performs. Single home of the
/// slot-prefix rule — exactly `slot[..range]` is written, the tail beyond
/// the mode's range stays zero from the rental.
#[inline]
pub(crate) fn pack_mode_lane(cs: &CountSketch, v: &[f64], slot: &mut [f64]) {
    cs.apply_into(v, &mut slot[..cs.range()]);
}

/// Batched inverse over independent per-job product spectra: chunks of up to
/// [`MAX_FFT_LANES`] jobs share one [`inverse_real_many_into`] dispatch, and
/// each job's length-`n` real signal is handed to `emit(job, signal)`
/// (mutable, so emitters may truncate in place). The batched recombination
/// is expression-for-expression the scalar one [`fft::inverse_real_into`]
/// runs, and the underlying complex kernel keeps lanes independent of batch
/// width, so each job's signal is bit-identical to a serial inverse of its
/// spectrum — for even `n` (every linear/FCS core: `fft_len` is a power of
/// two), which is the only parameterization the fused flights dispatch.
pub(crate) fn inverse_spectra_fused(
    specs: &[Vec<C64>],
    n: usize,
    ws: &mut FftWorkspace,
    mut emit: impl FnMut(usize, &mut [f64]),
) {
    let jobs = specs.len();
    if jobs == 0 || n == 0 {
        return;
    }
    let per = jobs.min(MAX_FFT_LANES);
    let mut pre = ws.take_f64(n * per);
    let mut pim = ws.take_f64(n * per);
    let mut z = ws.take_f64(0);
    let mut j0 = 0usize;
    let mut timer = StageTimer::sample();
    while j0 < jobs {
        let jc = (jobs - j0).min(per);
        let t = timer.start();
        for (b, spec) in specs[j0..j0 + jc].iter().enumerate() {
            debug_assert_eq!(spec.len(), n);
            for (k, v) in spec.iter().enumerate() {
                pre[k * jc + b] = v.re;
                pim[k * jc + b] = v.im;
            }
        }
        timer.lap(Stage::Pack, t);
        let t = timer.start();
        inverse_real_many_into(&mut pre[..n * jc], &mut pim[..n * jc], jc, ws, &mut z);
        timer.lap(Stage::Inverse, t);
        for gi in 0..jc {
            emit(j0 + gi, &mut z[gi * n..(gi + 1) * n]);
        }
        j0 += jc;
    }
    ws.give_f64(z);
    ws.give_f64(pim);
    ws.give_f64(pre);
}

/// One job of a cross-request fused CP flight: the per-job spectral core
/// (over that request's *own* hash draw) plus the CP payload it sketches.
pub(crate) struct FusedCpJob<'a> {
    /// Spectral pipeline over this job's per-mode count sketches.
    pub core: SpectralSketchCore<'a>,
    /// CP factor matrices `U_1..U_N` (one column per rank).
    pub factors: &'a [Matrix],
    /// Per-rank weights `λ_r`.
    pub lambda: &'a [f64],
    /// Rank count — this job's group count in the shared lane flight.
    pub rank: usize,
}

/// Cross-request fused CP sketching: all jobs' rank groups share
/// [`SpectralDriver`] lane chunks (one pack → one batched rfft → per-job
/// [`mul_lane_run`] fold via [`SpectralDriver::accumulate_spectra_multi`])
/// and the per-job product spectra return through shared batched inverses
/// ([`inverse_spectra_fused`]). `emit(job, signal)` receives each job's
/// full length-`fft_len` signal; callers truncate to `sketch_len`.
///
/// Every job keeps its own hash draw and its own accumulator, so each
/// output is **bit-identical** to a serial [`SpectralSketchCore::apply_cp_into`]
/// over the same core — the property the coordinator's determinism tests
/// enforce. All jobs in a flight must share spectral geometry (same order
/// and the same per-mode ranges, hence the same `fft_len`); ranks may
/// differ. The coordinator's exact fusion key guarantees this; it is
/// debug-asserted here.
pub(crate) fn apply_cp_fused(
    jobs: &[FusedCpJob<'_>],
    ws: &mut FftWorkspace,
    emit: impl FnMut(usize, &mut [f64]),
) {
    let Some(first) = jobs.first() else { return };
    let order = first.core.modes.len();
    let n = first.core.fft_len;
    debug_assert!(
        jobs.iter().all(|jb| {
            jb.core.modes.len() == order
                && jb.core.fft_len == n
                && jb
                    .core
                    .modes
                    .iter()
                    .map(|m| m.range())
                    .eq(first.core.modes.iter().map(|m| m.range()))
        }),
        "apply_cp_fused: flight mixes spectral geometries"
    );
    let job_groups: Vec<usize> = jobs.iter().map(|jb| jb.rank).collect();
    let mut accs: Vec<Vec<C64>> = jobs.iter().map(|_| ws.take_c64(n)).collect();
    first.core.driver(order, false).accumulate_spectra_multi(
        &job_groups,
        ws,
        |jb, r, d, slot| pack_mode_lane(&jobs[jb].core.modes[d], jobs[jb].factors[d].col(r), slot),
        |jb, r| jobs[jb].lambda[r],
        &mut accs,
    );
    inverse_spectra_fused(&accs, n, ws, emit);
    for acc in accs.into_iter().rev() {
        ws.give_c64(acc);
    }
}

/// Accumulate the sketch of a dense tensor into `out`.
///
/// * `modulo = Some(J)` → TS bucket `(Σ h_n) mod J` (`out.len() == J`).
/// * `modulo = None`   → FCS bucket `Σ h_n` (`out.len() == J̃`).
pub fn sketch_dense_into(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>, out: &mut [f64]) {
    assert_eq!(t.shape, mh.dims, "tensor/hash shape mismatch");
    match modulo {
        Some(j) => {
            assert_eq!(out.len(), j);
            assert!(
                mh.modes.iter().all(|m| m.range == j),
                "TS requires uniform mode ranges"
            );
        }
        None => assert_eq!(out.len(), mh.composite_range()),
    }
    out.fill(0.0);
    let n = t.order();
    let i0 = t.shape[0];
    let h0 = &mh.modes[0].h;
    let s0 = &mh.modes[0].s;
    let fibers = t.numel() / i0;
    // Multi-index over modes 1..N. Stack storage keeps this function
    // allocation-free (it sits on the coordinator's zero-alloc service
    // path); tensors beyond 32 modes fall back to the heap.
    let mut idx_stack = [0usize; 32];
    let mut idx_heap: Vec<usize>;
    let idx_hi: &mut [usize] = if n - 1 <= idx_stack.len() {
        &mut idx_stack[..n - 1]
    } else {
        idx_heap = vec![0usize; n - 1];
        &mut idx_heap
    };
    let mut l = 0usize;
    for _fiber in 0..fibers {
        // Contributions of the fixed higher modes.
        let mut hbase = 0usize;
        let mut neg = 0usize;
        for (d, &i) in idx_hi.iter().enumerate() {
            let m = &mh.modes[d + 1];
            hbase += m.h[i] as usize;
            if m.s[i] < 0 {
                neg += 1;
            }
        }
        let sbase = if neg & 1 == 0 { 1.0 } else { -1.0 };
        match modulo {
            Some(j) => {
                let hb = hbase % j;
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    let mut b = hb + h0[i] as usize;
                    if b >= j {
                        b -= j; // hb, h0 < J ⇒ sum < 2J: one subtract replaces `%`
                    }
                    out[b] += sbase * (s0[i] as f64) * v;
                }
            }
            None => {
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    out[hbase + h0[i] as usize] += sbase * (s0[i] as f64) * v;
                }
            }
        }
        // Increment the higher-mode multi-index.
        for (d, ix) in idx_hi.iter_mut().enumerate() {
            *ix += 1;
            if *ix < t.shape[d + 1] {
                break;
            }
            *ix = 0;
        }
    }
}

/// Convenience allocating wrapper.
pub fn sketch_dense(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
    let len = modulo.unwrap_or_else(|| mh.composite_range());
    let mut out = vec![0.0; len];
    sketch_dense_into(t, mh, modulo, &mut out);
    out
}

// ---------------------------------------------------------------------------
// SpectralSketchCore — the one spectral pipeline behind TS and FCS
// ---------------------------------------------------------------------------

/// Borrowing view over the per-mode count sketches plus the two lengths that
/// fully determine a spectral sketch pipeline. Everything TS and FCS do in
/// the frequency domain — CP accumulation (Eq. 3/8), rank-1 sketches
/// (Eq. 16), and the Eq. 17 correlate-and-gather the estimators run — is a
/// method on this one type, so a new backend (SIMD butterflies, GPU) lands
/// in exactly one place.
#[derive(Clone, Copy)]
pub struct SpectralSketchCore<'a> {
    /// Per-mode count sketches `CS_1..CS_N`.
    pub modes: &'a [CountSketch],
    /// Output sketch length: `J` for TS (circular), `J̃ = Σ J_n − N + 1` for
    /// FCS (linear).
    pub sketch_len: usize,
    /// Transform length: `== sketch_len` for TS (the circular convolution
    /// *is* length-J); `next_power_of_two(J̃)` for FCS — any `n ≥ J̃` is
    /// exact because no wraparound can reach the gathered buckets, and the
    /// power of two skips Bluestein entirely (§Perf: ~3–6× on t_mode).
    pub fft_len: usize,
}

impl<'a> SpectralSketchCore<'a> {
    /// TS parameterization: circular convolution at length `j`.
    pub fn circular(modes: &'a [CountSketch], j: usize) -> Self {
        Self { modes, sketch_len: j, fft_len: j }
    }

    /// FCS parameterization: linear convolution of length `j_tilde`, padded
    /// to a power of two.
    pub fn linear(modes: &'a [CountSketch], j_tilde: usize) -> Self {
        Self { modes, sketch_len: j_tilde, fft_len: j_tilde.next_power_of_two() }
    }

    /// Linear parameterization with `J̃ = Σ J_n − N + 1` (Definition 4)
    /// derived from the mode sketches themselves — callers that only hold
    /// per-mode tables (the coordinator's arena path) use this instead of
    /// re-deriving the composite-range formula.
    pub fn linear_from_modes(modes: &'a [CountSketch]) -> Self {
        let j_tilde = modes.iter().map(|m| m.range()).sum::<usize>() - modes.len() + 1;
        Self::linear(modes, j_tilde)
    }

    /// Largest per-mode sketch range — the uniform slot stride the batched
    /// transforms pack mode sketches at (the estimator's cross-repetition
    /// packing reuses it, so this is the single home of the stride rule).
    /// Always `≤ fft_len`: for TS every range *is* `J = fft_len`; for FCS
    /// `J̃ = Σ J_d − N + 1 ≥ max_d J_d` and `fft_len = next_pow2(J̃)`.
    #[inline]
    pub(crate) fn mode_stride(&self) -> usize {
        self.modes.iter().map(|m| m.range()).max().unwrap_or(0)
    }

    /// The driver for this core's fold direction/lane layout: `lanes` is the
    /// signals-per-group count (`N` for convolution folds, `N−1` for the
    /// Eq. 17 correlation), `conj` picks the fold direction.
    #[inline]
    pub(crate) fn driver(&self, lanes: usize, conj: bool) -> SpectralDriver {
        let (n, stride) = (self.fft_len, self.mode_stride());
        if conj {
            SpectralDriver::correlate(n, stride, lanes)
        } else {
            SpectralDriver::convolve(n, stride, lanes)
        }
    }

    /// Write `Π_d F(CS_d(vs[d]))` at `fft_len` points into `out`: one
    /// single-group [`SpectralDriver`] accumulation (all N mode sketches in
    /// one batched forward, folded batch-innermost).
    pub fn rank1_spectrum_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<C64>) {
        // Hard assert (matching the pre-refactor inherent methods): a wrong
        // arity must fail loudly, not silently drop the extra vector in
        // release builds.
        assert_eq!(self.modes.len(), vs.len(), "rank-1 sketch arity mismatch");
        out.clear();
        out.resize(self.fft_len, ZERO);
        self.driver(self.modes.len(), false).accumulate_spectra(
            0..1,
            ws,
            |_, d, slot| pack_mode_lane(&self.modes[d], vs[d], slot),
            |_| 1.0,
            out,
        );
    }

    /// Accumulate `Σ_{r ∈ ranks} λ_r · Π_d F(CS_d(U_d[:, r]))` into `acc`
    /// (length `fft_len`). The caller inverts once at the end — R IFFTs → 1.
    /// One rank-chunk [`SpectralDriver`] accumulation: every chunk's
    /// `chunk·N` mode sketches share one batched forward transform (instead
    /// of R·N single-plan dispatches).
    pub fn accumulate_cp_spectra(
        &self,
        factors: &[Matrix],
        lambda: &[f64],
        ranks: std::ops::Range<usize>,
        ws: &mut FftWorkspace,
        acc: &mut [C64],
    ) {
        debug_assert_eq!(acc.len(), self.fft_len);
        debug_assert_eq!(self.modes.len(), factors.len());
        assert!(
            lambda.len() >= ranks.end,
            "accumulate_cp_spectra: lambda shorter than rank range"
        );
        if self.modes.is_empty() {
            return;
        }
        self.driver(self.modes.len(), false).accumulate_spectra(
            ranks,
            ws,
            |r, d, slot| pack_mode_lane(&self.modes[d], factors[d].col(r), slot),
            |r| lambda[r],
            acc,
        );
    }

    /// Rank-parallel variant: chunks the CP ranks over `par_map` worker
    /// threads (each with its own workspace), then sums the partial spectra
    /// in deterministic chunk order.
    pub fn accumulate_cp_spectra_parallel(
        &self,
        factors: &[Matrix],
        lambda: &[f64],
        rank: usize,
    ) -> Vec<C64> {
        let n = self.fft_len;
        let threads = crate::util::parallel::default_threads().min(rank).max(1);
        let chunk = (rank + threads - 1) / threads;
        let nchunks = (rank + chunk - 1) / chunk;
        let partials = crate::util::parallel::par_map(nchunks, threads, |ci| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(rank);
            let mut ws = FftWorkspace::new();
            let mut acc = vec![ZERO; n];
            self.accumulate_cp_spectra(factors, lambda, lo..hi, &mut ws, &mut acc);
            acc
        });
        let mut it = partials.into_iter();
        let mut acc = it.next().expect("rank >= 1");
        for p in it {
            for (a, b) in acc.iter_mut().zip(&p) {
                *a += *b;
            }
        }
        acc
    }

    /// Sketch of a rank-1 tensor `v_1 ∘ … ∘ v_N`: mode product, one inverse
    /// transform, truncate to `sketch_len`. Zero allocations in steady state.
    pub fn apply_rank1_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        let mut spec = ws.take_c64(self.fft_len);
        self.rank1_spectrum_into(vs, ws, &mut spec);
        fft::inverse_real_into(&mut spec, ws, out);
        out.truncate(self.sketch_len);
        ws.give_c64(spec);
    }

    /// Serial CP fast path: spectral rank accumulation, a **single** inverse
    /// FFT, truncate to `sketch_len`. Zero allocations in steady state.
    pub fn apply_cp_into(&self, cp: &CpTensor, ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        debug_assert_eq!(self.modes.len(), cp.order());
        let mut acc = ws.take_c64(self.fft_len);
        self.accumulate_cp_spectra(&cp.factors, &cp.lambda, 0..cp.rank(), ws, &mut acc);
        let mut timer = StageTimer::sample();
        let t = timer.start();
        fft::inverse_real_into(&mut acc, ws, out);
        timer.lap(Stage::Inverse, t);
        out.truncate(self.sketch_len);
        ws.give_c64(acc);
    }

    /// Allocating CP entry point; fans ranks out over threads above the
    /// [`cp_rank_parallel`] threshold.
    pub fn apply_cp(&self, cp: &CpTensor) -> Vec<f64> {
        if cp_rank_parallel(cp.rank(), self.fft_len) {
            let mut acc = self.accumulate_cp_spectra_parallel(&cp.factors, &cp.lambda, cp.rank());
            return fft::with_thread_workspace(|ws| {
                // Capacity = transform length: inverse_real_into fills to
                // fft_len before the truncate to sketch_len.
                let mut out = Vec::with_capacity(self.fft_len);
                fft::inverse_real_into(&mut acc, ws, &mut out);
                out.truncate(self.sketch_len);
                out
            });
        }
        fft::with_thread_workspace(|ws| {
            let mut out = Vec::with_capacity(self.fft_len);
            self.apply_cp_into(cp, ws, &mut out);
            out
        })
    }

    /// Forward transform of a sketch at `fft_len` points — the per-rep
    /// `F(st)` cache the estimators hoist out of every `t_mode` call.
    pub fn sketch_spectrum(&self, st: &[f64]) -> Vec<C64> {
        debug_assert_eq!(st.len(), self.sketch_len);
        fft::fft_real(st, self.fft_len)
    }

    /// One repetition of Eq. 17 generalized — the estimator `t_mode` body:
    /// `z = F⁻¹( F(st) · Π_{d≠mode} conj(F(CS_d(vs[d]))) )`, then the
    /// mode-`mode` basis gather `out[i] = s_mode(i) · z(h_mode(i))`. For the
    /// FCS (linear) instantiation no wraparound can occur because
    /// `h_mode(i) + Σ_{d≠mode}(J_d − 1) ≤ J̃ − 1 < fft_len`; for TS the
    /// circular length *is* the semantics. All scratch rented from `ws`.
    pub fn correlate_gather_into(
        &self,
        st_fft: &[C64],
        mode: usize,
        vs: &[&[f64]],
        ws: &mut FftWorkspace,
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(st_fft.len(), self.fft_len);
        let nm = self.modes.len();
        let cs_m = &self.modes[mode];
        out.clear();
        out.resize(cs_m.domain(), 0.0);
        // One single-group correlation pass: the N−1 contracted-mode sketches
        // share one batched forward, the fold is seeded with F(st), and the
        // product returns through the driver's batched inverse.
        self.driver(nm - 1, true).fold_inverse(
            1,
            ws,
            |_, l, slot| {
                let d = if l < mode { l } else { l + 1 };
                pack_mode_lane(&self.modes[d], vs[d], slot);
            },
            FoldSeed::External(|_, k: usize| (st_fft[k].re, st_fft[k].im)),
            |_, z| {
                // The mode-`mode` basis gather (Eq. 17's ⟨z, CS(e_i)⟩ trick).
                for (i, o) in out.iter_mut().enumerate() {
                    let (b, s) = cs_m.basis(i);
                    *o = s * z[b];
                }
            },
        );
    }
}

/// Work threshold above which the CP fast paths fan ranks out across
/// threads: enough ranks to chunk, and large enough transforms that thread
/// startup is amortized.
pub(crate) fn cp_rank_parallel(rank: usize, n: usize) -> bool {
    rank >= 8 && n >= 4096
}

/// Allocation-free `cp.shape() == dims` check: `CpTensor::shape()` collects
/// a fresh `Vec`, which would put one heap allocation per call on the
/// zero-alloc `apply_cp_into` paths (and fail `tests/alloc_discipline.rs`).
pub(crate) fn cp_shape_matches(cp: &CpTensor, dims: &[usize]) -> bool {
    cp.factors.iter().map(|f| f.rows).eq(dims.iter().copied())
}

/// The interface the generic [`crate::sketch::estimator::SpectralEstimator`]
/// programs against: both [`crate::sketch::TensorSketch`] and
/// [`crate::sketch::FastCountSketch`] are a [`SpectralSketchCore`]
/// parameterization plus an `O(nnz(T))` dense path.
pub trait SpectralSketchOp: Send + Sync {
    /// Estimator name tag (`"ts"` / `"fcs"`).
    const NAME: &'static str;

    fn from_hashes(hashes: ModeHashes) -> Self;

    fn hashes(&self) -> &ModeHashes;

    /// The spectral pipeline view over this operator's mode sketches.
    fn core(&self) -> SpectralSketchCore<'_>;

    /// Sketch a general dense tensor — `O(nnz(T))`.
    fn apply_dense(&self, t: &Tensor) -> Vec<f64>;

    /// CP fast path (workspace-backed); default routes through the core.
    fn apply_cp_into(&self, cp: &CpTensor, ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        self.core().apply_cp_into(cp, ws, out);
    }

    /// Rank-1 fast path (workspace-backed); default routes through the core.
    fn apply_rank1_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        self.core().apply_rank1_into(vs, ws, out);
    }

    /// Memory of the stored hash functions (bytes) — `O(Σ I_n)`.
    fn hash_memory_bytes(&self) -> usize {
        self.hashes().memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::unravel_colmajor;
    use crate::util::prng::Rng;

    /// Reference implementation straight from Eq. 2 / Eq. 13.
    fn sketch_dense_naive(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
        let len = modulo.unwrap_or_else(|| mh.composite_range());
        let mut out = vec![0.0; len];
        let mut idx = vec![0usize; t.order()];
        for l in 0..t.numel() {
            unravel_colmajor(l, &t.shape, &mut idx);
            let h = mh.composite_h(&idx);
            let b = match modulo {
                Some(j) => h % j,
                None => h,
            };
            out[b] += mh.composite_s(&idx) * t.data[l];
        }
        out
    }

    #[test]
    fn fast_matches_naive_fcs() {
        let mut rng = Rng::seed_from_u64(1);
        for shape in [vec![7, 5, 3], vec![4, 4], vec![3, 2, 2, 3]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 6);
            let fast = sketch_dense(&t, &mh, None);
            let slow = sketch_dense_naive(&t, &mh, None);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fast_matches_naive_ts() {
        let mut rng = Rng::seed_from_u64(2);
        for shape in [vec![7, 5, 3], vec![6, 6, 6]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 9);
            let fast = sketch_dense(&t, &mh, Some(9));
            let slow = sketch_dense_naive(&t, &mh, Some(9));
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ts_is_folded_fcs() {
        // TS(T) = fold(FCS(T)) mod J — §3 point (2) of the paper.
        let mut rng = Rng::seed_from_u64(3);
        let shape = [5usize, 6, 4];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 8);
        let fcs = sketch_dense(&t, &mh, None);
        let ts = sketch_dense(&t, &mh, Some(8));
        let mut folded = vec![0.0; 8];
        for (k, &v) in fcs.iter().enumerate() {
            folded[k % 8] += v;
        }
        for (a, b) in folded.iter().zip(&ts) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn circular_and_linear_cores_agree_with_dense() {
        // The one shared pipeline must reproduce both sketch semantics:
        // core::apply_rank1_into ≡ sketch_dense on the materialized outer
        // product, for the circular (TS) and linear (FCS) parameterizations.
        let mut rng = Rng::seed_from_u64(4);
        let shape = [5usize, 4, 6];
        let j = 7usize;
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, j);
        let modes: Vec<CountSketch> =
            mh.modes.iter().map(|t| CountSketch::new(t.clone())).collect();
        let vs: Vec<Vec<f64>> = shape.iter().map(|&d| rng.normal_vec(d)).collect();
        let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
        let cube = crate::tensor::outer(&refs);
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();

        let circ = SpectralSketchCore::circular(&modes, j);
        circ.apply_rank1_into(&refs, &mut ws, &mut out);
        let dense_ts = sketch_dense(&cube, &mh, Some(j));
        assert_eq!(out.len(), j);
        for (a, b) in out.iter().zip(&dense_ts) {
            assert!((a - b).abs() < 1e-9, "circular {a} vs {b}");
        }

        let lin = SpectralSketchCore::linear(&modes, mh.composite_range());
        lin.apply_rank1_into(&refs, &mut ws, &mut out);
        let dense_fcs = sketch_dense(&cube, &mh, None);
        assert_eq!(out.len(), mh.composite_range());
        for (a, b) in out.iter().zip(&dense_fcs) {
            assert!((a - b).abs() < 1e-9, "linear {a} vs {b}");
        }
    }

    #[test]
    fn fused_cp_flight_is_bit_identical_to_serial() {
        // apply_cp_fused over W independent jobs — each with its own hash
        // draw, payload, and rank — must reproduce every job's serial
        // apply_cp_into EXACTLY (`==`, not approximately): the batched
        // kernels keep each lane's flop sequence independent of batch width
        // and the per-job accumulation order is preserved across chunk
        // boundaries. This is the kernel-level half of the coordinator's
        // fused-flight determinism contract.
        let mut rng = Rng::seed_from_u64(6);
        let shape = [5usize, 4, 6];
        let j = 8usize;
        let width = 5usize;
        let mut tables: Vec<Vec<CountSketch>> = Vec::new();
        let mut cps = Vec::new();
        for w in 0..width {
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, j);
            tables.push(mh.modes.iter().map(|t| CountSketch::new(t.clone())).collect());
            // Mixed ranks: rank is a group count, not flight geometry.
            cps.push(CpTensor::randn(&mut rng, &shape, 1 + w % 3));
        }
        let mut ws = FftWorkspace::new();
        let mut serial = Vec::new();
        for (modes, cp) in tables.iter().zip(&cps) {
            let core = SpectralSketchCore::linear_from_modes(modes);
            let mut out = Vec::new();
            core.apply_cp_into(cp, &mut ws, &mut out);
            serial.push(out);
        }
        let flight: Vec<FusedCpJob<'_>> = tables
            .iter()
            .zip(&cps)
            .map(|(modes, cp)| FusedCpJob {
                core: SpectralSketchCore::linear_from_modes(modes),
                factors: &cp.factors,
                lambda: &cp.lambda,
                rank: cp.rank(),
            })
            .collect();
        let sketch_len = flight[0].core.sketch_len;
        let mut fused: Vec<Vec<f64>> = vec![Vec::new(); width];
        apply_cp_fused(&flight, &mut ws, |jb, z| {
            fused[jb].extend_from_slice(&z[..sketch_len]);
        });
        for (w, (a, b)) in fused.iter().zip(&serial).enumerate() {
            assert_eq!(a, b, "job {w}: fused sketch differs from serial");
        }
    }

    #[test]
    fn correlate_gather_matches_manual_contraction() {
        // core::correlate_gather_into on a D=1 sketch must equal the direct
        // computation ⟨st, sketch(e_i ∘ v_1 ∘ v_2)⟩ per free index.
        let mut rng = Rng::seed_from_u64(5);
        let shape = [4usize, 5, 3];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 6);
        let modes: Vec<CountSketch> =
            mh.modes.iter().map(|h| CountSketch::new(h.clone())).collect();
        let core = SpectralSketchCore::linear(&modes, mh.composite_range());
        let st = sketch_dense(&t, &mh, None);
        let st_fft = core.sketch_spectrum(&st);
        let v1 = rng.normal_vec(5);
        let v2 = rng.normal_vec(3);
        let dummy = vec![0.0; 4];
        let vs: [&[f64]; 3] = [&dummy, &v1, &v2];
        let mut ws = FftWorkspace::new();
        let mut got = Vec::new();
        core.correlate_gather_into(&st_fft, 0, &vs, &mut ws, &mut got);
        assert_eq!(got.len(), 4);
        for i in 0..4 {
            let mut e = vec![0.0; 4];
            e[i] = 1.0;
            let cube = crate::tensor::outer(&[&e[..], &v1[..], &v2[..]]);
            let s3 = sketch_dense(&cube, &mh, None);
            let expect = crate::linalg::dot(&st, &s3);
            assert!((got[i] - expect).abs() < 1e-8, "i={i}: {} vs {expect}", got[i]);
        }
    }
}
