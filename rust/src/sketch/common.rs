//! Shared `O(nnz(T))` dense-tensor sketching core used by TS (Eq. 2) and FCS
//! (Eq. 13). Both walk `vec(T)` once, accumulating under the composite hash
//! `Σ_n h_n(i_n)` — TS folds it `mod J`, FCS keeps it un-folded.
//!
//! The hot loop is specialized for the first mode: within a mode-0 fiber only
//! `h_0(i_0)` and `s_0(i_0)` change, so the outer-mode contributions are
//! hoisted to a per-fiber `(hbase, sbase)`.

use super::cs::CountSketch;
use crate::fft::complex::ZERO;
use crate::fft::{fft_real_into, C64, FftWorkspace};
use crate::hash::ModeHashes;
use crate::linalg::Matrix;
use crate::tensor::Tensor;

/// Accumulate the sketch of a dense tensor into `out`.
///
/// * `modulo = Some(J)` → TS bucket `(Σ h_n) mod J` (`out.len() == J`).
/// * `modulo = None`   → FCS bucket `Σ h_n` (`out.len() == J̃`).
pub fn sketch_dense_into(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>, out: &mut [f64]) {
    assert_eq!(t.shape, mh.dims, "tensor/hash shape mismatch");
    match modulo {
        Some(j) => {
            assert_eq!(out.len(), j);
            assert!(
                mh.modes.iter().all(|m| m.range == j),
                "TS requires uniform mode ranges"
            );
        }
        None => assert_eq!(out.len(), mh.composite_range()),
    }
    out.fill(0.0);
    let n = t.order();
    let i0 = t.shape[0];
    let h0 = &mh.modes[0].h;
    let s0 = &mh.modes[0].s;
    let fibers = t.numel() / i0;
    let mut idx_hi = vec![0usize; n - 1]; // indices of modes 1..N
    let mut l = 0usize;
    for _fiber in 0..fibers {
        // Contributions of the fixed higher modes.
        let mut hbase = 0usize;
        let mut neg = 0usize;
        for (d, &i) in idx_hi.iter().enumerate() {
            let m = &mh.modes[d + 1];
            hbase += m.h[i] as usize;
            if m.s[i] < 0 {
                neg += 1;
            }
        }
        let sbase = if neg & 1 == 0 { 1.0 } else { -1.0 };
        match modulo {
            Some(j) => {
                let hb = hbase % j;
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    let mut b = hb + h0[i] as usize;
                    if b >= j {
                        b -= j; // hb, h0 < J ⇒ sum < 2J: one subtract replaces `%`
                    }
                    out[b] += sbase * (s0[i] as f64) * v;
                }
            }
            None => {
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    out[hbase + h0[i] as usize] += sbase * (s0[i] as f64) * v;
                }
            }
        }
        // Increment the higher-mode multi-index.
        for (d, ix) in idx_hi.iter_mut().enumerate() {
            *ix += 1;
            if *ix < t.shape[d + 1] {
                break;
            }
            *ix = 0;
        }
    }
}

/// Convenience allocating wrapper.
pub fn sketch_dense(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
    let len = modulo.unwrap_or_else(|| mh.composite_range());
    let mut out = vec![0.0; len];
    sketch_dense_into(t, mh, modulo, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Spectral accumulation core shared by the TS (circular, Eq. 3) and FCS
// (linear, Eq. 8) CP fast paths: rank products are composed and summed in
// the frequency domain so the caller runs a **single** inverse FFT per
// output instead of one per rank (R IFFTs → 1, §Perf).
// ---------------------------------------------------------------------------

/// Write `Π_d F(CS_d(vs[d]))` at `n` points into `out`. Per-mode count
/// sketches go through the half-length real-input transform; all scratch is
/// rented from `ws` (zero allocations in steady state).
pub(crate) fn rank1_spectrum_into(
    modes: &[CountSketch],
    vs: &[&[f64]],
    n: usize,
    ws: &mut FftWorkspace,
    out: &mut Vec<C64>,
) {
    debug_assert_eq!(modes.len(), vs.len());
    let max_j = modes.iter().map(|m| m.range()).max().unwrap_or(0);
    let mut csbuf = ws.take_f64(max_j);
    let mut fs = ws.take_c64(n);
    for (d, cs) in modes.iter().enumerate() {
        let jd = cs.range();
        cs.apply_into(vs[d], &mut csbuf[..jd]);
        if d == 0 {
            fft_real_into(&csbuf[..jd], n, ws, out);
        } else {
            fft_real_into(&csbuf[..jd], n, ws, &mut fs);
            for (x, y) in out.iter_mut().zip(fs.iter()) {
                *x = *x * *y;
            }
        }
    }
    ws.give_c64(fs);
    ws.give_f64(csbuf);
}

/// Accumulate `Σ_{r ∈ ranks} λ_r · Π_d F(CS_d(U_d[:, r]))` into `acc`
/// (length `n`). The caller inverts once at the end.
pub(crate) fn accumulate_cp_spectra(
    modes: &[CountSketch],
    factors: &[Matrix],
    lambda: &[f64],
    ranks: std::ops::Range<usize>,
    n: usize,
    ws: &mut FftWorkspace,
    acc: &mut [C64],
) {
    debug_assert_eq!(acc.len(), n);
    debug_assert_eq!(modes.len(), factors.len());
    let max_j = modes.iter().map(|m| m.range()).max().unwrap_or(0);
    let mut csbuf = ws.take_f64(max_j);
    let mut spec = ws.take_c64(n);
    let mut fs = ws.take_c64(n);
    for r in ranks {
        for (d, cs) in modes.iter().enumerate() {
            let jd = cs.range();
            cs.apply_into(factors[d].col(r), &mut csbuf[..jd]);
            if d == 0 {
                fft_real_into(&csbuf[..jd], n, ws, &mut spec);
            } else {
                fft_real_into(&csbuf[..jd], n, ws, &mut fs);
                for (x, y) in spec.iter_mut().zip(fs.iter()) {
                    *x = *x * *y;
                }
            }
        }
        let lr = lambda[r];
        for (a, s) in acc.iter_mut().zip(spec.iter()) {
            *a += s.scale(lr);
        }
    }
    ws.give_c64(fs);
    ws.give_c64(spec);
    ws.give_f64(csbuf);
}

/// Rank-parallel variant: chunks the CP ranks over `par_map` worker threads
/// (each with its own workspace), then sums the partial spectra in
/// deterministic chunk order. Used above a size threshold by the TS/FCS
/// `apply_cp` entry points.
pub(crate) fn accumulate_cp_spectra_parallel(
    modes: &[CountSketch],
    factors: &[Matrix],
    lambda: &[f64],
    rank: usize,
    n: usize,
) -> Vec<C64> {
    let threads = crate::util::parallel::default_threads().min(rank).max(1);
    let chunk = (rank + threads - 1) / threads;
    let nchunks = (rank + chunk - 1) / chunk;
    let partials = crate::util::parallel::par_map(nchunks, threads, |ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(rank);
        let mut ws = FftWorkspace::new();
        let mut acc = vec![ZERO; n];
        accumulate_cp_spectra(modes, factors, lambda, lo..hi, n, &mut ws, &mut acc);
        acc
    });
    let mut it = partials.into_iter();
    let mut acc = it.next().expect("rank >= 1");
    for p in it {
        for (a, b) in acc.iter_mut().zip(&p) {
            *a += *b;
        }
    }
    acc
}

/// Work threshold above which the CP fast paths fan ranks out across
/// threads: enough ranks to chunk, and large enough transforms that thread
/// startup is amortized.
pub(crate) fn cp_rank_parallel(rank: usize, n: usize) -> bool {
    rank >= 8 && n >= 4096
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::unravel_colmajor;
    use crate::util::prng::Rng;

    /// Reference implementation straight from Eq. 2 / Eq. 13.
    fn sketch_dense_naive(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
        let len = modulo.unwrap_or_else(|| mh.composite_range());
        let mut out = vec![0.0; len];
        let mut idx = vec![0usize; t.order()];
        for l in 0..t.numel() {
            unravel_colmajor(l, &t.shape, &mut idx);
            let h = mh.composite_h(&idx);
            let b = match modulo {
                Some(j) => h % j,
                None => h,
            };
            out[b] += mh.composite_s(&idx) * t.data[l];
        }
        out
    }

    #[test]
    fn fast_matches_naive_fcs() {
        let mut rng = Rng::seed_from_u64(1);
        for shape in [vec![7, 5, 3], vec![4, 4], vec![3, 2, 2, 3]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 6);
            let fast = sketch_dense(&t, &mh, None);
            let slow = sketch_dense_naive(&t, &mh, None);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fast_matches_naive_ts() {
        let mut rng = Rng::seed_from_u64(2);
        for shape in [vec![7, 5, 3], vec![6, 6, 6]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 9);
            let fast = sketch_dense(&t, &mh, Some(9));
            let slow = sketch_dense_naive(&t, &mh, Some(9));
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ts_is_folded_fcs() {
        // TS(T) = fold(FCS(T)) mod J — §3 point (2) of the paper.
        let mut rng = Rng::seed_from_u64(3);
        let shape = [5usize, 6, 4];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 8);
        let fcs = sketch_dense(&t, &mh, None);
        let ts = sketch_dense(&t, &mh, Some(8));
        let mut folded = vec![0.0; 8];
        for (k, &v) in fcs.iter().enumerate() {
            folded[k % 8] += v;
        }
        for (a, b) in folded.iter().zip(&ts) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
