//! Shared sketching cores used by TS (Eq. 2/3) and FCS (Eq. 8/13).
//!
//! Two layers live here:
//!
//! 1. [`sketch_dense_into`] — the `O(nnz(T))` dense-tensor walk, accumulating
//!    under the composite hash `Σ_n h_n(i_n)` (TS folds it `mod J`, FCS keeps
//!    it un-folded).
//! 2. [`SpectralSketchCore`] — the CS-hash → rfft → spectral product →
//!    one-IFFT pipeline every CP/rank-1/estimator fast path is a
//!    parameterization of. TS is the *circular* instantiation
//!    (`fft_len == sketch_len == J`), FCS the *linear* one
//!    (`sketch_len = J̃`, `fft_len = next_pow2(J̃)` — exact because FCS's
//!    non-modular structure leaves the padded tail untouched).
//!
//! The dense hot loop is specialized for the first mode: within a mode-0
//! fiber only `h_0(i_0)` and `s_0(i_0)` change, so the outer-mode
//! contributions are hoisted to a per-fiber `(hbase, sbase)`.

use super::cs::CountSketch;
use crate::fft::complex::ZERO;
use crate::fft::{self, fft_real_many_into, C64, FftWorkspace};
use crate::hash::ModeHashes;
use crate::linalg::Matrix;
use crate::tensor::{CpTensor, Tensor};

/// Upper bound on simultaneous lanes in the batched spectral transforms:
/// wide enough to keep the batch (innermost SIMD) axis full with headroom,
/// small enough that the lane-major `fft_len × lanes` planes stay cache- and
/// pool-friendly at the largest practical transform lengths.
pub(crate) const MAX_FFT_LANES: usize = 16;

/// Multiply the complex product of `count` consecutive lanes
/// `(sre, sim)[s..s+count]` of one lane-major frequency row into the
/// accumulator `(pr, pi)`; with `conj` each lane enters conjugated (spectral
/// correlation rather than convolution). The single home of the batched
/// pointwise-product inner loop every spectral fold runs.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn mul_lane_run(
    sre: &[f64],
    sim: &[f64],
    s: usize,
    count: usize,
    conj: bool,
    pr: &mut f64,
    pi: &mut f64,
) {
    for d in 0..count {
        let qr = sre[s + d];
        let qi = if conj { -sim[s + d] } else { sim[s + d] };
        let t = *pr * qr - *pi * qi;
        *pi = *pr * qi + *pi * qr;
        *pr = t;
    }
}

/// Accumulate the sketch of a dense tensor into `out`.
///
/// * `modulo = Some(J)` → TS bucket `(Σ h_n) mod J` (`out.len() == J`).
/// * `modulo = None`   → FCS bucket `Σ h_n` (`out.len() == J̃`).
pub fn sketch_dense_into(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>, out: &mut [f64]) {
    assert_eq!(t.shape, mh.dims, "tensor/hash shape mismatch");
    match modulo {
        Some(j) => {
            assert_eq!(out.len(), j);
            assert!(
                mh.modes.iter().all(|m| m.range == j),
                "TS requires uniform mode ranges"
            );
        }
        None => assert_eq!(out.len(), mh.composite_range()),
    }
    out.fill(0.0);
    let n = t.order();
    let i0 = t.shape[0];
    let h0 = &mh.modes[0].h;
    let s0 = &mh.modes[0].s;
    let fibers = t.numel() / i0;
    // Multi-index over modes 1..N. Stack storage keeps this function
    // allocation-free (it sits on the coordinator's zero-alloc service
    // path); tensors beyond 32 modes fall back to the heap.
    let mut idx_stack = [0usize; 32];
    let mut idx_heap: Vec<usize>;
    let idx_hi: &mut [usize] = if n - 1 <= idx_stack.len() {
        &mut idx_stack[..n - 1]
    } else {
        idx_heap = vec![0usize; n - 1];
        &mut idx_heap
    };
    let mut l = 0usize;
    for _fiber in 0..fibers {
        // Contributions of the fixed higher modes.
        let mut hbase = 0usize;
        let mut neg = 0usize;
        for (d, &i) in idx_hi.iter().enumerate() {
            let m = &mh.modes[d + 1];
            hbase += m.h[i] as usize;
            if m.s[i] < 0 {
                neg += 1;
            }
        }
        let sbase = if neg & 1 == 0 { 1.0 } else { -1.0 };
        match modulo {
            Some(j) => {
                let hb = hbase % j;
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    let mut b = hb + h0[i] as usize;
                    if b >= j {
                        b -= j; // hb, h0 < J ⇒ sum < 2J: one subtract replaces `%`
                    }
                    out[b] += sbase * (s0[i] as f64) * v;
                }
            }
            None => {
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    out[hbase + h0[i] as usize] += sbase * (s0[i] as f64) * v;
                }
            }
        }
        // Increment the higher-mode multi-index.
        for (d, ix) in idx_hi.iter_mut().enumerate() {
            *ix += 1;
            if *ix < t.shape[d + 1] {
                break;
            }
            *ix = 0;
        }
    }
}

/// Convenience allocating wrapper.
pub fn sketch_dense(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
    let len = modulo.unwrap_or_else(|| mh.composite_range());
    let mut out = vec![0.0; len];
    sketch_dense_into(t, mh, modulo, &mut out);
    out
}

// ---------------------------------------------------------------------------
// SpectralSketchCore — the one spectral pipeline behind TS and FCS
// ---------------------------------------------------------------------------

/// Borrowing view over the per-mode count sketches plus the two lengths that
/// fully determine a spectral sketch pipeline. Everything TS and FCS do in
/// the frequency domain — CP accumulation (Eq. 3/8), rank-1 sketches
/// (Eq. 16), and the Eq. 17 correlate-and-gather the estimators run — is a
/// method on this one type, so a new backend (SIMD butterflies, GPU) lands
/// in exactly one place.
#[derive(Clone, Copy)]
pub struct SpectralSketchCore<'a> {
    /// Per-mode count sketches `CS_1..CS_N`.
    pub modes: &'a [CountSketch],
    /// Output sketch length: `J` for TS (circular), `J̃ = Σ J_n − N + 1` for
    /// FCS (linear).
    pub sketch_len: usize,
    /// Transform length: `== sketch_len` for TS (the circular convolution
    /// *is* length-J); `next_power_of_two(J̃)` for FCS — any `n ≥ J̃` is
    /// exact because no wraparound can reach the gathered buckets, and the
    /// power of two skips Bluestein entirely (§Perf: ~3–6× on t_mode).
    pub fft_len: usize,
}

impl<'a> SpectralSketchCore<'a> {
    /// TS parameterization: circular convolution at length `j`.
    pub fn circular(modes: &'a [CountSketch], j: usize) -> Self {
        Self { modes, sketch_len: j, fft_len: j }
    }

    /// FCS parameterization: linear convolution of length `j_tilde`, padded
    /// to a power of two.
    pub fn linear(modes: &'a [CountSketch], j_tilde: usize) -> Self {
        Self { modes, sketch_len: j_tilde, fft_len: j_tilde.next_power_of_two() }
    }

    /// Linear parameterization with `J̃ = Σ J_n − N + 1` (Definition 4)
    /// derived from the mode sketches themselves — callers that only hold
    /// per-mode tables (the coordinator's arena path) use this instead of
    /// re-deriving the composite-range formula.
    pub fn linear_from_modes(modes: &'a [CountSketch]) -> Self {
        let j_tilde = modes.iter().map(|m| m.range()).sum::<usize>() - modes.len() + 1;
        Self::linear(modes, j_tilde)
    }

    /// Largest per-mode sketch range — the uniform slot stride the batched
    /// transforms pack mode sketches at (the estimator's cross-repetition
    /// packing reuses it, so this is the single home of the stride rule).
    /// Always `≤ fft_len`: for TS every range *is* `J = fft_len`; for FCS
    /// `J̃ = Σ J_d − N + 1 ≥ max_d J_d` and `fft_len = next_pow2(J̃)`.
    #[inline]
    pub(crate) fn mode_stride(&self) -> usize {
        self.modes.iter().map(|m| m.range()).max().unwrap_or(0)
    }

    /// Write `Π_d F(CS_d(vs[d]))` at `fft_len` points into `out`. All N mode
    /// sketches are transformed by **one** batched call (`fft_real_many_into`
    /// with the modes as lanes) and folded pointwise, batch innermost.
    pub fn rank1_spectrum_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<C64>) {
        // Hard assert (matching the pre-refactor inherent methods): a wrong
        // arity must fail loudly, not silently drop the extra vector in
        // release builds.
        assert_eq!(self.modes.len(), vs.len(), "rank-1 sketch arity mismatch");
        let n = self.fft_len;
        let nm = self.modes.len();
        let stride = self.mode_stride();
        let mut xs = ws.take_f64(nm * stride);
        for (d, cs) in self.modes.iter().enumerate() {
            let jd = cs.range();
            cs.apply_into(vs[d], &mut xs[d * stride..d * stride + jd]);
        }
        let mut sre = ws.take_f64(0);
        let mut sim = ws.take_f64(0);
        fft_real_many_into(&xs, stride, nm, n, ws, &mut sre, &mut sim);
        out.clear();
        out.resize(n, ZERO);
        for (k, o) in out.iter_mut().enumerate() {
            let row = k * nm;
            let mut pr = sre[row];
            let mut pi = sim[row];
            mul_lane_run(&sre, &sim, row + 1, nm - 1, false, &mut pr, &mut pi);
            o.re = pr;
            o.im = pi;
        }
        ws.give_f64(sim);
        ws.give_f64(sre);
        ws.give_f64(xs);
    }

    /// Accumulate `Σ_{r ∈ ranks} λ_r · Π_d F(CS_d(U_d[:, r]))` into `acc`
    /// (length `fft_len`). The caller inverts once at the end — R IFFTs → 1.
    ///
    /// Ranks are processed in chunks of whole ranks, all `chunk·N` mode
    /// sketches of a chunk going through **one** batched forward transform
    /// (instead of R·N single-plan dispatches); the fold below then reads
    /// each rank's N spectra side by side in the lane-major planes.
    pub fn accumulate_cp_spectra(
        &self,
        factors: &[Matrix],
        lambda: &[f64],
        ranks: std::ops::Range<usize>,
        ws: &mut FftWorkspace,
        acc: &mut [C64],
    ) {
        debug_assert_eq!(acc.len(), self.fft_len);
        debug_assert_eq!(self.modes.len(), factors.len());
        if self.modes.is_empty() {
            return;
        }
        let n = self.fft_len;
        let nm = self.modes.len();
        let stride = self.mode_stride();
        let ranks_per = (MAX_FFT_LANES / nm).max(1);
        // Slot tails beyond each mode's J_d stay zero: the rental arrives
        // zeroed and every chunk rewrites the same (lane-slot, J_d) layout.
        let mut xs = ws.take_f64(ranks_per * nm * stride);
        let mut sre = ws.take_f64(0);
        let mut sim = ws.take_f64(0);
        let mut r0 = ranks.start;
        while r0 < ranks.end {
            let rc = (ranks.end - r0).min(ranks_per);
            let lanes = rc * nm;
            for ri in 0..rc {
                for (d, cs) in self.modes.iter().enumerate() {
                    let jd = cs.range();
                    let slot = (ri * nm + d) * stride;
                    cs.apply_into(factors[d].col(r0 + ri), &mut xs[slot..slot + jd]);
                }
            }
            fft_real_many_into(&xs[..lanes * stride], stride, lanes, n, ws, &mut sre, &mut sim);
            for (k, a) in acc.iter_mut().enumerate() {
                let row = k * lanes;
                for ri in 0..rc {
                    let s = row + ri * nm;
                    let mut pr = sre[s];
                    let mut pi = sim[s];
                    mul_lane_run(&sre, &sim, s + 1, nm - 1, false, &mut pr, &mut pi);
                    let lr = lambda[r0 + ri];
                    a.re += lr * pr;
                    a.im += lr * pi;
                }
            }
            r0 += rc;
        }
        ws.give_f64(sim);
        ws.give_f64(sre);
        ws.give_f64(xs);
    }

    /// Rank-parallel variant: chunks the CP ranks over `par_map` worker
    /// threads (each with its own workspace), then sums the partial spectra
    /// in deterministic chunk order.
    pub fn accumulate_cp_spectra_parallel(
        &self,
        factors: &[Matrix],
        lambda: &[f64],
        rank: usize,
    ) -> Vec<C64> {
        let n = self.fft_len;
        let threads = crate::util::parallel::default_threads().min(rank).max(1);
        let chunk = (rank + threads - 1) / threads;
        let nchunks = (rank + chunk - 1) / chunk;
        let partials = crate::util::parallel::par_map(nchunks, threads, |ci| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(rank);
            let mut ws = FftWorkspace::new();
            let mut acc = vec![ZERO; n];
            self.accumulate_cp_spectra(factors, lambda, lo..hi, &mut ws, &mut acc);
            acc
        });
        let mut it = partials.into_iter();
        let mut acc = it.next().expect("rank >= 1");
        for p in it {
            for (a, b) in acc.iter_mut().zip(&p) {
                *a += *b;
            }
        }
        acc
    }

    /// Sketch of a rank-1 tensor `v_1 ∘ … ∘ v_N`: mode product, one inverse
    /// transform, truncate to `sketch_len`. Zero allocations in steady state.
    pub fn apply_rank1_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        let mut spec = ws.take_c64(self.fft_len);
        self.rank1_spectrum_into(vs, ws, &mut spec);
        fft::inverse_real_into(&mut spec, ws, out);
        out.truncate(self.sketch_len);
        ws.give_c64(spec);
    }

    /// Serial CP fast path: spectral rank accumulation, a **single** inverse
    /// FFT, truncate to `sketch_len`. Zero allocations in steady state.
    pub fn apply_cp_into(&self, cp: &CpTensor, ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        debug_assert_eq!(self.modes.len(), cp.order());
        let mut acc = ws.take_c64(self.fft_len);
        self.accumulate_cp_spectra(&cp.factors, &cp.lambda, 0..cp.rank(), ws, &mut acc);
        fft::inverse_real_into(&mut acc, ws, out);
        out.truncate(self.sketch_len);
        ws.give_c64(acc);
    }

    /// Allocating CP entry point; fans ranks out over threads above the
    /// [`cp_rank_parallel`] threshold.
    pub fn apply_cp(&self, cp: &CpTensor) -> Vec<f64> {
        if cp_rank_parallel(cp.rank(), self.fft_len) {
            let mut acc = self.accumulate_cp_spectra_parallel(&cp.factors, &cp.lambda, cp.rank());
            return fft::with_thread_workspace(|ws| {
                // Capacity = transform length: inverse_real_into fills to
                // fft_len before the truncate to sketch_len.
                let mut out = Vec::with_capacity(self.fft_len);
                fft::inverse_real_into(&mut acc, ws, &mut out);
                out.truncate(self.sketch_len);
                out
            });
        }
        fft::with_thread_workspace(|ws| {
            let mut out = Vec::with_capacity(self.fft_len);
            self.apply_cp_into(cp, ws, &mut out);
            out
        })
    }

    /// Forward transform of a sketch at `fft_len` points — the per-rep
    /// `F(st)` cache the estimators hoist out of every `t_mode` call.
    pub fn sketch_spectrum(&self, st: &[f64]) -> Vec<C64> {
        debug_assert_eq!(st.len(), self.sketch_len);
        fft::fft_real(st, self.fft_len)
    }

    /// One repetition of Eq. 17 generalized — the estimator `t_mode` body:
    /// `z = F⁻¹( F(st) · Π_{d≠mode} conj(F(CS_d(vs[d]))) )`, then the
    /// mode-`mode` basis gather `out[i] = s_mode(i) · z(h_mode(i))`. For the
    /// FCS (linear) instantiation no wraparound can occur because
    /// `h_mode(i) + Σ_{d≠mode}(J_d − 1) ≤ J̃ − 1 < fft_len`; for TS the
    /// circular length *is* the semantics. All scratch rented from `ws`.
    pub fn correlate_gather_into(
        &self,
        st_fft: &[C64],
        mode: usize,
        vs: &[&[f64]],
        ws: &mut FftWorkspace,
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(st_fft.len(), self.fft_len);
        let n = self.fft_len;
        let nm = self.modes.len();
        let lanes = nm - 1;
        let stride = self.mode_stride();
        // One batched forward transform for the N−1 contracted-mode sketches.
        let mut xs = ws.take_f64(lanes * stride);
        let mut lane = 0usize;
        for (d, cs) in self.modes.iter().enumerate() {
            if d == mode {
                continue;
            }
            let jd = cs.range();
            cs.apply_into(vs[d], &mut xs[lane * stride..lane * stride + jd]);
            lane += 1;
        }
        let mut sre = ws.take_f64(0);
        let mut sim = ws.take_f64(0);
        fft_real_many_into(&xs, stride, lanes, n, ws, &mut sre, &mut sim);
        let mut fz = ws.take_c64(n);
        for (k, z) in fz.iter_mut().enumerate() {
            let mut pr = st_fft[k].re;
            let mut pi = st_fft[k].im;
            // conjugated factors: spectral correlation, not convolution
            mul_lane_run(&sre, &sim, k * lanes, lanes, true, &mut pr, &mut pi);
            z.re = pr;
            z.im = pi;
        }
        ws.give_f64(sim);
        ws.give_f64(sre);
        ws.give_f64(xs);
        let mut z = ws.take_f64(self.fft_len);
        fft::inverse_real_into(&mut fz, ws, &mut z);
        let cs_m = &self.modes[mode];
        out.clear();
        out.resize(cs_m.domain(), 0.0);
        for (i, o) in out.iter_mut().enumerate() {
            let (b, s) = cs_m.basis(i);
            *o = s * z[b];
        }
        ws.give_f64(z);
        ws.give_c64(fz);
    }
}

/// Work threshold above which the CP fast paths fan ranks out across
/// threads: enough ranks to chunk, and large enough transforms that thread
/// startup is amortized.
pub(crate) fn cp_rank_parallel(rank: usize, n: usize) -> bool {
    rank >= 8 && n >= 4096
}

/// Allocation-free `cp.shape() == dims` check: `CpTensor::shape()` collects
/// a fresh `Vec`, which would put one heap allocation per call on the
/// zero-alloc `apply_cp_into` paths (and fail `tests/alloc_discipline.rs`).
pub(crate) fn cp_shape_matches(cp: &CpTensor, dims: &[usize]) -> bool {
    cp.factors.iter().map(|f| f.rows).eq(dims.iter().copied())
}

/// The interface the generic [`crate::sketch::estimator::SpectralEstimator`]
/// programs against: both [`crate::sketch::TensorSketch`] and
/// [`crate::sketch::FastCountSketch`] are a [`SpectralSketchCore`]
/// parameterization plus an `O(nnz(T))` dense path.
pub trait SpectralSketchOp: Send + Sync {
    /// Estimator name tag (`"ts"` / `"fcs"`).
    const NAME: &'static str;

    fn from_hashes(hashes: ModeHashes) -> Self;

    fn hashes(&self) -> &ModeHashes;

    /// The spectral pipeline view over this operator's mode sketches.
    fn core(&self) -> SpectralSketchCore<'_>;

    /// Sketch a general dense tensor — `O(nnz(T))`.
    fn apply_dense(&self, t: &Tensor) -> Vec<f64>;

    /// CP fast path (workspace-backed); default routes through the core.
    fn apply_cp_into(&self, cp: &CpTensor, ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        self.core().apply_cp_into(cp, ws, out);
    }

    /// Rank-1 fast path (workspace-backed); default routes through the core.
    fn apply_rank1_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        self.core().apply_rank1_into(vs, ws, out);
    }

    /// Memory of the stored hash functions (bytes) — `O(Σ I_n)`.
    fn hash_memory_bytes(&self) -> usize {
        self.hashes().memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::unravel_colmajor;
    use crate::util::prng::Rng;

    /// Reference implementation straight from Eq. 2 / Eq. 13.
    fn sketch_dense_naive(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
        let len = modulo.unwrap_or_else(|| mh.composite_range());
        let mut out = vec![0.0; len];
        let mut idx = vec![0usize; t.order()];
        for l in 0..t.numel() {
            unravel_colmajor(l, &t.shape, &mut idx);
            let h = mh.composite_h(&idx);
            let b = match modulo {
                Some(j) => h % j,
                None => h,
            };
            out[b] += mh.composite_s(&idx) * t.data[l];
        }
        out
    }

    #[test]
    fn fast_matches_naive_fcs() {
        let mut rng = Rng::seed_from_u64(1);
        for shape in [vec![7, 5, 3], vec![4, 4], vec![3, 2, 2, 3]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 6);
            let fast = sketch_dense(&t, &mh, None);
            let slow = sketch_dense_naive(&t, &mh, None);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fast_matches_naive_ts() {
        let mut rng = Rng::seed_from_u64(2);
        for shape in [vec![7, 5, 3], vec![6, 6, 6]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 9);
            let fast = sketch_dense(&t, &mh, Some(9));
            let slow = sketch_dense_naive(&t, &mh, Some(9));
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ts_is_folded_fcs() {
        // TS(T) = fold(FCS(T)) mod J — §3 point (2) of the paper.
        let mut rng = Rng::seed_from_u64(3);
        let shape = [5usize, 6, 4];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 8);
        let fcs = sketch_dense(&t, &mh, None);
        let ts = sketch_dense(&t, &mh, Some(8));
        let mut folded = vec![0.0; 8];
        for (k, &v) in fcs.iter().enumerate() {
            folded[k % 8] += v;
        }
        for (a, b) in folded.iter().zip(&ts) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn circular_and_linear_cores_agree_with_dense() {
        // The one shared pipeline must reproduce both sketch semantics:
        // core::apply_rank1_into ≡ sketch_dense on the materialized outer
        // product, for the circular (TS) and linear (FCS) parameterizations.
        let mut rng = Rng::seed_from_u64(4);
        let shape = [5usize, 4, 6];
        let j = 7usize;
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, j);
        let modes: Vec<CountSketch> =
            mh.modes.iter().map(|t| CountSketch::new(t.clone())).collect();
        let vs: Vec<Vec<f64>> = shape.iter().map(|&d| rng.normal_vec(d)).collect();
        let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
        let cube = crate::tensor::outer(&refs);
        let mut ws = FftWorkspace::new();
        let mut out = Vec::new();

        let circ = SpectralSketchCore::circular(&modes, j);
        circ.apply_rank1_into(&refs, &mut ws, &mut out);
        let dense_ts = sketch_dense(&cube, &mh, Some(j));
        assert_eq!(out.len(), j);
        for (a, b) in out.iter().zip(&dense_ts) {
            assert!((a - b).abs() < 1e-9, "circular {a} vs {b}");
        }

        let lin = SpectralSketchCore::linear(&modes, mh.composite_range());
        lin.apply_rank1_into(&refs, &mut ws, &mut out);
        let dense_fcs = sketch_dense(&cube, &mh, None);
        assert_eq!(out.len(), mh.composite_range());
        for (a, b) in out.iter().zip(&dense_fcs) {
            assert!((a - b).abs() < 1e-9, "linear {a} vs {b}");
        }
    }

    #[test]
    fn correlate_gather_matches_manual_contraction() {
        // core::correlate_gather_into on a D=1 sketch must equal the direct
        // computation ⟨st, sketch(e_i ∘ v_1 ∘ v_2)⟩ per free index.
        let mut rng = Rng::seed_from_u64(5);
        let shape = [4usize, 5, 3];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 6);
        let modes: Vec<CountSketch> =
            mh.modes.iter().map(|h| CountSketch::new(h.clone())).collect();
        let core = SpectralSketchCore::linear(&modes, mh.composite_range());
        let st = sketch_dense(&t, &mh, None);
        let st_fft = core.sketch_spectrum(&st);
        let v1 = rng.normal_vec(5);
        let v2 = rng.normal_vec(3);
        let dummy = vec![0.0; 4];
        let vs: [&[f64]; 3] = [&dummy, &v1, &v2];
        let mut ws = FftWorkspace::new();
        let mut got = Vec::new();
        core.correlate_gather_into(&st_fft, 0, &vs, &mut ws, &mut got);
        assert_eq!(got.len(), 4);
        for i in 0..4 {
            let mut e = vec![0.0; 4];
            e[i] = 1.0;
            let cube = crate::tensor::outer(&[&e[..], &v1[..], &v2[..]]);
            let s3 = sketch_dense(&cube, &mh, None);
            let expect = crate::linalg::dot(&st, &s3);
            assert!((got[i] - expect).abs() < 1e-8, "i={i}: {} vs {expect}", got[i]);
        }
    }
}
