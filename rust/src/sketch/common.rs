//! Shared `O(nnz(T))` dense-tensor sketching core used by TS (Eq. 2) and FCS
//! (Eq. 13). Both walk `vec(T)` once, accumulating under the composite hash
//! `Σ_n h_n(i_n)` — TS folds it `mod J`, FCS keeps it un-folded.
//!
//! The hot loop is specialized for the first mode: within a mode-0 fiber only
//! `h_0(i_0)` and `s_0(i_0)` change, so the outer-mode contributions are
//! hoisted to a per-fiber `(hbase, sbase)`.

use crate::hash::ModeHashes;
use crate::tensor::Tensor;

/// Accumulate the sketch of a dense tensor into `out`.
///
/// * `modulo = Some(J)` → TS bucket `(Σ h_n) mod J` (`out.len() == J`).
/// * `modulo = None`   → FCS bucket `Σ h_n` (`out.len() == J̃`).
pub fn sketch_dense_into(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>, out: &mut [f64]) {
    assert_eq!(t.shape, mh.dims, "tensor/hash shape mismatch");
    match modulo {
        Some(j) => {
            assert_eq!(out.len(), j);
            assert!(
                mh.modes.iter().all(|m| m.range == j),
                "TS requires uniform mode ranges"
            );
        }
        None => assert_eq!(out.len(), mh.composite_range()),
    }
    out.fill(0.0);
    let n = t.order();
    let i0 = t.shape[0];
    let h0 = &mh.modes[0].h;
    let s0 = &mh.modes[0].s;
    let fibers = t.numel() / i0;
    let mut idx_hi = vec![0usize; n - 1]; // indices of modes 1..N
    let mut l = 0usize;
    for _fiber in 0..fibers {
        // Contributions of the fixed higher modes.
        let mut hbase = 0usize;
        let mut neg = 0usize;
        for (d, &i) in idx_hi.iter().enumerate() {
            let m = &mh.modes[d + 1];
            hbase += m.h[i] as usize;
            if m.s[i] < 0 {
                neg += 1;
            }
        }
        let sbase = if neg & 1 == 0 { 1.0 } else { -1.0 };
        match modulo {
            Some(j) => {
                let hb = hbase % j;
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    let mut b = hb + h0[i] as usize;
                    if b >= j {
                        b -= j; // hb, h0 < J ⇒ sum < 2J: one subtract replaces `%`
                    }
                    out[b] += sbase * (s0[i] as f64) * v;
                }
            }
            None => {
                for i in 0..i0 {
                    let v = t.data[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    out[hbase + h0[i] as usize] += sbase * (s0[i] as f64) * v;
                }
            }
        }
        // Increment the higher-mode multi-index.
        for (d, ix) in idx_hi.iter_mut().enumerate() {
            *ix += 1;
            if *ix < t.shape[d + 1] {
                break;
            }
            *ix = 0;
        }
    }
}

/// Convenience allocating wrapper.
pub fn sketch_dense(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
    let len = modulo.unwrap_or_else(|| mh.composite_range());
    let mut out = vec![0.0; len];
    sketch_dense_into(t, mh, modulo, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::unravel_colmajor;
    use crate::util::prng::Rng;

    /// Reference implementation straight from Eq. 2 / Eq. 13.
    fn sketch_dense_naive(t: &Tensor, mh: &ModeHashes, modulo: Option<usize>) -> Vec<f64> {
        let len = modulo.unwrap_or_else(|| mh.composite_range());
        let mut out = vec![0.0; len];
        let mut idx = vec![0usize; t.order()];
        for l in 0..t.numel() {
            unravel_colmajor(l, &t.shape, &mut idx);
            let h = mh.composite_h(&idx);
            let b = match modulo {
                Some(j) => h % j,
                None => h,
            };
            out[b] += mh.composite_s(&idx) * t.data[l];
        }
        out
    }

    #[test]
    fn fast_matches_naive_fcs() {
        let mut rng = Rng::seed_from_u64(1);
        for shape in [vec![7, 5, 3], vec![4, 4], vec![3, 2, 2, 3]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 6);
            let fast = sketch_dense(&t, &mh, None);
            let slow = sketch_dense_naive(&t, &mh, None);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fast_matches_naive_ts() {
        let mut rng = Rng::seed_from_u64(2);
        for shape in [vec![7, 5, 3], vec![6, 6, 6]] {
            let t = Tensor::randn(&mut rng, &shape);
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, 9);
            let fast = sketch_dense(&t, &mh, Some(9));
            let slow = sketch_dense_naive(&t, &mh, Some(9));
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ts_is_folded_fcs() {
        // TS(T) = fold(FCS(T)) mod J — §3 point (2) of the paper.
        let mut rng = Rng::seed_from_u64(3);
        let shape = [5usize, 6, 4];
        let t = Tensor::randn(&mut rng, &shape);
        let mh = ModeHashes::draw_uniform(&mut rng, &shape, 8);
        let fcs = sketch_dense(&t, &mh, None);
        let ts = sketch_dense(&t, &mh, Some(8));
        let mut folded = vec![0.0; 8];
        for (k, &v) in fcs.iter().enumerate() {
            folded[k % 8] += v;
        }
        for (a, b) in folded.iter().zip(&ts) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
