//! Tensor sketch (Definition 2, Pham & Pagh): buckets by
//! `(Σ_n h_n(i_n)) mod J`, which for CP tensors is the mode-J **circular**
//! convolution of the per-mode count sketches (Eq. 3).
//!
//! All frequency-domain work delegates to the shared
//! [`SpectralSketchCore`] (circular parameterization): TS and FCS differ
//! only in the two lengths handed to the core.

use super::common::{sketch_dense, sketch_dense_into, SpectralSketchCore, SpectralSketchOp};
use super::cs::CountSketch;
use crate::fft::FftWorkspace;
use crate::hash::ModeHashes;
use crate::tensor::{CpTensor, Tensor};

#[derive(Debug, Clone)]
pub struct TensorSketch {
    pub hashes: ModeHashes,
    pub modes: Vec<CountSketch>,
    pub j: usize,
}

impl TensorSketch {
    /// Build from shared hash draws (TS and FCS are "equalized" by handing
    /// both the same `ModeHashes`, as the paper does in §4.1).
    pub fn new(hashes: ModeHashes) -> Self {
        let j = hashes.modes[0].range;
        assert!(
            hashes.modes.iter().all(|m| m.range == j),
            "TS needs uniform hash ranges"
        );
        let modes = hashes.modes.iter().map(|t| CountSketch::new(t.clone())).collect();
        Self { hashes, modes, j }
    }

    pub fn order(&self) -> usize {
        self.modes.len()
    }

    /// The circular spectral-pipeline view (`fft_len == sketch_len == J`).
    pub fn core(&self) -> SpectralSketchCore<'_> {
        SpectralSketchCore::circular(&self.modes, self.j)
    }

    /// Sketch a general dense tensor — `O(nnz(T))` (Eq. 2).
    pub fn apply_dense(&self, t: &Tensor) -> Vec<f64> {
        sketch_dense(t, &self.hashes, Some(self.j))
    }

    /// In-place variant for the hot path.
    pub fn apply_dense_into(&self, t: &Tensor, out: &mut [f64]) {
        sketch_dense_into(t, &self.hashes, Some(self.j), out);
    }

    /// Sketch a CP tensor by circular convolution of per-mode count sketches
    /// (Eq. 3) — `O(max_n nnz(U^{(n)}) + R·J log J)`. Rank products are
    /// accumulated in the spectral domain (one inverse FFT total instead of
    /// one per rank); large rank counts fan out over threads.
    pub fn apply_cp(&self, cp: &CpTensor) -> Vec<f64> {
        assert!(
            super::common::cp_shape_matches(cp, &self.hashes.dims),
            "CP/hash shape mismatch"
        );
        self.core().apply_cp(cp)
    }

    /// Serial workspace variant of [`Self::apply_cp`] — zero heap
    /// allocations in steady state.
    pub fn apply_cp_into(&self, cp: &CpTensor, ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        assert!(
            super::common::cp_shape_matches(cp, &self.hashes.dims),
            "CP/hash shape mismatch"
        );
        self.core().apply_cp_into(cp, ws, out);
    }

    /// Pre-spectral-accumulation reference (one circular convolution and one
    /// inverse FFT per rank) — property-test oracle and §Perf baseline.
    /// Deliberately *not* routed through [`SpectralSketchCore`] so it stays
    /// an independent check on the shared pipeline.
    pub fn apply_cp_per_rank(&self, cp: &CpTensor) -> Vec<f64> {
        assert!(
            super::common::cp_shape_matches(cp, &self.hashes.dims),
            "CP/hash shape mismatch"
        );
        let mut out = vec![0.0; self.j];
        for r in 0..cp.rank() {
            let sketched: Vec<Vec<f64>> = self
                .modes
                .iter()
                .zip(&cp.factors)
                .map(|(cs, u)| cs.apply(u.col(r)))
                .collect();
            let refs: Vec<&[f64]> = sketched.iter().map(|v| v.as_slice()).collect();
            let conv = crate::fft::conv_circular_many(&refs);
            crate::linalg::axpy(cp.lambda[r], &conv, &mut out);
        }
        out
    }

    /// Sketch of a rank-1 tensor `v_1 ∘ … ∘ v_N` without materializing it.
    pub fn apply_rank1(&self, vs: &[&[f64]]) -> Vec<f64> {
        crate::fft::with_thread_workspace(|ws| {
            let mut out = Vec::with_capacity(self.j);
            self.apply_rank1_into(vs, ws, &mut out);
            out
        })
    }

    /// Workspace variant of [`Self::apply_rank1`] — zero allocations in
    /// steady state.
    pub fn apply_rank1_into(&self, vs: &[&[f64]], ws: &mut FftWorkspace, out: &mut Vec<f64>) {
        assert_eq!(vs.len(), self.order());
        self.core().apply_rank1_into(vs, ws, out);
    }
}

impl SpectralSketchOp for TensorSketch {
    const NAME: &'static str = "ts";

    fn from_hashes(hashes: ModeHashes) -> Self {
        TensorSketch::new(hashes)
    }

    fn hashes(&self) -> &ModeHashes {
        &self.hashes
    }

    fn core(&self) -> SpectralSketchCore<'_> {
        TensorSketch::core(self)
    }

    fn apply_dense(&self, t: &Tensor) -> Vec<f64> {
        TensorSketch::apply_dense(self, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn cp_path_matches_dense_path() {
        // Eq. 3 == Eq. 2 on the materialized tensor.
        let mut rng = Rng::seed_from_u64(1);
        let cp = CpTensor::randn(&mut rng, &[6, 5, 4], 3);
        let mh = ModeHashes::draw_uniform(&mut rng, &[6, 5, 4], 8);
        let ts = TensorSketch::new(mh);
        let via_cp = ts.apply_cp(&cp);
        let via_dense = ts.apply_dense(&cp.to_dense());
        let via_per_rank = ts.apply_cp_per_rank(&cp);
        for (a, b) in via_cp.iter().zip(&via_dense) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        for (a, b) in via_cp.iter().zip(&via_per_rank) {
            assert!((a - b).abs() < 1e-9, "spectral {a} vs per-rank {b}");
        }
    }

    #[test]
    fn qcheck_spectral_cp_matches_reference_and_dense() {
        // Property over random shapes, ranks and (possibly odd, non-pow2) J:
        // one-IFFT spectral accumulation ≡ per-rank circular reference ≡
        // apply_dense on the materialized CP tensor.
        use crate::util::qcheck::qcheck;
        qcheck(10, |g| {
            let order = g.usize_in(2, 3);
            let shape = g.shape(order, 2, 5);
            let j = g.usize_in(2, 13);
            let rank = g.usize_in(1, 4);
            let cp = CpTensor::randn(g.rng(), &shape, rank);
            let mh = ModeHashes::draw_uniform(g.rng(), &shape, j);
            let ts = TensorSketch::new(mh);
            let spectral = ts.apply_cp(&cp);
            let per_rank = ts.apply_cp_per_rank(&cp);
            let dense = ts.apply_dense(&cp.to_dense());
            let scale = dense.iter().map(|v| v.abs()).fold(1.0, f64::max);
            for k in 0..j {
                assert!(
                    (spectral[k] - per_rank[k]).abs() < 1e-9 * scale,
                    "case {}: k={k}",
                    g.case
                );
                assert!(
                    (spectral[k] - dense[k]).abs() < 1e-8 * scale,
                    "case {}: k={k}",
                    g.case
                );
            }
        });
    }

    #[test]
    fn rank1_matches_dense() {
        let mut rng = Rng::seed_from_u64(2);
        let u = rng.normal_vec(7);
        let v = rng.normal_vec(5);
        let w = rng.normal_vec(6);
        let mh = ModeHashes::draw_uniform(&mut rng, &[7, 5, 6], 10);
        let ts = TensorSketch::new(mh);
        let fast = ts.apply_rank1(&[&u, &v, &w]);
        let dense = ts.apply_dense(&crate::tensor::outer(&[&u, &v, &w]));
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn inner_product_unbiased() {
        // E[⟨TS(M), TS(N)⟩] = ⟨M, N⟩
        let mut rng = Rng::seed_from_u64(3);
        let m = Tensor::randn(&mut rng, &[5, 5, 5]);
        let n = Tensor::randn(&mut rng, &[5, 5, 5]);
        let truth = m.inner(&n);
        let trials = 1500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &[5, 5, 5], 24);
            let ts = TensorSketch::new(mh);
            acc += crate::linalg::dot(&ts.apply_dense(&m), &ts.apply_dense(&n));
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - truth).abs() < 0.75,
            "mean={mean} truth={truth}"
        );
    }
}
