//! The paper's four sketching operators and the contraction estimators built
//! on them.
//!
//! | Operator | Definition | CP fast path | Output |
//! |---|---|---|---|
//! | [`cs::CountSketch`] | Def. 1 | — | `R^J` |
//! | [`ts::TensorSketch`] | Def. 2 | circular conv (Eq. 3) | `R^J` |
//! | [`hcs::HigherOrderCountSketch`] | Def. 3 | outer product (Eq. 5) | `R^{J_1×…×J_N}` |
//! | [`fcs::FastCountSketch`] | Def. 4 | **linear conv (Eq. 8)** | `R^{J̃}`, `J̃ = ΣJ_n−N+1` |
//!
//! TS and FCS share one frequency-domain pipeline,
//! [`common::SpectralSketchCore`] (circular vs linear parameterization), and
//! one estimator implementation, [`estimator::SpectralEstimator`].
//!
//! [`merge`] adds the distributed-scale layer on top: sharded, mergeable,
//! streaming sketches under a shared-seed hash protocol (CS linearity makes
//! per-shard sketches additive), which the coordinator exposes as a
//! `SketchShard`/`MergeShards` reduce front-end.

pub mod common;
pub mod cs;
pub mod estimator;
pub mod fcs;
pub mod hcs;
pub mod merge;
pub mod ts;

pub use common::{SpectralSketchCore, SpectralSketchOp};
pub use cs::CountSketch;
pub use merge::{group_rng, scatter_slab, tree_reduce_parts, ShardSketch};
pub use estimator::{
    build_equalized, elementwise_median, elementwise_median_flat, ContractionEstimator,
    CsEstimator, FcsEstimator, HcsEstimator, Method, PlainEstimator, SpectralEstimator,
    SpectralRep, TsEstimator,
};
pub use fcs::FastCountSketch;
pub use hcs::HigherOrderCountSketch;
pub use ts::TensorSketch;
