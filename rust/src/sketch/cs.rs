//! Count sketch (Definition 1, Charikar et al.): `CS(x)_j = Σ_{h(i)=j} s(i)·x(i)`.

use crate::hash::HashTable;
use crate::linalg::Matrix;

/// Count sketch operator for vectors, defined by a materialized `(h, s)`
/// table.
#[derive(Debug, Clone)]
pub struct CountSketch {
    pub table: HashTable,
}

impl CountSketch {
    pub fn new(table: HashTable) -> Self {
        Self { table }
    }

    #[inline]
    pub fn domain(&self) -> usize {
        self.table.domain()
    }

    #[inline]
    pub fn range(&self) -> usize {
        self.table.range
    }

    /// Apply to a dense vector — `O(nnz(x))`.
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.domain(), "CS domain mismatch");
        let mut out = vec![0.0; self.range()];
        self.apply_into(x, &mut out);
        out
    }

    /// Apply, accumulating into a caller-provided buffer (hot path: avoids
    /// re-allocation inside power iterations).
    ///
    /// Dense inputs take the scatter unconditionally — the old `xi != 0.0`
    /// skip-branch made the loop data-dependent (defeating vectorization and
    /// mispredicting on dense signals) to save an add of `±0.0`. Sparsity is
    /// [`Self::apply_sparse`]'s job.
    pub fn apply_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.range());
        out.fill(0.0);
        let h = &self.table.h;
        let s = &self.table.s;
        for (i, &xi) in x.iter().enumerate() {
            // s as i8 → f64 multiply compiles to a select; branch-free.
            out[h[i] as usize] += (s[i] as f64) * xi;
        }
    }

    /// Apply to a sparse vector given as (index, value) pairs.
    pub fn apply_sparse(&self, entries: &[(usize, f64)]) -> Vec<f64> {
        let mut out = vec![0.0; self.range()];
        self.apply_sparse_into(entries, &mut out);
        out
    }

    /// Buffer-reusing variant of [`Self::apply_sparse`]. Asserts every entry
    /// index is in-domain, matching the length assert of
    /// [`Self::apply`]/[`Self::apply_into`] — an out-of-range index would
    /// otherwise read a hash slot belonging to nothing.
    pub fn apply_sparse_into(&self, entries: &[(usize, f64)], out: &mut [f64]) {
        assert_eq!(out.len(), self.range());
        out.fill(0.0);
        let domain = self.domain();
        for &(i, v) in entries {
            assert!(i < domain, "CS domain mismatch: sparse index {i} ≥ {domain}");
            out[self.table.h(i)] += self.table.s(i) * v;
        }
    }

    /// Column-wise application to a matrix (`CS_n(U^{(n)})` in Eqs. 3/5/8).
    pub fn apply_matrix(&self, m: &Matrix) -> Matrix {
        assert_eq!(m.rows, self.domain());
        let mut out = Matrix::zeros(self.range(), m.cols);
        for r in 0..m.cols {
            let src = m.col(r);
            let dst = out.col_mut(r);
            for (i, &v) in src.iter().enumerate() {
                if v != 0.0 {
                    dst[self.table.h[i] as usize] += (self.table.s[i] as f64) * v;
                }
            }
        }
        out
    }

    /// Sketch of a standard basis vector `e_i`: `s(i)·e_{h(i)}` — returned as
    /// the (bucket, sign) pair to avoid materializing it (Eq. 17's
    /// `⟨z, CS_1(e_i)⟩ = s_1(i)·z(h_1(i))` trick).
    #[inline]
    pub fn basis(&self, i: usize) -> (usize, f64) {
        (self.table.h(i), self.table.s(i))
    }

    /// Unbiased single-entry decode: `x̂(i) = s(i)·CS(x)(h(i))`.
    #[inline]
    pub fn decode(&self, sketch: &[f64], i: usize) -> f64 {
        self.table.s(i) * sketch[self.table.h(i)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashPair;
    use crate::util::prng::Rng;
    use crate::util::timing::median;

    fn make(rng: &mut Rng, i: usize, j: usize) -> CountSketch {
        CountSketch::new(HashPair::draw(rng, i, j).materialize())
    }

    #[test]
    fn preserves_l2_in_expectation() {
        // E[‖CS(x)‖²] = ‖x‖²
        let mut rng = Rng::seed_from_u64(1);
        let x = rng.normal_vec(200);
        let x2: f64 = x.iter().map(|v| v * v).sum();
        let trials = 500;
        let mut acc = 0.0;
        for _ in 0..trials {
            let cs = make(&mut rng, 200, 64);
            let y = cs.apply(&x);
            acc += y.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - x2).abs() / x2 < 0.1, "mean={mean} x2={x2}");
    }

    #[test]
    fn inner_product_unbiased() {
        // E[⟨CS(x), CS(y)⟩] = ⟨x, y⟩
        let mut rng = Rng::seed_from_u64(2);
        let x = rng.normal_vec(100);
        let y = rng.normal_vec(100);
        let xy: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let trials = 2000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let cs = make(&mut rng, 100, 32);
            let sx = cs.apply(&x);
            let sy = cs.apply(&y);
            acc += crate::linalg::dot(&sx, &sy);
        }
        // Var per trial ≈ (‖x‖²‖y‖² + ⟨x,y⟩²)/J ≈ 320 ⇒ std of the mean over
        // 2000 trials ≈ 0.4; allow ~3σ.
        let mean = acc / trials as f64;
        assert!((mean - xy).abs() < 1.2, "mean={mean} true={xy}");
    }

    #[test]
    fn linear_operator() {
        let mut rng = Rng::seed_from_u64(3);
        let cs = make(&mut rng, 50, 16);
        let x = rng.normal_vec(50);
        let y = rng.normal_vec(50);
        let alpha = 2.5;
        let combo: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + alpha * b).collect();
        let lhs = cs.apply(&combo);
        let sx = cs.apply(&x);
        let sy = cs.apply(&y);
        for j in 0..16 {
            assert!((lhs[j] - (sx[j] + alpha * sy[j])).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_matches_dense() {
        let mut rng = Rng::seed_from_u64(4);
        let cs = make(&mut rng, 80, 20);
        let mut x = vec![0.0; 80];
        x[3] = 1.5;
        x[77] = -2.0;
        let dense = cs.apply(&x);
        let sparse = cs.apply_sparse(&[(3, 1.5), (77, -2.0)]);
        assert_eq!(dense, sparse);
    }

    #[test]
    fn sparse_into_reuses_buffer_and_matches() {
        let mut rng = Rng::seed_from_u64(14);
        let cs = make(&mut rng, 60, 12);
        let mut out = vec![7.0; 12]; // stale contents must be cleared
        cs.apply_sparse_into(&[(0, 2.0), (59, -1.0)], &mut out);
        let fresh = cs.apply_sparse(&[(0, 2.0), (59, -1.0)]);
        assert_eq!(out, fresh);
    }

    #[test]
    #[should_panic(expected = "CS domain mismatch")]
    fn sparse_rejects_out_of_domain_index() {
        let mut rng = Rng::seed_from_u64(15);
        let cs = make(&mut rng, 10, 4);
        let _ = cs.apply_sparse(&[(10, 1.0)]);
    }

    #[test]
    fn basis_matches_apply() {
        let mut rng = Rng::seed_from_u64(5);
        let cs = make(&mut rng, 30, 10);
        for i in 0..30 {
            let mut e = vec![0.0; 30];
            e[i] = 1.0;
            let full = cs.apply(&e);
            let (j, s) = cs.basis(i);
            assert_eq!(full[j], s);
            assert_eq!(full.iter().filter(|&&v| v != 0.0).count(), 1);
        }
    }

    #[test]
    fn matrix_apply_is_columnwise() {
        let mut rng = Rng::seed_from_u64(6);
        let cs = make(&mut rng, 40, 12);
        let m = Matrix::randn(&mut rng, 40, 3);
        let out = cs.apply_matrix(&m);
        for r in 0..3 {
            let col = cs.apply(m.col(r));
            assert_eq!(out.col(r), col.as_slice());
        }
    }

    #[test]
    fn median_decode_estimates_entries() {
        let mut rng = Rng::seed_from_u64(7);
        let mut x = vec![0.0; 64];
        x[5] = 10.0;
        x[20] = -4.0;
        x[40] = 1.0;
        let mut est5 = Vec::new();
        for _ in 0..21 {
            let cs = make(&mut rng, 64, 16);
            let sk = cs.apply(&x);
            est5.push(cs.decode(&sk, 5));
        }
        assert!((median(&est5) - 10.0).abs() < 2.0);
    }
}
