//! Sharded, mergeable, streaming sketches — the distributed-scale layer.
//!
//! Count sketch is **linear**: `CS(A + B) = CS(A) + CS(B)` whenever both
//! sides are sketched under the *same* hash draws (Wang et al. 2015, the
//! setting the ROADMAP's sharded-sketches item names). TS and FCS inherit
//! that linearity bucket-for-bucket, so a huge tensor can be partitioned
//! into contiguous `vec(T)` slabs, each slab sketched locally on its own
//! node, and the partial sketches added — the merged vector *is* the sketch
//! of the whole tensor. The same identity powers streaming: a rank-1 update
//! `T ← T + λ·v₁∘…∘v_N` is absorbed by sketching only the update through
//! the spectral rank-1 pipeline (never re-sketching `T`), which is what
//! incremental `deflate`/RTPM on tensors too big for one node rides.
//!
//! Shared-seed protocol: every shard of a merge group draws its
//! [`ModeHashes`] from [`group_rng`]`(seed, group)` — a deterministic
//! stream keyed by the *group*, not the request, so any worker sketching
//! any shard of the group reproduces identical tables. `group_rng` uses its
//! own mixing salt, disjoint from the coordinator's per-request
//! [`job_rng`](crate::coordinator::job_rng) stream: a group id can never
//! collide with a request id's draws.
//!
//! Bit-exactness contract (what `tests/merge_conformance.rs` pins): the
//! shard scatter [`scatter_slab`] visits entries in the same column-major
//! order as the whole-tensor walk [`sketch_dense_into`], restricted to the
//! slab. Merging reassociates IEEE additions, so *arbitrary real* data
//! agrees only to roundoff — but on integer-valued (exact-dyadic) data
//! every partial sum is exactly representable and any association yields
//! identical bits, making `f64::to_bits` equality a genuine test of the
//! hash draws, bucket indexing, and sign logic.

use super::common::{SpectralSketchCore, MAX_FFT_LANES};
use super::cs::CountSketch;
use crate::fft::{self, complex::ZERO, C64, FftWorkspace};
use crate::hash::{unravel_colmajor, ModeHashes};
use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// The deterministic per-merge-group RNG: shards of one group must consume
/// identical hash draws, so their RNG is keyed by `(seed, group)` — never
/// by the request id. Single home of that rule; the coordinator's
/// `SketchShard` arm and every conformance test derive through it. The salt
/// and multiplier differ from `job_rng`'s so the two draw streams are
/// disjoint even when `group == req_id`.
pub fn group_rng(seed: u64, group: u64) -> Rng {
    Rng::seed_from_u64(seed ^ 0xC0FF_EE00_5EED_F00D ^ group.wrapping_mul(0xA24B_AED4_963E_E407))
}

/// Additive scatter of one contiguous column-major slab of `vec(T)` into a
/// sketch accumulator. `slab` holds `vec(T)[offset .. offset + slab.len()]`;
/// `mh` is drawn for the **full** tensor dims (that is the shared-hash
/// requirement), and `out` is *accumulated into* — unlike
/// [`sketch_dense_into`](super::common::sketch_dense_into) it is not
/// zeroed, so successive slabs of one tensor sum to the whole-tensor
/// sketch. Entry order within the slab matches the whole-tensor walk.
///
/// * `modulo = Some(J)` → TS bucket `(Σ h_n) mod J` (`out.len() == J`).
/// * `modulo = None`   → FCS bucket `Σ h_n` (`out.len() == J̃`).
pub fn scatter_slab(
    slab: &[f64],
    offset: usize,
    mh: &ModeHashes,
    modulo: Option<usize>,
    out: &mut [f64],
) {
    // Failpoint: a Panic here poisons exactly one shard's scatter — the
    // per-job catch_unwind must confine it to that shard's merge group.
    crate::fault::act("shard_scatter");
    let total: usize = mh.dims.iter().product();
    assert!(
        offset + slab.len() <= total,
        "slab [{offset}, {}) exceeds vec(T) of {total} entries",
        offset + slab.len()
    );
    match modulo {
        Some(j) => {
            assert_eq!(out.len(), j);
            assert!(
                mh.modes.iter().all(|m| m.range == j),
                "TS requires uniform mode ranges"
            );
        }
        None => assert_eq!(out.len(), mh.composite_range()),
    }
    if slab.is_empty() {
        return;
    }
    let n = mh.dims.len();
    let i0 = mh.dims[0];
    let h0 = &mh.modes[0].h;
    let s0 = &mh.modes[0].s;
    // Multi-index of the slab's first entry; `i` is its position within the
    // (possibly partial) first mode-0 fiber.
    let mut idx = vec![0usize; n];
    unravel_colmajor(offset, &mh.dims, &mut idx);
    let mut i = idx[0];
    let idx_hi = &mut idx[1..];
    let mut l = 0usize;
    while l < slab.len() {
        // Contributions of the fixed higher modes (same fiber walk as the
        // whole-tensor scatter).
        let mut hbase = 0usize;
        let mut neg = 0usize;
        for (d, &ii) in idx_hi.iter().enumerate() {
            let m = &mh.modes[d + 1];
            hbase += m.h[ii] as usize;
            if m.s[ii] < 0 {
                neg += 1;
            }
        }
        let sbase = if neg & 1 == 0 { 1.0 } else { -1.0 };
        let run = (i0 - i).min(slab.len() - l);
        match modulo {
            Some(j) => {
                let hb = hbase % j;
                for ii in i..i + run {
                    let v = slab[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    let mut b = hb + h0[ii] as usize;
                    if b >= j {
                        b -= j; // hb, h0 < J ⇒ sum < 2J: one subtract replaces `%`
                    }
                    out[b] += sbase * (s0[ii] as f64) * v;
                }
            }
            None => {
                for ii in i..i + run {
                    let v = slab[l];
                    l += 1;
                    if v == 0.0 {
                        continue;
                    }
                    out[hbase + h0[ii] as usize] += sbase * (s0[ii] as f64) * v;
                }
            }
        }
        i = 0;
        for (d, ix) in idx_hi.iter_mut().enumerate() {
            *ix += 1;
            if *ix < mh.dims[d + 1] {
                break;
            }
            *ix = 0;
        }
    }
}

/// Pairwise tree reduce over raw shard sketch vectors (the coordinator's
/// `MergeShards` body). Returns the merged sketch and the tree depth
/// (`⌈log₂ k⌉`; 0 for a single part). All parts must share one length —
/// deliberately an **execution-time** assert rather than a submit-time
/// validation, mirroring the kernel-assert poison contract the stress suite
/// exercises: a malformed merge group costs exactly its own reply.
pub fn tree_reduce_parts(parts: &[Vec<f64>]) -> (Vec<f64>, usize) {
    assert!(!parts.is_empty(), "merge_shards: empty part list");
    let len = parts[0].len();
    assert!(
        parts.iter().all(|p| p.len() == len),
        "merge_shards: shard sketch lengths differ"
    );
    let mut layer = parts.to_vec();
    let mut depth = 0usize;
    while layer.len() > 1 {
        depth += 1;
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (x, y) in a.iter_mut().zip(&b) {
                    *x += y;
                }
            }
            next.push(a);
        }
        layer = next;
    }
    (layer.pop().unwrap(), depth)
}

/// One shard's mergeable sketch state: the shared-seed hash draw, the
/// per-mode count sketches (the [`SpectralSketchCore`] view for streaming
/// rank-1 absorbs), and the additive accumulator. `modulo = Some(J)` is the
/// TS (circular) parameterization, `None` the FCS (linear) one — the same
/// switch the dense service path uses.
#[derive(Debug, Clone)]
pub struct ShardSketch {
    hashes: ModeHashes,
    modes: Vec<CountSketch>,
    modulo: Option<usize>,
    sketch_len: usize,
    acc: Vec<f64>,
    updates: u64,
}

impl ShardSketch {
    pub fn new(hashes: ModeHashes, modulo: Option<usize>) -> Self {
        if let Some(j) = modulo {
            assert!(
                hashes.modes.iter().all(|m| m.range == j),
                "TS shards need uniform hash ranges"
            );
        }
        let sketch_len = modulo.unwrap_or_else(|| hashes.composite_range());
        let modes = hashes.modes.iter().map(|t| CountSketch::new(t.clone())).collect();
        let acc = vec![0.0; sketch_len];
        Self { hashes, modes, modulo, sketch_len, acc, updates: 0 }
    }

    /// Build a shard under the group's shared hash draw: any caller with
    /// the same `(seed, group, dims, j, circular)` gets identical tables,
    /// which is what makes its sketches mergeable with its siblings'.
    /// `circular = true` → TS, `false` → FCS.
    pub fn for_group(seed: u64, group: u64, dims: &[usize], j: usize, circular: bool) -> Self {
        let hashes = ModeHashes::draw_uniform(&mut group_rng(seed, group), dims, j);
        Self::new(hashes, circular.then_some(j))
    }

    pub fn dims(&self) -> &[usize] {
        &self.hashes.dims
    }

    pub fn sketch_len(&self) -> usize {
        self.sketch_len
    }

    /// `Some(J)` → TS circular buckets; `None` → FCS linear buckets.
    pub fn modulo(&self) -> Option<usize> {
        self.modulo
    }

    /// Absorbed updates (slabs, dense tensors, and rank-1 streams), summed
    /// across merges.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// The accumulated sketch.
    pub fn sketch(&self) -> &[f64] {
        &self.acc
    }

    pub fn into_sketch(self) -> Vec<f64> {
        self.acc
    }

    /// The spectral-pipeline view over this shard's hash draw.
    pub fn core(&self) -> SpectralSketchCore<'_> {
        match self.modulo {
            Some(j) => SpectralSketchCore::circular(&self.modes, j),
            None => SpectralSketchCore::linear(&self.modes, self.sketch_len),
        }
    }

    /// Absorb one contiguous column-major slab of `vec(T)` (additive).
    pub fn absorb_slab(&mut self, slab: &[f64], offset: usize) {
        scatter_slab(slab, offset, &self.hashes, self.modulo, &mut self.acc);
        self.updates += 1;
    }

    /// Absorb a whole dense tensor (shape must match the hash draw).
    pub fn absorb_dense(&mut self, t: &Tensor) {
        assert_eq!(t.shape, self.hashes.dims, "absorb_dense: shape mismatch");
        self.absorb_slab(&t.data, 0);
    }

    /// Streaming rank-1 absorb: `sketch ← sketch + λ·sketch(v₁∘…∘v_N)` via
    /// the core's spectral rank-1 pipeline — `O(Σ J_n + n log n)` per
    /// update, never touching the (possibly never-materialized) tensor.
    pub fn absorb_rank1(&mut self, lambda: f64, vs: &[&[f64]]) {
        let Self { modes, modulo, sketch_len, acc, updates, .. } = self;
        assert_eq!(vs.len(), modes.len(), "absorb_rank1: arity mismatch");
        let core = match modulo {
            Some(j) => SpectralSketchCore::circular(modes, *j),
            None => SpectralSketchCore::linear(modes, *sketch_len),
        };
        fft::with_thread_workspace(|ws| {
            let mut sk = ws.take_f64(*sketch_len);
            core.apply_rank1_into(vs, ws, &mut sk);
            crate::linalg::axpy(lambda, &sk[..*sketch_len], acc);
            ws.give_f64(sk);
        });
        *updates += 1;
    }

    /// Geometry compatibility for merging; hash-draw equality is a
    /// debug-only check (O(Σ I_n), and shards built through [`group_rng`]
    /// share draws by construction).
    fn assert_mergeable(&self, other: &ShardSketch) {
        assert_eq!(self.hashes.dims, other.hashes.dims, "merge: dims differ");
        assert_eq!(self.modulo, other.modulo, "merge: backend differs");
        assert_eq!(self.sketch_len, other.sketch_len, "merge: sketch lengths differ");
        debug_assert!(
            self.hashes
                .modes
                .iter()
                .zip(&other.hashes.modes)
                .all(|(a, b)| a.h == b.h && a.s == b.s),
            "merge: shards drawn under different hashes"
        );
    }

    /// Additive merge: fold this shard's sketch into `dst` (linearity of CS
    /// under shared draws). `dst` keeps its own hash tables — they are
    /// identical by the shared-seed protocol.
    pub fn merge_into(&self, dst: &mut ShardSketch) {
        dst.assert_mergeable(self);
        for (d, s) in dst.acc.iter_mut().zip(&self.acc) {
            *d += s;
        }
        dst.updates += self.updates;
    }

    /// Pairwise tree reduce over shard states; returns the merged shard and
    /// the merge depth (`⌈log₂ k⌉`).
    pub fn tree_merge(shards: Vec<ShardSketch>) -> (ShardSketch, usize) {
        assert!(!shards.is_empty(), "tree_merge: no shards");
        let mut layer = shards;
        let mut depth = 0usize;
        while layer.len() > 1 {
            depth += 1;
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            let mut it = layer.into_iter();
            while let Some(mut a) = it.next() {
                if let Some(b) = it.next() {
                    b.merge_into(&mut a);
                }
                next.push(a);
            }
            layer = next;
        }
        (layer.pop().unwrap(), depth)
    }

    /// Merge at the **spectrum** level: `F(Σ s_i) = Σ F(s_i)` by linearity
    /// of the transform, computed with one batched forward dispatch per
    /// ≤[`MAX_FFT_LANES`]-shard chunk riding the shards' `SpectralDriver`.
    /// This is the reduce shape a spectral consumer (an estimator's cached
    /// `F(st)`) wants: the merged spectrum lands directly, without an extra
    /// time-domain round trip.
    pub fn merged_spectrum(shards: &[ShardSketch], ws: &mut FftWorkspace) -> Vec<C64> {
        let first = shards.first().expect("merged_spectrum: no shards");
        for s in &shards[1..] {
            first.assert_mergeable(s);
        }
        let core = first.core();
        let n = core.fft_len;
        let groups = shards.len();
        let driver = core.driver(MAX_FFT_LANES.min(groups), false);
        // take_f64 rents zeroed — only each shard's sketch_len prefix needs
        // writing; the tail up to fft_len stays zero padding.
        let mut signals = ws.take_f64(groups * n);
        for (g, s) in shards.iter().enumerate() {
            signals[g * n..g * n + s.sketch_len].copy_from_slice(&s.acc);
        }
        let mut spec = vec![ZERO; n];
        driver.forward_each(&signals, groups, ws, |_, k, re, im| {
            let x = &mut spec[k];
            x.re += re;
            x.im += im;
        });
        ws.give_f64(signals);
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::common::sketch_dense;
    use crate::util::qcheck::qcheck;

    /// Integer-valued tensor: every bucket partial sum is exactly dyadic,
    /// so *any* association of the adds yields identical bits.
    fn integer_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        let data: Vec<f64> = (0..n).map(|_| rng.below(41) as f64 - 20.0).collect();
        Tensor::from_data(shape, data)
    }

    #[test]
    fn whole_slab_matches_sketch_dense_bitwise() {
        // One slab covering all of vec(T) replays the exact whole-tensor
        // walk — bitwise equal even on real-valued data.
        let mut rng = Rng::seed_from_u64(1);
        let shape = [5usize, 4, 6];
        let t = Tensor::randn(&mut rng, &shape);
        for circular in [true, false] {
            let mut sh = ShardSketch::for_group(7, 3, &shape, 8, circular);
            sh.absorb_slab(&t.data, 0);
            let whole = sketch_dense(&t, &sh.hashes, sh.modulo);
            assert_eq!(sh.sketch().len(), whole.len());
            for (a, b) in sh.sketch().iter().zip(&whole) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn uneven_slabs_merge_to_whole_bitwise_on_integer_data() {
        let mut rng = Rng::seed_from_u64(2);
        let shape = [4usize, 5, 3];
        let t = integer_tensor(&mut rng, &shape);
        for circular in [true, false] {
            // Uneven, fiber-misaligned cuts (7 and 23 are coprime to I₁=4).
            let cuts = [0usize, 7, 30, 53, t.data.len()];
            let shards: Vec<ShardSketch> = cuts
                .windows(2)
                .map(|w| {
                    let mut sh = ShardSketch::for_group(9, 1, &shape, 6, circular);
                    sh.absorb_slab(&t.data[w[0]..w[1]], w[0]);
                    sh
                })
                .collect();
            let (merged, depth) = ShardSketch::tree_merge(shards);
            assert_eq!(depth, 2); // 4 shards → ⌈log₂ 4⌉
            let whole = sketch_dense(&t, &merged.hashes, merged.modulo);
            for (a, b) in merged.sketch().iter().zip(&whole) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn tree_merge_depth_is_log2() {
        for (k, want) in [(1usize, 0usize), (2, 1), (3, 2), (5, 3), (8, 3)] {
            let shards: Vec<ShardSketch> =
                (0..k).map(|_| ShardSketch::for_group(1, 2, &[3, 3], 4, true)).collect();
            let (_, depth) = ShardSketch::tree_merge(shards);
            assert_eq!(depth, want, "k={k}");
        }
    }

    #[test]
    fn tree_reduce_parts_matches_shard_merge() {
        let parts = vec![vec![1.0, 2.0], vec![0.5, -1.0], vec![3.0, 0.25]];
        let (merged, depth) = tree_reduce_parts(&parts);
        assert_eq!(depth, 2);
        assert_eq!(merged, vec![4.5, 1.25]);
    }

    #[test]
    #[should_panic(expected = "shard sketch lengths differ")]
    fn tree_reduce_rejects_mixed_lengths() {
        tree_reduce_parts(&[vec![1.0, 2.0], vec![1.0]]);
    }

    #[test]
    fn absorb_rank1_matches_core_apply_rank1() {
        // Absorbing into a zero accumulator == λ · apply_rank1, bitwise
        // (axpy into zeros performs the same multiply the scaled reference
        // does, and the spectral pipeline is shared).
        let mut rng = Rng::seed_from_u64(3);
        let shape = [5usize, 6, 4];
        let vs: Vec<Vec<f64>> = shape.iter().map(|&d| rng.normal_vec(d)).collect();
        let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
        for circular in [true, false] {
            let mut sh = ShardSketch::for_group(11, 4, &shape, 9, circular);
            sh.absorb_rank1(0.75, &refs);
            let mut reference = Vec::new();
            fft::with_thread_workspace(|ws| {
                sh.core().apply_rank1_into(&refs, ws, &mut reference);
            });
            assert_eq!(sh.sketch().len(), reference.len());
            for (a, &b) in sh.sketch().iter().zip(&reference) {
                assert_eq!(a.to_bits(), (0.75 * b).to_bits());
            }
        }
    }

    #[test]
    fn streaming_rank1_matches_from_scratch_resketch() {
        // A stream of rank-1 absorbs lands within roundoff of sketching the
        // materialized updated tensor from scratch (linearity).
        let mut rng = Rng::seed_from_u64(4);
        let shape = [4usize, 5, 3];
        let base = Tensor::randn(&mut rng, &shape);
        let mut dense = base.clone();
        for circular in [true, false] {
            let mut sh = ShardSketch::for_group(13, 5, &shape, 7, circular);
            sh.absorb_dense(&base);
            for step in 0..3 {
                let vs: Vec<Vec<f64>> = shape.iter().map(|&d| rng.normal_vec(d)).collect();
                let refs: Vec<&[f64]> = vs.iter().map(|v| v.as_slice()).collect();
                let lambda = 0.5 + 0.25 * step as f64;
                sh.absorb_rank1(lambda, &refs);
                dense = dense.add(&crate::tensor::outer(&refs).scaled(lambda));
            }
            let scratch = sketch_dense(&dense, &sh.hashes, sh.modulo);
            let scale = scratch.iter().map(|v| v.abs()).fold(1.0, f64::max);
            for (a, b) in sh.sketch().iter().zip(&scratch) {
                assert!((a - b).abs() < 1e-9 * scale, "{a} vs {b}");
            }
            dense = base.clone();
        }
    }

    #[test]
    fn merged_spectrum_matches_spectrum_of_merge() {
        let mut rng = Rng::seed_from_u64(5);
        let shape = [4usize, 4, 4];
        let t = Tensor::randn(&mut rng, &shape);
        for circular in [true, false] {
            let cuts = [0usize, 20, 45, t.data.len()];
            let shards: Vec<ShardSketch> = cuts
                .windows(2)
                .map(|w| {
                    let mut sh = ShardSketch::for_group(17, 6, &shape, 8, circular);
                    sh.absorb_slab(&t.data[w[0]..w[1]], w[0]);
                    sh
                })
                .collect();
            let spec = fft::with_thread_workspace(|ws| ShardSketch::merged_spectrum(&shards, ws));
            let (merged, _) = ShardSketch::tree_merge(shards);
            let direct = merged.core().sketch_spectrum(merged.sketch());
            assert_eq!(spec.len(), direct.len());
            let scale = direct.iter().map(|c| c.re.abs().max(c.im.abs())).fold(1.0, f64::max);
            for (a, b) in spec.iter().zip(&direct) {
                assert!((a.re - b.re).abs() < 1e-9 * scale, "{} vs {}", a.re, b.re);
                assert!((a.im - b.im).abs() < 1e-9 * scale, "{} vs {}", a.im, b.im);
            }
        }
    }

    #[test]
    fn group_rng_is_deterministic_and_disjoint_from_job_rng() {
        assert_eq!(group_rng(7, 42).next_u64(), group_rng(7, 42).next_u64());
        assert_ne!(group_rng(7, 42).next_u64(), group_rng(7, 43).next_u64());
        assert_ne!(
            group_rng(7, 42).next_u64(),
            crate::coordinator::job_rng(7, 42).next_u64()
        );
    }

    #[test]
    fn qcheck_linearity_of_scaled_sums() {
        // CS(αA + βB) = α·CS(A) + β·CS(B) under shared draws — tolerance-
        // based: the two sides associate their IEEE adds differently.
        qcheck(12, |g| {
            let order = g.usize_in(2, 3);
            let shape = g.shape(order, 2, 5);
            let j = g.usize_in(2, 9);
            let circular = g.bool();
            let (alpha, beta) = (g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0));
            let a = Tensor::randn(g.rng(), &shape);
            let b = Tensor::randn(g.rng(), &shape);
            let combined = a.scaled(alpha).add(&b.scaled(beta));
            let mut lhs = ShardSketch::for_group(23, g.case as u64, &shape, j, circular);
            lhs.absorb_dense(&combined);
            let mut sa = ShardSketch::for_group(23, g.case as u64, &shape, j, circular);
            sa.absorb_dense(&a);
            let mut sb = ShardSketch::for_group(23, g.case as u64, &shape, j, circular);
            sb.absorb_dense(&b);
            let scale = lhs.sketch().iter().map(|v| v.abs()).fold(1.0, f64::max);
            for (k, l) in lhs.sketch().iter().enumerate() {
                let r = alpha * sa.sketch()[k] + beta * sb.sketch()[k];
                assert!((l - r).abs() < 1e-9 * scale, "case {}: k={k} {l} vs {r}", g.case);
            }
        });
    }

    #[test]
    fn qcheck_merge_is_associative_and_commutative() {
        // Merge order must not matter beyond IEEE reassociation: any
        // shuffle/tree of the same shard set lands within roundoff.
        qcheck(10, |g| {
            let order = g.usize_in(2, 3);
            let shape = g.shape(order, 2, 5);
            let j = g.usize_in(2, 9);
            let circular = g.bool();
            let t = Tensor::randn(g.rng(), &shape);
            let total: usize = shape.iter().product();
            let k = g.usize_in(2, 5).min(total);
            // Random uneven cut points.
            let mut cuts: Vec<usize> = (0..k - 1).map(|_| g.usize_in(0, total)).collect();
            cuts.push(0);
            cuts.push(total);
            cuts.sort_unstable();
            let build = |w: &[usize]| {
                let mut sh = ShardSketch::for_group(29, g.case as u64, &shape, j, circular);
                sh.absorb_slab(&t.data[w[0]..w[1]], w[0]);
                sh
            };
            let shards: Vec<ShardSketch> = cuts.windows(2).map(build).collect();
            let mut reversed: Vec<ShardSketch> = cuts.windows(2).map(build).collect();
            reversed.reverse();
            let (fwd, _) = ShardSketch::tree_merge(shards);
            let (rev, _) = ShardSketch::tree_merge(reversed);
            // Left fold as a third association.
            let mut fold = ShardSketch::for_group(29, g.case as u64, &shape, j, circular);
            for w in cuts.windows(2) {
                build(w).merge_into(&mut fold);
            }
            let scale = fwd.sketch().iter().map(|v| v.abs()).fold(1.0, f64::max);
            for i in 0..fwd.sketch().len() {
                let (a, b, c) = (fwd.sketch()[i], rev.sketch()[i], fold.sketch()[i]);
                assert!((a - b).abs() < 1e-12 * scale, "case {}: comm {a} vs {b}", g.case);
                assert!((a - c).abs() < 1e-12 * scale, "case {}: assoc {a} vs {c}", g.case);
            }
        });
    }
}
