//! Experiment metrics shared across benches and examples.

use crate::tensor::{CpTensor, Tensor};

/// The paper's "residual norm" for synthetic CPD experiments:
/// `‖T − T̂‖_F` against the **noisy input** tensor. Identified from
/// Table 3's plain-ALS rows (0.1000 at σ=0.01, 0.3162 at σ=0.1 — exactly
/// `√σ`, the injected noise norm; see `data::synthetic_cp`).
pub fn residual_norm(recovered: &CpTensor, input: &Tensor) -> f64 {
    recovered.to_dense().sub(input).frob_norm()
}

/// Relative Frobenius error.
pub fn rel_error(approx: &Tensor, truth: &Tensor) -> f64 {
    approx.sub(truth).frob_norm() / truth.frob_norm()
}

/// Factor-recovery score: mean over true components of the best |cosine|
/// alignment achieved by any recovered component (1.0 = perfect recovery).
pub fn alignment_score(recovered: &CpTensor, truth: &CpTensor, mode: usize) -> f64 {
    let rf = &recovered.factors[mode];
    let tf = &truth.factors[mode];
    let mut acc = 0.0;
    for s in 0..tf.cols {
        let mut best: f64 = 0.0;
        for r in 0..rf.cols {
            let num = crate::linalg::dot(rf.col(r), tf.col(s)).abs();
            let den = crate::linalg::norm2(rf.col(r)) * crate::linalg::norm2(tf.col(s));
            if den > 0.0 {
                best = best.max(num / den);
            }
        }
        acc += best;
    }
    acc / tf.cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn residual_zero_on_exact() {
        let mut rng = Rng::seed_from_u64(1);
        let cp = CpTensor::randn(&mut rng, &[4, 4, 4], 2);
        assert!(residual_norm(&cp, &cp.to_dense()) < 1e-12);
    }

    #[test]
    fn alignment_perfect_on_self() {
        let mut rng = Rng::seed_from_u64(2);
        let cp = CpTensor::random_orthogonal(&mut rng, &[6, 6, 6], 3);
        assert!((alignment_score(&cp, &cp, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alignment_low_on_random() {
        let mut rng = Rng::seed_from_u64(3);
        let a = CpTensor::random_orthogonal(&mut rng, &[40, 40, 40], 3);
        let b = CpTensor::random_orthogonal(&mut rng, &[40, 40, 40], 3);
        assert!(alignment_score(&a, &b, 0) < 0.6);
    }
}
