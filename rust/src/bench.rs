//! Measurement core for the `rust/benches/*` harnesses (criterion is not
//! available offline). Provides warmup + repeated timing with robust stats,
//! paper-style table printing, and JSON result dumps under `results/`.

use crate::util::json::Json;
use crate::util::timing::{Stopwatch, Summary};
use std::path::PathBuf;

/// Time `f` with `warmup` discarded runs and `reps` measured runs.
pub fn measure<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let sw = Stopwatch::start();
        std::hint::black_box(f());
        samples.push(sw.elapsed_secs());
    }
    Summary::of(&samples)
}

/// Quick-mode check: set `FCS_BENCH_QUICK=1` to shrink sweeps.
pub fn quick_mode() -> bool {
    std::env::var("FCS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// A paper-style results table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{c:>w$} | ", w = w));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Accumulates result rows and writes them to `results/<name>.json`.
pub struct ResultSink {
    name: String,
    rows: Vec<Json>,
}

impl ResultSink {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// Build a row from key/value pairs.
    pub fn record(&mut self, pairs: &[(&str, Json)]) {
        let mut obj = Json::obj();
        for (k, v) in pairs {
            obj.set(k, v.clone());
        }
        self.rows.push(obj);
    }

    pub fn results_dir() -> PathBuf {
        let dir = crate::runtime::find_artifacts_dir()
            .map(|a| a.parent().unwrap().join("results"))
            .unwrap_or_else(|| PathBuf::from("results"));
        let _ = std::fs::create_dir_all(&dir);
        dir
    }

    /// Write and report the path.
    pub fn flush(&self) {
        let path = Self::results_dir().join(format!("{}.json", self.name));
        let json = Json::Arr(self.rows.clone());
        if let Err(e) = std::fs::write(&path, json.to_string()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("[results] wrote {}", path.display());
        }
    }
}

/// Format seconds for tables.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sane_summary() {
        let s = measure(1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(s.n, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-7).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
