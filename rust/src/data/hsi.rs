//! Procedural hyperspectral cube — the substitution for CAVE *Watercolors*
//! (512×512×31, Fig. 2). See DESIGN.md §5 for the substitution argument.
//!
//! Construction: `rank_signal` spatial abundance maps (smooth 2-D Gaussian
//! blobs) each paired with a smooth spectral signature across the band axis,
//! plus band-correlated sensor noise — approximately low CP rank with a
//! realistic spatial/spectral structure, normalized to [0, 1] grayscale.

use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// Generate a `height × width × bands` hyperspectral-like cube.
pub fn hsi_cube(
    rng: &mut Rng,
    height: usize,
    width: usize,
    bands: usize,
    rank_signal: usize,
    noise_sigma: f64,
) -> Tensor {
    // Spatial abundance maps: mixtures of anisotropic Gaussian blobs.
    let mut maps: Vec<Vec<f64>> = Vec::with_capacity(rank_signal);
    for _ in 0..rank_signal {
        let mut map = vec![0.0f64; height * width];
        let blobs = 2 + rng.below(4) as usize;
        for _ in 0..blobs {
            let cy = rng.uniform_in(0.1, 0.9) * height as f64;
            let cx = rng.uniform_in(0.1, 0.9) * width as f64;
            let sy = rng.uniform_in(0.05, 0.25) * height as f64;
            let sx = rng.uniform_in(0.05, 0.25) * width as f64;
            let amp = rng.uniform_in(0.3, 1.0);
            for y in 0..height {
                let dy = (y as f64 - cy) / sy;
                let ey = (-0.5 * dy * dy).exp();
                if ey < 1e-6 {
                    continue;
                }
                for x in 0..width {
                    let dx = (x as f64 - cx) / sx;
                    map[y * width + x] += amp * ey * (-0.5 * dx * dx).exp();
                }
            }
        }
        maps.push(map);
    }
    // Spectral signatures: smooth bumps over the band axis (400–700 nm-ish).
    let mut sigs: Vec<Vec<f64>> = Vec::with_capacity(rank_signal);
    for _ in 0..rank_signal {
        let center = rng.uniform_in(0.0, 1.0) * bands as f64;
        let widthb = rng.uniform_in(0.15, 0.5) * bands as f64;
        let tilt = rng.uniform_in(-0.3, 0.3);
        let sig: Vec<f64> = (0..bands)
            .map(|b| {
                let d = (b as f64 - center) / widthb;
                ((-0.5 * d * d).exp() + tilt * b as f64 / bands as f64).max(0.0)
            })
            .collect();
        sigs.push(sig);
    }
    // Assemble cube (column-major [h, w, band]) + noise, normalize to [0,1].
    let mut t = Tensor::zeros(&[height, width, bands]);
    for b in 0..bands {
        for x in 0..width {
            for y in 0..height {
                let mut v = 0.0;
                for r in 0..rank_signal {
                    v += maps[r][y * width + x] * sigs[r][b];
                }
                t.data[(b * width + x) * height + y] = v;
            }
        }
    }
    if noise_sigma > 0.0 {
        // Band-correlated noise: per-band gain drift + iid read noise.
        for b in 0..bands {
            let gain = 1.0 + noise_sigma * rng.normal();
            for x in 0..width {
                for y in 0..height {
                    let idx = (b * width + x) * height + y;
                    t.data[idx] = t.data[idx] * gain + noise_sigma * rng.normal();
                }
            }
        }
    }
    normalize01(&mut t);
    t
}

/// Scale data into [0, 1].
pub(crate) fn normalize01(t: &mut Tensor) {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in &t.data {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-12);
    for v in t.data.iter_mut() {
        *v = (*v - lo) / span;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube_shape_and_range() {
        let mut rng = Rng::seed_from_u64(1);
        let t = hsi_cube(&mut rng, 32, 32, 8, 5, 0.01);
        assert_eq!(t.shape, vec![32, 32, 8]);
        assert!(t.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(t.frob_norm() > 0.0);
    }

    #[test]
    fn cube_is_approximately_low_rank() {
        // rank_signal=4 cube: a rank-8 CP fit should capture most energy.
        let mut rng = Rng::seed_from_u64(2);
        let t = hsi_cube(&mut rng, 24, 24, 8, 4, 0.005);
        let cfg = crate::cpd::AlsConfig { rank: 8, n_iter: 25, seed: 3 };
        let cp = crate::cpd::als_plain(&t, &cfg);
        let res = cp.residual(&t);
        assert!(res < 0.2, "relative residual {res}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = hsi_cube(&mut Rng::seed_from_u64(7), 16, 16, 4, 3, 0.01);
        let b = hsi_cube(&mut Rng::seed_from_u64(7), 16, 16, 4, 3, 0.01);
        assert_eq!(a, b);
    }
}
