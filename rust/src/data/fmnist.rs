//! Procedural FMNIST-like dataset — the substitution for Fashion-MNIST
//! (Table 4). Ten parametric 28×28 grayscale shape classes with random
//! translation / scale / intensity jitter and pixel noise: enough learnable
//! structure to rank the CS/TS/FCS-sketched TRL heads, with no external
//! download (DESIGN.md §5).

use crate::util::prng::Rng;

pub const FMNIST_CLASSES: usize = 10;
pub const IMG: usize = 28;

/// A generated dataset: row-major images (`[n, 28, 28]` flattened) + labels.
pub struct FmnistLike {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
}

impl FmnistLike {
    pub fn generate(rng: &mut Rng, n: usize) -> Self {
        let mut images = vec![0.0f32; n * IMG * IMG];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = (i % FMNIST_CLASSES) as i32;
            labels.push(class);
            let img = &mut images[i * IMG * IMG..(i + 1) * IMG * IMG];
            draw_class(rng, class as usize, img);
        }
        // Shuffle jointly.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut s_images = vec![0.0f32; n * IMG * IMG];
        let mut s_labels = vec![0i32; n];
        for (dst, &src) in order.iter().enumerate() {
            s_images[dst * IMG * IMG..(dst + 1) * IMG * IMG]
                .copy_from_slice(&images[src * IMG * IMG..(src + 1) * IMG * IMG]);
            s_labels[dst] = labels[src];
        }
        Self { images: s_images, labels: s_labels, n }
    }

    /// Borrow image `i` as a row-major 28×28 slice.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG * IMG..(i + 1) * IMG * IMG]
    }

    /// Copy a batch `[b, 28, 28, 1]` (row-major, XLA layout) + labels.
    pub fn batch(&self, start: usize, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(b * IMG * IMG);
        let mut y = Vec::with_capacity(b);
        for k in 0..b {
            let i = (start + k) % self.n;
            x.extend_from_slice(self.image(i));
            y.push(self.labels[i]);
        }
        (x, y)
    }
}

/// Render one jittered instance of a class into `img` (28×28 row-major).
fn draw_class(rng: &mut Rng, class: usize, img: &mut [f32]) {
    // Jitter/noise chosen so a full-capacity head plateaus well below 1.0 —
    // otherwise every sketched variant saturates and Table 4 cannot rank
    // them (Fashion-MNIST's ~0.9 ceiling plays the same role in the paper).
    let cx = 14.0 + rng.uniform_in(-4.0, 4.0);
    let cy = 14.0 + rng.uniform_in(-4.0, 4.0);
    let scale = rng.uniform_in(0.7, 1.3);
    let fg = rng.uniform_in(0.5, 1.0) as f32;
    let inside = |x: f64, y: f64| -> bool {
        // normalized body coordinates relative to jittered center/scale
        let u = (x - cx) / (10.0 * scale);
        let v = (y - cy) / (10.0 * scale);
        match class {
            0 => u.abs() < 0.9 && v.abs() < 0.6,                                  // wide block
            1 => u.abs() < 0.45 && v.abs() < 0.95,                                // tall block
            2 => u * u + v * v < 0.8,                                             // disc
            3 => {
                let r2 = u * u + v * v;
                (0.35..0.85).contains(&r2)                                        // ring
            }
            4 => v > -0.8 && v < 0.8 && u.abs() < (v + 0.8) * 0.55,               // triangle
            5 => (u.abs() < 0.25 && v.abs() < 0.9) || (v.abs() < 0.25 && u.abs() < 0.9), // cross
            6 => (u + 0.45).abs() < 0.2 && v.abs() < 0.9
                || (u - 0.45).abs() < 0.2 && v.abs() < 0.9,                       // trousers
            7 => (u.abs() < 0.3 && v < 0.1 && v > -0.95) || (v.abs() < 0.3 && u > -0.1 && u < 0.95), // L-shape
            8 => (u - v).abs() < 0.3 && u.abs() < 0.95 && v.abs() < 0.95,         // diagonal
            _ => ((u * 3.0).floor() as i64 + (v * 3.0).floor() as i64) % 2 == 0
                && u.abs() < 0.9
                && v.abs() < 0.9,                                                 // checker
        }
    };
    for y in 0..IMG {
        for x in 0..IMG {
            let mut v = if inside(x as f64, y as f64) { fg } else { 0.0 };
            v += 0.25 * rng.normal() as f32; // heavy sensor noise
            img[y * IMG + x] = v.clamp(0.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_labels() {
        let mut rng = Rng::seed_from_u64(1);
        let ds = FmnistLike::generate(&mut rng, 200);
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn images_in_range() {
        let mut rng = Rng::seed_from_u64(2);
        let ds = FmnistLike::generate(&mut rng, 50);
        assert!(ds.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean intra-class L2 distance should be well below inter-class.
        let mut rng = Rng::seed_from_u64(3);
        let ds = FmnistLike::generate(&mut rng, 400);
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); 10];
        for i in 0..ds.n {
            by_class[ds.labels[i] as usize].push(i);
        }
        let dist = |a: usize, b: usize| -> f64 {
            ds.image(a)
                .iter()
                .zip(ds.image(b))
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n_intra = 0;
        let mut n_inter = 0;
        for c in 0..10 {
            for k in 1..by_class[c].len().min(6) {
                intra += dist(by_class[c][0], by_class[c][k]);
                n_intra += 1;
            }
            let c2 = (c + 1) % 10;
            inter += dist(by_class[c][0], by_class[c2][0]);
            n_inter += 1;
        }
        // Heavy jitter/noise (deliberate — see draw_class) makes raw pixel
        // distance noise-dominated; classes need only be separable on
        // average (the TRN pipeline test is the real learnability check:
        // ~0.6–0.8 accuracy vs 0.1 chance).
        let (intra, inter) = (intra / n_intra as f64, inter / n_inter as f64);
        assert!(inter > 1.02 * intra, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn batch_wraps_around() {
        let mut rng = Rng::seed_from_u64(4);
        let ds = FmnistLike::generate(&mut rng, 10);
        let (x, y) = ds.batch(8, 4);
        assert_eq!(x.len(), 4 * 784);
        assert_eq!(y.len(), 4);
        assert_eq!(y[2], ds.labels[0]); // wrapped
    }
}
