//! Procedural light field — the substitution for HCI *Buddha*
//! (192×192×81 after preprocessing, Fig. 3). See DESIGN.md §5.
//!
//! Construction: a smooth base texture plus a few depth layers, each shifted
//! per view by its disparity across a 9×9 camera grid — the 81 views are
//! near-duplicates, giving the strongly low-rank view axis the experiment
//! exploits.

use super::hsi::normalize01;
use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// Generate a `height × width × (grid²)` light-field tensor.
pub fn lightfield_cube(
    rng: &mut Rng,
    height: usize,
    width: usize,
    grid: usize,
    layers: usize,
    noise_sigma: f64,
) -> Tensor {
    let views = grid * grid;
    // Base texture: sum of random smooth sinusoids.
    let waves: Vec<(f64, f64, f64, f64)> = (0..8)
        .map(|_| {
            (
                rng.uniform_in(0.5, 4.0),  // fy
                rng.uniform_in(0.5, 4.0),  // fx
                rng.uniform_in(0.0, std::f64::consts::TAU), // phase
                rng.uniform_in(0.3, 1.0),  // amplitude
            )
        })
        .collect();
    let texture = |y: f64, x: f64| -> f64 {
        waves
            .iter()
            .map(|&(fy, fx, p, a)| {
                a * (fy * y * std::f64::consts::TAU / height as f64
                    + fx * x * std::f64::consts::TAU / width as f64
                    + p)
                    .sin()
            })
            .sum()
    };
    // Depth layers: circular blobs at random depths (disparities).
    struct Layer {
        cy: f64,
        cx: f64,
        radius: f64,
        disparity: f64,
        value: f64,
    }
    let layer_objs: Vec<Layer> = (0..layers)
        .map(|_| Layer {
            cy: rng.uniform_in(0.2, 0.8) * height as f64,
            cx: rng.uniform_in(0.2, 0.8) * width as f64,
            radius: rng.uniform_in(0.08, 0.25) * height.min(width) as f64,
            disparity: rng.uniform_in(-2.0, 2.0),
            value: rng.uniform_in(0.5, 2.0),
        })
        .collect();

    let mut t = Tensor::zeros(&[height, width, views]);
    for v in 0..views {
        let (gy, gx) = ((v / grid) as f64, (v % grid) as f64);
        let (oy, ox) = (gy - (grid as f64 - 1.0) / 2.0, gx - (grid as f64 - 1.0) / 2.0);
        for x in 0..width {
            for y in 0..height {
                // background texture shifts with a small global disparity
                let mut val = texture(y as f64 + 0.3 * oy, x as f64 + 0.3 * ox);
                for l in &layer_objs {
                    let dy = y as f64 - (l.cy + l.disparity * oy);
                    let dx = x as f64 - (l.cx + l.disparity * ox);
                    if dy * dy + dx * dx < l.radius * l.radius {
                        val += l.value;
                    }
                }
                t.data[(v * width + x) * height + y] = val;
            }
        }
    }
    if noise_sigma > 0.0 {
        t.add_noise(rng, noise_sigma);
    }
    normalize01(&mut t);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_range() {
        let mut rng = Rng::seed_from_u64(1);
        let t = lightfield_cube(&mut rng, 24, 24, 3, 3, 0.005);
        assert_eq!(t.shape, vec![24, 24, 9]);
        assert!(t.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn views_are_strongly_correlated() {
        // Adjacent views must be near-duplicates (high correlation).
        let mut rng = Rng::seed_from_u64(2);
        let t = lightfield_cube(&mut rng, 32, 32, 3, 3, 0.0);
        let view = |v: usize| -> Vec<f64> {
            let mut out = Vec::with_capacity(32 * 32);
            for x in 0..32 {
                for y in 0..32 {
                    out.push(t.data[(v * 32 + x) * 32 + y]);
                }
            }
            out
        };
        let (a, b) = (view(0), view(1));
        let corr = {
            let ma = a.iter().sum::<f64>() / a.len() as f64;
            let mb = b.iter().sum::<f64>() / b.len() as f64;
            let mut num = 0.0;
            let mut da = 0.0;
            let mut db = 0.0;
            for (x, y) in a.iter().zip(&b) {
                num += (x - ma) * (y - mb);
                da += (x - ma) * (x - ma);
                db += (y - mb) * (y - mb);
            }
            num / (da * db).sqrt()
        };
        assert!(corr > 0.9, "adjacent view correlation {corr}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = lightfield_cube(&mut Rng::seed_from_u64(9), 16, 16, 3, 2, 0.01);
        let b = lightfield_cube(&mut Rng::seed_from_u64(9), 16, 16, 3, 2, 0.01);
        assert_eq!(a, b);
    }
}
