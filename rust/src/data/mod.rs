//! Synthetic dataset generators — the documented substitutions for the
//! paper's external datasets (DESIGN.md §5).

pub mod fmnist;
pub mod hsi;
pub mod lightfield;

pub use fmnist::{FmnistLike, FMNIST_CLASSES};
pub use hsi::hsi_cube;
pub use lightfield::lightfield_cube;

use crate::tensor::{CpTensor, Tensor};
use crate::util::prng::Rng;

/// The paper's synthetic CPD setup (§4.1): a CP rank-R tensor with random
/// orthonormal factors (symmetric or not), perturbed by a Gaussian noise
/// tensor **normalized to total Frobenius norm √σ**.
///
/// The normalization is identified from the paper's own numbers: plain ALS
/// in Table 3 reports residuals of exactly 0.1000 (σ = 0.01) and 0.3162
/// (σ = 0.1) — i.e. `‖noise‖_F = √σ` — since a rank-10 fit recovers the
/// clean signal and leaves precisely the noise. Per-entry std σ would give
/// `‖noise‖_F = σ·I^{3/2}` (= 80 at 400³!), contradicting every reported
/// residual.
pub fn synthetic_cp(
    rng: &mut Rng,
    shape: &[usize],
    rank: usize,
    sigma: f64,
    symmetric: bool,
) -> (Tensor, CpTensor) {
    let cp = if symmetric {
        assert!(shape.iter().all(|&d| d == shape[0]));
        CpTensor::random_orthogonal_symmetric(rng, shape[0], rank, shape.len())
    } else {
        CpTensor::random_orthogonal(rng, shape, rank)
    };
    let mut t = cp.to_dense();
    if sigma > 0.0 {
        let mut noise = Tensor::randn(rng, shape);
        let scale = sigma.sqrt() / noise.frob_norm();
        for (dst, n) in t.data.iter_mut().zip(&noise.data) {
            *dst += n * scale;
        }
        noise.data.clear();
    }
    (t, cp)
}

/// Peak signal-to-noise ratio in dB between a reconstruction and reference,
/// matching the paper's Figs. 2–3 metric. `peak` is the reference dynamic
/// range (max value; 1.0 for normalized images).
pub fn psnr(approx: &Tensor, reference: &Tensor, peak: f64) -> f64 {
    assert_eq!(approx.shape, reference.shape);
    let mse = approx
        .data
        .iter()
        .zip(&reference.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / approx.numel() as f64;
    if mse == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (peak * peak / mse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_cp_symmetric_shape_and_noise() {
        let mut rng = Rng::seed_from_u64(1);
        let (t, cp) = synthetic_cp(&mut rng, &[20, 20, 20], 5, 0.01, true);
        assert_eq!(t.shape, vec![20, 20, 20]);
        assert_eq!(cp.rank(), 5);
        let clean = cp.to_dense();
        // ‖noise‖_F = √σ exactly (the Table-3 plain-ALS identity).
        let noise = t.sub(&clean).frob_norm();
        assert!((noise - 0.1).abs() < 1e-12, "noise norm {noise}");
    }

    #[test]
    fn synthetic_cp_asymmetric() {
        let mut rng = Rng::seed_from_u64(2);
        let (t, cp) = synthetic_cp(&mut rng, &[10, 12, 14], 3, 0.0, false);
        assert_eq!(t.shape, vec![10, 12, 14]);
        assert!(cp.residual(&t) < 1e-12);
    }

    #[test]
    fn psnr_identical_is_infinite() {
        let mut rng = Rng::seed_from_u64(3);
        let t = Tensor::randn(&mut rng, &[5, 5]);
        assert!(psnr(&t, &t, 1.0).is_infinite());
    }

    #[test]
    fn psnr_known_value() {
        // MSE = 0.01, peak 1 → PSNR = 20 dB
        let a = Tensor::from_data(&[4], vec![0.1, 0.1, 0.1, 0.1]);
        let b = Tensor::from_data(&[4], vec![0.0, 0.0, 0.0, 0.0]);
        assert!((psnr(&a, &b, 1.0) - 20.0).abs() < 1e-9);
    }
}
