//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the Rust hot path. Python is never on the request path — `make artifacts`
//! runs once, this module serves forever after.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`), following /opt/xla-example/load_hlo.

pub mod artifact;
pub mod engine;
pub mod exec;

pub use artifact::{ArtifactStore, Manifest};
pub use engine::{spawn_runtime, RuntimeHandle};
pub use exec::{Executable, TensorArg, TensorOut};

/// CPU PJRT client. `xla::PjRtClient` is `Rc`-based (neither `Send` nor
/// `Sync`), so each client is confined to the thread that created it; for
/// cross-thread use go through [`engine::RuntimeHandle`].
pub fn cpu_client() -> anyhow::Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}

/// Locate the `artifacts/` directory: `$FCS_ARTIFACTS_DIR`, else walk up
/// from the current dir / executable looking for `artifacts/manifest.json`.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("FCS_ARTIFACTS_DIR") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut candidates = Vec::new();
    if let Ok(cwd) = std::env::current_dir() {
        candidates.push(cwd);
    }
    if let Ok(exe) = std::env::current_exe() {
        if let Some(dir) = exe.parent() {
            candidates.push(dir.to_path_buf());
        }
    }
    for base in candidates {
        let mut cur = Some(base.as_path());
        while let Some(dir) = cur {
            let p = dir.join("artifacts");
            if p.join("manifest.json").exists() {
                return Some(p);
            }
            cur = dir.parent();
        }
    }
    None
}
