//! Typed execution wrapper over `xla::PjRtLoadedExecutable`.
//!
//! Artifacts are lowered with `return_tuple=True`, so every execution yields
//! one tuple literal; `run` decomposes it into per-output `f32` vectors.

use super::artifact::ArtifactEntry;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A runtime argument: either f32 data or i32 data (hash tables, labels)
/// plus its shape.
#[derive(Debug, Clone)]
pub enum TensorArg {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl TensorArg {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorArg::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorArg::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        TensorArg::F32 { shape: vec![], data: vec![v] }
    }

    /// From f64 slice (the library's native dtype) with down-conversion.
    pub fn f32_from_f64(shape: &[usize], data: &[f64]) -> Self {
        Self::f32(shape, data.iter().map(|&x| x as f32).collect())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            TensorArg::F32 { shape, .. } | TensorArg::I32 { shape, .. } => shape,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            TensorArg::F32 { shape, data } => {
                let l = xla::Literal::vec1(data);
                if shape.is_empty() {
                    // scalar: reshape to rank-0
                    l.reshape(&[])?
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
            TensorArg::I32 { shape, data } => {
                let l = xla::Literal::vec1(data);
                if shape.is_empty() {
                    l.reshape(&[])?
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }
}

/// One output tensor (always f32 in our artifacts).
#[derive(Debug, Clone)]
pub struct TensorOut {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub entry: ArtifactEntry,
}

impl Executable {
    /// Load an HLO-text artifact and compile it on the given client.
    pub fn from_hlo_text_file(
        client: &xla::PjRtClient,
        path: &Path,
        entry: ArtifactEntry,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self { exe, entry })
    }

    /// Execute with typed args; returns the decomposed tuple outputs.
    pub fn run(&self, args: &[TensorArg]) -> Result<Vec<TensorOut>> {
        if !self.entry.inputs.is_empty() && self.entry.inputs.len() != args.len() {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            ));
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        let mut tensors = Vec::with_capacity(outs.len());
        for o in outs {
            let shape = o.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            // convert non-f32 outputs (e.g. f64 losses) to f32 first
            let o32 = o.convert(xla::PrimitiveType::F32)?;
            tensors.push(TensorOut { shape: dims, data: o32.to_vec::<f32>()? });
        }
        Ok(tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_arg_shape_check() {
        let a = TensorArg::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(a.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn tensor_arg_shape_mismatch_panics() {
        TensorArg::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn f64_conversion() {
        let a = TensorArg::f32_from_f64(&[2], &[1.5, -2.5]);
        match a {
            TensorArg::F32 { data, .. } => assert_eq!(data, vec![1.5f32, -2.5]),
            _ => panic!(),
        }
    }
}
