//! Artifact manifest + compiled-executable cache.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! HLO-text artifact (input shapes/dtypes + metadata). The store parses it,
//! compiles artifacts on first use, and caches the loaded executables.

use super::exec::Executable;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// (shape, dtype-string) per input.
    pub inputs: Vec<(Vec<usize>, String)>,
    /// Free-form metadata (method, cr, j, batch, …).
    pub meta: HashMap<String, Json>,
}

impl ArtifactEntry {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|j| j.as_f64())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|j| j.as_str())
    }
}

/// Parsed manifest.json.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: HashMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let Json::Obj(map) = root else {
            return Err(anyhow!("manifest root must be an object"));
        };
        let mut entries = HashMap::new();
        for (name, entry) in map {
            let file = entry
                .get("file")
                .and_then(|j| j.as_str())
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            let mut inputs = Vec::new();
            if let Some(arr) = entry.get("inputs").and_then(|j| j.as_arr()) {
                for spec in arr {
                    let shape: Vec<usize> = spec
                        .get("shape")
                        .and_then(|j| j.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default();
                    let dtype = spec
                        .get("dtype")
                        .and_then(|j| j.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    inputs.push((shape, dtype));
                }
            }
            let mut meta = HashMap::new();
            if let Some(Json::Obj(m)) = entry.get("meta") {
                for (k, v) in m {
                    meta.insert(k.clone(), v.clone());
                }
            }
            entries.insert(name.clone(), ArtifactEntry { name, file, inputs, meta });
        }
        Ok(Self { entries })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        Self::parse(&text)
    }
}

/// Compiled-executable cache over an artifacts directory.
///
/// Not `Send`/`Sync` (the PJRT client is `Rc`-based): use it from one thread,
/// or go through [`crate::runtime::RuntimeHandle`] for cross-thread access.
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl ArtifactStore {
    /// Open the store at an explicit directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        Ok(Self { dir, manifest, client: super::cpu_client()?, cache: Mutex::new(HashMap::new()) })
    }

    /// Open via `find_artifacts_dir()`.
    pub fn discover() -> Result<Self> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Self::open(dir)
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.entries.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Load + compile (cached) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.entry(name)?.clone();
        let path = self.dir.join(&entry.file);
        let exe = Arc::new(Executable::from_hlo_text_file(&self.client, &path, entry)?);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let text = r#"{
            "cs_batch": {
                "file": "cs_batch.hlo.txt",
                "inputs": [
                    {"shape": [32, 1568], "dtype": "float32"},
                    {"shape": [1568], "dtype": "int32"}
                ],
                "meta": {"batch": 32, "out_dim": 256, "method": "fcs"}
            }
        }"#;
        let m = Manifest::parse(text).unwrap();
        let e = &m.entries["cs_batch"];
        assert_eq!(e.file, "cs_batch.hlo.txt");
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].0, vec![32, 1568]);
        assert_eq!(e.inputs[1].1, "int32");
        assert_eq!(e.meta_usize("batch"), Some(32));
        assert_eq!(e.meta_str("method"), Some("fcs"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Manifest::parse("[1,2,3]").is_err());
        assert!(Manifest::parse("{\"x\": {}}").is_err()); // missing file
    }
}
