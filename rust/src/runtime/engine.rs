//! The XLA engine thread: owns the (thread-confined) PJRT client and the
//! compiled-executable cache, and serves execution requests over a channel.
//! Everything that needs cross-thread XLA access (the coordinator's worker
//! pool, examples, benches) holds a cheap, cloneable [`RuntimeHandle`].

use super::artifact::{ArtifactStore, Manifest};
use super::exec::{TensorArg, TensorOut};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

enum Msg {
    Run {
        name: String,
        args: Vec<TensorArg>,
        reply: Sender<Result<Vec<TensorOut>>>,
    },
    /// Pre-compile an artifact (warm the cache off the latency path).
    Warm {
        name: String,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Msg>,
    manifest: Arc<Manifest>,
    pub dir: PathBuf,
}

impl RuntimeHandle {
    /// Execute an artifact by name (blocking until the result is ready).
    pub fn run(&self, name: &str, args: Vec<TensorArg>) -> Result<Vec<TensorOut>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Run { name: name.to_string(), args, reply })
            .map_err(|_| anyhow!("runtime engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime engine dropped the request"))?
    }

    /// Compile an artifact ahead of first use.
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warm { name: name.to_string(), reply })
            .map_err(|_| anyhow!("runtime engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime engine dropped the request"))?
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Spawn the engine thread over an artifacts directory (pass `None` to
/// auto-discover). Returns once the manifest is loaded and the client is up.
pub fn spawn_runtime(dir: Option<PathBuf>) -> Result<RuntimeHandle> {
    let dir = match dir {
        Some(d) => d,
        None => super::find_artifacts_dir()
            .ok_or_else(|| anyhow!("artifacts/ not found — run `make artifacts`"))?,
    };
    let manifest = Arc::new(Manifest::load(&dir)?);
    let (tx, rx) = channel::<Msg>();
    let thread_dir = dir.clone();
    let (ready_tx, ready_rx) = channel();
    std::thread::Builder::new()
        .name("xla-engine".into())
        .spawn(move || {
            let store = match ArtifactStore::open(&thread_dir) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Run { name, args, reply } => {
                        let result = store.load(&name).and_then(|exe| exe.run(&args));
                        let _ = reply.send(result);
                    }
                    Msg::Warm { name, reply } => {
                        let _ = reply.send(store.load(&name).map(|_| ()));
                    }
                    Msg::Shutdown => break,
                }
            }
        })
        .expect("spawn xla-engine");
    ready_rx
        .recv()
        .map_err(|_| anyhow!("engine thread died during startup"))??;
    Ok(RuntimeHandle { tx, manifest, dir })
}
