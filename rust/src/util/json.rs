//! Minimal JSON support (writer + small reader) — `serde`/`serde_json` are
//! not available offline. The writer is used to dump bench results under
//! `results/`; the reader parses the artifact `manifest.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. BTreeMap keeps key order deterministic for diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from<T: Into<Json>>(v: T) -> Json {
        v.into()
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON string. Supports the full grammar minus `\uXXXX` escapes
    /// beyond the BMP surrogate handling we need (manifest files are ASCII).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{txt}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_obj() {
        let mut j = Json::obj();
        j.set("name", "fcs".into())
            .set("J", 4096usize.into())
            .set("err", 0.125.into())
            .set("ok", true.into())
            .set("xs", vec![1.0, 2.0, 3.5].into());
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_rejects_trailing() {
        assert!(Json::parse("{} junk").is_err());
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
