//! Timing utilities: stopwatch and robust summary statistics.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.elapsed_secs())
}

/// Robust summary of a sample of measurements (seconds or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Median absolute deviation (scaled by 1.4826 for normal consistency).
    pub mad: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_unstable_by(f64::total_cmp);
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_unstable_by(f64::total_cmp);
        let mad = percentile_sorted(&devs, 50.0) * 1.4826;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            mad,
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
///
/// **Non-panicking contract** (the estimator/coordinator hot paths call this
/// on worker threads a panic would permanently shrink): an empty slice
/// returns `NaN` — a degenerate *value* the caller can observe — instead of
/// asserting. Callers sort with [`f64::total_cmp`], so NaN inputs land at
/// the tail rather than aborting the sort.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of a mutable scratch sample — the allocation-free, NaN-tolerant
/// primitive the estimator and decompression hot paths share. Selection
/// (O(n) `select_nth_unstable_by` under `total_cmp`) rather than a full
/// sort; empty ⇒ `NaN`, never panics. Matches `percentile_sorted(·, 50)` on
/// a sorted copy: odd n takes the middle element, even n averages the two.
pub fn median_inplace(xs: &mut [f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return f64::NAN;
    }
    if n == 1 {
        return xs[0];
    }
    let mid = n / 2;
    let (_, &mut upper_med, _) = xs.select_nth_unstable_by(mid, |a, b| a.total_cmp(b));
    if n % 2 == 1 {
        upper_med
    } else {
        // lower median = max of the left partition
        let lower_med = xs[..mid].iter().fold(f64::NEG_INFINITY, |m, &x| m.max(x));
        0.5 * (lower_med + upper_med)
    }
}

/// Median of a possibly-unsorted slice (does not mutate the input;
/// allocates — use [`median_inplace`] on hot paths).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    median_inplace(&mut v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn median_even() {
        assert!((median(&[4.0, 1.0, 3.0, 2.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 3.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.0, 1.0, 1.0, 100.0]);
        assert!(s.mad < 1.0, "mad should ignore the outlier, got {}", s.mad);
    }

    #[test]
    fn nan_inputs_do_not_panic() {
        // Regression (PR 5): a NaN from a degenerate sketch used to abort
        // the partial_cmp sort; total_cmp sends it to the tail instead.
        let m = median(&[f64::NAN, 1.0, 2.0]);
        assert_eq!(m, 2.0, "NaN must sort last, leaving the finite median");
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.median, 3.0); // [1, 3, NaN] under total_cmp
        let mut buf = [4.0, f64::NAN, 0.0];
        assert_eq!(median_inplace(&mut buf), 4.0);
        assert!(percentile_sorted(&[], 50.0).is_nan(), "empty sample yields NaN, not a panic");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
    }
}
