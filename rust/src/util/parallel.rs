//! Minimal data-parallel helpers built on `std::thread::scope` (rayon and
//! crossbeam are not available offline; scoped threads landed in std 1.63).
//! Used for the D independent sketch repetitions, the rank fan-out of the
//! spectral CP paths, and embarrassingly-parallel bench sweeps.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Mutex;

/// Number of worker threads to use by default (logical cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Parallel map over `0..n` with dynamic (work-stealing-ish atomic counter)
/// scheduling. Results are returned in index order. `f` must be `Sync`.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    // ordering: Relaxed — work distribution only: RMW makes
                    // each index unique, and `scope` joins (a full barrier)
                    // before any result is read.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                let mut guard = out.lock().unwrap();
                for (i, v) in local {
                    guard[i] = Some(v);
                }
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("par_map missing result"))
        .collect()
}

/// Parallel for-each over mutable chunks of a slice.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    let threads = threads.max(1);
    if threads == 1 || data.len() <= chunk {
        for (ci, c) in data.chunks_mut(chunk).enumerate() {
            f(ci, c);
        }
        return;
    }
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let work = Mutex::new(chunks);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = work.lock().unwrap().pop();
                match item {
                    Some((ci, c)) => f(ci, c),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<usize> = (0..1000).map(|i| i * i).collect();
        let parallel = par_map(1000, 8, |i| i * i);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut data = vec![0usize; 1003];
        par_chunks_mut(&mut data, 64, 8, |_ci, c| {
            for x in c.iter_mut() {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }
}
