//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `subcommand --key value --key=value --flag positional` layouts,
//! which is all the `fcs` binary and the bench drivers need.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut items: Vec<String> = iter.into_iter().collect();
        if !items.is_empty() && !items[0].starts_with('-') {
            out.subcommand = Some(items.remove(0));
        }
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    out.options.insert(body.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'")))
            .unwrap_or(default)
    }

    /// Parse a comma-separated list of usizes, e.g. `--lens 1000,2000,5000`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: `--key value` binds greedily, so bare flags go last (or use
        // `--flag=1`); positionals come before the first option.
        let a = parse("rtpm input.bin --dim 100 --rank=10 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("rtpm"));
        assert_eq!(a.get("dim"), Some("100"));
        assert_eq!(a.get_usize("rank", 0), 10);
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --verbose");
        assert!(a.flag("verbose"));
        assert!(a.get("verbose").is_none());
    }

    #[test]
    fn usize_list() {
        let a = parse("x --lens 1,2,3");
        assert_eq!(a.get_usize_list("lens", &[9]), vec![1, 2, 3]);
        assert_eq!(a.get_usize_list("other", &[9]), vec![9]);
    }

    #[test]
    fn no_subcommand_when_first_is_flag() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }
}
