//! General-purpose substrates: PRNG, timing, JSON, parallelism, CLI parsing,
//! and a mini property-testing framework. These replace crates that are not
//! available in the offline build environment (rand, serde_json, rayon,
//! clap, proptest).

pub mod cli;
pub mod json;
pub mod parallel;
pub mod prng;
pub mod qcheck;
pub mod timing;

pub use prng::Rng;
pub use timing::{median, timeit, Stopwatch, Summary};
