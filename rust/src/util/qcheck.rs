//! Mini property-based testing framework (proptest is not available
//! offline). Deterministic: every case derives from a fixed seed, and a
//! failing case reports the seed + case index so it can be replayed.
//!
//! ```text
//! use fcs::util::qcheck::{qcheck, Gen};
//! qcheck(100, |g: &mut Gen| {
//!     let n = g.usize_in(1, 64);
//!     let xs = g.f64_vec(n, -1.0, 1.0);
//!     let sum: f64 = xs.iter().sum();
//!     assert!(sum.abs() <= n as f64);
//! });
//! ```
//! (fenced as text: doctest binaries don't inherit the xla rpath)

use crate::util::prng::Rng;

/// Case-local generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Which case (0-based) is running — useful in failure messages.
    pub case: usize,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }

    pub fn f64_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        self.rng.uniform_vec(n, lo, hi)
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.normal_vec(n)
    }

    /// A random shape with `order` modes, each dim in `[lo, hi]`.
    pub fn shape(&mut self, order: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..order).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Default seed; override with env var `QCHECK_SEED` to replay.
fn base_seed() -> u64 {
    std::env::var("QCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_BEEF)
}

/// Run `prop` against `cases` generated inputs. Panics (with replay info) on
/// the first failing case. Catches property panics so the report includes
/// seed and case index.
pub fn qcheck<F: FnMut(&mut Gen)>(cases: usize, mut prop: F) {
    let seed = base_seed();
    for case in 0..cases {
        let mut g = Gen { rng: Rng::seed_from_u64(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "qcheck property failed at case {case}/{cases} (replay: QCHECK_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        qcheck(50, |g| {
            let n = g.usize_in(0, 10);
            assert!(n <= 10);
        });
    }

    #[test]
    #[should_panic(expected = "qcheck property failed")]
    fn reports_failure_with_seed() {
        qcheck(50, |g| {
            let n = g.usize_in(0, 10);
            assert!(n < 10, "boom");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        qcheck(10, |g| {
            first.push(g.usize_in(0, 1000));
        });
        let mut second: Vec<usize> = Vec::new();
        qcheck(10, |g| {
            second.push(g.usize_in(0, 1000));
        });
        assert_eq!(first, second);
    }
}
