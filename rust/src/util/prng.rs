//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand` / `rand_chacha`, so we implement the
//! generators we need: SplitMix64 (seeding) and Xoshiro256++ (bulk stream).
//! Both are well-studied, pass BigCrush (xoshiro) and are more than adequate
//! for drawing 2-wise independent hash coefficients and synthetic data.

/// SplitMix64: used to expand a single `u64` seed into a full generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the main PRNG used across the library.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64 (via SplitMix64, per the
    /// xoshiro authors' recommendation).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child stream (used to hand each worker thread or
    /// each sketch repetition its own generator).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire rejection method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a new vector with standard normal entries.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fill a new vector with uniform `[lo, hi)` entries.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::seed_from_u64(7);
        let n = 10u64;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.below(n);
            assert!(v < n);
            counts[v as usize] += 1;
        }
        for &c in &counts {
            // expectation 10_000, allow generous slack
            assert!((8_500..11_500).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = a.fork();
        let mut c = a.fork();
        let xs: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from_u64(9);
        let s = rng.sample_indices(50, 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Rng::seed_from_u64(11);
        let sum: f64 = (0..100_000).map(|_| rng.sign()).sum();
        assert!(sum.abs() < 2_000.0);
    }
}
