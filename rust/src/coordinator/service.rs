//! The sketch service: bounded ingress queues (backpressure), a dynamic
//! batcher in front of the XLA `cs_batch` executable, and a pure-Rust worker
//! pool for the remaining ops. See DESIGN.md §7.

use super::msg::{Request, Response, ServiceError, SketchMethod};
use super::stats::{Stats, StatsReport};
use crate::hash::{HashPair, ModeHashes};
use crate::runtime::{RuntimeHandle, TensorArg};
use crate::sketch::{FastCountSketch, TensorSketch};
use crate::util::prng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Pure-Rust worker threads.
    pub workers: usize,
    /// Bounded queue capacity (per queue) — the backpressure limit.
    pub queue_capacity: usize,
    /// Batcher flush deadline.
    pub batch_deadline: Duration,
    /// Seed for the service's shared hash tables and per-request draws.
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: crate::util::parallel::default_threads().min(8),
            queue_capacity: 1024,
            batch_deadline: Duration::from_micros(500),
            seed: 0xFC5,
        }
    }
}

struct Job {
    req: Request,
    reply: Sender<Result<Response, ServiceError>>,
    enqueued: Instant,
}

/// Queue message: a job or an explicit stop sentinel. The sentinel makes
/// `Service::shutdown` deterministic even while clients still hold
/// `ServiceHandle` clones (whose senders would otherwise keep the queues
/// open forever).
enum QueueMsg {
    Work(Box<Job>),
    Stop,
}

/// Cheap, cloneable client handle.
#[derive(Clone)]
pub struct ServiceHandle {
    batch_tx: SyncSender<QueueMsg>,
    work_tx: SyncSender<QueueMsg>,
    stats: Arc<Stats>,
    pub cs_in_dim: usize,
    pub cs_out_dim: usize,
}

impl ServiceHandle {
    /// Non-blocking submit; returns a receiver for the response.
    pub fn submit(
        &self,
        req: Request,
    ) -> Result<Receiver<Result<Response, ServiceError>>, ServiceError> {
        self.validate(&req)?;
        let (reply, rx) = std::sync::mpsc::channel();
        let job = Box::new(Job { req, reply, enqueued: Instant::now() });
        let target = match &job.req {
            Request::CsVec { .. } => &self.batch_tx,
            _ => &self.work_tx,
        };
        match target.try_send(QueueMsg::Work(job)) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.stats.record_rejection();
                Err(ServiceError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::Closed),
        }
    }

    /// Blocking call.
    pub fn call(&self, req: Request) -> Result<Response, ServiceError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServiceError::Closed)?
    }

    fn validate(&self, req: &Request) -> Result<(), ServiceError> {
        match req {
            Request::CsVec { x } => {
                if x.len() != self.cs_in_dim {
                    return Err(ServiceError::BadRequest(format!(
                        "cs_vec expects dim {}, got {}",
                        self.cs_in_dim,
                        x.len()
                    )));
                }
            }
            Request::SketchDense { tensor, j, .. } => {
                if tensor.numel() == 0 || *j == 0 {
                    return Err(ServiceError::BadRequest("empty tensor or j=0".into()));
                }
            }
            Request::SketchCp { cp, j } => {
                if cp.rank() == 0 || *j == 0 {
                    return Err(ServiceError::BadRequest("empty cp or j=0".into()));
                }
            }
            Request::InnerEstimate { a, b, d, j, .. } => {
                if a.shape != b.shape {
                    return Err(ServiceError::BadRequest("shape mismatch".into()));
                }
                if *d == 0 || *j == 0 {
                    return Err(ServiceError::BadRequest("d=0 or j=0".into()));
                }
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> StatsReport {
        self.stats.report()
    }
}

/// The running service (shut down with [`Service::shutdown`]).
pub struct Service {
    handle: ServiceHandle,
    threads: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl Service {
    /// Start the service. `runtime = None` runs fully on the pure-Rust path
    /// (used when artifacts are absent); with a runtime, `cs_vec` batches on
    /// the XLA executable and `sketch_cp` uses `fcs_rank1` when shapes match.
    pub fn start(cfg: ServiceConfig, runtime: Option<RuntimeHandle>) -> anyhow::Result<Service> {
        let stats = Arc::new(Stats::new());
        stats.mark_started();

        // Shared CS table for the cs_vec op: dims follow the artifact when a
        // runtime is available, else a default.
        let (in_dim, out_dim) = match &runtime {
            Some(rt) => {
                let e = rt
                    .manifest()
                    .entries
                    .get("cs_batch")
                    .ok_or_else(|| anyhow::anyhow!("cs_batch artifact missing"))?;
                (
                    e.meta_usize("in_dim").unwrap_or(1568),
                    e.meta_usize("out_dim").unwrap_or(256),
                )
            }
            None => (1568, 256),
        };
        let batch_size = runtime
            .as_ref()
            .and_then(|rt| rt.manifest().entries.get("cs_batch"))
            .and_then(|e| e.meta_usize("batch"))
            .unwrap_or(32);
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let table = HashPair::draw(&mut rng, in_dim, out_dim).materialize();

        let (batch_tx, batch_rx) = sync_channel::<QueueMsg>(cfg.queue_capacity);
        let (work_tx, work_rx) = sync_channel::<QueueMsg>(cfg.queue_capacity);
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut threads = Vec::new();

        // --- batcher thread ------------------------------------------------
        {
            let stats = stats.clone();
            let runtime = runtime.clone();
            let table = table.clone();
            let deadline = cfg.batch_deadline;
            threads.push(
                std::thread::Builder::new()
                    .name("fcs-batcher".into())
                    .spawn(move || {
                        batcher_loop(batch_rx, runtime, table, batch_size, deadline, stats);
                    })
                    .expect("spawn batcher"),
            );
        }

        // --- worker pool -----------------------------------------------------
        let req_counter = Arc::new(AtomicU64::new(0));
        for w in 0..cfg.workers.max(1) {
            let rx = work_rx.clone();
            let stats = stats.clone();
            let runtime = runtime.clone();
            let counter = req_counter.clone();
            let seed = cfg.seed;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("fcs-worker-{w}"))
                    .spawn(move || {
                        worker_loop(rx, runtime, seed, counter, stats);
                    })
                    .expect("spawn worker"),
            );
        }

        let handle = ServiceHandle {
            batch_tx,
            work_tx,
            stats,
            cs_in_dim: in_dim,
            cs_out_dim: out_dim,
        };
        Ok(Service { handle, threads, workers: cfg.workers.max(1) })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    pub fn stats(&self) -> StatsReport {
        self.handle.stats.report()
    }

    /// Graceful shutdown: send stop sentinels (one per consumer) and join.
    /// Deterministic even if clients still hold handle clones.
    pub fn shutdown(self) {
        let Service { handle, threads, workers } = self;
        let _ = handle.batch_tx.send(QueueMsg::Stop);
        for _ in 0..workers {
            let _ = handle.work_tx.send(QueueMsg::Stop);
        }
        drop(handle);
        for t in threads {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Batcher: dynamic batching of cs_vec onto the XLA cs_batch executable
// ---------------------------------------------------------------------------

fn batcher_loop(
    rx: Receiver<QueueMsg>,
    runtime: Option<RuntimeHandle>,
    table: crate::hash::HashTable,
    batch_size: usize,
    deadline: Duration,
    stats: Arc<Stats>,
) {
    let in_dim = table.domain();
    let out_dim = table.range;
    let h_i32: Vec<i32> = table.h.iter().map(|&v| v as i32).collect();
    let s_f32: Vec<f32> = table.s.iter().map(|&v| v as f32).collect();
    let cs = crate::sketch::CountSketch::new(table.clone());
    let mut stopping = false;

    while !stopping {
        // Block for the first job of the batch.
        let first = match rx.recv() {
            Ok(QueueMsg::Work(j)) => j,
            Ok(QueueMsg::Stop) | Err(_) => return,
        };
        let mut batch = vec![first];
        let flush_at = Instant::now() + deadline;
        while batch.len() < batch_size {
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            match rx.recv_timeout(flush_at - now) {
                Ok(QueueMsg::Work(j)) => batch.push(j),
                Ok(QueueMsg::Stop) => {
                    stopping = true; // flush this batch, then exit
                    break;
                }
                Err(_) => break,
            }
        }
        stats.record_batch(batch.len());

        // Execute: XLA path (pad to batch_size) or pure-Rust fallback.
        let results: Vec<Result<Vec<f64>, ServiceError>> = match &runtime {
            Some(rt) => {
                let mut x = vec![0.0f32; batch_size * in_dim];
                for (row, job) in batch.iter().enumerate() {
                    let Request::CsVec { x: v } = &job.req else { unreachable!() };
                    for (c, &val) in v.iter().enumerate() {
                        x[row * in_dim + c] = val as f32;
                    }
                }
                let args = vec![
                    TensorArg::f32(&[batch_size, in_dim], x),
                    TensorArg::i32(&[in_dim], h_i32.clone()),
                    TensorArg::f32(&[in_dim], s_f32.clone()),
                ];
                match rt.run("cs_batch", args) {
                    Ok(outs) => {
                        let data = &outs[0].data;
                        (0..batch.len())
                            .map(|row| {
                                Ok(data[row * out_dim..(row + 1) * out_dim]
                                    .iter()
                                    .map(|&v| v as f64)
                                    .collect())
                            })
                            .collect()
                    }
                    Err(e) => batch
                        .iter()
                        .map(|_| Err(ServiceError::Exec(e.to_string())))
                        .collect(),
                }
            }
            None => batch
                .iter()
                .map(|job| {
                    let Request::CsVec { x } = &job.req else { unreachable!() };
                    Ok(cs.apply(x))
                })
                .collect(),
        };

        for (job, result) in batch.into_iter().zip(results) {
            let latency = job.enqueued.elapsed().as_secs_f64() * 1e6;
            stats.record("cs_vec", latency);
            let _ = job.reply.send(result.map(Response::Sketch));
        }
    }
}

// ---------------------------------------------------------------------------
// Worker pool: pure-Rust sketch ops (+ XLA fcs_rank1 when shapes match)
// ---------------------------------------------------------------------------

fn worker_loop(
    rx: Arc<Mutex<Receiver<QueueMsg>>>,
    runtime: Option<RuntimeHandle>,
    seed: u64,
    counter: Arc<AtomicU64>,
    stats: Arc<Stats>,
) {
    // One FFT workspace per worker: sketch_cp requests at a steady shape run
    // allocation-free after the first request (§Perf).
    let mut ws = crate::fft::FftWorkspace::new();
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(QueueMsg::Work(j)) => j,
                Ok(QueueMsg::Stop) | Err(_) => return,
            }
        };
        let op = job.req.op_name();
        let req_id = counter.fetch_add(1, Ordering::Relaxed);
        let mut rng = Rng::seed_from_u64(seed ^ req_id.wrapping_mul(0x9E3779B97F4A7C15));
        let result = execute_work(job.req, &runtime, &mut rng, &mut ws);
        let latency = job.enqueued.elapsed().as_secs_f64() * 1e6;
        stats.record(op, latency);
        let _ = job.reply.send(result);
    }
}

fn execute_work(
    req: Request,
    runtime: &Option<RuntimeHandle>,
    rng: &mut Rng,
    ws: &mut crate::fft::FftWorkspace,
) -> Result<Response, ServiceError> {
    match req {
        Request::CsVec { .. } => unreachable!("cs_vec is routed to the batcher"),
        Request::SketchDense { tensor, method, j } => {
            let mh = ModeHashes::draw_uniform(rng, &tensor.shape, j);
            let sk = match method {
                SketchMethod::Ts => TensorSketch::new(mh).apply_dense(&tensor),
                SketchMethod::Fcs => FastCountSketch::new(mh).apply_dense(&tensor),
            };
            Ok(Response::Sketch(sk))
        }
        Request::SketchCp { cp, j } => {
            // XLA fast path if the artifact's static shapes match.
            if let Some(rt) = runtime {
                if let Some(e) = rt.manifest().entries.get("fcs_rank1") {
                    let dims_match = e.meta_usize("dim").map(|d| {
                        cp.order() == 3 && cp.shape().iter().all(|&s| s == d)
                    }) == Some(true)
                        && e.meta_usize("rank") == Some(cp.rank())
                        && e.meta_usize("j") == Some(j);
                    if dims_match {
                        return sketch_cp_xla(rt, &cp, j, rng);
                    }
                }
            }
            let mh = ModeHashes::draw_uniform(rng, &cp.shape(), j);
            // Workers are already a pool: run the serial spectral path with
            // this worker's reusable workspace (one IFFT per request).
            let mut out = Vec::new();
            FastCountSketch::new(mh).apply_cp_into(&cp, ws, &mut out);
            Ok(Response::Sketch(out))
        }
        Request::InnerEstimate { a, b, method, j, d } => {
            let mut estimates = Vec::with_capacity(d);
            for _ in 0..d {
                let mh = ModeHashes::draw_uniform(rng, &a.shape, j);
                let (sa, sb) = match method {
                    SketchMethod::Ts => {
                        let ts = TensorSketch::new(mh);
                        (ts.apply_dense(&a), ts.apply_dense(&b))
                    }
                    SketchMethod::Fcs => {
                        let f = FastCountSketch::new(mh);
                        (f.apply_dense(&a), f.apply_dense(&b))
                    }
                };
                estimates.push(crate::linalg::dot(&sa, &sb));
            }
            Ok(Response::Scalar(crate::util::timing::median(&estimates)))
        }
    }
}

fn sketch_cp_xla(
    rt: &RuntimeHandle,
    cp: &crate::tensor::CpTensor,
    j: usize,
    rng: &mut Rng,
) -> Result<Response, ServiceError> {
    let dim = cp.factors[0].rows;
    let rank = cp.rank();
    let mh = ModeHashes::draw_uniform(rng, &cp.shape(), j);
    let to_rowmajor = |m: &crate::linalg::Matrix| -> Vec<f32> {
        let mut v = Vec::with_capacity(m.rows * m.cols);
        for r in 0..m.rows {
            for c in 0..m.cols {
                v.push(m.get(r, c) as f32);
            }
        }
        v
    };
    let mut args = Vec::new();
    for f in &cp.factors {
        args.push(TensorArg::f32(&[dim, rank], to_rowmajor(f)));
    }
    args.push(TensorArg::f32(
        &[rank],
        cp.lambda.iter().map(|&l| l as f32).collect(),
    ));
    for m in &mh.modes {
        args.push(TensorArg::i32(&[dim], m.h.iter().map(|&v| v as i32).collect()));
        args.push(TensorArg::f32(&[dim], m.s.iter().map(|&v| v as f32).collect()));
    }
    let outs = rt
        .run("fcs_rank1", args)
        .map_err(|e| ServiceError::Exec(e.to_string()))?;
    Ok(Response::Sketch(outs[0].data.iter().map(|&v| v as f64).collect()))
}
