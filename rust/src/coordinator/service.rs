//! The sketch service: bounded ingress queues (backpressure), a dynamic
//! batcher in front of the XLA `cs_batch` executable, and a pure-Rust worker
//! pool for the remaining ops. See DESIGN.md §7.
//!
//! Workers execute through a per-worker [`WorkerState`] — an FFT workspace,
//! a hash-redraw arena, and per-mode count-sketch storage — so the
//! `sketch_dense` / `sketch_cp` / `inner_estimate` compute paths perform
//! **zero heap allocations** in steady state (the response `Vec` handed to
//! the client is the one unavoidable per-request allocation; it transfers
//! ownership out of the worker). When the pool is saturated (every other
//! worker mid-job), a worker also drains the backlog opportunistically
//! (waiting up to [`FUSE_MAX_WAIT`] for batch-mates) and sorts the drained
//! batch by [`Request::shape_key`], so same-shape jobs run consecutively on
//! a warm workspace. Under light load workers take one job per wakeup,
//! keeping bursts fanned out across the pool.
//!
//! **Cross-request fused flights**: within a sorted batch, maximal runs of
//! requests that [`Request::fuses_with`] each other execute as one *flight*.
//! `SketchCp` flights wider than one job go through
//! [`WorkerState::sketch_cp_fused`], which packs the rank spectra of
//! *different requests* into shared `SpectralDriver` lane chunks — one pack
//! → one batched rfft → per-job fold → one batched inverse per ≤16-lane
//! chunk — so N small same-shape requests cost ⌈N·lanes/16⌉ transform
//! dispatches instead of N·⌈lanes/16⌉. Every job keeps its own
//! deterministic hash draw ([`job_rng`] over its `req_id`), so fused output
//! is **bit-identical** to serial execution. `SketchDense` runs have no
//! transform to share (the dense path is a pure `O(nnz)` scatter); their
//! flights are warm-arena runs recorded at their true width. Per-width
//! flight summaries and the queue-wait/exec split land in
//! [`super::stats::Stats`].
//!
//! Robustness: requests are validated up front (shape/data coherence with an
//! overflow-checked shape product, zero-dim/zero-rep rejection), and each
//! job of a drained batch executes under `catch_unwind` — a poisoned request
//! that still trips a kernel assert costs exactly its own reply (an
//! [`ServiceError::Exec`]), never the rest of the batch or the worker. A
//! panic inside a *fused* flight falls back to per-job serial retry (each
//! job's RNG re-derived from its stored `req_id`), preserving both the
//! isolation contract and bit-identical healthy outputs.
//!
//! **Overload resilience**: requests may carry an absolute deadline
//! ([`ServiceHandle::submit_with_deadline`]). An admission controller
//! refuses jobs whose deadline the queue-wait EWMA says cannot be met;
//! expired jobs are shed at dequeue and between flight members —
//! [`ServiceError::DeadlineExceeded`] in every case, booked per stage in
//! `fcs_deadline_shed_total{stage=...}` and
//! [`StatsReport`]`::shed_*`. A supervisor thread replaces workers that die
//! by panic (`fcs_worker_respawns_total`), and
//! [`ServiceHandle::call_with_retry`] adds budgeted, full-jitter retry for
//! `Busy`/`Exec` failures ([`super::retry`]). The `failpoints` feature arms
//! deterministic fault-injection sites ([`crate::fault`]) on these paths;
//! the chaos suite (`rust/tests/chaos.rs`) drives them.
//!
//! **Sharded reduce front-end**: `sketch_shard` scatters one slab of a
//! partitioned tensor under its merge group's *shared* hash draws
//! ([`crate::sketch::merge::group_rng`] over `(seed, group)` rather than the
//! per-request [`job_rng`]), and `merge_shards` pairwise tree-reduces the
//! replies ([`crate::sketch::merge::tree_reduce_parts`]) — CS linearity
//! makes the merged sum bit-identical to whole-tensor sketching on exactly
//! representable data. Shard widths and merge depths land in the `obs`
//! histograms `fcs_shard_width` / `fcs_merge_depth`.

use super::msg::{Request, Response, ServiceError, SketchMethod};
use super::retry::{RetryBudget, RetryPolicy};
use super::stats::{ShedStage, Stats, StatsReport};
use crate::fault::FaultAction;
use crate::fft::FftWorkspace;
use crate::hash::{HashPair, HashTable, ModeHashes};
use crate::obs::trace;
use crate::runtime::{RuntimeHandle, TensorArg};
use crate::sketch::common::{apply_cp_fused, sketch_dense_into, FusedCpJob};
use crate::sketch::{CountSketch, SpectralSketchCore};
use crate::tensor::{CpTensor, Tensor};
use crate::util::prng::Rng;
use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::{Arc, Mutex};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Pure-Rust worker threads.
    pub workers: usize,
    /// Bounded queue capacity (per queue) — the backpressure limit.
    pub queue_capacity: usize,
    /// Batcher flush deadline.
    pub batch_deadline: Duration,
    /// Seed for the service's shared hash tables and per-request draws.
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: crate::util::parallel::default_threads().min(8),
            queue_capacity: 1024,
            batch_deadline: Duration::from_micros(500),
            seed: 0xFC5,
        }
    }
}

struct Job {
    req: Request,
    reply: Sender<Result<Response, ServiceError>>,
    enqueued: Instant,
    /// Absolute completion deadline. Expired jobs are shed at dequeue (and
    /// between fused-flight members) with [`ServiceError::DeadlineExceeded`]
    /// instead of burning a spectral pass on an answer nobody waits for.
    deadline: Option<Instant>,
}

/// Queue message: a job or an explicit stop sentinel. The sentinel makes
/// `Service::shutdown` deterministic even while clients still hold
/// `ServiceHandle` clones (whose senders would otherwise keep the queues
/// open forever).
enum QueueMsg {
    Work(Box<Job>),
    Stop,
}

/// Cheap, cloneable client handle.
#[derive(Clone)]
pub struct ServiceHandle {
    batch_tx: SyncSender<QueueMsg>,
    work_tx: SyncSender<QueueMsg>,
    stats: Arc<Stats>,
    /// Shared anti-amplification budget for [`Self::call_with_retry`] —
    /// per *service* (shared by every handle clone), not per caller.
    retry_budget: Arc<RetryBudget>,
    pub cs_in_dim: usize,
    pub cs_out_dim: usize,
}

impl ServiceHandle {
    /// Non-blocking submit; returns a receiver for the response.
    pub fn submit(
        &self,
        req: Request,
    ) -> Result<Receiver<Result<Response, ServiceError>>, ServiceError> {
        self.submit_with_deadline(req, None)
    }

    /// [`Self::submit`] with an absolute completion deadline. The admission
    /// controller refuses up front — [`ServiceError::DeadlineExceeded`] —
    /// when the deadline has already passed, or when the worker pool's
    /// queue-wait estimate ([`Stats::queue_wait_estimate_us`], an EWMA of
    /// the same stream behind `queue_p50_us`) says the job would expire in
    /// the queue anyway; queueing it would only steal capacity from
    /// requests that can still make their deadlines.
    pub fn submit_with_deadline(
        &self,
        req: Request,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<Response, ServiceError>>, ServiceError> {
        self.validate(&req)?;
        if let Some(dl) = deadline {
            let remaining_us = dl.saturating_duration_since(Instant::now()).as_micros() as u64;
            // cs_vec rides the batcher, whose wait is bounded by the flush
            // deadline — the worker-pool estimate does not apply to it.
            let est_us = if matches!(req, Request::CsVec { .. }) {
                0
            } else {
                self.stats.queue_wait_estimate_us()
            };
            if remaining_us == 0 || est_us > remaining_us {
                self.stats.record_deadline_shed(ShedStage::Submit);
                return Err(ServiceError::DeadlineExceeded);
            }
        }
        let (reply, rx) = std::sync::mpsc::channel();
        let job = Box::new(Job { req, reply, enqueued: Instant::now(), deadline });
        // Queue-depth gauges: incremented on a successful enqueue here,
        // decremented at the single dequeue point of each consumer loop.
        let (target, depth) = match &job.req {
            Request::CsVec { .. } => {
                (&self.batch_tx, &crate::obs::metrics().queue_depth_batcher)
            }
            _ => (&self.work_tx, &crate::obs::metrics().queue_depth_worker),
        };
        match target.try_send(QueueMsg::Work(job)) {
            Ok(()) => {
                depth.inc();
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.stats.record_rejection();
                Err(ServiceError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::Closed),
        }
    }

    /// Blocking call.
    pub fn call(&self, req: Request) -> Result<Response, ServiceError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServiceError::Closed)?
    }

    /// Blocking call with an absolute completion deadline.
    pub fn call_with_deadline(
        &self,
        req: Request,
        deadline: Instant,
    ) -> Result<Response, ServiceError> {
        let rx = self.submit_with_deadline(req, Some(deadline))?;
        rx.recv().map_err(|_| ServiceError::Closed)?
    }

    /// Blocking call that rides out transient failures: `Busy` (queue full)
    /// and `Exec` replies are retried up to `policy.max_retries` times with
    /// full-jitter exponential backoff — but **only** while the service-wide
    /// [`RetryBudget`] can pay for the retry. A broke budget surfaces the
    /// original error immediately (and bumps
    /// `fcs_retry_budget_exhausted_total`), so a retrying client population
    /// cannot amplify the very overload it is reacting to. `BadRequest`,
    /// `Closed`, and `DeadlineExceeded` never retry — they are not
    /// transient. With a deadline, a backoff that would outlive the
    /// remaining budget short-circuits to `DeadlineExceeded`.
    pub fn call_with_retry(
        &self,
        req: Request,
        deadline: Option<Instant>,
        policy: &RetryPolicy,
    ) -> Result<Response, ServiceError> {
        let op = req.op_name();
        self.retry_budget.deposit(op);
        let mut rng = Rng::seed_from_u64(policy.jitter_seed);
        let mut attempt = 0u32;
        loop {
            let err = match self.submit_with_deadline(req.clone(), deadline) {
                Ok(rx) => match rx.recv().map_err(|_| ServiceError::Closed)? {
                    Ok(resp) => return Ok(resp),
                    Err(e) => e,
                },
                Err(e) => e,
            };
            let retryable = matches!(err, ServiceError::Busy | ServiceError::Exec(_));
            if !retryable || attempt >= policy.max_retries {
                return Err(err);
            }
            if !self.retry_budget.try_withdraw(op) {
                self.stats.record_retry_budget_exhausted();
                return Err(err);
            }
            let pause = policy.backoff(attempt, &mut rng);
            if let Some(dl) = deadline {
                if dl.saturating_duration_since(Instant::now()) <= pause {
                    // The backoff alone would blow the deadline; don't sleep
                    // into a guaranteed failure. Not a shed — the service
                    // never saw this attempt.
                    return Err(ServiceError::DeadlineExceeded);
                }
            }
            self.stats.record_retry();
            std::thread::sleep(pause);
            attempt += 1;
        }
    }

    /// Replace the shared retry budget (e.g. to tighten the
    /// anti-amplification cap in tests or overload drills). Affects this
    /// handle and everything cloned *from it afterwards*.
    #[must_use]
    pub fn with_retry_budget(mut self, budget: Arc<RetryBudget>) -> ServiceHandle {
        self.retry_budget = budget;
        self
    }

    fn validate(&self, req: &Request) -> Result<(), ServiceError> {
        // Tensor/Matrix fields are pub, so a client *can* hand us an
        // internally inconsistent value (data length ≠ shape product). The
        // sketch kernels index hash tables by shape-derived fibers, so such
        // a request would panic a worker mid-batch — reject it up front.
        // The shape product is overflow-checked (a hostile shape like
        // `[usize::MAX, 2]` must be a BadRequest, not a client-thread
        // overflow panic), and the zero-dim / zero-rep degenerate cases are
        // rejected here so they never reach a worker.
        fn checked_numel(t: &Tensor) -> Option<usize> {
            if t.shape.is_empty() {
                return None;
            }
            let numel = t
                .shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))?;
            (t.data.len() == numel).then_some(numel)
        }
        match req {
            Request::CsVec { x } => {
                if x.len() != self.cs_in_dim {
                    return Err(ServiceError::BadRequest(format!(
                        "cs_vec expects dim {}, got {}",
                        self.cs_in_dim,
                        x.len()
                    )));
                }
            }
            Request::SketchDense { tensor, j, .. } => {
                let Some(numel) = checked_numel(tensor) else {
                    return Err(ServiceError::BadRequest("tensor shape/data mismatch".into()));
                };
                if numel == 0 || *j == 0 {
                    return Err(ServiceError::BadRequest("empty tensor or j=0".into()));
                }
            }
            Request::SketchCp { cp, j } => {
                if cp.rank() == 0 || cp.order() == 0 || *j == 0 {
                    return Err(ServiceError::BadRequest("empty cp or j=0".into()));
                }
                for f in &cp.factors {
                    // Same overflow-checked product discipline as the dense
                    // tensor arms: hostile dims must be a BadRequest, not a
                    // client-thread overflow panic (debug) or wrap (release).
                    let numel = f.rows.checked_mul(f.cols);
                    if f.rows == 0 || f.cols != cp.rank() || numel != Some(f.data.len()) {
                        return Err(ServiceError::BadRequest(
                            "cp factor shape/data mismatch".into(),
                        ));
                    }
                }
            }
            Request::InnerEstimate { a, b, d, j, .. } => {
                if a.shape != b.shape {
                    return Err(ServiceError::BadRequest("shape mismatch".into()));
                }
                let (Some(na), Some(_)) = (checked_numel(a), checked_numel(b)) else {
                    return Err(ServiceError::BadRequest("tensor shape/data mismatch".into()));
                };
                if *d == 0 || *j == 0 || na == 0 {
                    return Err(ServiceError::BadRequest("empty tensor, d=0 or j=0".into()));
                }
            }
            Request::SketchShard { slab, offset, dims, j, .. } => {
                // Same overflow-checked product discipline as the dense arms,
                // on the *full-tensor* dims (the hash tables are drawn for
                // them), plus the slab-window bound: the scatter kernel
                // asserts `offset + slab.len() <= numel` at execution time,
                // and a hostile request must be a BadRequest, not a worker
                // panic. Empty slabs are legal (a shard may own zero rows of
                // an uneven partition) — the scatter is a no-op.
                if dims.is_empty() || *j == 0 {
                    return Err(ServiceError::BadRequest("empty dims or j=0".into()));
                }
                let Some(numel) = dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
                else {
                    return Err(ServiceError::BadRequest("shard dims overflow".into()));
                };
                if numel == 0 {
                    return Err(ServiceError::BadRequest("empty dims or j=0".into()));
                }
                let end = offset.checked_add(slab.len());
                if end.is_none() || end > Some(numel) {
                    return Err(ServiceError::BadRequest(format!(
                        "shard slab [{offset}, {offset}+{}) exceeds tensor numel {numel}",
                        slab.len()
                    )));
                }
            }
            Request::MergeShards { parts } => {
                // Only emptiness is checked here. Part-length agreement is
                // deliberately left to the execution-time assert in
                // `tree_reduce_parts`: the merge is the reduce step of a
                // scatter the *client* orchestrated, so a mismatch means one
                // of its shard replies was corrupted/mispaired — a per-job
                // Exec failure (poisoning only its own merge group), not a
                // submission-shape problem. The stress suite relies on this
                // split to prove poison isolation.
                if parts.is_empty() {
                    return Err(ServiceError::BadRequest("merge_shards with no parts".into()));
                }
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> StatsReport {
        self.stats.report()
    }
}

/// The running service (shut down with [`Service::shutdown`]).
pub struct Service {
    handle: ServiceHandle,
    batcher: std::thread::JoinHandle<()>,
    /// Owns the worker `JoinHandle`s; respawns crashed workers
    /// ([`supervisor_loop`]) and joins them all on shutdown.
    supervisor: std::thread::JoinHandle<()>,
    /// Shutdown latch read by the supervisor — set *before* the stop
    /// sentinels go out so a worker observed exiting during shutdown is
    /// joined, never respawned.
    stop: Arc<AtomicBool>,
    workers: usize,
}

/// Everything needed to (re)spawn one worker thread — the supervisor holds
/// this so a replacement worker is wired to the same queue, runtime, seed,
/// request counter, and saturation signal as the one it replaces.
struct WorkerCtx {
    rx: Arc<Mutex<Receiver<QueueMsg>>>,
    runtime: Option<RuntimeHandle>,
    seed: u64,
    counter: Arc<AtomicU64>,
    busy: Arc<AtomicUsize>,
    pool_size: usize,
    stats: Arc<Stats>,
}

impl WorkerCtx {
    fn spawn(&self, w: usize) -> std::thread::JoinHandle<()> {
        let rx = self.rx.clone();
        let runtime = self.runtime.clone();
        let seed = self.seed;
        let counter = self.counter.clone();
        let busy = self.busy.clone();
        let pool_size = self.pool_size;
        let stats = self.stats.clone();
        std::thread::Builder::new()
            .name(format!("fcs-worker-{w}"))
            .spawn(move || {
                worker_loop(w, rx, runtime, seed, counter, busy, pool_size, stats);
            })
            .expect("spawn worker")
    }
}

impl Service {
    /// Start the service. `runtime = None` runs fully on the pure-Rust path
    /// (used when artifacts are absent); with a runtime, `cs_vec` batches on
    /// the XLA executable and `sketch_cp` uses `fcs_rank1` when shapes match.
    pub fn start(cfg: ServiceConfig, runtime: Option<RuntimeHandle>) -> anyhow::Result<Service> {
        // Pin the trace epoch and force metric registration before any job
        // is stamped or any hot path records — steady-state `metrics()`
        // lookups must never hit the registration slow path.
        crate::obs::init();
        let stats = Arc::new(Stats::new());
        stats.mark_started();

        // Shared CS table for the cs_vec op: dims follow the artifact when a
        // runtime is available, else a default.
        let (in_dim, out_dim) = match &runtime {
            Some(rt) => {
                let e = rt
                    .manifest()
                    .entries
                    .get("cs_batch")
                    .ok_or_else(|| anyhow::anyhow!("cs_batch artifact missing"))?;
                (
                    e.meta_usize("in_dim").unwrap_or(1568),
                    e.meta_usize("out_dim").unwrap_or(256),
                )
            }
            None => (1568, 256),
        };
        let batch_size = runtime
            .as_ref()
            .and_then(|rt| rt.manifest().entries.get("cs_batch"))
            .and_then(|e| e.meta_usize("batch"))
            .unwrap_or(32);
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let table = HashPair::draw(&mut rng, in_dim, out_dim).materialize();

        let (batch_tx, batch_rx) = sync_channel::<QueueMsg>(cfg.queue_capacity);
        let (work_tx, work_rx) = sync_channel::<QueueMsg>(cfg.queue_capacity);
        let work_rx = Arc::new(Mutex::new(work_rx));

        // --- batcher thread ------------------------------------------------
        let batcher = {
            let stats = stats.clone();
            let runtime = runtime.clone();
            let table = table.clone();
            let deadline = cfg.batch_deadline;
            std::thread::Builder::new()
                .name("fcs-batcher".into())
                .spawn(move || {
                    batcher_loop(batch_rx, runtime, table, batch_size, deadline, stats);
                })
                .expect("spawn batcher")
        };

        // --- worker pool, under supervision ----------------------------------
        let pool_size = cfg.workers.max(1);
        let ctx = WorkerCtx {
            rx: work_rx,
            runtime,
            seed: cfg.seed,
            counter: Arc::new(AtomicU64::new(0)),
            busy: Arc::new(AtomicUsize::new(0)),
            pool_size,
            stats: stats.clone(),
        };
        let slots: Vec<Option<std::thread::JoinHandle<()>>> =
            (0..pool_size).map(|w| Some(ctx.spawn(w))).collect();
        let stop = Arc::new(AtomicBool::new(false));
        let supervisor = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("fcs-supervisor".into())
                .spawn(move || supervisor_loop(ctx, slots, stop))
                .expect("spawn supervisor")
        };

        let handle = ServiceHandle {
            batch_tx,
            work_tx,
            stats,
            retry_budget: Arc::new(RetryBudget::default()),
            cs_in_dim: in_dim,
            cs_out_dim: out_dim,
        };
        Ok(Service { handle, batcher, supervisor, stop, workers: pool_size })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    pub fn stats(&self) -> StatsReport {
        self.handle.stats.report()
    }

    /// Graceful shutdown: send stop sentinels (one per consumer) and join.
    /// Deterministic even if clients still hold handle clones. The stop
    /// latch is set *before* the sentinels go out, so the supervisor can
    /// never mistake a sentinel-consuming worker's clean exit for a crash
    /// and respawn a thread into a draining pool.
    pub fn shutdown(self) {
        let Service { handle, batcher, supervisor, stop, workers } = self;
        // ordering: SeqCst — the latch must be globally visible before the
        // stop sentinels below can be consumed: a worker that exits on a
        // sentinel is joined by the supervisor, whose post-join
        // `should_respawn` re-check must already see the latch raised
        // (loom model: `supervisor_latch_no_respawn_after_stop`).
        stop.store(true, Ordering::SeqCst);
        let _ = handle.batch_tx.send(QueueMsg::Stop);
        for _ in 0..workers {
            let _ = handle.work_tx.send(QueueMsg::Stop);
        }
        drop(handle);
        let _ = supervisor.join();
        let _ = batcher.join();
    }
}

/// How often the supervisor sweeps the pool for dead workers. The sweep is
/// cheap (`is_finished` per slot), so recovery latency — not overhead — sets
/// the cadence.
const SUPERVISE_INTERVAL: Duration = Duration::from_millis(10);

/// Worker-pool supervision: sweep the slots; a worker that *panicked* out of
/// its loop (join reports an `Err` payload) is replaced with a fresh thread
/// on the same queue — the thread is gone, but its `WorkerState` died with
/// it, so the replacement rebuilds arenas from scratch and the pool heals at
/// full width (`fcs_worker_respawns_total` counts these). A worker that
/// exited *cleanly* (stop sentinel, closed queue) is joined and its slot
/// retired: clean exits are lifecycle, not failures. Returns when the stop
/// latch is raised (joining every survivor) or when every slot has retired.
/// The supervisor's respawn decision for one finished slot, factored out so
/// the loom suite (`tests/loom_models.rs`) model-checks the exact predicate
/// the supervisor runs: respawn only a *crashed* worker, and never once the
/// stop latch is raised — a crash racing shutdown must not spawn a thread
/// into a pool being torn down.
pub fn should_respawn(crashed: bool, stop: &AtomicBool) -> bool {
    // ordering: SeqCst — pairs with the SeqCst latch store in
    // `Service::shutdown`; because the worker's exit (sentinel consumption)
    // happens after that store, the join that reported `crashed` cannot
    // complete before the latch became visible, so this load can never miss
    // a raised latch for a sentinel-triggered exit.
    crashed && !stop.load(Ordering::SeqCst)
}

fn supervisor_loop(
    ctx: WorkerCtx,
    mut slots: Vec<Option<std::thread::JoinHandle<()>>>,
    stop: Arc<AtomicBool>,
) {
    loop {
        // ordering: SeqCst — pairs with the shutdown latch store; see
        // `should_respawn`.
        if stop.load(Ordering::SeqCst) {
            for h in slots.iter_mut().filter_map(Option::take) {
                let _ = h.join();
            }
            return;
        }
        let mut alive = 0usize;
        for w in 0..slots.len() {
            let finished = slots[w].as_ref().is_some_and(|h| h.is_finished());
            if finished {
                let crashed =
                    slots[w].take().expect("slot checked Some above").join().is_err();
                // Re-check the latch after the join: a crash racing shutdown
                // must not respawn a worker into a pool being torn down.
                if should_respawn(crashed, &stop) {
                    slots[w] = Some(ctx.spawn(w));
                    ctx.stats.record_respawn();
                    alive += 1;
                }
            } else if slots[w].is_some() {
                alive += 1;
            }
        }
        if alive == 0 {
            // Every worker exited cleanly (service dropped without shutdown,
            // or all sentinels consumed) — nothing left to supervise.
            return;
        }
        std::thread::park_timeout(SUPERVISE_INTERVAL);
    }
}

// ---------------------------------------------------------------------------
// Batcher: dynamic batching of cs_vec onto the XLA cs_batch executable
// ---------------------------------------------------------------------------

fn batcher_loop(
    rx: Receiver<QueueMsg>,
    runtime: Option<RuntimeHandle>,
    table: crate::hash::HashTable,
    batch_size: usize,
    deadline: Duration,
    stats: Arc<Stats>,
) {
    let in_dim = table.domain();
    let out_dim = table.range;
    let h_i32: Vec<i32> = table.h.iter().map(|&v| v as i32).collect();
    let s_f32: Vec<f32> = table.s.iter().map(|&v| v as f32).collect();
    let cs = crate::sketch::CountSketch::new(table.clone());
    let mut stopping = false;

    let depth = &crate::obs::metrics().queue_depth_batcher;
    while !stopping {
        // Block for the first job of the batch.
        let first = match rx.recv() {
            Ok(QueueMsg::Work(j)) => {
                depth.dec();
                j
            }
            Ok(QueueMsg::Stop) | Err(_) => return,
        };
        let mut batch = vec![first];
        let flush_at = Instant::now() + deadline;
        while batch.len() < batch_size {
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            match rx.recv_timeout(flush_at - now) {
                Ok(QueueMsg::Work(j)) => {
                    depth.dec();
                    batch.push(j);
                }
                Ok(QueueMsg::Stop) => {
                    stopping = true; // flush this batch, then exit
                    break;
                }
                Err(_) => break,
            }
        }
        // Dequeue-time load shedding: a job whose deadline expired while it
        // sat in the queue gets its DeadlineExceeded reply *now*, before the
        // batch buys transform work on its behalf — under overload this is
        // the difference between a queue that drains and one that melts.
        batch.retain(|job| match job.deadline {
            Some(dl) if Instant::now() >= dl => {
                stats.record_deadline_shed(ShedStage::Dequeue);
                let _ = job.reply.send(Err(ServiceError::DeadlineExceeded));
                false
            }
            _ => true,
        });
        if batch.is_empty() {
            continue;
        }
        stats.record_batch(batch.len());

        // Execute: XLA path (pad to batch_size) or pure-Rust fallback.
        let results: Vec<Result<Vec<f64>, ServiceError>> = match &runtime {
            Some(rt) => {
                let mut x = vec![0.0f32; batch_size * in_dim];
                for (row, job) in batch.iter().enumerate() {
                    let Request::CsVec { x: v } = &job.req else { unreachable!() };
                    for (c, &val) in v.iter().enumerate() {
                        x[row * in_dim + c] = val as f32;
                    }
                }
                let args = vec![
                    TensorArg::f32(&[batch_size, in_dim], x),
                    TensorArg::i32(&[in_dim], h_i32.clone()),
                    TensorArg::f32(&[in_dim], s_f32.clone()),
                ];
                match rt.run("cs_batch", args) {
                    Ok(outs) => {
                        let data = &outs[0].data;
                        (0..batch.len())
                            .map(|row| {
                                Ok(data[row * out_dim..(row + 1) * out_dim]
                                    .iter()
                                    .map(|&v| v as f64)
                                    .collect())
                            })
                            .collect()
                    }
                    Err(e) => batch
                        .iter()
                        .map(|_| Err(ServiceError::Exec(e.to_string())))
                        .collect(),
                }
            }
            None => batch
                .iter()
                .map(|job| {
                    let Request::CsVec { x } = &job.req else { unreachable!() };
                    Ok(cs.apply(x))
                })
                .collect(),
        };

        for (job, result) in batch.into_iter().zip(results) {
            let latency = job.enqueued.elapsed().as_secs_f64() * 1e6;
            stats.record("cs_vec", latency);
            let _ = job.reply.send(result.map(Response::Sketch));
        }
    }
}

// ---------------------------------------------------------------------------
// Worker pool: pure-Rust sketch ops (+ XLA fcs_rank1 when shapes match)
// ---------------------------------------------------------------------------

/// How many already-queued jobs a worker drains per wakeup when the pool is
/// saturated. Drained jobs are committed to this worker, so the bound also
/// caps the transient head-of-line blocking if a sibling frees up mid-batch:
/// small enough to keep that bounded, large enough that a burst of
/// same-shape jobs shares one warm-up. Fused flights are bounded by the
/// same constant — a flight never exceeds one drained batch.
const WORKER_DRAIN: usize = 8;

/// Bounded batch-mate wait: when a saturated worker's opportunistic drain
/// finds the queue momentarily empty, it waits at most this long for more
/// jobs to arrive before executing what it has. This is the fusion flush
/// policy's "lone request is never held hostage" bound — the extra latency
/// a solitary request can pay for the *chance* of a wider flight.
const FUSE_MAX_WAIT: Duration = Duration::from_micros(100);

/// The deterministic per-request RNG: every worker-pool job's hash draws
/// come from `seed ^ (req_id · φ₆₄)`, fully determined by the service seed
/// and the request counter. This is the single home of that rule — the
/// fused execution path re-derives per-job RNGs from stored `req_id`s (both
/// for the flight itself and for the serial retry after a poisoned flight),
/// and the determinism tests reconstruct reference outputs through it.
pub fn job_rng(seed: u64, req_id: u64) -> Rng {
    Rng::seed_from_u64(seed ^ req_id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Per-worker reusable execution state: FFT workspace (scratch arenas +
/// cached plan handles), a [`ModeHashes`] redraw arena for the dense paths,
/// and per-mode [`CountSketch`] storage for the spectral CP path. Public so
/// the allocation-discipline test can drive the exact service compute paths
/// with a counting allocator.
pub struct WorkerState {
    ws: FftWorkspace,
    /// Hash arena for `sketch_dense` / `inner_estimate` (redrawn in place).
    hashes: ModeHashes,
    /// Per-mode count sketches for `sketch_cp` (tables redrawn in place).
    cs_modes: Vec<CountSketch>,
    /// Flight-wide hash arena for fused `sketch_cp`: `width · order` tables,
    /// job-major, each job's slice redrawn from its own RNG.
    fused_tables: Vec<CountSketch>,
    /// Sketch scratch for `inner_estimate`.
    sa: Vec<f64>,
    sb: Vec<f64>,
    /// Per-repetition estimates for `inner_estimate`.
    ests: Vec<f64>,
}

impl Default for WorkerState {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerState {
    pub fn new() -> Self {
        Self {
            ws: FftWorkspace::new(),
            hashes: ModeHashes::empty(),
            cs_modes: Vec::new(),
            fused_tables: Vec::new(),
            sa: Vec::new(),
            sb: Vec::new(),
            ests: Vec::new(),
        }
    }

    /// Fold/length parameters of a dense sketch under the *current* hash
    /// arena: TS buckets mod `J`, FCS keeps the composite range un-folded.
    /// The single source of truth for both dense service ops.
    fn dense_params(&self, method: SketchMethod, j: usize) -> (Option<usize>, usize) {
        match method {
            SketchMethod::Ts => (Some(j), j),
            SketchMethod::Fcs => (None, self.hashes.composite_range()),
        }
    }

    /// The `sketch_dense` op body: fresh per-mode hash draw (arena storage
    /// reused) + the `O(nnz)` dense walk into `out`. Zero heap allocations
    /// in steady state (same shape/J stream).
    pub fn sketch_dense_into(
        &mut self,
        tensor: &Tensor,
        method: SketchMethod,
        j: usize,
        rng: &mut Rng,
        out: &mut Vec<f64>,
    ) {
        self.hashes.redraw_uniform(rng, &tensor.shape, j);
        let (modulo, len) = self.dense_params(method, j);
        out.clear();
        out.resize(len, 0.0);
        sketch_dense_into(tensor, &self.hashes, modulo, out);
    }

    /// The `sketch_shard` op body: redraw the dense hash arena from the
    /// merge **group's** RNG (so every shard of the group scatters under
    /// identical tables — the additivity contract), then the `O(slab)`
    /// windowed scatter. Same arena/steady-state discipline as
    /// [`Self::sketch_dense_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn sketch_shard_into(
        &mut self,
        slab: &[f64],
        offset: usize,
        dims: &[usize],
        method: SketchMethod,
        j: usize,
        rng: &mut Rng,
        out: &mut Vec<f64>,
    ) {
        self.hashes.redraw_uniform(rng, dims, j);
        let (modulo, len) = self.dense_params(method, j);
        out.clear();
        out.resize(len, 0.0);
        crate::sketch::merge::scatter_slab(slab, offset, &self.hashes, modulo, out);
    }

    /// The `sketch_cp` pure-Rust body: per-mode hash redraw into the
    /// count-sketch arena, then the shared spectral core's one-IFFT rank
    /// accumulation — which batches all R·N mode spectra of each rank chunk
    /// through one `fft_real_many_into` blocked pass over this worker's
    /// arena (split-plane kernel, batch innermost). Zero heap allocations in
    /// steady state.
    pub fn sketch_cp_into(&mut self, cp: &CpTensor, j: usize, rng: &mut Rng, out: &mut Vec<f64>) {
        let order = cp.order();
        self.cs_modes.truncate(order);
        while self.cs_modes.len() < order {
            self.cs_modes
                .push(CountSketch::new(HashTable { h: Vec::new(), s: Vec::new(), range: 0 }));
        }
        crate::hash::redraw_tables_uniform(
            rng,
            j,
            self.cs_modes
                .iter_mut()
                .map(|cs| &mut cs.table)
                .zip(cp.factors.iter().map(|f| f.rows)),
        );
        // J̃ derived from the tables actually drawn (one formula home in the
        // core), so this stays correct if ranges ever become heterogeneous.
        let core = SpectralSketchCore::linear_from_modes(&self.cs_modes);
        core.apply_cp_into(cp, &mut self.ws, out);
    }

    /// Cross-request fused `sketch_cp`: execute `cps.len()` same-geometry CP
    /// jobs as one spectral flight. Every job's per-mode tables are redrawn
    /// into the flight arena from **its own** RNG (exactly the draw stream a
    /// serial [`Self::sketch_cp_into`] would consume), then all jobs' rank
    /// spectra share `SpectralDriver` lane chunks and batched inverses
    /// through [`apply_cp_fused`]. `outs[jb]` receives job `jb`'s sketch,
    /// **bit-identical** to its serial run — the coordinator's determinism
    /// tests drive this entry point directly against the serial one.
    ///
    /// Requires all jobs to share `j`, order, and per-mode dims (the fusion
    /// class [`Request::fuses_with`] enforces); ranks may differ.
    pub fn sketch_cp_fused(
        &mut self,
        cps: &[&CpTensor],
        j: usize,
        rngs: &mut [Rng],
        outs: &mut Vec<Vec<f64>>,
    ) {
        assert_eq!(cps.len(), rngs.len(), "one RNG per fused job");
        outs.clear();
        let width = cps.len();
        if width == 0 {
            return;
        }
        let order = cps[0].order();
        debug_assert!(
            cps.iter().all(|cp| cp.order() == order
                && cp
                    .factors
                    .iter()
                    .map(|f| f.rows)
                    .eq(cps[0].factors.iter().map(|f| f.rows))),
            "sketch_cp_fused: flight mixes shapes"
        );
        // Flight hash arena: width · order tables, job-major. Draw order is
        // per job, modes in order — the same stream the serial path's
        // per-job `cs_modes` redraw consumes.
        let total = width * order;
        self.fused_tables.truncate(total);
        while self.fused_tables.len() < total {
            self.fused_tables
                .push(CountSketch::new(HashTable { h: Vec::new(), s: Vec::new(), range: 0 }));
        }
        for ((jb, cp), rng) in cps.iter().enumerate().zip(rngs.iter_mut()) {
            crate::hash::redraw_tables_uniform(
                rng,
                j,
                self.fused_tables[jb * order..(jb + 1) * order]
                    .iter_mut()
                    .map(|cs| &mut cs.table)
                    .zip(cp.factors.iter().map(|f| f.rows)),
            );
        }
        let tables = &self.fused_tables;
        let flight: Vec<FusedCpJob<'_>> = cps
            .iter()
            .enumerate()
            .map(|(jb, cp)| FusedCpJob {
                core: SpectralSketchCore::linear_from_modes(&tables[jb * order..(jb + 1) * order]),
                factors: &cp.factors,
                lambda: &cp.lambda,
                rank: cp.rank(),
            })
            .collect();
        let sketch_len = flight[0].core.sketch_len;
        outs.resize(width, Vec::new());
        apply_cp_fused(&flight, &mut self.ws, |jb, z| {
            outs[jb].clear();
            outs[jb].extend_from_slice(&z[..sketch_len]);
        });
    }

    /// The `inner_estimate` op body: `d` independent hash redraws, both
    /// tensors sketched into reusable scratch, median of the per-repetition
    /// inner products. Zero heap allocations in steady state.
    pub fn inner_estimate(
        &mut self,
        a: &Tensor,
        b: &Tensor,
        method: SketchMethod,
        j: usize,
        d: usize,
        rng: &mut Rng,
    ) -> f64 {
        self.ests.clear();
        self.ests.reserve(d);
        for _ in 0..d {
            self.hashes.redraw_uniform(rng, &a.shape, j);
            let (modulo, len) = self.dense_params(method, j);
            self.sa.clear();
            self.sa.resize(len, 0.0);
            self.sb.clear();
            self.sb.resize(len, 0.0);
            sketch_dense_into(a, &self.hashes, modulo, &mut self.sa);
            sketch_dense_into(b, &self.hashes, modulo, &mut self.sb);
            self.ests.push(crate::linalg::dot(&self.sa, &self.sb));
        }
        // total_cmp, not partial_cmp().unwrap(): a NaN smuggled in through a
        // client tensor must not panic a worker mid-batch (which would drop
        // every other committed job's reply and shrink the pool for good).
        self.ests.sort_unstable_by(f64::total_cmp);
        crate::util::timing::percentile_sorted(&self.ests, 50.0)
    }

    /// Execute one worker-pool request. The returned `Response` owns its
    /// payload (it leaves the worker), so the payload `Vec` is the only
    /// per-request allocation on the pure-Rust paths.
    fn execute(
        &mut self,
        req: &Request,
        runtime: &Option<RuntimeHandle>,
        seed: u64,
        rng: &mut Rng,
    ) -> Result<Response, ServiceError> {
        match req {
            Request::CsVec { .. } => unreachable!("cs_vec is routed to the batcher"),
            Request::SketchDense { tensor, method, j } => {
                let mut out = Vec::new();
                self.sketch_dense_into(tensor, *method, *j, rng, &mut out);
                Ok(Response::Sketch(out))
            }
            Request::SketchCp { cp, j } => {
                // XLA fast path if the artifact's static shapes match.
                if let Some(rt) = runtime {
                    if let Some(e) = rt.manifest().entries.get("fcs_rank1") {
                        // Probe via the factors directly — cp.shape() would
                        // heap-allocate a Vec per request on this path.
                        let dims_match = e.meta_usize("dim").map(|d| {
                            cp.order() == 3 && cp.factors.iter().all(|f| f.rows == d)
                        }) == Some(true)
                            && e.meta_usize("rank") == Some(cp.rank())
                            && e.meta_usize("j") == Some(*j);
                        if dims_match {
                            return sketch_cp_xla(rt, cp, *j, rng);
                        }
                    }
                }
                // Workers are already a pool: run the serial spectral path
                // with this worker's reusable state (one IFFT per request).
                let mut out = Vec::new();
                self.sketch_cp_into(cp, *j, rng, &mut out);
                Ok(Response::Sketch(out))
            }
            Request::InnerEstimate { a, b, method, j, d } => {
                Ok(Response::Scalar(self.inner_estimate(a, b, *method, *j, *d, rng)))
            }
            Request::SketchShard { slab, offset, dims, method, j, group } => {
                // Hash draws come from the merge *group's* RNG, not the
                // per-request one — every shard of `group` must reproduce
                // identical tables or the merged sum is garbage. The per-
                // request rng stays untouched (shard determinism is keyed
                // `(seed, group)`, independent of req_id arrival order).
                let mut grng = crate::sketch::merge::group_rng(seed, *group);
                let mut out = Vec::new();
                self.sketch_shard_into(slab, *offset, dims, *method, *j, &mut grng, &mut out);
                crate::obs::metrics().shard_width.observe(slab.len() as u64);
                Ok(Response::Sketch(out))
            }
            Request::MergeShards { parts } => {
                // Failpoint: Error maps onto the local Exec path;
                // TruncateSlab tears one element off the first part before
                // the reduce, arriving exactly the way a corrupted shard
                // reply would — the equal-length assert inside
                // `tree_reduce_parts` then panics, and the per-job
                // catch_unwind confines the damage to this merge group.
                match crate::fault::check("merge_shards") {
                    Some(FaultAction::Error) => {
                        return Err(ServiceError::Exec("merge_shards: injected fault".into()))
                    }
                    Some(FaultAction::TruncateSlab) => {
                        let mut torn = parts.clone();
                        if let Some(p) = torn.first_mut() {
                            p.pop();
                        }
                        let (merged, depth) = crate::sketch::merge::tree_reduce_parts(&torn);
                        crate::obs::metrics().merge_depth.observe(depth as u64);
                        return Ok(Response::Sketch(merged));
                    }
                    _ => {}
                }
                // Pure reduce — no draws, no arena. The equal-length assert
                // inside fires as an execution-time panic, which the serial
                // per-job catch_unwind turns into an Exec error for exactly
                // this merge group.
                let (merged, depth) = crate::sketch::merge::tree_reduce_parts(parts);
                crate::obs::metrics().merge_depth.observe(depth as u64);
                Ok(Response::Sketch(merged))
            }
        }
    }
}

fn worker_loop(
    worker: usize,
    rx: Arc<Mutex<Receiver<QueueMsg>>>,
    runtime: Option<RuntimeHandle>,
    seed: u64,
    counter: Arc<AtomicU64>,
    busy: Arc<AtomicUsize>,
    pool_size: usize,
    stats: Arc<Stats>,
) {
    let depth = &crate::obs::metrics().queue_depth_worker;
    let mut state = WorkerState::new();
    let mut batch: Vec<Box<Job>> = Vec::with_capacity(WORKER_DRAIN);
    loop {
        // Failpoint: a Panic here kills the whole worker thread *outside*
        // any catch_unwind — the supervisor's respawn path. Deliberately
        // before the queue lock: dying while holding it would poison the
        // mutex and wedge every sibling.
        crate::fault::act("worker_loop");
        let mut stopping = false;
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(QueueMsg::Work(j)) => {
                    depth.dec();
                    batch.push(j);
                }
                Ok(QueueMsg::Stop) | Err(_) => return,
            }
            // Opportunistic drain — but only while every *other* worker is
            // executing (advisory counter, re-read per iteration): an idle
            // sibling would pick queued jobs up immediately, so grabbing
            // them here would serialize a light-load burst onto this one
            // thread. Under saturation the backlog waits either way, and
            // draining buys same-shape warm-workspace grouping plus the
            // chance of a fused flight (residual trade-off: a drained job is
            // committed to this worker, so a sibling freeing up mid-batch
            // waits at most WORKER_DRAIN − 1 jobs). When the queue is
            // momentarily empty, wait up to FUSE_MAX_WAIT for batch-mates —
            // bounded, so a lone request is never held hostage. Stop
            // draining at the first sentinel — it is *this* worker's; eating
            // further ones could leave a sibling running.
            let flush_at = Instant::now() + FUSE_MAX_WAIT;
            // ordering: Relaxed — advisory saturation signal, re-read every
            // iteration; a stale value only mis-sizes one drain decision.
            while busy.load(Ordering::Relaxed) + 1 >= pool_size
                && batch.len() < WORKER_DRAIN
                && !stopping
            {
                match guard.try_recv() {
                    Ok(QueueMsg::Work(j)) => {
                        depth.dec();
                        batch.push(j);
                    }
                    Ok(QueueMsg::Stop) => stopping = true,
                    Err(_) => {
                        let now = Instant::now();
                        if now >= flush_at {
                            break;
                        }
                        match guard.recv_timeout(flush_at - now) {
                            Ok(QueueMsg::Work(j)) => {
                                depth.dec();
                                batch.push(j);
                            }
                            Ok(QueueMsg::Stop) => stopping = true,
                            Err(_) => break,
                        }
                    }
                }
            }
        }
        // Dequeue timestamp for this drained batch — the trace spans' "queue"
        // event (the moment the jobs left the queue for this worker).
        let drained = Instant::now();
        // Same-shape grouping: stable order within a key does not matter for
        // correctness (every job gets its own hash draw), so use the
        // in-place unstable sort — no allocation in the drain loop.
        batch.sort_unstable_by_key(|job| job.req.shape_key());
        // ordering: Relaxed — advisory saturation counter (see drain loop);
        // the RMW pairs exactly with BusyGuard's decrement, so the count
        // can sag or lag but never drift.
        busy.fetch_add(1, Ordering::Relaxed);
        // Drop guard: if anything below panics mid-batch, the unwind must
        // still decrement the busy counter, or every surviving worker would
        // see a permanently inflated saturation signal and over-drain.
        let _busy_guard = BusyGuard(&busy);
        // Partition the sorted batch into maximal fusion-class runs
        // (flights). shape_key sorting makes same-class jobs adjacent;
        // fuses_with draws the exact boundary (an FNV key collision lands
        // two classes next to each other but never inside one flight).
        let mut i = 0;
        while i < batch.len() {
            let mut end = i + 1;
            while end < batch.len() && batch[end].req.fuses_with(&batch[i].req) {
                end += 1;
            }
            execute_flight(&mut state, worker, &batch[i..end], drained, &runtime, seed, &counter, &stats);
            i = end;
        }
        batch.clear();
        drop(_busy_guard);
        if stopping {
            return;
        }
    }
}

/// Execute one flight — a maximal run of mutually fusing jobs from a sorted
/// drained batch. CP flights wider than one job (whose class the XLA
/// artifact would *not* serve) run through [`WorkerState::sketch_cp_fused`];
/// everything else (dense warm-arena runs, inner estimates, singletons,
/// XLA-eligible CP classes) runs serially per job so backend choice and
/// draw streams match pre-fusion behavior exactly.
///
/// Every job's `req_id` is drawn from the shared counter *up front*, in
/// batch order, so its deterministic [`job_rng`] is fixed before the
/// execution strategy is chosen — a panic inside a fused attempt rebuilds
/// the worker state and retries each job serially with the *same* RNG,
/// keeping healthy outputs bit-identical while the poisoned job alone pays
/// with an [`ServiceError::Exec`] reply.
#[allow(clippy::too_many_arguments)]
fn execute_flight(
    state: &mut WorkerState,
    worker: usize,
    jobs: &[Box<Job>],
    drained: Instant,
    runtime: &Option<RuntimeHandle>,
    seed: u64,
    counter: &AtomicU64,
    stats: &Stats,
) {
    let width = jobs.len();
    debug_assert!((1..=WORKER_DRAIN).contains(&width));
    let mut req_ids = [0u64; WORKER_DRAIN];
    for slot in req_ids.iter_mut().take(width) {
        // ordering: Relaxed — RMW uniqueness is all `job_rng` keying needs;
        // cross-worker draw order is inherently racy and meaningless.
        *slot = counter.fetch_add(1, Ordering::Relaxed);
    }
    let exec_start = Instant::now();
    let op = jobs[0].req.op_name();
    // Queue-wait is submit → flight start; exec is flight start → reply.
    // saturating: Instant math must not panic on cross-thread clock skew.
    // Besides the reservoir/registry recording, every finished job leaves a
    // trace span (submit → queue → flight-start → reply, keyed by its
    // `job_rng` req_id) in this worker's ring; each edge is clamped to its
    // predecessor so the recorded ordering is structural, not clock-trusting.
    let finish = |job: &Job, req_id: u64, result: Result<Response, ServiceError>| {
        let total_us = job.enqueued.elapsed().as_secs_f64() * 1e6;
        let queue_us = exec_start.saturating_duration_since(job.enqueued).as_secs_f64() * 1e6;
        let exec_us = exec_start.elapsed().as_secs_f64() * 1e6;
        stats.record_job(op, total_us, queue_us, exec_us);
        let ok = result.is_ok();
        let _ = job.reply.send(result);
        let submit_us = trace::epoch_us(job.enqueued);
        let queue_evt_us = trace::epoch_us(drained).max(submit_us);
        let flight_start_us = trace::epoch_us(exec_start).max(queue_evt_us);
        let reply_us = trace::epoch_us(Instant::now()).max(flight_start_us);
        trace::global().record(
            worker,
            trace::TraceSpan {
                req_id,
                op,
                submit_us,
                queue_us: queue_evt_us,
                flight_start_us,
                reply_us,
                width: width as u16,
                ok,
            },
        );
    };
    // Shed a job whose deadline expired before (or between) executions: the
    // DeadlineExceeded reply costs no spectral work, the shed is booked at
    // its stage, and the trace ring gets an `ok: false` span with the same
    // structurally clamped edges as a finished job.
    let shed = |job: &Job, req_id: u64, stage: ShedStage| {
        stats.record_deadline_shed(stage);
        let _ = job.reply.send(Err(ServiceError::DeadlineExceeded));
        let submit_us = trace::epoch_us(job.enqueued);
        let queue_evt_us = trace::epoch_us(drained).max(submit_us);
        let flight_start_us = trace::epoch_us(exec_start).max(queue_evt_us);
        let reply_us = trace::epoch_us(Instant::now()).max(flight_start_us);
        trace::global().record(
            worker,
            trace::TraceSpan {
                req_id,
                op,
                submit_us,
                queue_us: queue_evt_us,
                flight_start_us,
                reply_us,
                width: width as u16,
                ok: false,
            },
        );
    };
    // Flight-start shed pass: jobs already expired when the flight begins
    // are dropped from the live set before any strategy is chosen — a fused
    // flight packs *survivors only* into the shared transform lanes, and
    // each survivor keeps the `job_rng` of its up-front req_id, so shedding
    // a flight-mate never perturbs a survivor's bit-exact output.
    let mut live = [true; WORKER_DRAIN];
    let mut live_n = 0usize;
    for (k, job) in jobs.iter().enumerate() {
        if job.deadline.is_some_and(|dl| exec_start >= dl) {
            live[k] = false;
            shed(job, req_ids[k], ShedStage::Dequeue);
        } else {
            live_n += 1;
        }
    }
    if live_n == 0 {
        return;
    }
    let fused_cp = live_n > 1
        && matches!(jobs[0].req, Request::SketchCp { .. })
        && !cp_flight_matches_xla(runtime, &jobs[0].req);
    let mut fused_done = false;
    let mut executed = 0usize;
    if fused_cp {
        let Request::SketchCp { j, .. } = &jobs[0].req else { unreachable!() };
        let live_idx: Vec<usize> = (0..width).filter(|&k| live[k]).collect();
        let cps: Vec<&CpTensor> = live_idx
            .iter()
            .map(|&k| match &jobs[k].req {
                Request::SketchCp { cp, .. } => cp,
                _ => unreachable!("fused flight mixes ops"),
            })
            .collect();
        // Flight-level panic isolation: a poisoned job inside the shared
        // transform (validation is best-effort — degenerate numerics can
        // still trip kernel asserts) unwinds the whole fused attempt; fall
        // through to the per-job serial loop below, where it costs exactly
        // its own reply.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rngs: Vec<Rng> =
                live_idx.iter().map(|&k| job_rng(seed, req_ids[k])).collect();
            let mut outs = Vec::new();
            state.sketch_cp_fused(&cps, *j, &mut rngs, &mut outs);
            outs
        }));
        match caught {
            Ok(outs) => {
                for (&k, out) in live_idx.iter().zip(outs) {
                    finish(&jobs[k], req_ids[k], Ok(Response::Sketch(out)));
                }
                executed = live_idx.len();
                fused_done = true;
            }
            Err(_) => {
                // The arenas may have been mid-rewrite when the unwind tore
                // through them — rebuild rather than trust a torn workspace,
                // then retry serially (fresh RNGs re-derived per req_id).
                crate::obs::metrics().fused_flight_aborts.inc();
                *state = WorkerState::new();
            }
        }
    }
    // Serial path: the sole path for non-CP flights and singletons, and the
    // retry path after a poisoned fused attempt. Per-job panic isolation: a
    // poisoned request must cost exactly its own reply, not unwind the
    // worker and silently drop every remaining drained job's sender.
    // Between members, the deadline is re-checked: a job whose budget a
    // flight-mate's execution just consumed is shed (Flight stage) instead
    // of executed late. The first live member always runs — its deadline
    // was checked at flight start moments ago.
    if !fused_done {
        for (k, job) in jobs.iter().enumerate() {
            if !live[k] {
                continue;
            }
            if executed > 0 && job.deadline.is_some_and(|dl| Instant::now() >= dl) {
                shed(job, req_ids[k], ShedStage::Flight);
                continue;
            }
            let mut rng = job_rng(seed, req_ids[k]);
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // Failpoint: Delay manufactures queue backlog/deadline expiry;
                // Panic exercises exactly the per-job isolation below.
                crate::fault::act("worker_job");
                state.execute(&job.req, runtime, seed, &mut rng)
            }));
            let result = match caught {
                Ok(r) => r,
                Err(payload) => {
                    crate::obs::metrics().poisoned_jobs.inc();
                    *state = WorkerState::new();
                    Err(ServiceError::Exec(format!(
                        "worker panicked: {}",
                        panic_message(payload.as_ref())
                    )))
                }
            };
            executed += 1;
            finish(job, req_ids[k], result);
        }
    }
    if executed > 0 {
        stats.record_flight(executed, exec_start.elapsed().as_secs_f64() * 1e6);
    }
}

/// Whether a CP request's fusion class would be served by the XLA
/// `fcs_rank1` executable on the serial path. Such flights run serially per
/// job — fusion must never change backend choice. Rank is deliberately
/// unchecked: it is not part of the fusion class, so a mixed-rank flight
/// where *some* jobs would go XLA still runs whole-flight serial, which
/// preserves exact per-job serial behavior.
fn cp_flight_matches_xla(runtime: &Option<RuntimeHandle>, req: &Request) -> bool {
    let (Some(rt), Request::SketchCp { cp, j }) = (runtime.as_ref(), req) else {
        return false;
    };
    let Some(e) = rt.manifest().entries.get("fcs_rank1") else {
        return false;
    };
    e.meta_usize("dim")
        .map(|d| cp.order() == 3 && cp.factors.iter().all(|f| f.rows == d))
        == Some(true)
        && e.meta_usize("j") == Some(*j)
}

/// Best-effort human-readable message from a caught panic payload
/// (`panic!("…")` carries a `&str` or `String`; anything else gets a tag).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Decrements the worker-pool busy counter on drop (including panic
/// unwinds), keeping the drain heuristic's saturation signal truthful.
struct BusyGuard<'a>(&'a AtomicUsize);

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        // ordering: Relaxed — pairs with the worker loop's increment on the
        // advisory saturation counter; exactness comes from the RMW pair,
        // not from publication order.
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

fn sketch_cp_xla(
    rt: &RuntimeHandle,
    cp: &crate::tensor::CpTensor,
    j: usize,
    rng: &mut Rng,
) -> Result<Response, ServiceError> {
    let dim = cp.factors[0].rows;
    let rank = cp.rank();
    let mh = ModeHashes::draw_uniform(rng, &cp.shape(), j);
    let to_rowmajor = |m: &crate::linalg::Matrix| -> Vec<f32> {
        let mut v = Vec::with_capacity(m.rows * m.cols);
        for r in 0..m.rows {
            for c in 0..m.cols {
                v.push(m.get(r, c) as f32);
            }
        }
        v
    };
    let mut args = Vec::new();
    for f in &cp.factors {
        args.push(TensorArg::f32(&[dim, rank], to_rowmajor(f)));
    }
    args.push(TensorArg::f32(
        &[rank],
        cp.lambda.iter().map(|&l| l as f32).collect(),
    ));
    for m in &mh.modes {
        args.push(TensorArg::i32(&[dim], m.h.iter().map(|&v| v as i32).collect()));
        args.push(TensorArg::f32(&[dim], m.s.iter().map(|&v| v as f32).collect()));
    }
    let outs = rt
        .run("fcs_rank1", args)
        .map_err(|e| ServiceError::Exec(e.to_string()))?;
    Ok(Response::Sketch(outs[0].data.iter().map(|&v| v as f64).collect()))
}
