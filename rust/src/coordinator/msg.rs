//! Request/response vocabulary of the sketch service.

use crate::tensor::{CpTensor, Tensor};

/// Client-visible request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Count-sketch one vector under the service's shared hash table.
    /// Batched onto the AOT `cs_batch` XLA executable when available.
    CsVec { x: Vec<f64> },
    /// Sketch a dense tensor with freshly drawn per-mode hashes.
    SketchDense { tensor: Tensor, method: SketchMethod, j: usize },
    /// Sketch a CP tensor (FCS rank-R fast path; served by the `fcs_rank1`
    /// XLA executable when shapes match the artifact, else pure Rust).
    SketchCp { cp: CpTensor, j: usize },
    /// Median-of-D sketched inner-product estimate ⟨A, B⟩.
    InnerEstimate { a: Tensor, b: Tensor, method: SketchMethod, j: usize, d: usize },
    /// Sketch one contiguous column-major slab of a partitioned tensor
    /// under its merge group's **shared** hash draws
    /// ([`crate::sketch::merge::group_rng`]`(seed, group)` — keyed by the
    /// group, not the request, so every shard of `group` reproduces
    /// identical tables and the replies are additive).
    SketchShard {
        /// `vec(T)[offset .. offset + slab.len()]`.
        slab: Vec<f64>,
        /// Column-major linear position of `slab[0]` in the full tensor.
        offset: usize,
        /// Full-tensor dims the shared hashes are drawn for.
        dims: Vec<usize>,
        method: SketchMethod,
        j: usize,
        /// Merge-group id.
        group: u64,
    },
    /// Pairwise tree-reduce previously sketched shard replies (elementwise
    /// add — CS linearity under shared draws). Pure reduce: no hash draws.
    MergeShards { parts: Vec<Vec<f64>> },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchMethod {
    Ts,
    Fcs,
}

/// Successful response payloads.
#[derive(Debug, Clone)]
pub enum Response {
    Sketch(Vec<f64>),
    Scalar(f64),
}

/// Service errors.
#[derive(Debug)]
pub enum ServiceError {
    /// Service queue is full (backpressure).
    Busy,
    /// Service is shutting down.
    Closed,
    /// Request failed validation.
    BadRequest(String),
    /// Execution failed.
    Exec(String),
    /// The request's deadline expired (or provably could not be met) before
    /// execution: refused at submit by the admission controller, shed at
    /// dequeue, or shed mid-flight — in every case *without* burning a
    /// spectral pass on an answer nobody is waiting for.
    DeadlineExceeded,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Busy => write!(f, "service queue is full (backpressure)"),
            ServiceError::Closed => write!(f, "service is shutting down"),
            ServiceError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServiceError::Exec(msg) => write!(f, "execution failed: {msg}"),
            ServiceError::DeadlineExceeded => write!(f, "deadline exceeded"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl Request {
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::CsVec { .. } => "cs_vec",
            Request::SketchDense { .. } => "sketch_dense",
            Request::SketchCp { .. } => "sketch_cp",
            Request::InnerEstimate { .. } => "inner_estimate",
            Request::SketchShard { .. } => "sketch_shard",
            Request::MergeShards { .. } => "merge_shards",
        }
    }

    /// Grouping key `(op·method, j, dims-fold)` — the worker pool sorts its
    /// drained batch by this so same-shape jobs run consecutively on a warm
    /// workspace/hash arena (one plan lookup and zero redraw reallocation
    /// for the whole run). Arena warmth depends on the exact per-mode
    /// domains and the order (they set hash-table sizes, J̃ and the FFT
    /// plan lengths), so the key folds the dims order-sensitively instead
    /// of collapsing them to a product — `[8,8]` and `[4,4,4]` must not
    /// group together.
    ///
    /// This key is for *sorting only*: an FNV dims-fold collision costs
    /// warmth, never correctness. Cross-request fused flights need true
    /// shape equality and must gate on [`Self::fuses_with`] instead.
    pub fn shape_key(&self) -> (u8, usize, usize) {
        // Tiny FNV-style mix; collisions only cost grouping quality, never
        // correctness (every job still gets its own hash draw).
        fn dims_key(dims: impl Iterator<Item = usize>) -> usize {
            dims.fold(0usize, |h, d| {
                h.wrapping_mul(0x0100_0000_01B3).wrapping_add(d.wrapping_add(1))
            })
        }
        match self {
            Request::CsVec { x } => (0, 0, x.len()),
            Request::SketchDense { tensor, method, j } => {
                let m = match method {
                    SketchMethod::Ts => 1,
                    SketchMethod::Fcs => 2,
                };
                (m, *j, dims_key(tensor.shape.iter().copied()))
            }
            Request::SketchCp { cp, j } => {
                // Rank does not affect arena warmth — key on the dims only.
                (3, *j, dims_key(cp.factors.iter().map(|f| f.rows)))
            }
            Request::InnerEstimate { a, method, j, .. } => {
                // Method is part of the shape: Ts and Fcs sketch to
                // different lengths (j vs J̃). The repetition count d does
                // not touch the arenas, so it stays out of the key.
                let m = match method {
                    SketchMethod::Ts => 4,
                    SketchMethod::Fcs => 5,
                };
                (m, *j, dims_key(a.shape.iter().copied()))
            }
            Request::SketchShard { dims, method, j, .. } => {
                // Same arena-warmth logic as SketchDense (the shard scatter
                // reuses the dense hash arena); offset/group stay out of the
                // key — they change neither table sizes nor plan lengths.
                let m = match method {
                    SketchMethod::Ts => 6,
                    SketchMethod::Fcs => 7,
                };
                (m, *j, dims_key(dims.iter().copied()))
            }
            Request::MergeShards { parts } => {
                // The reduce touches no arena; group by fan-in and part
                // length so equal-size merges at least run consecutively.
                (8, parts.len(), parts.first().map_or(0, |p| p.len()))
            }
        }
    }

    /// Exact fusion-class equality: whether two requests may share one fused
    /// worker flight. Unlike [`Self::shape_key`]'s FNV dims-fold (where a
    /// collision merely costs arena warmth), fusion packs jobs into shared
    /// transform lanes, so the dims are compared **verbatim** — a hash
    /// collision between `[8,8]` and `[4,4,4]` can never fuse them. Only
    /// `SketchDense`/`SketchCp` fuse; CP rank is deliberately *not* part of
    /// the class (rank is a per-job group count, not spectral geometry).
    pub fn fuses_with(&self, other: &Request) -> bool {
        match (self, other) {
            (
                Request::SketchDense { tensor: ta, method: ma, j: ja },
                Request::SketchDense { tensor: tb, method: mb, j: jb },
            ) => ma == mb && ja == jb && ta.shape == tb.shape,
            (
                Request::SketchCp { cp: ca, j: ja },
                Request::SketchCp { cp: cb, j: jb },
            ) => {
                ja == jb
                    && ca.factors.len() == cb.factors.len()
                    && ca
                        .factors
                        .iter()
                        .map(|f| f.rows)
                        .eq(cb.factors.iter().map(|f| f.rows))
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_error_display() {
        assert_eq!(ServiceError::Busy.to_string(), "service queue is full (backpressure)");
        assert_eq!(ServiceError::Closed.to_string(), "service is shutting down");
        assert_eq!(
            ServiceError::BadRequest("nope".into()).to_string(),
            "bad request: nope"
        );
        assert_eq!(ServiceError::Exec("boom".into()).to_string(), "execution failed: boom");
        assert_eq!(ServiceError::DeadlineExceeded.to_string(), "deadline exceeded");
    }

    #[test]
    fn fnv_collision_groups_but_never_fuses() {
        // Deliberate dims-fold collision: with the FNV-style fold
        // `h -> h·P + (d+1)` (P = 0x0100_0000_01B3), the one-mode shape
        // `[9P + 8]` folds to exactly the same key as `[8, 8]`:
        //   fold([x])    = x + 1
        //   fold([8, 8]) = 9·P + 9
        // shape_key may (and here does) group them — that only costs arena
        // warmth — but fuses_with must still tell them apart, because a
        // fused flight packs jobs into shared transform lanes.
        const P: usize = 0x0100_0000_01B3;
        let square = Request::SketchDense {
            tensor: Tensor { shape: vec![8, 8], data: Vec::new() },
            method: SketchMethod::Fcs,
            j: 8,
        };
        let colliding = Request::SketchDense {
            tensor: Tensor { shape: vec![9 * P + 8], data: Vec::new() },
            method: SketchMethod::Fcs,
            j: 8,
        };
        assert_eq!(
            square.shape_key(),
            colliding.shape_key(),
            "test premise: the shapes must actually collide under the fold"
        );
        assert!(!square.fuses_with(&colliding), "collision must not fuse");
        assert!(!colliding.fuses_with(&square), "collision must not fuse");
        // Sanity: true same-shape requests do fuse, and fusion is symmetric.
        let square2 = Request::SketchDense {
            tensor: Tensor { shape: vec![8, 8], data: Vec::new() },
            method: SketchMethod::Fcs,
            j: 8,
        };
        assert!(square.fuses_with(&square2) && square2.fuses_with(&square));
        // Method, j, and op-kind all split the fusion class.
        let ts = Request::SketchDense {
            tensor: Tensor { shape: vec![8, 8], data: Vec::new() },
            method: SketchMethod::Ts,
            j: 8,
        };
        assert!(!square.fuses_with(&ts));
        let other_j = Request::SketchDense {
            tensor: Tensor { shape: vec![8, 8], data: Vec::new() },
            method: SketchMethod::Fcs,
            j: 16,
        };
        assert!(!square.fuses_with(&other_j));
    }

    #[test]
    fn cp_requests_fuse_on_dims_not_rank() {
        let mut rng = crate::util::prng::Rng::seed_from_u64(2);
        let a = Request::SketchCp { cp: CpTensor::randn(&mut rng, &[5, 4, 6], 2), j: 12 };
        let b = Request::SketchCp { cp: CpTensor::randn(&mut rng, &[5, 4, 6], 7), j: 12 };
        let c = Request::SketchCp { cp: CpTensor::randn(&mut rng, &[5, 6, 4], 2), j: 12 };
        assert!(a.fuses_with(&b), "rank is not part of the fusion class");
        assert!(!a.fuses_with(&c), "dims order matters");
        assert!(
            !a.fuses_with(&Request::SketchCp {
                cp: CpTensor::randn(&mut rng, &[5, 4, 6], 2),
                j: 16
            }),
            "j splits the class"
        );
    }

    #[test]
    fn shape_key_groups_same_shape() {
        let mut rng = crate::util::prng::Rng::seed_from_u64(1);
        let a = Tensor::randn(&mut rng, &[4, 5, 6]);
        let b = Tensor::randn(&mut rng, &[4, 5, 6]);
        let c = Tensor::randn(&mut rng, &[7, 2, 2]);
        let ka = Request::SketchDense { tensor: a, method: SketchMethod::Fcs, j: 8 }.shape_key();
        let kb = Request::SketchDense { tensor: b, method: SketchMethod::Fcs, j: 8 }.shape_key();
        let kc = Request::SketchDense { tensor: c, method: SketchMethod::Fcs, j: 8 }.shape_key();
        assert_eq!(ka, kb);
        assert_ne!(ka, kc);
        assert_ne!(
            Request::SketchDense {
                tensor: Tensor::randn(&mut rng, &[4, 5, 6]),
                method: SketchMethod::Ts,
                j: 8
            }
            .shape_key(),
            ka
        );
    }
}
