//! Request/response vocabulary of the sketch service.

use crate::tensor::{CpTensor, Tensor};

/// Client-visible request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Count-sketch one vector under the service's shared hash table.
    /// Batched onto the AOT `cs_batch` XLA executable when available.
    CsVec { x: Vec<f64> },
    /// Sketch a dense tensor with freshly drawn per-mode hashes.
    SketchDense { tensor: Tensor, method: SketchMethod, j: usize },
    /// Sketch a CP tensor (FCS rank-R fast path; served by the `fcs_rank1`
    /// XLA executable when shapes match the artifact, else pure Rust).
    SketchCp { cp: CpTensor, j: usize },
    /// Median-of-D sketched inner-product estimate ⟨A, B⟩.
    InnerEstimate { a: Tensor, b: Tensor, method: SketchMethod, j: usize, d: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchMethod {
    Ts,
    Fcs,
}

/// Successful response payloads.
#[derive(Debug, Clone)]
pub enum Response {
    Sketch(Vec<f64>),
    Scalar(f64),
}

/// Service errors.
#[derive(Debug, thiserror::Error)]
pub enum ServiceError {
    #[error("service queue is full (backpressure)")]
    Busy,
    #[error("service is shutting down")]
    Closed,
    #[error("bad request: {0}")]
    BadRequest(String),
    #[error("execution failed: {0}")]
    Exec(String),
}

impl Request {
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::CsVec { .. } => "cs_vec",
            Request::SketchDense { .. } => "sketch_dense",
            Request::SketchCp { .. } => "sketch_cp",
            Request::InnerEstimate { .. } => "inner_estimate",
        }
    }
}
