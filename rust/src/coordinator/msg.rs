//! Request/response vocabulary of the sketch service.

use crate::tensor::{CpTensor, Tensor};

/// Client-visible request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Count-sketch one vector under the service's shared hash table.
    /// Batched onto the AOT `cs_batch` XLA executable when available.
    CsVec { x: Vec<f64> },
    /// Sketch a dense tensor with freshly drawn per-mode hashes.
    SketchDense { tensor: Tensor, method: SketchMethod, j: usize },
    /// Sketch a CP tensor (FCS rank-R fast path; served by the `fcs_rank1`
    /// XLA executable when shapes match the artifact, else pure Rust).
    SketchCp { cp: CpTensor, j: usize },
    /// Median-of-D sketched inner-product estimate ⟨A, B⟩.
    InnerEstimate { a: Tensor, b: Tensor, method: SketchMethod, j: usize, d: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchMethod {
    Ts,
    Fcs,
}

/// Successful response payloads.
#[derive(Debug, Clone)]
pub enum Response {
    Sketch(Vec<f64>),
    Scalar(f64),
}

/// Service errors.
#[derive(Debug)]
pub enum ServiceError {
    /// Service queue is full (backpressure).
    Busy,
    /// Service is shutting down.
    Closed,
    /// Request failed validation.
    BadRequest(String),
    /// Execution failed.
    Exec(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Busy => write!(f, "service queue is full (backpressure)"),
            ServiceError::Closed => write!(f, "service is shutting down"),
            ServiceError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServiceError::Exec(msg) => write!(f, "execution failed: {msg}"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl Request {
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::CsVec { .. } => "cs_vec",
            Request::SketchDense { .. } => "sketch_dense",
            Request::SketchCp { .. } => "sketch_cp",
            Request::InnerEstimate { .. } => "inner_estimate",
        }
    }

    /// Grouping key `(op·method, j, dims-fold)` — the worker pool sorts its
    /// drained batch by this so same-shape jobs run consecutively on a warm
    /// workspace/hash arena (one plan lookup and zero redraw reallocation
    /// for the whole run). Arena warmth depends on the exact per-mode
    /// domains and the order (they set hash-table sizes, J̃ and the FFT
    /// plan lengths), so the key folds the dims order-sensitively instead
    /// of collapsing them to a product — `[8,8]` and `[4,4,4]` must not
    /// group together.
    pub fn shape_key(&self) -> (u8, usize, usize) {
        // Tiny FNV-style mix; collisions only cost grouping quality, never
        // correctness (every job still gets its own hash draw).
        fn dims_key(dims: impl Iterator<Item = usize>) -> usize {
            dims.fold(0usize, |h, d| {
                h.wrapping_mul(0x0100_0000_01B3).wrapping_add(d.wrapping_add(1))
            })
        }
        match self {
            Request::CsVec { x } => (0, 0, x.len()),
            Request::SketchDense { tensor, method, j } => {
                let m = match method {
                    SketchMethod::Ts => 1,
                    SketchMethod::Fcs => 2,
                };
                (m, *j, dims_key(tensor.shape.iter().copied()))
            }
            Request::SketchCp { cp, j } => {
                // Rank does not affect arena warmth — key on the dims only.
                (3, *j, dims_key(cp.factors.iter().map(|f| f.rows)))
            }
            Request::InnerEstimate { a, method, j, .. } => {
                // Method is part of the shape: Ts and Fcs sketch to
                // different lengths (j vs J̃). The repetition count d does
                // not touch the arenas, so it stays out of the key.
                let m = match method {
                    SketchMethod::Ts => 4,
                    SketchMethod::Fcs => 5,
                };
                (m, *j, dims_key(a.shape.iter().copied()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_error_display() {
        assert_eq!(ServiceError::Busy.to_string(), "service queue is full (backpressure)");
        assert_eq!(ServiceError::Closed.to_string(), "service is shutting down");
        assert_eq!(
            ServiceError::BadRequest("nope".into()).to_string(),
            "bad request: nope"
        );
        assert_eq!(ServiceError::Exec("boom".into()).to_string(), "execution failed: boom");
    }

    #[test]
    fn shape_key_groups_same_shape() {
        let mut rng = crate::util::prng::Rng::seed_from_u64(1);
        let a = Tensor::randn(&mut rng, &[4, 5, 6]);
        let b = Tensor::randn(&mut rng, &[4, 5, 6]);
        let c = Tensor::randn(&mut rng, &[7, 2, 2]);
        let ka = Request::SketchDense { tensor: a, method: SketchMethod::Fcs, j: 8 }.shape_key();
        let kb = Request::SketchDense { tensor: b, method: SketchMethod::Fcs, j: 8 }.shape_key();
        let kc = Request::SketchDense { tensor: c, method: SketchMethod::Fcs, j: 8 }.shape_key();
        assert_eq!(ka, kb);
        assert_ne!(ka, kc);
        assert_ne!(
            Request::SketchDense {
                tensor: Tensor::randn(&mut rng, &[4, 5, 6]),
                method: SketchMethod::Ts,
                j: 8
            }
            .shape_key(),
            ka
        );
    }
}
