//! Client-side retry policy and the shared anti-amplification budget.
//!
//! Retrying `Busy`/`Exec` failures is how a client rides out a transient
//! overload spike — and also exactly how a client *creates* a metastable
//! overload: when every caller retries, offered load multiplies right when
//! capacity is scarcest. Two mechanisms bound that feedback loop:
//!
//! * [`RetryPolicy`] — capped exponential backoff with **full jitter**
//!   (`uniform(0, base·2^attempt)` clamped to `max_backoff`), so retry
//!   waves decorrelate instead of re-arriving in synchronized thundering
//!   herds.
//! * [`RetryBudget`] — a token bucket in **millitokens**, keyed per op
//!   class: every first attempt deposits a small amount, every retry
//!   withdraws a large amount. Steady state therefore admits roughly
//!   `deposit_m / withdraw_m` retries per request (10% at the defaults);
//!   under sustained failure the bucket runs dry and retries stop, leaving
//!   first attempts the whole queue. Refused retries are visible as
//!   `fcs_retry_budget_exhausted_total`.
//!
//! The budget is shared via `Arc` across every handle clone, so the cap is
//! per *service*, not per caller — see
//! [`ServiceHandle::call_with_retry`](super::service::ServiceHandle::call_with_retry).

use crate::sync::atomic::{AtomicI64, Ordering};
use crate::util::prng::Rng;
use std::time::Duration;

/// Bounded, jittered exponential backoff schedule.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Max retries after the first attempt (attempts ≤ `max_retries + 1`).
    pub max_retries: u32,
    /// Backoff ceiling *before* jitter at attempt 0.
    pub base_backoff: Duration,
    /// Absolute backoff ceiling at any attempt.
    pub max_backoff: Duration,
    /// Seed of the caller-local jitter RNG (deterministic in tests).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(5),
            jitter_seed: 0xB0FF,
        }
    }
}

impl RetryPolicy {
    /// Full-jitter backoff for the given 0-based retry attempt: uniform in
    /// `[0, min(base·2^attempt, max_backoff)]`.
    pub fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let ceiling = self
            .base_backoff
            .checked_mul(1u32 << attempt.min(20))
            .unwrap_or(self.max_backoff)
            .min(self.max_backoff);
        ceiling.mul_f64(rng.uniform())
    }
}

/// Token-bucket parameters, in millitokens (1 token = 1000 m).
#[derive(Clone, Copy, Debug)]
pub struct BudgetConfig {
    /// Opening balance per op class.
    pub initial_m: i64,
    /// Credited on every first attempt.
    pub deposit_m: i64,
    /// Debited by every retry.
    pub withdraw_m: i64,
    /// Advisory balance cap — deposits beyond it are clamped back, so a
    /// long quiet period cannot bank an unbounded retry storm.
    pub cap_m: i64,
}

impl Default for BudgetConfig {
    fn default() -> Self {
        // 10 tokens to start, 0.1 per request, 1 per retry, 100 cap:
        // ≈ 10% steady-state retry ratio with a 10-retry opening burst.
        BudgetConfig { initial_m: 10_000, deposit_m: 100, withdraw_m: 1000, cap_m: 100_000 }
    }
}

/// Shared per-op-class retry budget. Balances are independent per op (a
/// `merge_shards` failure storm cannot starve `sketch_dense` retries); ops
/// outside [`crate::obs::OPS`] share the trailing `"other"` slot.
#[derive(Debug)]
pub struct RetryBudget {
    cfg: BudgetConfig,
    per_op: [AtomicI64; crate::obs::OPS.len()],
}

impl Default for RetryBudget {
    fn default() -> Self {
        RetryBudget::new(BudgetConfig::default())
    }
}

impl RetryBudget {
    pub fn new(cfg: BudgetConfig) -> Self {
        RetryBudget { cfg, per_op: std::array::from_fn(|_| AtomicI64::new(cfg.initial_m)) }
    }

    fn slot(&self, op: &str) -> &AtomicI64 {
        let i = crate::obs::OPS
            .iter()
            .position(|&o| o == op)
            .unwrap_or(crate::obs::OPS.len() - 1);
        &self.per_op[i]
    }

    /// Credit a first attempt. The cap clamp is advisory (racing deposits
    /// may briefly overshoot); it bounds banked burst, not correctness.
    pub fn deposit(&self, op: &str) {
        let slot = self.slot(op);
        // ordering: Relaxed — RMW keeps the balance books exact; no other
        // memory is published alongside (loom model: `retry_budget_books`).
        let after = slot.fetch_add(self.cfg.deposit_m, Ordering::Relaxed) + self.cfg.deposit_m;
        if after > self.cfg.cap_m {
            // ordering: Relaxed — clamp correction on the same counter.
            slot.fetch_sub(after - self.cfg.cap_m, Ordering::Relaxed);
        }
    }

    /// Try to pay for one retry; `false` (with the debit refunded) when the
    /// class is broke — the caller must surface the original error instead
    /// of amplifying the overload.
    pub fn try_withdraw(&self, op: &str) -> bool {
        let slot = self.slot(op);
        // ordering: Relaxed — debit-then-refund keeps the net effect of a
        // refused withdraw exactly zero under any interleaving; transient
        // negative balances between the two RMWs are part of the contract
        // (loom model: `retry_budget_books`).
        let prev = slot.fetch_sub(self.cfg.withdraw_m, Ordering::Relaxed);
        if prev < self.cfg.withdraw_m {
            // ordering: Relaxed — exact refund on the same counter.
            slot.fetch_add(self.cfg.withdraw_m, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Current balance for an op class, in millitokens.
    pub fn balance_m(&self, op: &str) -> i64 {
        // ordering: Relaxed — advisory snapshot; may observe a transient
        // mid-withdraw debit, which only underreports the balance.
        self.slot(op).load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_refuses_when_broke() {
        let b = RetryBudget::new(BudgetConfig {
            initial_m: 2500,
            deposit_m: 100,
            withdraw_m: 1000,
            cap_m: 100_000,
        });
        assert!(b.try_withdraw("sketch_dense"));
        assert!(b.try_withdraw("sketch_dense"));
        assert!(!b.try_withdraw("sketch_dense"), "third retry exceeds the 2.5-token balance");
        assert_eq!(b.balance_m("sketch_dense"), 500, "refused withdraw must refund");
        // Classes are independent: sketch_cp still has its opening balance.
        assert!(b.try_withdraw("sketch_cp"));
        assert_eq!(b.balance_m("sketch_cp"), 1500);
    }

    #[test]
    fn deposits_refill_and_clamp_at_cap() {
        let b = RetryBudget::new(BudgetConfig {
            initial_m: 0,
            deposit_m: 100,
            withdraw_m: 1000,
            cap_m: 1200,
        });
        assert!(!b.try_withdraw("cs_vec"), "broke until deposits accrue");
        for _ in 0..10 {
            b.deposit("cs_vec");
        }
        assert!(b.try_withdraw("cs_vec"), "10 deposits fund one retry");
        for _ in 0..1000 {
            b.deposit("cs_vec");
        }
        assert_eq!(b.balance_m("cs_vec"), 1200, "balance clamps at cap_m");
    }

    #[test]
    fn backoff_is_bounded_and_grows_with_attempts() {
        let policy = RetryPolicy::default();
        let mut rng = Rng::seed_from_u64(11);
        for attempt in 0..64 {
            let d = policy.backoff(attempt, &mut rng);
            assert!(d <= policy.max_backoff, "attempt {attempt} exceeded max_backoff");
        }
        // The pre-jitter ceiling doubles until it hits the cap; with full
        // jitter the *max over many draws* tracks that ceiling.
        let max_at = |attempt: u32| -> Duration {
            let mut rng = Rng::seed_from_u64(99);
            (0..256).map(|_| policy.backoff(attempt, &mut rng)).max().unwrap()
        };
        assert!(max_at(3) > max_at(0), "later attempts must back off longer");
        assert!(max_at(40) <= policy.max_backoff, "shift overflow clamps to max");
    }
}
