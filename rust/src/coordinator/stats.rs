//! Service metrics: per-op latency percentiles (total, split into queue-wait
//! vs execution), per-width fused-flight summaries, throughput, batching
//! stats, backpressure counters.
//!
//! Every `record*` method feeds **two** sinks from the same call site: the
//! in-process reservoirs this module reports percentiles from, and the
//! crate-wide registry series behind `GET /metrics`
//! ([`crate::obs::metrics`]). Single-sourcing the recording points is what
//! keeps [`StatsReport`] and a scrape from ever disagreeing about counts.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::Mutex;
use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

/// Where a deadline violation was caught — the index into the
/// `fcs_deadline_shed_total{stage=...}` counter family
/// ([`crate::obs::SHED_STAGES`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedStage {
    /// Refused by the admission controller before ever entering the queue
    /// (already expired, or the queue-wait estimate exceeded the budget).
    Submit = 0,
    /// Expired while queued; dropped when the batcher/worker dequeued it.
    Dequeue = 1,
    /// Expired mid-flight — a flight-mate's execution outlived the budget,
    /// so the job is shed between fused-flight members.
    Flight = 2,
}

/// Bounded reservoir size per series. Retention is a *ring*: once full, the
/// newest sample overwrites the oldest, so percentiles always describe the
/// most recent `RESERVOIR_CAP` samples instead of freezing on the first
/// 100k a long-running service ever saw.
/// (Under `--cfg loom` the cap shrinks so the wraparound models in
/// `tests/loom_models.rs` can overwrite slots within a tractable schedule
/// budget; the ring arithmetic is cap-independent.)
const RESERVOIR_CAP: usize = if cfg!(loom) { 64 } else { 100_000 };

/// Fixed-capacity ring of `f64` samples. `push` is O(1) and allocation-free
/// once the ring has filled; `samples` returns the retained window in
/// arbitrary order (fine for percentiles, which sort a copy anyway).
#[derive(Debug, Default)]
struct Reservoir {
    buf: Vec<f64>,
    /// Total samples ever offered; `written % RESERVOIR_CAP` is the next slot.
    written: u64,
}

impl Reservoir {
    fn push(&mut self, v: f64) {
        let slot = (self.written % RESERVOIR_CAP as u64) as usize;
        if slot == self.buf.len() {
            self.buf.push(v);
        } else {
            self.buf[slot] = v;
        }
        self.written += 1;
    }

    fn samples(&self) -> &[f64] {
        &self.buf
    }
}

#[derive(Debug, Default)]
struct OpStats {
    latencies_us: Reservoir,
    /// Submit → flight-start wait, recorded by [`Stats::record_job`]
    /// (worker-pool ops only; the batcher's `record` leaves it empty).
    queue_us: Reservoir,
    /// Flight-start → reply execution time, parallel to `queue_us`.
    exec_us: Reservoir,
    completed: u64,
}

/// Per-flight-width accounting for the worker pool's fused execution: how
/// many flights ran at each width, how many jobs they carried, and how long
/// the flights took end to end.
#[derive(Debug, Default)]
struct FlightStats {
    flights: u64,
    jobs: u64,
    exec_us: Reservoir,
}

#[derive(Debug, Default)]
pub struct Stats {
    inner: Mutex<StatsInner>,
    /// Lock-free EWMA (α = 1/8) of worker-pool queue-wait in µs — the same
    /// stream that feeds `queue_p50_us`, folded incrementally so the
    /// admission controller can read it on the submit path without taking
    /// the reservoir mutex.
    queue_ewma_us: AtomicU64,
}

#[derive(Debug, Default)]
struct StatsInner {
    per_op: HashMap<&'static str, OpStats>,
    /// Fused-flight accounting keyed by flight width (BTreeMap so the
    /// report comes out width-sorted for free).
    flights: BTreeMap<usize, FlightStats>,
    rejected_busy: u64,
    batches: u64,
    batched_items: u64,
    /// Deadline sheds indexed by [`ShedStage`].
    shed: [u64; 3],
    retries: u64,
    retry_budget_exhausted: u64,
    worker_respawns: u64,
    started: Option<Instant>,
}

/// Snapshot for reporting.
#[derive(Debug, Clone)]
pub struct StatsReport {
    pub per_op: Vec<OpReport>,
    /// Per-width fused-flight summaries, sorted by width. Widths > 1 here
    /// are the direct evidence that cross-request fusion actually engaged.
    pub flights: Vec<FlightReport>,
    /// FFT plan-cache accounting, split per cache (forward complex plans vs
    /// real recombination twiddles), read from the global planner at
    /// snapshot time.
    pub plan_cache: PlanCacheReport,
    pub rejected_busy: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub total_completed: u64,
    pub throughput_rps: f64,
    /// Deadline sheds by stage (see [`ShedStage`]). Books invariant: every
    /// worker-pool submission that was accepted is accounted exactly once
    /// as a completion, a `shed_dequeue`, or a `shed_flight`; `shed_submit`
    /// jobs never entered the queue at all.
    pub shed_submit: u64,
    pub shed_dequeue: u64,
    pub shed_flight: u64,
    /// Client-handle retry attempts actually slept for and re-submitted.
    pub retries: u64,
    /// Retries refused because the shared budget was exhausted.
    pub retry_budget_exhausted: u64,
    /// Dead worker threads replaced by the supervisor.
    pub worker_respawns: u64,
    /// Current queue-wait EWMA in µs (the admission controller's estimate).
    pub queue_wait_estimate_us: u64,
}

#[derive(Debug, Clone)]
pub struct OpReport {
    pub op: &'static str,
    pub completed: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Median submit → flight-start wait (0 when the op records no split,
    /// e.g. the batcher's `cs_vec`).
    pub queue_p50_us: f64,
    /// Median flight-start → reply execution time (0 when no split).
    pub exec_p50_us: f64,
}

/// One row of the per-width fused-flight summary.
#[derive(Debug, Clone)]
pub struct FlightReport {
    /// Jobs fused into each flight of this row.
    pub width: usize,
    /// Number of flights that ran at this width.
    pub flights: u64,
    /// Total jobs those flights carried (`width · flights`).
    pub jobs: u64,
    pub exec_p50_us: f64,
    pub exec_p95_us: f64,
}

/// Per-cache FFT plan-cache snapshot: a cold real-twiddle cache is a
/// different operational signal than a cold complex-plan cache.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanCacheReport {
    pub forward_hits: u64,
    pub forward_misses: u64,
    pub real_hits: u64,
    pub real_misses: u64,
}

impl PlanCacheReport {
    fn snapshot() -> Self {
        let c = crate::fft::global_planner().cache_counters_by_cache();
        PlanCacheReport {
            forward_hits: c.forward.0,
            forward_misses: c.forward.1,
            real_hits: c.real.0,
            real_misses: c.real.1,
        }
    }

    fn rate(h: u64, m: u64) -> f64 {
        if h + m == 0 { f64::NAN } else { h as f64 / (h + m) as f64 }
    }

    /// Forward-cache hit rate in `[0, 1]` (`NaN` when the cache is untouched).
    pub fn forward_hit_rate(&self) -> f64 {
        Self::rate(self.forward_hits, self.forward_misses)
    }

    /// Real-plan-cache hit rate in `[0, 1]` (`NaN` when untouched).
    pub fn real_hit_rate(&self) -> f64 {
        Self::rate(self.real_hits, self.real_misses)
    }
}

/// Clamp a (nonnegative) microsecond / count float into histogram domain.
fn as_u64(v: f64) -> u64 {
    if v >= 0.0 { v as u64 } else { 0 }
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_started(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    pub fn record(&self, op: &'static str, latency_us: f64) {
        let m = crate::obs::metrics().op(op);
        m.completed.inc();
        m.latency_us.observe(as_u64(latency_us));
        let mut g = self.inner.lock().unwrap();
        let e = g.per_op.entry(op).or_default();
        e.completed += 1;
        e.latencies_us.push(latency_us);
    }

    /// Worker-pool job completion with its queue-wait/execution split:
    /// `total_us` is submit → reply, `queue_us` is submit → flight start,
    /// `exec_us` is flight start → reply (`queue + exec ≈ total`).
    pub fn record_job(&self, op: &'static str, total_us: f64, queue_us: f64, exec_us: f64) {
        let m = crate::obs::metrics().op(op);
        m.completed.inc();
        m.latency_us.observe(as_u64(total_us));
        m.queue_wait_us.observe(as_u64(queue_us));
        m.exec_us.observe(as_u64(exec_us));
        let mut g = self.inner.lock().unwrap();
        let e = g.per_op.entry(op).or_default();
        e.completed += 1;
        e.latencies_us.push(total_us);
        e.queue_us.push(queue_us);
        e.exec_us.push(exec_us);
        drop(g);
        // Fold the same queue-wait sample into the lock-free EWMA the
        // admission controller reads. α = 1/8; integer truncation of the
        // delta stalls for |diff| < 8, so a signum step keeps the estimate
        // converging all the way instead of plateauing a few µs off.
        let sample = as_u64(queue_us) as i64;
        // ordering: Relaxed — advisory estimate; an unsynchronized
        // load/store pair may drop a concurrent update (slower convergence),
        // but `(prev + step).max(0)` keeps any interleaving in range
        // (loom model: `stats_ewma_bounded_and_decays`).
        let prev = self.queue_ewma_us.load(Ordering::Relaxed) as i64;
        let delta = (sample - prev) / 8;
        let step = if delta != 0 { delta } else { (sample - prev).signum() };
        // ordering: Relaxed — see load above; value is self-contained.
        self.queue_ewma_us.store((prev + step).max(0) as u64, Ordering::Relaxed);
    }

    /// Current queue-wait estimate in µs — the EWMA of the same
    /// submit → flight-start stream behind `queue_p50_us`, readable without
    /// the reservoir mutex. The estimate is advisory: concurrent
    /// read-modify-write pairs may drop updates, which only slows
    /// convergence, never corrupts the value.
    pub fn queue_wait_estimate_us(&self) -> u64 {
        // ordering: Relaxed — single self-contained value; staleness by one
        // sample only delays admission-control reaction by one job.
        self.queue_ewma_us.load(Ordering::Relaxed)
    }

    /// A job's deadline was refused or shed at `stage`.
    pub fn record_deadline_shed(&self, stage: ShedStage) {
        crate::obs::metrics().deadline_shed[stage as usize].inc();
        self.inner.lock().unwrap().shed[stage as usize] += 1;
    }

    /// The client handle slept out a backoff and re-submitted.
    pub fn record_retry(&self) {
        crate::obs::metrics().retries.inc();
        self.inner.lock().unwrap().retries += 1;
    }

    /// A retry was refused because the shared budget was broke.
    pub fn record_retry_budget_exhausted(&self) {
        crate::obs::metrics().retry_budget_exhausted.inc();
        self.inner.lock().unwrap().retry_budget_exhausted += 1;
    }

    /// The supervisor replaced a dead worker thread.
    pub fn record_respawn(&self) {
        crate::obs::metrics().worker_respawns.inc();
        self.inner.lock().unwrap().worker_respawns += 1;
    }

    /// One worker flight finished: `width` jobs executed as a unit taking
    /// `exec_us` end to end.
    pub fn record_flight(&self, width: usize, exec_us: f64) {
        let m = crate::obs::metrics();
        m.flight_width.observe(width as u64);
        m.flight_exec_us.observe(as_u64(exec_us));
        let mut g = self.inner.lock().unwrap();
        let f = g.flights.entry(width).or_default();
        f.flights += 1;
        f.jobs += width as u64;
        f.exec_us.push(exec_us);
    }

    pub fn record_rejection(&self) {
        crate::obs::metrics().rejected_busy.inc();
        self.inner.lock().unwrap().rejected_busy += 1;
    }

    pub fn record_batch(&self, fill: usize) {
        let m = crate::obs::metrics();
        m.batches.inc();
        m.batched_jobs.add(fill as u64);
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_items += fill as u64;
    }

    pub fn report(&self) -> StatsReport {
        // Sort-and-read a percentile from an unsorted reservoir window (0
        // when the series recorded nothing, e.g. queue/exec for batcher ops).
        fn pct_of(samples: &[f64], p: f64) -> f64 {
            if samples.is_empty() {
                return 0.0;
            }
            let mut s = samples.to_vec();
            s.sort_unstable_by(f64::total_cmp);
            crate::util::timing::percentile_sorted(&s, p)
        }
        let g = self.inner.lock().unwrap();
        let mut per_op = Vec::new();
        let mut total = 0u64;
        for (op, s) in &g.per_op {
            total += s.completed;
            per_op.push(OpReport {
                op,
                completed: s.completed,
                p50_us: pct_of(s.latencies_us.samples(), 50.0),
                p95_us: pct_of(s.latencies_us.samples(), 95.0),
                p99_us: pct_of(s.latencies_us.samples(), 99.0),
                queue_p50_us: pct_of(s.queue_us.samples(), 50.0),
                exec_p50_us: pct_of(s.exec_us.samples(), 50.0),
            });
        }
        per_op.sort_by_key(|r| r.op);
        let flights = g
            .flights
            .iter()
            .map(|(&width, f)| FlightReport {
                width,
                flights: f.flights,
                jobs: f.jobs,
                exec_p50_us: pct_of(f.exec_us.samples(), 50.0),
                exec_p95_us: pct_of(f.exec_us.samples(), 95.0),
            })
            .collect();
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        StatsReport {
            per_op,
            flights,
            plan_cache: PlanCacheReport::snapshot(),
            rejected_busy: g.rejected_busy,
            batches: g.batches,
            mean_batch_fill: if g.batches > 0 {
                g.batched_items as f64 / g.batches as f64
            } else {
                0.0
            },
            total_completed: total,
            throughput_rps: if elapsed > 0.0 { total as f64 / elapsed } else { 0.0 },
            shed_submit: g.shed[ShedStage::Submit as usize],
            shed_dequeue: g.shed[ShedStage::Dequeue as usize],
            shed_flight: g.shed[ShedStage::Flight as usize],
            retries: g.retries,
            retry_budget_exhausted: g.retry_budget_exhausted,
            worker_respawns: g.worker_respawns,
            queue_wait_estimate_us: self.queue_wait_estimate_us(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let s = Stats::new();
        s.mark_started();
        for i in 0..100 {
            s.record("cs_vec", i as f64);
        }
        s.record_batch(32);
        s.record_batch(16);
        s.record_rejection();
        let r = s.report();
        assert_eq!(r.total_completed, 100);
        assert_eq!(r.rejected_busy, 1);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch_fill - 24.0).abs() < 1e-9);
        let op = &r.per_op[0];
        assert_eq!(op.op, "cs_vec");
        assert!(op.p50_us > 40.0 && op.p50_us < 60.0);
        assert!(op.p99_us >= op.p95_us);
        // Plain `record` carries no queue/exec split.
        assert_eq!(op.queue_p50_us, 0.0);
        assert_eq!(op.exec_p50_us, 0.0);
    }

    #[test]
    fn flight_and_split_reporting() {
        let s = Stats::new();
        s.mark_started();
        // 8 jobs in one width-8 flight, 1 singleton: queue + exec == total.
        for i in 0..8 {
            s.record_job("sketch_cp", 100.0 + i as f64, 40.0, 60.0 + i as f64);
        }
        s.record_flight(8, 75.0);
        s.record_job("sketch_cp", 50.0, 10.0, 40.0);
        s.record_flight(1, 40.0);
        let r = s.report();
        assert_eq!(r.total_completed, 9);
        let op = r.per_op.iter().find(|o| o.op == "sketch_cp").unwrap();
        assert_eq!(op.completed, 9);
        assert!(op.queue_p50_us > 0.0 && op.exec_p50_us > 0.0);
        // Width-sorted flight rows with consistent job accounting.
        assert_eq!(r.flights.len(), 2);
        assert_eq!((r.flights[0].width, r.flights[0].flights, r.flights[0].jobs), (1, 1, 1));
        assert_eq!((r.flights[1].width, r.flights[1].flights, r.flights[1].jobs), (8, 1, 8));
        assert!(r.flights[1].exec_p50_us > 0.0);
        assert!(r.flights[1].exec_p95_us >= r.flights[1].exec_p50_us);
    }

    /// Regression for the pre-PR 7 retention bug: the reservoir used to
    /// *stop accepting* samples at the cap, freezing percentiles on the
    /// first 100k observations forever. The ring must instead report the
    /// newest `RESERVOIR_CAP` window.
    #[test]
    fn reservoir_overfill_reports_recent_window() {
        let s = Stats::new();
        s.mark_started();
        // 110k monotonically increasing latencies: the retained window is
        // 10_000..110_000, so the median must sit near 60_000 — under the
        // old freeze-at-cap behavior it would sit near 50_000.
        let n = RESERVOIR_CAP + 10_000;
        for i in 0..n {
            s.record("sketch_dense", i as f64);
        }
        let r = s.report();
        let op = r.per_op.iter().find(|o| o.op == "sketch_dense").unwrap();
        assert_eq!(op.completed, n as u64);
        assert!(
            (op.p50_us - 60_000.0).abs() < 500.0,
            "p50 {} should reflect the recent window (~60k), not the frozen prefix (~50k)",
            op.p50_us
        );
        assert!(op.p99_us > 108_000.0, "p99 {} must see the newest samples", op.p99_us);
    }

    /// Concurrent companion to `reservoir_overfill_reports_recent_window`:
    /// 8 writers push the ring past `RESERVOIR_CAP` (forcing wraparound
    /// overwrites) while a reader snapshots percentiles mid-wrap. Every
    /// writer only ever records values from a known lattice, so a torn
    /// window — a snapshot exposing a partially-written slot or an
    /// out-of-range artifact — would surface as a percentile outside the
    /// lattice's hull or an inverted p50/p95/p99 ladder.
    #[test]
    fn reservoir_concurrent_wraparound_never_tears_window() {
        use std::sync::Arc;
        const WRITERS: usize = 8;
        let total = RESERVOIR_CAP + 40_000; // well past one full wrap
        let per_writer = total / WRITERS;
        let s = Arc::new(Stats::new());
        s.mark_started();
        let handles: Vec<_> = (0..WRITERS)
            .map(|w| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let v = (w + 1) as f64 * 1000.0; // lattice: 1000..=8000
                    for _ in 0..per_writer {
                        s.record("cs_vec", v);
                    }
                })
            })
            .collect();
        // Snapshot mid-wrap, repeatedly, while writers are overwriting slots.
        for _ in 0..50 {
            let r = s.report();
            if let Some(op) = r.per_op.iter().find(|o| o.op == "cs_vec") {
                if op.completed == 0 {
                    continue;
                }
                for (name, p) in
                    [("p50", op.p50_us), ("p95", op.p95_us), ("p99", op.p99_us)]
                {
                    assert!(
                        (1000.0..=8000.0).contains(&p),
                        "{name} {p} escaped the written lattice — torn window"
                    );
                }
                assert!(op.p50_us <= op.p95_us && op.p95_us <= op.p99_us);
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        let r = s.report();
        let op = r.per_op.iter().find(|o| o.op == "cs_vec").unwrap();
        assert_eq!(op.completed, (per_writer * WRITERS) as u64);
        assert!((1000.0..=8000.0).contains(&op.p50_us));
    }

    #[test]
    fn shed_retry_and_respawn_books() {
        let s = Stats::new();
        s.mark_started();
        s.record_deadline_shed(ShedStage::Submit);
        s.record_deadline_shed(ShedStage::Submit);
        s.record_deadline_shed(ShedStage::Dequeue);
        s.record_deadline_shed(ShedStage::Flight);
        s.record_retry();
        s.record_retry();
        s.record_retry();
        s.record_retry_budget_exhausted();
        s.record_respawn();
        let r = s.report();
        assert_eq!((r.shed_submit, r.shed_dequeue, r.shed_flight), (2, 1, 1));
        assert_eq!(r.retries, 3);
        assert_eq!(r.retry_budget_exhausted, 1);
        assert_eq!(r.worker_respawns, 1);
        // Sheds are not completions: the books stay separate.
        assert_eq!(r.total_completed, 0);
    }

    #[test]
    fn queue_wait_ewma_tracks_samples() {
        let s = Stats::new();
        s.mark_started();
        assert_eq!(s.queue_wait_estimate_us(), 0);
        for _ in 0..200 {
            s.record_job("sketch_dense", 1100.0, 1000.0, 100.0);
        }
        let est = s.queue_wait_estimate_us();
        assert!(
            (900..=1100).contains(&est),
            "EWMA {est} should converge near the steady 1000µs queue wait"
        );
        // A drained queue must pull the estimate back down — including the
        // last few µs the truncated α=1/8 step alone would never cover.
        for _ in 0..2000 {
            s.record_job("sketch_dense", 100.0, 0.0, 100.0);
        }
        assert!(s.queue_wait_estimate_us() <= 10, "estimate must decay to ~0 when idle");
        let r = s.report();
        assert_eq!(r.queue_wait_estimate_us, s.queue_wait_estimate_us());
    }

    #[test]
    fn plan_cache_report_reads_global_planner() {
        // Touch the global planner so the snapshot has definite structure.
        let before = crate::fft::global_planner().cache_counters_by_cache();
        let _ = crate::fft::global_planner().plan(64);
        let _ = crate::fft::global_planner().plan(64);
        let s = Stats::new();
        let r = s.report();
        let pc = r.plan_cache;
        assert!(pc.forward_hits + pc.forward_misses >= before.forward.0 + before.forward.1 + 2);
        let rate = pc.forward_hit_rate();
        assert!(rate.is_nan() || (0.0..=1.0).contains(&rate));
    }
}
