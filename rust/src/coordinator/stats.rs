//! Service metrics: per-op latency percentiles, throughput, batching stats,
//! backpressure counters.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Default)]
struct OpStats {
    latencies_us: Vec<f64>,
    completed: u64,
}

#[derive(Debug, Default)]
pub struct Stats {
    inner: Mutex<StatsInner>,
}

#[derive(Debug, Default)]
struct StatsInner {
    per_op: HashMap<&'static str, OpStats>,
    rejected_busy: u64,
    batches: u64,
    batched_items: u64,
    started: Option<Instant>,
}

/// Snapshot for reporting.
#[derive(Debug, Clone)]
pub struct StatsReport {
    pub per_op: Vec<OpReport>,
    pub rejected_busy: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub total_completed: u64,
    pub throughput_rps: f64,
}

#[derive(Debug, Clone)]
pub struct OpReport {
    pub op: &'static str,
    pub completed: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_started(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    pub fn record(&self, op: &'static str, latency_us: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.per_op.entry(op).or_default();
        e.completed += 1;
        // Bounded reservoir: keep the newest 100k samples.
        if e.latencies_us.len() < 100_000 {
            e.latencies_us.push(latency_us);
        }
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected_busy += 1;
    }

    pub fn record_batch(&self, fill: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_items += fill as u64;
    }

    pub fn report(&self) -> StatsReport {
        let g = self.inner.lock().unwrap();
        let mut per_op = Vec::new();
        let mut total = 0u64;
        for (op, s) in &g.per_op {
            total += s.completed;
            let mut lat = s.latencies_us.clone();
            lat.sort_unstable_by(f64::total_cmp);
            let pct = |p: f64| {
                if lat.is_empty() {
                    0.0
                } else {
                    crate::util::timing::percentile_sorted(&lat, p)
                }
            };
            per_op.push(OpReport {
                op,
                completed: s.completed,
                p50_us: pct(50.0),
                p95_us: pct(95.0),
                p99_us: pct(99.0),
            });
        }
        per_op.sort_by_key(|r| r.op);
        let elapsed = g.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        StatsReport {
            per_op,
            rejected_busy: g.rejected_busy,
            batches: g.batches,
            mean_batch_fill: if g.batches > 0 {
                g.batched_items as f64 / g.batches as f64
            } else {
                0.0
            },
            total_completed: total,
            throughput_rps: if elapsed > 0.0 { total as f64 / elapsed } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let s = Stats::new();
        s.mark_started();
        for i in 0..100 {
            s.record("cs_vec", i as f64);
        }
        s.record_batch(32);
        s.record_batch(16);
        s.record_rejection();
        let r = s.report();
        assert_eq!(r.total_completed, 100);
        assert_eq!(r.rejected_busy, 1);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch_fill - 24.0).abs() < 1e-9);
        let op = &r.per_op[0];
        assert_eq!(op.op, "cs_vec");
        assert!(op.p50_us > 40.0 && op.p50_us < 60.0);
        assert!(op.p99_us >= op.p95_us);
    }
}
