//! Layer-3 coordinator: the serving side of the sketch library.
//!
//! ```text
//! client → ServiceHandle (bounded queues, Busy on overflow)
//!            ├─ cs_vec          → Batcher → XLA cs_batch executable
//!            └─ sketch_* / est. → worker pool (pure Rust, or XLA fcs_rank1)
//!          Stats: p50/p95/p99 per op, batch fill, rejections, throughput
//! ```
//!
//! Invariants (property-tested in `rust/tests/coordinator_service.rs`):
//! every accepted request is answered exactly once; batches never exceed the
//! artifact batch size; XLA and pure-Rust paths agree numerically.

pub mod msg;
pub mod service;
pub mod stats;

pub use msg::{Request, Response, ServiceError, SketchMethod};
pub use service::{Service, ServiceConfig, ServiceHandle, WorkerState};
pub use stats::{Stats, StatsReport};
