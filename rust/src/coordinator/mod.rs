//! Layer-3 coordinator: the serving side of the sketch library.
//!
//! ```text
//! client → ServiceHandle (bounded queues, Busy on overflow)
//!            ├─ cs_vec          → Batcher → XLA cs_batch executable
//!            └─ sketch_* / est. → worker pool (pure Rust, or XLA fcs_rank1)
//!                                  └─ fused flights: same-class sketch runs
//!                                     share spectral transform dispatches
//!          Stats: p50/p95/p99 per op (queue-wait vs exec split), per-width
//!                 fused-flight summaries, plan-cache hit rates, batch fill,
//!                 rejections, throughput
//! ```
//!
//! Observability: every `Stats::record*` call site also feeds the crate-wide
//! registry (`crate::obs`), so the in-process [`StatsReport`] and a
//! Prometheus scrape of `GET /metrics` (serve one with
//! `crate::obs::exporter::Exporter::bind`) can never disagree; workers
//! additionally leave per-request trace spans
//! (submit → queue → flight-start → reply, keyed by [`service::job_rng`]
//! req ids) in `crate::obs::trace`, dumpable via `GET /traces`.
//!
//! Invariants (property-tested in `rust/tests/coordinator_service.rs` and
//! `rust/tests/coordinator_stress.rs`): every accepted request is answered
//! exactly once; batches never exceed the artifact batch size; XLA and
//! pure-Rust paths agree numerically; fused flights are bit-identical to
//! serial execution (per-job RNGs derive from [`service::job_rng`] either
//! way) and a poisoned job inside a flight costs exactly its own reply.
//!
//! Sharded reduce front-end (`rust/tests/merge_conformance.rs`): the
//! `sketch_shard` op scatters tensor slabs under *group-shared* hash draws
//! ([`crate::sketch::merge::group_rng`]), `merge_shards` tree-reduces the
//! replies, and the merged result is bit-identical to a whole-tensor
//! `sketch_shard` of the same group on exactly representable data.
//!
//! Overload resilience (`rust/tests/deadlines.rs`, and under the
//! `failpoints` feature `rust/tests/chaos.rs`): deadlines with submit-time
//! admission control and dequeue/mid-flight load shedding
//! ([`ServiceError::DeadlineExceeded`], booked per [`stats::ShedStage`]),
//! supervisor-respawned workers, and budgeted client retry
//! ([`retry::RetryBudget`]) — every accepted request is still answered
//! exactly once, shed or served.

pub mod msg;
pub mod retry;
pub mod service;
pub mod stats;

pub use msg::{Request, Response, ServiceError, SketchMethod};
pub use retry::{BudgetConfig, RetryBudget, RetryPolicy};
pub use service::{
    job_rng, should_respawn, Service, ServiceConfig, ServiceHandle, WorkerState,
};
pub use stats::{FlightReport, PlanCacheReport, ShedStage, Stats, StatsReport};
