//! CP decomposition algorithms over pluggable contraction estimators:
//! RTPM (§4.1.1) and ALS (§4.1.2).

pub mod als;
pub mod rtpm;

pub use als::{als_plain, als_sketched, mttkrp, AlsConfig};
pub use rtpm::{rtpm_asymmetric, rtpm_symmetric, RtpmConfig};
