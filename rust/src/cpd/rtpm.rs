//! Robust tensor power method (RTPM, Anandkumar et al. 2014) over a
//! pluggable [`ContractionEstimator`] — the §4.1.1 experiment.
//!
//! Symmetric variant: power iteration `u ← T(I,u,u)/‖T(I,u,u)‖` from `L`
//! random initializations, `T` iterations each; the best candidate (largest
//! `T(u,u,u)`) gets a refinement run, yields `λ_r = T(u,u,u)`, and the
//! tensor is deflated `T ← T − λ_r u∘u∘u` (in the sketch domain for
//! sketched estimators).
//!
//! Asymmetric variant (real-world data, Figs. 2–3): alternating rank-1
//! updates `u ← T(I,v,w)`, `v ← T(u,I,w)`, `w ← T(u,v,I)` (Anandkumar et
//! al. 2014b).

use crate::linalg::Matrix;
use crate::sketch::ContractionEstimator;
use crate::tensor::CpTensor;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct RtpmConfig {
    /// Target CP rank (number of deflation rounds).
    pub rank: usize,
    /// L — number of random initializations per component.
    pub n_init: usize,
    /// T — power iterations per candidate (and for the refinement run).
    pub n_iter: usize,
    pub seed: u64,
}

impl Default for RtpmConfig {
    fn default() -> Self {
        Self { rank: 10, n_init: 15, n_iter: 20, seed: 0 }
    }
}

/// Normalized random unit vector.
fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f64> {
    let mut u = rng.normal_vec(dim);
    crate::linalg::normalize(&mut u);
    u
}

/// Symmetric RTPM on a cubical 3rd-order tensor accessed through `est`.
/// Returns a CP tensor whose three factors are identical.
pub fn rtpm_symmetric(
    est: &mut dyn ContractionEstimator,
    dim: usize,
    cfg: &RtpmConfig,
) -> CpTensor {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut lambda = Vec::with_capacity(cfg.rank);
    let mut factors = Matrix::zeros(dim, cfg.rank);
    // Single iterate buffer reused across every power step (the estimator's
    // `t_iuu_into` path is allocation-free in steady state, §Perf).
    let mut next: Vec<f64> = Vec::new();

    for r in 0..cfg.rank {
        // L candidates, T power iterations each.
        let mut best_u: Option<Vec<f64>> = None;
        let mut best_val = f64::NEG_INFINITY;
        for _tau in 0..cfg.n_init {
            let mut u = random_unit(&mut rng, dim);
            for _t in 0..cfg.n_iter {
                est.t_iuu_into(&u, &mut next);
                if crate::linalg::normalize(&mut next) == 0.0 {
                    u = random_unit(&mut rng, dim);
                } else {
                    std::mem::swap(&mut u, &mut next);
                }
            }
            let val = est.t_uuu(&u);
            if val > best_val {
                best_val = val;
                best_u = Some(u);
            }
        }
        // Refinement run on the winner.
        let mut u = best_u.expect("n_init >= 1");
        for _t in 0..cfg.n_iter {
            est.t_iuu_into(&u, &mut next);
            if crate::linalg::normalize(&mut next) == 0.0 {
                break;
            }
            std::mem::swap(&mut u, &mut next);
        }
        // |λ| = |T(u,u,u)| ≤ ‖T‖_F for unit u: clamp the noisy estimate so a
        // bad draw cannot blow up the deflation (runaway feedback otherwise).
        let cap = est.norm_estimate();
        let lam = est.t_uuu(&u).clamp(-cap, cap);
        est.deflate(lam, &[&u, &u, &u]);
        lambda.push(lam);
        factors.set_col(r, &u);
        let _ = r;
    }

    CpTensor::new(lambda, vec![factors.clone(), factors.clone(), factors])
}

/// Asymmetric RTPM via alternating rank-1 updates on a general 3rd-order
/// tensor. Each component alternately updates (u, v, w); deflation after
/// each component.
pub fn rtpm_asymmetric(
    est: &mut dyn ContractionEstimator,
    shape: &[usize],
    cfg: &RtpmConfig,
) -> CpTensor {
    assert_eq!(shape.len(), 3);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut lambda = Vec::with_capacity(cfg.rank);
    let mut f0 = Matrix::zeros(shape[0], cfg.rank);
    let mut f1 = Matrix::zeros(shape[1], cfg.rank);
    let mut f2 = Matrix::zeros(shape[2], cfg.rank);

    // Shared iterate buffer for all three alternating updates (§Perf).
    let mut next: Vec<f64> = Vec::new();
    for r in 0..cfg.rank {
        let mut best: Option<(Vec<f64>, Vec<f64>, Vec<f64>)> = None;
        let mut best_val = f64::NEG_INFINITY;
        for _tau in 0..cfg.n_init {
            let mut u = random_unit(&mut rng, shape[0]);
            let mut v = random_unit(&mut rng, shape[1]);
            let mut w = random_unit(&mut rng, shape[2]);
            for _t in 0..cfg.n_iter {
                est.t_mode_into(0, &[&u, &v, &w], &mut next);
                if crate::linalg::normalize(&mut next) > 0.0 {
                    std::mem::swap(&mut u, &mut next);
                }
                est.t_mode_into(1, &[&u, &v, &w], &mut next);
                if crate::linalg::normalize(&mut next) > 0.0 {
                    std::mem::swap(&mut v, &mut next);
                }
                est.t_mode_into(2, &[&u, &v, &w], &mut next);
                if crate::linalg::normalize(&mut next) > 0.0 {
                    std::mem::swap(&mut w, &mut next);
                }
            }
            // λ candidate = u^T T(I, v, w)
            est.t_mode_into(0, &[&u, &v, &w], &mut next);
            let val = crate::linalg::dot(&next, &u).abs();
            if val > best_val {
                best_val = val;
                best = Some((u, v, w));
            }
        }
        let (mut u, mut v, mut w) = best.expect("n_init >= 1");
        for _t in 0..cfg.n_iter {
            est.t_mode_into(0, &[&u, &v, &w], &mut next);
            if crate::linalg::normalize(&mut next) > 0.0 {
                std::mem::swap(&mut u, &mut next);
            }
            est.t_mode_into(1, &[&u, &v, &w], &mut next);
            if crate::linalg::normalize(&mut next) > 0.0 {
                std::mem::swap(&mut v, &mut next);
            }
            est.t_mode_into(2, &[&u, &v, &w], &mut next);
            if crate::linalg::normalize(&mut next) > 0.0 {
                std::mem::swap(&mut w, &mut next);
            }
        }
        // Same clamp as the symmetric case: |T(u,v,w)| ≤ ‖T‖_F.
        let cap = est.norm_estimate();
        est.t_mode_into(0, &[&u, &v, &w], &mut next);
        let lam = crate::linalg::dot(&next, &u).clamp(-cap, cap);
        est.deflate(lam, &[&u, &v, &w]);
        lambda.push(lam);
        f0.set_col(r, &u);
        f1.set_col(r, &v);
        f2.set_col(r, &w);
    }

    CpTensor::new(lambda, vec![f0, f1, f2])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{FcsEstimator, Method, PlainEstimator};
    use crate::tensor::Tensor;

    fn symmetric_testcase(rng: &mut Rng, dim: usize, rank: usize, sigma: f64) -> (Tensor, CpTensor) {
        let cp = CpTensor::random_orthogonal_symmetric(rng, dim, rank, 3);
        let mut t = cp.to_dense();
        t.add_noise(rng, sigma);
        (t, cp)
    }

    #[test]
    fn plain_rtpm_recovers_orthogonal_components() {
        let mut rng = Rng::seed_from_u64(1);
        let (t, truth) = symmetric_testcase(&mut rng, 20, 3, 0.001);
        let mut est = PlainEstimator::new(t.clone());
        let cfg = RtpmConfig { rank: 3, n_init: 10, n_iter: 15, seed: 7 };
        let cp = rtpm_symmetric(&mut est, 20, &cfg);
        // Residual should be near the noise floor.
        let res = cp.to_dense().sub(&t).frob_norm();
        assert!(res < 0.2, "residual {res}");
        // Each recovered u must align with some true component (up to sign).
        for r in 0..3 {
            let u = cp.factors[0].col(r);
            let max_align = (0..3)
                .map(|s| crate::linalg::dot(u, truth.factors[0].col(s)).abs())
                .fold(0.0, f64::max);
            assert!(max_align > 0.98, "component {r} align {max_align}");
        }
    }

    #[test]
    fn plain_rtpm_eigenvalues_near_one() {
        let mut rng = Rng::seed_from_u64(2);
        let (t, _) = symmetric_testcase(&mut rng, 16, 3, 0.001);
        let mut est = PlainEstimator::new(t);
        let cfg = RtpmConfig { rank: 3, n_init: 8, n_iter: 15, seed: 3 };
        let cp = rtpm_symmetric(&mut est, 16, &cfg);
        for &l in &cp.lambda {
            assert!((l - 1.0).abs() < 0.15, "lambda {l}");
        }
    }

    #[test]
    fn fcs_rtpm_recovers_signal_under_noise() {
        let mut rng = Rng::seed_from_u64(3);
        let (t, truth) = symmetric_testcase(&mut rng, 24, 3, 0.01);
        let mut est = FcsEstimator::build(&t, 6, 1500, &mut rng);
        let cfg = RtpmConfig { rank: 3, n_init: 10, n_iter: 12, seed: 11 };
        let cp = rtpm_symmetric(&mut est, 24, &cfg);
        // Residual against the *noisy* input is dominated by the noise floor
        // σ·√(I³) ≈ 1.18; compare against the clean signal instead.
        let res_clean = cp.to_dense().sub(&truth.to_dense()).frob_norm();
        assert!(res_clean < 0.35, "clean-signal residual {res_clean}");
        for r in 0..3 {
            let u = cp.factors[0].col(r);
            let max_align = (0..3)
                .map(|s| crate::linalg::dot(u, truth.factors[0].col(s)).abs())
                .fold(0.0, f64::max);
            assert!(max_align > 0.95, "component {r} align {max_align}");
        }
    }

    #[test]
    fn asymmetric_rtpm_plain_recovers() {
        let mut rng = Rng::seed_from_u64(4);
        let truth = CpTensor::random_orthogonal(&mut rng, &[14, 12, 10], 2);
        let mut t = truth.to_dense();
        t.add_noise(&mut rng, 0.001);
        let mut est = PlainEstimator::new(t.clone());
        let cfg = RtpmConfig { rank: 2, n_init: 8, n_iter: 15, seed: 5 };
        let cp = rtpm_asymmetric(&mut est, &[14, 12, 10], &cfg);
        let res = cp.to_dense().sub(&t).frob_norm();
        assert!(res < 0.2, "residual {res}");
    }

    #[test]
    fn sketched_methods_run_asymmetric() {
        let mut rng = Rng::seed_from_u64(5);
        let truth = CpTensor::random_orthogonal(&mut rng, &[10, 10, 10], 2);
        let mut t = truth.to_dense();
        t.add_noise(&mut rng, 0.01);
        for method in [Method::Ts, Method::Fcs] {
            let mut est = method.build(&t, 6, 800, &mut rng);
            let cfg = RtpmConfig { rank: 2, n_init: 6, n_iter: 10, seed: 9 };
            let cp = rtpm_asymmetric(est.as_mut(), &[10, 10, 10], &cfg);
            let res = cp.to_dense().sub(&t).frob_norm();
            assert!(res < 1.2, "{}: residual {res}", method.name());
        }
    }
}
