//! Alternating least squares CPD (Kolda & Bader 2009) — plain MTTKRP and
//! the sketched variant of §4.1.2 (Eq. 18: every MTTKRP column is a
//! `T(I, b_r, c_r)`-style contraction, estimated through the sketch).

use crate::linalg::{solve_spd_systems, Matrix};
use crate::sketch::ContractionEstimator;
use crate::tensor::{CpTensor, Tensor};
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct AlsConfig {
    pub rank: usize,
    pub n_iter: usize,
    pub seed: u64,
}

impl Default for AlsConfig {
    fn default() -> Self {
        Self { rank: 10, n_iter: 20, seed: 0 }
    }
}

/// Exact MTTKRP for a 3rd-order tensor: `T_(mode) · KR(·)` computed fiber-
/// wise over the contiguous mode-0 fibers (no matricization copy).
pub fn mttkrp(t: &Tensor, factors: &[Matrix; 3], mode: usize) -> Matrix {
    let (d0, d1, d2) = (t.shape[0], t.shape[1], t.shape[2]);
    let r = factors[0].cols;
    let (a, b, c) = (&factors[0], &factors[1], &factors[2]);
    let mut out = Matrix::zeros(t.shape[mode], r);
    match mode {
        0 => {
            // out[i, r] = Σ_{j,k} T[i,j,k] B[j,r] C[k,r]
            for k in 0..d2 {
                for j in 0..d1 {
                    let fiber = &t.data[(k * d1 + j) * d0..(k * d1 + j + 1) * d0];
                    for rr in 0..r {
                        let coef = b.get(j, rr) * c.get(k, rr);
                        if coef != 0.0 {
                            crate::linalg::axpy(coef, fiber, out.col_mut(rr));
                        }
                    }
                }
            }
        }
        1 => {
            // out[j, r] = Σ_{i,k} T[i,j,k] A[i,r] C[k,r]
            for k in 0..d2 {
                for j in 0..d1 {
                    let fiber = &t.data[(k * d1 + j) * d0..(k * d1 + j + 1) * d0];
                    for rr in 0..r {
                        let dotv = crate::linalg::dot(fiber, a.col(rr));
                        out.set(j, rr, out.get(j, rr) + dotv * c.get(k, rr));
                    }
                }
            }
        }
        2 => {
            // out[k, r] = Σ_{i,j} T[i,j,k] A[i,r] B[j,r]
            for k in 0..d2 {
                for j in 0..d1 {
                    let fiber = &t.data[(k * d1 + j) * d0..(k * d1 + j + 1) * d0];
                    for rr in 0..r {
                        let dotv = crate::linalg::dot(fiber, a.col(rr));
                        out.set(k, rr, out.get(k, rr) + dotv * b.get(j, rr));
                    }
                }
            }
        }
        _ => panic!("mode out of range"),
    }
    out
}

/// One ALS half-step: given the MTTKRP matrix `m` for `mode`, solve
/// `U_mode = m · V⁻¹` with `V = ⊛_{d≠mode} U_d^T U_d`.
fn als_update(m: &Matrix, factors: &[Matrix; 3], mode: usize) -> Matrix {
    let r = m.cols;
    let mut v = Matrix::from_fn(r, r, |_, _| 1.0);
    for (d, f) in factors.iter().enumerate() {
        if d != mode {
            v = v.hadamard(&f.t_matmul(f));
        }
    }
    // Solve V X^T = M^T  ⇒  X = M V⁻¹ (V is SPD up to degeneracy).
    let xt = solve_spd_systems(&v, &m.transpose());
    xt.transpose()
}

/// Plain (exact) ALS on a dense 3rd-order tensor.
pub fn als_plain(t: &Tensor, cfg: &AlsConfig) -> CpTensor {
    assert_eq!(t.order(), 3);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut factors = [
        Matrix::randn(&mut rng, t.shape[0], cfg.rank),
        Matrix::randn(&mut rng, t.shape[1], cfg.rank),
        Matrix::randn(&mut rng, t.shape[2], cfg.rank),
    ];
    for _it in 0..cfg.n_iter {
        for mode in 0..3 {
            let m = mttkrp(t, &factors, mode);
            factors[mode] = als_update(&m, &factors, mode);
            normalize_factor(&mut factors[mode]);
        }
    }
    finish(t, factors, cfg)
}

/// Sketched ALS: MTTKRP columns estimated via `est.t_mode` (Eq. 18 → Eq. 17
/// machinery). The estimator carries its own method (TS / FCS / …).
pub fn als_sketched(
    t_shape: &[usize],
    est: &dyn ContractionEstimator,
    t_for_scale: &Tensor,
    cfg: &AlsConfig,
) -> CpTensor {
    assert_eq!(t_shape.len(), 3);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut factors = [
        Matrix::randn(&mut rng, t_shape[0], cfg.rank),
        Matrix::randn(&mut rng, t_shape[1], cfg.rank),
        Matrix::randn(&mut rng, t_shape[2], cfg.rank),
    ];
    // The inner loop reuses one output buffer across every (iter, mode, rank)
    // estimate; with the sketched estimators' workspace paths the whole
    // MTTKRP estimation runs allocation-free in steady state (§Perf).
    let mut est_col: Vec<f64> = Vec::new();
    for _it in 0..cfg.n_iter {
        for mode in 0..3 {
            let mut m = Matrix::zeros(t_shape[mode], cfg.rank);
            for r in 0..cfg.rank {
                let cols = [factors[0].col(r), factors[1].col(r), factors[2].col(r)];
                est.t_mode_into(mode, &cols, &mut est_col);
                m.set_col(r, &est_col);
            }
            factors[mode] = als_update(&m, &factors, mode);
            normalize_factor(&mut factors[mode]);
        }
    }
    finish(t_for_scale, factors, cfg)
}

/// Normalize factor columns to unit norm (scale is re-estimated at the end).
fn normalize_factor(f: &mut Matrix) {
    for r in 0..f.cols {
        crate::linalg::normalize(f.col_mut(r));
    }
}

/// Final scale fit: with unit-norm factors, solve the 1-D least squares for
/// each λ_r jointly: λ = G⁻¹ g where G = ⊛ U^T U, g_r = ⟨T, u_r∘v_r∘w_r⟩.
fn finish(t: &Tensor, factors: [Matrix; 3], cfg: &AlsConfig) -> CpTensor {
    let r = cfg.rank;
    let mut g = Matrix::from_fn(r, r, |_, _| 1.0);
    for f in &factors {
        g = g.hadamard(&f.t_matmul(f));
    }
    let rhs: Vec<f64> = (0..r)
        .map(|rr| {
            let vs: Vec<&[f64]> = factors.iter().map(|f| f.col(rr)).collect();
            crate::tensor::multilinear_form(t, &vs)
        })
        .collect();
    let lambda = crate::linalg::cholesky_solve(&g, &rhs);
    CpTensor::new(lambda, factors.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{build_equalized, Method};

    #[test]
    fn mttkrp_matches_matricized_product() {
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&mut rng, &[5, 4, 6]);
        let factors = [
            Matrix::randn(&mut rng, 5, 3),
            Matrix::randn(&mut rng, 4, 3),
            Matrix::randn(&mut rng, 6, 3),
        ];
        // Reference: T_(n) · KR of the other factors in increasing mode
        // order (column-major flattening pairs mode order (a,b) with
        // KR(B_later, B_earlier)).
        for mode in 0..3 {
            let fast = mttkrp(&t, &factors, mode);
            let others: Vec<&Matrix> = (0..3).filter(|&d| d != mode).map(|d| &factors[d]).collect();
            let kr = others[1].khatri_rao(others[0]);
            let slow = t.matricize(mode).matmul(&kr);
            assert!(fast.sub(&slow).frob_norm() < 1e-10, "mode {mode}");
        }
    }

    #[test]
    fn plain_als_recovers_low_rank() {
        let mut rng = Rng::seed_from_u64(2);
        let truth = CpTensor::random_orthogonal(&mut rng, &[12, 10, 8], 3);
        let mut t = truth.to_dense();
        t.add_noise(&mut rng, 0.001);
        let cfg = AlsConfig { rank: 3, n_iter: 30, seed: 5 };
        let cp = als_plain(&t, &cfg);
        let res = cp.to_dense().sub(&t).frob_norm();
        assert!(res < 0.15, "residual {res}");
    }

    #[test]
    fn plain_als_exact_rank1() {
        let mut rng = Rng::seed_from_u64(3);
        let truth = CpTensor::randn(&mut rng, &[6, 7, 5], 1);
        let t = truth.to_dense();
        let cfg = AlsConfig { rank: 1, n_iter: 15, seed: 1 };
        let cp = als_plain(&t, &cfg);
        assert!(cp.to_dense().sub(&t).frob_norm() < 1e-6);
    }

    #[test]
    fn sketched_als_fcs_converges() {
        let mut rng = Rng::seed_from_u64(4);
        let truth = CpTensor::random_orthogonal(&mut rng, &[14, 14, 14], 2);
        let mut t = truth.to_dense();
        t.add_noise(&mut rng, 0.01);
        let (_, fcs) = build_equalized(&t, 8, 1200, &mut rng);
        let cfg = AlsConfig { rank: 2, n_iter: 12, seed: 2 };
        let cp = als_sketched(&t.shape, &fcs, &t, &cfg);
        let res = cp.to_dense().sub(&t).frob_norm();
        assert!(res < 0.8, "residual {res}");
    }

    #[test]
    fn fcs_als_not_worse_than_ts_als_shared_hashes() {
        // The Table-3 headline: under equalized hashes FCS-ALS residual ≤
        // TS-ALS residual (statistically; fixed seed here).
        let mut rng = Rng::seed_from_u64(5);
        let truth = CpTensor::random_orthogonal(&mut rng, &[14, 14, 14], 2);
        let mut t = truth.to_dense();
        t.add_noise(&mut rng, 0.01);
        let (ts, fcs) = build_equalized(&t, 8, 700, &mut rng);
        let cfg = AlsConfig { rank: 2, n_iter: 10, seed: 3 };
        let res_ts = als_sketched(&t.shape, &ts, &t, &cfg).to_dense().sub(&t).frob_norm();
        let res_fcs = als_sketched(&t.shape, &fcs, &t, &cfg).to_dense().sub(&t).frob_norm();
        assert!(
            res_fcs <= res_ts * 1.1,
            "FCS {res_fcs} should not be (much) worse than TS {res_ts}"
        );
    }

    #[test]
    fn sketched_matches_plain_when_estimator_is_plain() {
        let mut rng = Rng::seed_from_u64(6);
        let truth = CpTensor::randn(&mut rng, &[6, 5, 7], 2);
        let t = truth.to_dense();
        let cfg = AlsConfig { rank: 2, n_iter: 8, seed: 4 };
        let plain_cp = als_plain(&t, &cfg);
        let est = Method::Plain.build(&t, 1, 1, &mut rng);
        let sk_cp = als_sketched(&t.shape, est.as_ref(), &t, &cfg);
        // identical initialization (same seed) + exact estimates ⇒ identical
        let d = plain_cp.to_dense().sub(&sk_cp.to_dense()).frob_norm();
        assert!(d < 1e-8, "divergence {d}");
    }
}
