//! §4.3.1 — Kronecker product compression.
//!
//! `A ⊗ B` for `A ∈ R^{I1×I2}`, `B ∈ R^{I3×I4}` is the 4th-order tensor
//! `T[i1,i2,i3,i4] = A(i1,i2)·B(i3,i4)` laid out at row `I3·i1 + i3`,
//! column `I4·i2 + i4`. FCS compresses it **without materializing it**:
//!
//! `FCS(A⊗B) = F⁻¹( F(CS(vec A); J̃) · F(CS(vec B); J̃) )`, `J̃ = 4J − 3`,
//!
//! and decompresses entrywise by
//! `(A⊗B)ˆ = s1 s2 s3 s4 · FCS(A⊗B)[h1+h2+h3+h4]` (0-based Eq. in §4.3.1).

use super::{fcs_j_for_size, hcs_j_for_size, median_inplace, Codec};
use crate::fft;
use crate::hash::{HashPair, HashTable, ModeHashes};
use crate::linalg::Matrix;
use crate::util::prng::Rng;
use crate::util::timing::Stopwatch;

/// One compressed representation of `A ⊗ B` (D repetitions inside).
pub struct KronCodec {
    codec: Codec,
    dims: [usize; 4], // [I1, I2, I3, I4]
    reps: Vec<Rep>,
}

enum Rep {
    /// CS: one long hash over vec(A⊗B).
    Cs { table: HashTable, sketch: Vec<f64> },
    /// HCS: 4 mode hashes, sketched tensor of shape [J;4] (flat, col-major).
    Hcs { hashes: ModeHashes, sketch: Vec<f64>, j: usize },
    /// FCS: 4 mode hashes, linear-convolution sketch of length 4J−3.
    Fcs { hashes: ModeHashes, sketch: Vec<f64> },
}

impl Rep {
    /// Decode one entry from this repetition — branch-light, no iterators.
    #[inline]
    fn decode(&self, dims: [usize; 4], idx: [usize; 4]) -> f64 {
        match self {
            Rep::Cs { table, sketch } => {
                let l = idx[0] + dims[0] * (idx[1] + dims[1] * (idx[2] + dims[2] * idx[3]));
                (table.s[l] as f64) * sketch[table.h[l] as usize]
            }
            Rep::Hcs { hashes, sketch, j } => {
                let m = &hashes.modes;
                let b = m[0].h[idx[0]] as usize
                    + j * (m[1].h[idx[1]] as usize
                        + j * (m[2].h[idx[2]] as usize + j * m[3].h[idx[3]] as usize));
                let s = m[0].s[idx[0]] * m[1].s[idx[1]] * m[2].s[idx[2]] * m[3].s[idx[3]];
                (s as f64) * sketch[b]
            }
            Rep::Fcs { hashes, sketch } => {
                let m = &hashes.modes;
                let b = m[0].h[idx[0]] as usize
                    + m[1].h[idx[1]] as usize
                    + m[2].h[idx[2]] as usize
                    + m[3].h[idx[3]] as usize;
                let s = m[0].s[idx[0]] * m[1].s[idx[1]] * m[2].s[idx[2]] * m[3].s[idx[3]];
                (s as f64) * sketch[b]
            }
        }
    }
}

/// Metrics reported by Fig. 5.
#[derive(Debug, Clone)]
pub struct KronStats {
    pub codec: &'static str,
    pub cr: f64,
    pub sketch_len: usize,
    pub compress_secs: f64,
    pub decompress_secs: f64,
    pub rel_error: f64,
    pub hash_bytes: usize,
}

impl KronCodec {
    /// Compress `A ⊗ B` with `d` independent sketches of total size
    /// `sketch_size` each.
    pub fn compress(
        codec: Codec,
        a: &Matrix,
        b: &Matrix,
        sketch_size: usize,
        d: usize,
        rng: &mut Rng,
    ) -> Self {
        let dims = [a.rows, a.cols, b.rows, b.cols];
        // Repetitions are independent — parallelize across threads (§Perf).
        let seeds: Vec<u64> = (0..d).map(|_| rng.next_u64()).collect();
        let reps = crate::util::parallel::par_map(d, crate::util::parallel::default_threads(), |ri| {
            let rng = &mut Rng::seed_from_u64(seeds[ri]);
            match codec {
                Codec::Cs => {
                    // Materialize vec(A⊗B) — the CS baseline's unavoidable cost.
                    let total: usize = dims.iter().product();
                    let pair = HashPair::draw(rng, total, sketch_size);
                    let table = pair.materialize();
                    let mut sketch = vec![0.0; sketch_size];
                    // vec index (col-major over the 4th-order tensor
                    // [i1,i2,i3,i4]): l = i1 + I1(i2 + I2(i3 + I3 i4))
                    let (i1n, i2n, i3n, i4n) = (dims[0], dims[1], dims[2], dims[3]);
                    let mut l = 0usize;
                    for i4 in 0..i4n {
                        for i3 in 0..i3n {
                            let bv = b.get(i3, i4);
                            for i2 in 0..i2n {
                                for i1 in 0..i1n {
                                    let v = a.get(i1, i2) * bv;
                                    if v != 0.0 {
                                        sketch[table.h[l] as usize] += (table.s[l] as f64) * v;
                                    }
                                    l += 1;
                                }
                            }
                        }
                    }
                    Rep::Cs { table, sketch }
                }
                Codec::Hcs => {
                    let j = hcs_j_for_size(sketch_size);
                    let hashes = ModeHashes::draw_uniform(rng, &dims, j);
                    // HCS(A⊗B) = HCS₂(A) ∘ HCS₂(B): sketch each matrix into
                    // J×J, then materialize the outer product (Eq. 5 cost).
                    let sa = sketch_matrix_2d(a, &hashes.modes[0], &hashes.modes[1], j);
                    let sb = sketch_matrix_2d(b, &hashes.modes[2], &hashes.modes[3], j);
                    let jj = j * j;
                    let mut sketch = vec![0.0; jj * jj];
                    for (q, &bv) in sb.iter().enumerate() {
                        if bv != 0.0 {
                            crate::linalg::axpy(bv, &sa, &mut sketch[q * jj..(q + 1) * jj]);
                        }
                    }
                    Rep::Hcs { hashes, sketch, j }
                }
                Codec::Fcs => {
                    let j = fcs_j_for_size(sketch_size);
                    let hashes = ModeHashes::draw_uniform(rng, &dims, j);
                    let j_tilde = 4 * j - 3;
                    // FCS(A) over modes (1,2): length 2J−1; same for B; then
                    // one linear convolution — A⊗B never materialized. The
                    // workspace keeps the convolution allocation-free.
                    let fa = fcs_matrix(a, &hashes.modes[0], &hashes.modes[1], j);
                    let fb = fcs_matrix(b, &hashes.modes[2], &hashes.modes[3], j);
                    let mut ws = crate::fft::FftWorkspace::new();
                    // Capacity = padded FFT length conv_linear_into fills
                    // before truncating to 4J−3.
                    let mut sketch = Vec::with_capacity(j_tilde.next_power_of_two());
                    fft::conv_linear_into(&fa, &fb, &mut ws, &mut sketch);
                    debug_assert_eq!(sketch.len(), j_tilde);
                    sketch.truncate(j_tilde);
                    Rep::Fcs { hashes, sketch }
                }
            }
        });
        Self { codec, dims, reps }
    }

    /// Decode one entry of the 4th-order view (median over repetitions).
    /// The per-rep lookups are fully unrolled — this is the §4.3
    /// decompression hot loop.
    #[inline]
    pub fn decode(&self, idx: [usize; 4], buf: &mut Vec<f64>) -> f64 {
        buf.clear();
        for rep in &self.reps {
            buf.push(rep.decode(self.dims, idx));
        }
        median_inplace(buf)
    }

    /// Reconstruct the full Kronecker product `(I1·I3) × (I2·I4)`
    /// (column-parallel).
    pub fn decompress(&self) -> Matrix {
        let [i1n, i2n, i3n, i4n] = self.dims;
        let ncols = i2n * i4n;
        let cols = crate::util::parallel::par_map(
            ncols,
            crate::util::parallel::default_threads(),
            |col| {
                let (i4, i2) = (col % i4n, col / i4n);
                let mut buf = Vec::with_capacity(self.reps.len());
                let mut out = vec![0.0; i1n * i3n];
                for i1 in 0..i1n {
                    for i3 in 0..i3n {
                        out[i3 + i1 * i3n] = self.decode([i1, i2, i3, i4], &mut buf);
                    }
                }
                out
            },
        );
        let mut out = Matrix::zeros(i1n * i3n, ncols);
        for (c, colv) in cols.into_iter().enumerate() {
            out.set_col(c, &colv);
        }
        out
    }

    /// Total sketch length per repetition.
    pub fn sketch_len(&self) -> usize {
        match &self.reps[0] {
            Rep::Cs { sketch, .. } => sketch.len(),
            Rep::Hcs { sketch, .. } => sketch.len(),
            Rep::Fcs { sketch, .. } => sketch.len(),
        }
    }

    /// Bytes stored for hash functions across all repetitions (Fig. 5 panel 4).
    pub fn hash_bytes(&self) -> usize {
        self.reps
            .iter()
            .map(|rep| match rep {
                Rep::Cs { table, .. } => table.memory_bytes(),
                Rep::Hcs { hashes, .. } => hashes.memory_bytes(),
                Rep::Fcs { hashes, .. } => hashes.memory_bytes(),
            })
            .sum()
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Run the full Fig. 5 protocol for one codec and target CR.
    pub fn evaluate(
        codec: Codec,
        a: &Matrix,
        b: &Matrix,
        cr: f64,
        d: usize,
        rng: &mut Rng,
    ) -> KronStats {
        let total = a.rows * a.cols * b.rows * b.cols;
        let sketch_size = ((total as f64 / cr).round() as usize).max(4);
        let sw = Stopwatch::start();
        let codec_obj = Self::compress(codec, a, b, sketch_size, d, rng);
        let compress_secs = sw.elapsed_secs();
        let sw = Stopwatch::start();
        let approx = codec_obj.decompress();
        let decompress_secs = sw.elapsed_secs();
        let truth = a.kron(b);
        let rel_error = approx.sub(&truth).frob_norm() / truth.frob_norm();
        KronStats {
            codec: codec.name(),
            cr,
            sketch_len: codec_obj.sketch_len(),
            compress_secs,
            decompress_secs,
            rel_error,
            hash_bytes: codec_obj.hash_bytes(),
        }
    }
}

/// 2-mode count sketch of a matrix into a J×J grid (flat col-major):
/// `S[h_r(i), h_c(j)] += s_r(i)·s_c(j)·M(i,j)`.
fn sketch_matrix_2d(m: &Matrix, hr: &HashTable, hc: &HashTable, j: usize) -> Vec<f64> {
    let mut out = vec![0.0; j * j];
    for c in 0..m.cols {
        let bc = hc.h(c);
        let sc = hc.s(c);
        let col = m.col(c);
        for (r, &v) in col.iter().enumerate() {
            if v != 0.0 {
                out[hr.h(r) + j * bc] += hr.s(r) * sc * v;
            }
        }
    }
    out
}

/// FCS of a matrix viewed as a 2-mode tensor: length `2J − 1`.
fn fcs_matrix(m: &Matrix, hr: &HashTable, hc: &HashTable, j: usize) -> Vec<f64> {
    let mut out = vec![0.0; 2 * j - 1];
    for c in 0..m.cols {
        let bc = hc.h(c);
        let sc = hc.s(c);
        let col = m.col(c);
        for (r, &v) in col.iter().enumerate() {
            if v != 0.0 {
                out[hr.h(r) + bc] += hr.s(r) * sc * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_pair(rng: &mut Rng) -> (Matrix, Matrix) {
        (
            Matrix::from_data(6, 5, rng.uniform_vec(30, -5.0, 5.0)),
            Matrix::from_data(4, 7, rng.uniform_vec(28, -5.0, 5.0)),
        )
    }

    #[test]
    fn fcs_sketch_matches_dense_tensor_sketch() {
        // FCS(A⊗B) via convolution == FCS of the materialized 4th-order view.
        let mut rng = Rng::seed_from_u64(1);
        let (a, b) = test_pair(&mut rng);
        let codec = KronCodec::compress(Codec::Fcs, &a, &b, 61, 1, &mut rng);
        let Rep::Fcs { hashes, sketch } = &codec.reps[0] else {
            panic!()
        };
        // materialize T[i1,i2,i3,i4] = A(i1,i2) B(i3,i4) col-major
        let t = crate::tensor::Tensor::from_fn(&[6, 5, 4, 7], |idx| {
            a.get(idx[0], idx[1]) * b.get(idx[2], idx[3])
        });
        let fcs = crate::sketch::FastCountSketch::new(hashes.clone());
        let direct = fcs.apply_dense(&t);
        assert_eq!(direct.len(), sketch.len());
        for (x, y) in direct.iter().zip(sketch) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn all_codecs_error_decreases_with_size() {
        let mut rng = Rng::seed_from_u64(2);
        let (a, b) = test_pair(&mut rng);
        for codec in [Codec::Cs, Codec::Hcs, Codec::Fcs] {
            let small = KronCodec::evaluate(codec, &a, &b, 16.0, 7, &mut rng);
            let large = KronCodec::evaluate(codec, &a, &b, 1.2, 7, &mut rng);
            assert!(
                large.rel_error < small.rel_error,
                "{}: {} !< {}",
                codec.name(),
                large.rel_error,
                small.rel_error
            );
        }
    }

    #[test]
    fn fcs_high_accuracy_at_low_cr() {
        let mut rng = Rng::seed_from_u64(3);
        let (a, b) = test_pair(&mut rng);
        let stats = KronCodec::evaluate(Codec::Fcs, &a, &b, 1.05, 15, &mut rng);
        assert!(stats.rel_error < 0.35, "rel err {}", stats.rel_error);
    }

    #[test]
    fn hash_memory_ordering_cs_much_larger() {
        let mut rng = Rng::seed_from_u64(4);
        let (a, b) = test_pair(&mut rng);
        let cs = KronCodec::compress(Codec::Cs, &a, &b, 100, 3, &mut rng);
        let fcs = KronCodec::compress(Codec::Fcs, &a, &b, 100, 3, &mut rng);
        assert!(cs.hash_bytes() > 10 * fcs.hash_bytes());
    }

    #[test]
    fn decompress_shape() {
        let mut rng = Rng::seed_from_u64(5);
        let (a, b) = test_pair(&mut rng);
        let codec = KronCodec::compress(Codec::Fcs, &a, &b, 200, 3, &mut rng);
        let m = codec.decompress();
        assert_eq!((m.rows, m.cols), (24, 35));
    }

    #[test]
    fn decode_unbiased_single_entry() {
        let mut rng = Rng::seed_from_u64(6);
        let (a, b) = test_pair(&mut rng);
        let truth = a.get(2, 3) * b.get(1, 4);
        let mut acc = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let c = KronCodec::compress(Codec::Fcs, &a, &b, 301, 1, &mut rng);
            let mut buf = Vec::new();
            acc += c.decode([2, 3, 1, 4], &mut buf);
        }
        let mean = acc / trials as f64;
        assert!((mean - truth).abs() < 2.0, "mean {mean} truth {truth}");
    }
}
