//! §4.3.2 — Tensor contraction compression.
//!
//! For `A ∈ R^{I1×I2×L}`, `B ∈ R^{L×I3×I4}`, the contraction
//! `T = A ⊙_{3,1} B ∈ R^{I1×I2×I3×I4}` is compressed **without being
//! materialized**: each of the `L` slice pairs is a Kronecker-style rank-1
//! pairing, so
//!
//! `FCS(T) = Σ_l F⁻¹( F(CS(vec A(:,:,l))) · F(CS(vec B(l,:,:))) )`.
//!
//! The implementation accumulates the product **in the spectral domain** and
//! performs a single inverse FFT (an optimization over the paper's formula
//! that is exact by linearity of F⁻¹).

use super::{fcs_j_for_size, hcs_j_for_size, median_inplace, Codec};
use crate::fft::{self, C64, FftWorkspace};
use crate::hash::{HashPair, HashTable, ModeHashes};
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use crate::util::timing::Stopwatch;

/// Compressed representation of `A ⊙_{3,1} B`.
pub struct ContractCodec {
    codec: Codec,
    dims: [usize; 4], // [I1, I2, I3, I4]
    reps: Vec<Rep>,
}

enum Rep {
    Cs { table: HashTable, sketch: Vec<f64> },
    Hcs { hashes: ModeHashes, sketch: Vec<f64>, j: usize },
    Fcs { hashes: ModeHashes, sketch: Vec<f64> },
}

impl Rep {
    /// Decode one entry from this repetition — branch-light, no iterators.
    #[inline]
    fn decode(&self, dims: [usize; 4], idx: [usize; 4]) -> f64 {
        match self {
            Rep::Cs { table, sketch } => {
                let l = idx[0] + dims[0] * (idx[1] + dims[1] * (idx[2] + dims[2] * idx[3]));
                (table.s[l] as f64) * sketch[table.h[l] as usize]
            }
            Rep::Hcs { hashes, sketch, j } => {
                let m = &hashes.modes;
                let b = m[0].h[idx[0]] as usize
                    + j * (m[1].h[idx[1]] as usize
                        + j * (m[2].h[idx[2]] as usize + j * m[3].h[idx[3]] as usize));
                let s = m[0].s[idx[0]] * m[1].s[idx[1]] * m[2].s[idx[2]] * m[3].s[idx[3]];
                (s as f64) * sketch[b]
            }
            Rep::Fcs { hashes, sketch } => {
                let m = &hashes.modes;
                let b = m[0].h[idx[0]] as usize
                    + m[1].h[idx[1]] as usize
                    + m[2].h[idx[2]] as usize
                    + m[3].h[idx[3]] as usize;
                let s = m[0].s[idx[0]] * m[1].s[idx[1]] * m[2].s[idx[2]] * m[3].s[idx[3]];
                (s as f64) * sketch[b]
            }
        }
    }
}

/// Metrics reported by Fig. 6.
#[derive(Debug, Clone)]
pub struct ContractStats {
    pub codec: &'static str,
    pub cr: f64,
    pub sketch_len: usize,
    pub compress_secs: f64,
    pub decompress_secs: f64,
    pub rel_error: f64,
    pub hash_bytes: usize,
}

/// Count sketch of a matrix slice given row/col hash tables, producing a
/// J×J grid (flat col-major).
fn sketch_slice_2d(
    slice: impl Fn(usize, usize) -> f64,
    rows: usize,
    cols: usize,
    hr: &HashTable,
    hc: &HashTable,
    j: usize,
) -> Vec<f64> {
    let mut out = vec![0.0; j * j];
    for c in 0..cols {
        let bc = hc.h(c);
        let sc = hc.s(c);
        for r in 0..rows {
            let v = slice(r, c);
            if v != 0.0 {
                out[hr.h(r) + j * bc] += hr.s(r) * sc * v;
            }
        }
    }
    out
}

/// FCS (length 2J−1) of a matrix slice, accumulated into a caller-owned
/// buffer — the slice loop in `compress` reuses it `L` times (§Perf).
fn fcs_slice_into(
    slice: impl Fn(usize, usize) -> f64,
    rows: usize,
    cols: usize,
    hr: &HashTable,
    hc: &HashTable,
    out: &mut [f64],
) {
    out.fill(0.0);
    for c in 0..cols {
        let bc = hc.h(c);
        let sc = hc.s(c);
        for r in 0..rows {
            let v = slice(r, c);
            if v != 0.0 {
                out[hr.h(r) + bc] += hr.s(r) * sc * v;
            }
        }
    }
}

impl ContractCodec {
    /// Compress `A ⊙_{3,1} B` (A: [I1,I2,L], B: [L,I3,I4]).
    pub fn compress(
        codec: Codec,
        a: &Tensor,
        b: &Tensor,
        sketch_size: usize,
        d: usize,
        rng: &mut Rng,
    ) -> Self {
        assert_eq!(a.order(), 3);
        assert_eq!(b.order(), 3);
        assert_eq!(a.shape[2], b.shape[0], "contraction dim mismatch");
        let l_dim = a.shape[2];
        let dims = [a.shape[0], a.shape[1], b.shape[1], b.shape[2]];
        let (i1n, i2n, i3n, i4n) = (dims[0], dims[1], dims[2], dims[3]);
        // Repetitions are independent — parallelize across threads (§Perf).
        let seeds: Vec<u64> = (0..d).map(|_| rng.next_u64()).collect();
        let reps = crate::util::parallel::par_map(d, crate::util::parallel::default_threads(), |ri| {
            let rng = &mut Rng::seed_from_u64(seeds[ri]);
            match codec {
                Codec::Cs => {
                    // CS must materialize the contraction first.
                    let t = crate::tensor::contract_pair(a, 2, b, 0);
                    let total = t.numel();
                    let table = HashPair::draw(rng, total, sketch_size).materialize();
                    let mut sketch = vec![0.0; sketch_size];
                    for (l, &v) in t.data.iter().enumerate() {
                        if v != 0.0 {
                            sketch[table.h[l] as usize] += (table.s[l] as f64) * v;
                        }
                    }
                    Rep::Cs { table, sketch }
                }
                Codec::Hcs => {
                    let j = hcs_j_for_size(sketch_size);
                    let hashes = ModeHashes::draw_uniform(rng, &dims, j);
                    let jj = j * j;
                    let mut sketch = vec![0.0; jj * jj];
                    for l in 0..l_dim {
                        // A(:,:,l): col-major fiber base (l*I2 + c)*I1
                        let sa = sketch_slice_2d(
                            |r, c| a.data[(l * i2n + c) * i1n + r],
                            i1n,
                            i2n,
                            &hashes.modes[0],
                            &hashes.modes[1],
                            j,
                        );
                        // B(l,:,:): element (l, r, c) at (c*I3 + r)*L + l
                        let sb = sketch_slice_2d(
                            |r, c| b.data[(c * i3n + r) * l_dim + l],
                            i3n,
                            i4n,
                            &hashes.modes[2],
                            &hashes.modes[3],
                            j,
                        );
                        for (q, &bv) in sb.iter().enumerate() {
                            if bv != 0.0 {
                                crate::linalg::axpy(bv, &sa, &mut sketch[q * jj..(q + 1) * jj]);
                            }
                        }
                    }
                    Rep::Hcs { hashes, sketch, j }
                }
                Codec::Fcs => {
                    let j = fcs_j_for_size(sketch_size);
                    let hashes = ModeHashes::draw_uniform(rng, &dims, j);
                    let j_tilde = 4 * j - 3;
                    let n = j_tilde.next_power_of_two();
                    // Accumulate Σ_l F(FCS(A_l))·F(FCS(B_l)) spectrally,
                    // using the real-pair packing trick (one FFT per slice
                    // pair instead of two), one workspace and one pair of
                    // slice buffers reused across all L slices, and a single
                    // inverse FFT at the end (§Perf).
                    let mut ws = FftWorkspace::new();
                    let mut acc = vec![C64::default(); n];
                    let mut prod: Vec<C64> = Vec::with_capacity(n);
                    let mut fa = vec![0.0; 2 * j - 1];
                    let mut fb = vec![0.0; 2 * j - 1];
                    for l in 0..l_dim {
                        fcs_slice_into(
                            |r, c| a.data[(l * i2n + c) * i1n + r],
                            i1n,
                            i2n,
                            &hashes.modes[0],
                            &hashes.modes[1],
                            &mut fa,
                        );
                        fcs_slice_into(
                            |r, c| b.data[(c * i3n + r) * l_dim + l],
                            i3n,
                            i4n,
                            &hashes.modes[2],
                            &hashes.modes[3],
                            &mut fb,
                        );
                        fft::convolve::packed_product_spectrum_into(&fa, &fb, n, &mut ws, &mut prod);
                        for (z, p) in acc.iter_mut().zip(&prod) {
                            *z += *p;
                        }
                    }
                    let mut sketch = Vec::with_capacity(n);
                    fft::inverse_real_into(&mut acc, &mut ws, &mut sketch);
                    sketch.truncate(j_tilde);
                    Rep::Fcs { hashes, sketch }
                }
            }
        });
        Self { codec, dims, reps }
    }

    /// Decode one entry `T̂[i1,i2,i3,i4]` (median over repetitions; per-rep
    /// lookups unrolled — the §4.3 decompression hot loop).
    #[inline]
    pub fn decode(&self, idx: [usize; 4], buf: &mut Vec<f64>) -> f64 {
        buf.clear();
        for rep in &self.reps {
            buf.push(rep.decode(self.dims, idx));
        }
        median_inplace(buf)
    }

    /// Full reconstruction as a 4th-order tensor (slab-parallel).
    pub fn decompress(&self) -> Tensor {
        let [i1n, i2n, i3n, i4n] = self.dims;
        let slab = i1n * i2n * i3n;
        let slabs = crate::util::parallel::par_map(
            i4n,
            crate::util::parallel::default_threads(),
            |i4| {
                let mut buf = Vec::with_capacity(self.reps.len());
                let mut out = vec![0.0; slab];
                let mut l = 0usize;
                for i3 in 0..i3n {
                    for i2 in 0..i2n {
                        for i1 in 0..i1n {
                            out[l] = self.decode([i1, i2, i3, i4], &mut buf);
                            l += 1;
                        }
                    }
                }
                out
            },
        );
        let mut out = Tensor::zeros(&self.dims);
        for (i4, s) in slabs.into_iter().enumerate() {
            out.data[i4 * slab..(i4 + 1) * slab].copy_from_slice(&s);
        }
        out
    }

    pub fn sketch_len(&self) -> usize {
        match &self.reps[0] {
            Rep::Cs { sketch, .. } => sketch.len(),
            Rep::Hcs { sketch, .. } => sketch.len(),
            Rep::Fcs { sketch, .. } => sketch.len(),
        }
    }

    pub fn hash_bytes(&self) -> usize {
        self.reps
            .iter()
            .map(|rep| match rep {
                Rep::Cs { table, .. } => table.memory_bytes(),
                Rep::Hcs { hashes, .. } => hashes.memory_bytes(),
                Rep::Fcs { hashes, .. } => hashes.memory_bytes(),
            })
            .sum()
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Run the Fig. 6 protocol for one codec and target CR.
    pub fn evaluate(
        codec: Codec,
        a: &Tensor,
        b: &Tensor,
        cr: f64,
        d: usize,
        rng: &mut Rng,
    ) -> ContractStats {
        let total = a.shape[0] * a.shape[1] * b.shape[1] * b.shape[2];
        let sketch_size = ((total as f64 / cr).round() as usize).max(4);
        let sw = Stopwatch::start();
        let codec_obj = Self::compress(codec, a, b, sketch_size, d, rng);
        let compress_secs = sw.elapsed_secs();
        let sw = Stopwatch::start();
        let approx = codec_obj.decompress();
        let decompress_secs = sw.elapsed_secs();
        let truth = crate::tensor::contract_pair(a, 2, b, 0);
        let rel_error = approx.sub(&truth).frob_norm() / truth.frob_norm();
        ContractStats {
            codec: codec.name(),
            cr,
            sketch_len: codec_obj.sketch_len(),
            compress_secs,
            decompress_secs,
            rel_error,
            hash_bytes: codec_obj.hash_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_pair(rng: &mut Rng) -> (Tensor, Tensor) {
        (
            Tensor::rand_uniform(rng, &[5, 6, 8], 0.0, 10.0),
            Tensor::rand_uniform(rng, &[8, 4, 7], 0.0, 10.0),
        )
    }

    #[test]
    fn fcs_sketch_matches_dense_tensor_sketch() {
        // Σ_l conv(FCS(A_l), FCS(B_l)) == FCS of the materialized contraction.
        let mut rng = Rng::seed_from_u64(1);
        let (a, b) = test_pair(&mut rng);
        let codec = ContractCodec::compress(Codec::Fcs, &a, &b, 61, 1, &mut rng);
        let Rep::Fcs { hashes, sketch } = &codec.reps[0] else {
            panic!()
        };
        let t = crate::tensor::contract_pair(&a, 2, &b, 0);
        let fcs = crate::sketch::FastCountSketch::new(hashes.clone());
        let direct = fcs.apply_dense(&t);
        assert_eq!(direct.len(), sketch.len());
        for (x, y) in direct.iter().zip(sketch) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn hcs_sketch_matches_dense_tensor_sketch() {
        let mut rng = Rng::seed_from_u64(2);
        let (a, b) = test_pair(&mut rng);
        let codec = ContractCodec::compress(Codec::Hcs, &a, &b, 1296, 1, &mut rng);
        let Rep::Hcs { hashes, sketch, .. } = &codec.reps[0] else {
            panic!()
        };
        let t = crate::tensor::contract_pair(&a, 2, &b, 0);
        let hcs = crate::sketch::HigherOrderCountSketch::new(hashes.clone());
        let direct = hcs.apply_dense(&t);
        for (x, y) in direct.data.iter().zip(sketch) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn error_decreases_with_size_all_codecs() {
        let mut rng = Rng::seed_from_u64(3);
        let (a, b) = test_pair(&mut rng);
        for codec in [Codec::Cs, Codec::Hcs, Codec::Fcs] {
            let small = ContractCodec::evaluate(codec, &a, &b, 20.0, 7, &mut rng);
            let large = ContractCodec::evaluate(codec, &a, &b, 1.2, 7, &mut rng);
            assert!(
                large.rel_error < small.rel_error,
                "{}: {} !< {}",
                codec.name(),
                large.rel_error,
                small.rel_error
            );
        }
    }

    #[test]
    fn fcs_beats_hcs_at_small_cr() {
        // The Fig. 6 headline: at small CR, FCS has lower error than HCS.
        let mut rng = Rng::seed_from_u64(4);
        let (a, b) = test_pair(&mut rng);
        let fcs = ContractCodec::evaluate(Codec::Fcs, &a, &b, 1.5, 15, &mut rng);
        let hcs = ContractCodec::evaluate(Codec::Hcs, &a, &b, 1.5, 15, &mut rng);
        assert!(
            fcs.rel_error < hcs.rel_error,
            "fcs {} !< hcs {}",
            fcs.rel_error,
            hcs.rel_error
        );
    }
}
