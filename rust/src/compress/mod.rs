//! §4.3 — Kronecker-product and tensor-contraction compression.
//!
//! All three codecs (CS / HCS / FCS) compress the 4th-order view of the
//! target: `T[i1,i2,i3,i4] = A(i1,i2)·B(i3,i4)` for Kronecker (§4.3.1), and
//! `T = A ⊙_{3,1} B` for contraction (§4.3.2). Each stores `D` independent
//! sketches and decodes entries by the median rule.
//!
//! Sizing at a common compression ratio `CR = numel(T) / S`:
//! * CS  — one long hash into `S` buckets (hash storage `O(numel)`),
//! * FCS — four short hashes with `J̃ = 4J − 3 = S`,
//! * HCS — four short hashes into a `J_h⁴ = S` sketched tensor.

pub mod contract;
pub mod kron;

pub use contract::{ContractCodec, ContractStats};
pub use kron::{KronCodec, KronStats};

/// Which codec to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    Cs,
    Hcs,
    Fcs,
}

impl Codec {
    pub fn name(&self) -> &'static str {
        match self {
            Codec::Cs => "cs",
            Codec::Hcs => "hcs",
            Codec::Fcs => "fcs",
        }
    }
}

/// Median of a small scratch buffer (decode hot path: D ≤ ~20 entries) —
/// the selection-based O(D) primitive now lives in `util::timing` as the
/// crate-wide single home (§Perf: ~1.6× on decompress vs a full sort).
pub(crate) use crate::util::timing::median_inplace;

/// Given a target sketch size `s`, the per-mode hash length for FCS's
/// 4-mode composite (`J̃ = 4J − 3 = s` ⇒ `J = (s + 3) / 4`).
pub fn fcs_j_for_size(s: usize) -> usize {
    ((s + 3) / 4).max(1)
}

/// Given a target sketch size `s`, the per-mode hash length for HCS's
/// 4-mode sketched tensor (`J⁴ ≈ s`).
pub fn hcs_j_for_size(s: usize) -> usize {
    let j = (s as f64).powf(0.25).floor() as usize;
    j.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_inplace_odd_even() {
        let mut a = [3.0, 1.0, 2.0];
        assert_eq!(median_inplace(&mut a), 2.0);
        let mut b = [4.0, 1.0, 2.0, 3.0];
        assert_eq!(median_inplace(&mut b), 2.5);
    }

    #[test]
    fn sizing_invariants() {
        for s in [16usize, 100, 1000, 123456] {
            let j = fcs_j_for_size(s);
            assert!(4 * j - 3 >= s.saturating_sub(3));
            let jh = hcs_j_for_size(s);
            assert!(jh.pow(4) <= s);
        }
    }
}
