//! Table 2 — HCS vs FCS RTPM on a synthetic symmetric CP rank-10 tensor
//! `T ∈ R^{50×50×50}` at matched sketched dimensions (J₁³ ≈ 3J₂ − 2),
//! σ ∈ {0.01, 0.1}, D ∈ {10, 15, 20}. Residual norm + running time.

use fcs::bench::{fmt_secs, quick_mode, ResultSink, Table};
use fcs::cpd::{rtpm_symmetric, RtpmConfig};
use fcs::data::synthetic_cp;
use fcs::metrics::residual_norm;
use fcs::sketch::{ContractionEstimator, FcsEstimator, HcsEstimator};
use fcs::util::prng::Rng;
use fcs::util::timing::Stopwatch;

fn main() {
    let full = std::env::var("FCS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let dim = 50usize;
    let rank = 10usize;
    // paper pairs: J1 ∈ {14,18,21,23,25}, J2 ∈ {200,250,300,350,400}
    let (pairs, ds, sigmas, n_init, n_iter): (Vec<(usize, usize)>, Vec<usize>, Vec<f64>, usize, usize) =
        if quick_mode() {
            (vec![(14, 200), (25, 400)], vec![10], vec![0.01], 4, 8)
        } else if full {
            (
                vec![(14, 200), (18, 250), (21, 300), (23, 350), (25, 400)],
                vec![10, 15, 20],
                vec![0.01, 0.1],
                15,
                20,
            )
        } else {
            (
                vec![(14, 200), (21, 300), (25, 400)],
                vec![10, 20],
                vec![0.01, 0.1],
                8,
                12,
            )
        };

    let mut table = Table::new(
        "Table 2 — HCS vs FCS RTPM on 50³ rank-10 (matched sketched dims)",
        &["sigma", "method", "J", "D", "residual", "time"],
    );
    let mut sink = ResultSink::new("table2_hcs_vs_fcs");

    for &sigma in &sigmas {
        let mut rng = Rng::seed_from_u64(0x7AB2 ^ sigma.to_bits());
        let (t, _clean_cp) = synthetic_cp(&mut rng, &[dim, dim, dim], rank, sigma, true);
        
        for &d in &ds {
            for &(j1, j2) in &pairs {
                let cfg = RtpmConfig { rank, n_init, n_iter, seed: 3 };
                // HCS
                let sw = Stopwatch::start();
                let mut hcs = HcsEstimator::build(&t, d, j1, &mut rng);
                let cp = rtpm_symmetric(&mut hcs, dim, &cfg);
                let secs = sw.elapsed_secs();
                let res = residual_norm(&cp, &t);
                table.row(vec![
                    format!("{sigma}"),
                    "hcs".into(),
                    j1.to_string(),
                    d.to_string(),
                    format!("{res:.4}"),
                    fmt_secs(secs),
                ]);
                sink.record(&[
                    ("sigma", sigma.into()),
                    ("method", "hcs".into()),
                    ("j", j1.into()),
                    ("d", d.into()),
                    ("residual", res.into()),
                    ("secs", secs.into()),
                ]);
                // FCS
                let sw = Stopwatch::start();
                let mut fcs = FcsEstimator::build(&t, d, j2, &mut rng);
                let cp = rtpm_symmetric(&mut fcs, dim, &cfg);
                let secs = sw.elapsed_secs();
                let res = residual_norm(&cp, &t);
                let _ = &fcs as &dyn ContractionEstimator;
                table.row(vec![
                    format!("{sigma}"),
                    "fcs".into(),
                    j2.to_string(),
                    d.to_string(),
                    format!("{res:.4}"),
                    fmt_secs(secs),
                ]);
                sink.record(&[
                    ("sigma", sigma.into()),
                    ("method", "fcs".into()),
                    ("j", j2.into()),
                    ("d", d.into()),
                    ("residual", res.into()),
                    ("secs", secs.into()),
                ]);
            }
            eprintln!("[table2] sigma={sigma} D={d} done");
        }
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: FCS beats HCS on residual AND time at every\n\
         matched sketched dimension, noise level, and D."
    );
}
