//! Table 1 — computational & storage complexity of plain / CS / TS / HCS /
//! FCS primitives. The analytic rows are reproduced verbatim; next to each
//! we print *measured scaling exponents* fitted from timing sweeps, so the
//! implementation can be checked against its claimed asymptotics.

use fcs::bench::{measure, quick_mode, ResultSink, Table};
use fcs::sketch::{ContractionEstimator, FcsEstimator, HcsEstimator, Method, PlainEstimator, TsEstimator};
use fcs::tensor::CpTensor;
use fcs::util::prng::Rng;

/// Fit log t = a + b·log x, return b (the scaling exponent).
fn fit_exponent(xs: &[f64], ts: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let lt: Vec<f64> = ts.iter().map(|t| t.ln()).collect();
    let mx = lx.iter().sum::<f64>() / n;
    let mt = lt.iter().sum::<f64>() / n;
    let num: f64 = lx.iter().zip(&lt).map(|(x, t)| (x - mx) * (t - mt)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

fn main() {
    let (reps, dims): (usize, Vec<usize>) = if quick_mode() {
        (3, vec![30, 50])
    } else {
        (5, vec![30, 50, 80, 120])
    };
    let j = 800usize;
    let d = 1usize;
    let rank = 5usize;

    let mut sink = ResultSink::new("table1_complexity");

    // ---- scaling of T(I,u,u) / approximation with I --------------------
    let mut table = Table::new(
        "Table 1 (empirical) — T(I,u,u) cost scaling with I  (J fixed)",
        &["method", "analytic", "measured t(I) samples", "fit exp(I)"],
    );
    let analytic = [
        ("plain", "O(I³)"),
        ("cs", "O(nnz(u)² I) = O(I³)"),
        ("ts", "O(nnz(u)+J log J+I)"),
        ("hcs", "O(nnz(u)+I J²)"),
        ("fcs", "O(nnz(u)+J log J+I)"),
    ];
    for (name, formula) in analytic {
        let mut xs = Vec::new();
        let mut ts_ = Vec::new();
        let mut samples = Vec::new();
        for &dim in &dims {
            let mut rng = Rng::seed_from_u64(1000 + dim as u64);
            let cp = CpTensor::random_orthogonal_symmetric(&mut rng, dim, rank, 3);
            let mut t = cp.to_dense();
            t.add_noise(&mut rng, 0.01);
            let u = {
                let mut v = rng.normal_vec(dim);
                fcs::linalg::normalize(&mut v);
                v
            };
            let est: Box<dyn ContractionEstimator> = match name {
                "plain" => Box::new(PlainEstimator::new(t.clone())),
                "cs" => Method::Cs.build(&t, d, j, &mut rng),
                "ts" => Box::new(TsEstimator::build(&t, d, j, &mut rng)),
                "hcs" => Box::new(HcsEstimator::build(&t, d, 14, &mut rng)),
                _ => Box::new(FcsEstimator::build(&t, d, j, &mut rng)),
            };
            let s = measure(1, reps, || est.t_iuu(&u));
            xs.push(dim as f64);
            ts_.push(s.median);
            samples.push(format!("{}@{dim}", fcs::bench::fmt_secs(s.median)));
        }
        let exp = fit_exponent(&xs, &ts_);
        table.row(vec![
            name.into(),
            formula.into(),
            samples.join(" "),
            format!("{exp:.2}"),
        ]);
        sink.record(&[
            ("primitive", "t_iuu".into()),
            ("method", name.into()),
            ("exponent", exp.into()),
        ]);
        eprintln!("[table1] t_iuu {name}: exponent {exp:.2}");
    }
    table.print();

    // ---- scaling of sketch build with J (CP rank-R input) --------------
    let mut table2 = Table::new(
        "Table 1 (empirical) — CP sketch build cost scaling with J (I fixed)",
        &["method", "analytic", "fit exp(J)"],
    );
    let dim = 60usize;
    let jlist: Vec<usize> = if quick_mode() {
        vec![512, 2048]
    } else {
        vec![512, 1024, 2048, 4096, 8192]
    };
    let mut rng = Rng::seed_from_u64(2);
    let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
    for (name, formula, expect_near) in [
        ("ts", "O(nnz(U)+R·J log J)", 1.0),
        ("fcs", "O(nnz(U)+R·J̃ log J̃)", 1.0),
        ("hcs", "O(nnz(U)+R·J³)  [per-mode J]", 3.0),
    ] {
        let mut xs = Vec::new();
        let mut ts_ = Vec::new();
        for &jj in &jlist {
            // HCS's per-mode J scales as the cube root to stay comparable.
            let eff = if name == "hcs" { (jj as f64).cbrt().round() as usize } else { jj };
            let mut rng2 = Rng::seed_from_u64(3);
            let mh = fcs::hash::ModeHashes::draw_uniform(&mut rng2, &[dim, dim, dim], eff);
            let s = match name {
                "ts" => {
                    let sk = fcs::sketch::TensorSketch::new(mh);
                    measure(1, reps, || sk.apply_cp(&cp))
                }
                "fcs" => {
                    let sk = fcs::sketch::FastCountSketch::new(mh);
                    measure(1, reps, || sk.apply_cp(&cp))
                }
                _ => {
                    let sk = fcs::sketch::HigherOrderCountSketch::new(mh);
                    measure(1, reps, || sk.apply_cp(&cp))
                }
            };
            xs.push(eff as f64);
            ts_.push(s.median);
        }
        let exp = fit_exponent(&xs, &ts_);
        table2.row(vec![name.into(), formula.into(), format!("{exp:.2} (expect ≈{expect_near})")]);
        sink.record(&[
            ("primitive", "cp_sketch_build".into()),
            ("method", name.into()),
            ("exponent", exp.into()),
        ]);
        eprintln!("[table1] cp build {name}: exponent {exp:.2}");
    }
    table2.print();

    // ---- hash storage table --------------------------------------------
    let mut table3 = Table::new(
        "Table 1 — hash storage (measured bytes at I=100, J=1000, D=1)",
        &["method", "analytic", "measured bytes"],
    );
    let dim = 100usize;
    let mut rng = Rng::seed_from_u64(4);
    let cp = CpTensor::random_orthogonal_symmetric(&mut rng, dim, rank, 3);
    let mut t = cp.to_dense();
    t.add_noise(&mut rng, 0.01);
    for (name, formula) in [("cs", "O(I³)"), ("ts", "O(I)"), ("hcs", "O(I)"), ("fcs", "O(I)")] {
        let est: Box<dyn ContractionEstimator> = match name {
            "cs" => Method::Cs.build(&t, 1, 1000, &mut rng),
            "ts" => Box::new(TsEstimator::build(&t, 1, 1000, &mut rng)),
            "hcs" => Box::new(HcsEstimator::build(&t, 1, 14, &mut rng)),
            _ => Box::new(FcsEstimator::build(&t, 1, 1000, &mut rng)),
        };
        table3.row(vec![name.into(), formula.into(), est.hash_bytes().to_string()]);
        sink.record(&[
            ("primitive", "hash_bytes".into()),
            ("method", name.into()),
            ("bytes", est.hash_bytes().into()),
        ]);
    }
    table3.print();
    sink.flush();
    println!(
        "\npaper shape check: plain/cs fit exponents ≈ 3 in I; ts/fcs ≈ ~1 in I\n\
         (sketch-domain work is J-dominated); hcs build ≈ cubic in per-mode J;\n\
         cs hash storage is I²-I³ × larger than ts/hcs/fcs."
    );
}
