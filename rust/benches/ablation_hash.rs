//! Ablations for the design choices DESIGN.md calls out:
//!   (a) linear (FCS) vs circular (TS) convolution at equal hash draws —
//!       isolates the paper's Proposition-1 variance claim,
//!   (b) median-of-D vs mean-of-D aggregation,
//!   (c) hash-length sensitivity of the inner-product estimator.

use fcs::bench::{quick_mode, ResultSink, Table};
use fcs::hash::ModeHashes;
use fcs::sketch::{FastCountSketch, TensorSketch};
use fcs::tensor::Tensor;
use fcs::util::prng::Rng;
use fcs::util::timing::median;

fn main() {
    let trials = if quick_mode() { 100 } else { 600 };
    let shape = [20usize, 20, 20];
    let mut rng = Rng::seed_from_u64(0xAB1A);
    let m = Tensor::randn(&mut rng, &shape);
    let n = Tensor::randn(&mut rng, &shape);
    let truth = m.inner(&n);

    let mut sink = ResultSink::new("ablation_hash");

    // (a)+(c): variance of ⟨sketch(M), sketch(N)⟩ under equalized hashes.
    let mut table = Table::new(
        "Ablation (a/c) — inner-product estimator variance, TS vs FCS, equalized hashes",
        &["J", "Var[TS]", "Var[FCS]", "ratio TS/FCS"],
    );
    for &j in &[64usize, 256, 1024, 4096] {
        let mut ts_est = Vec::with_capacity(trials);
        let mut fcs_est = Vec::with_capacity(trials);
        for _ in 0..trials {
            let mh = ModeHashes::draw_uniform(&mut rng, &shape, j);
            let ts = TensorSketch::new(mh.clone());
            let fc = FastCountSketch::new(mh);
            ts_est.push(fcs::linalg::dot(&ts.apply_dense(&m), &ts.apply_dense(&n)));
            fcs_est.push(fcs::linalg::dot(&fc.apply_dense(&m), &fc.apply_dense(&n)));
        }
        let var = |xs: &[f64]| {
            let mu = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / xs.len() as f64
        };
        let (vt, vf) = (var(&ts_est), var(&fcs_est));
        table.row(vec![
            j.to_string(),
            format!("{vt:.3}"),
            format!("{vf:.3}"),
            format!("{:.2}", vt / vf),
        ]);
        sink.record(&[
            ("j", j.into()),
            ("var_ts", vt.into()),
            ("var_fcs", vf.into()),
        ]);
        eprintln!("[ablation] J={j}: Var[TS]/Var[FCS] = {:.2}", vt / vf);
    }
    table.print();

    // (b) median vs mean aggregation under heavy-tailed estimates
    let mut table2 = Table::new(
        "Ablation (b) — |error| of median-of-D vs mean-of-D (FCS, J=256)",
        &["D", "median agg", "mean agg"],
    );
    for &d in &[3usize, 5, 9, 15] {
        let runs = trials / 2;
        let mut med_err = Vec::with_capacity(runs);
        let mut mean_err = Vec::with_capacity(runs);
        for _ in 0..runs {
            let ests: Vec<f64> = (0..d)
                .map(|_| {
                    let mh = ModeHashes::draw_uniform(&mut rng, &shape, 256);
                    let fc = FastCountSketch::new(mh);
                    fcs::linalg::dot(&fc.apply_dense(&m), &fc.apply_dense(&n))
                })
                .collect();
            med_err.push((median(&ests) - truth).abs());
            mean_err.push((ests.iter().sum::<f64>() / d as f64 - truth).abs());
        }
        let m1 = med_err.iter().sum::<f64>() / med_err.len() as f64;
        let m2 = mean_err.iter().sum::<f64>() / mean_err.len() as f64;
        table2.row(vec![d.to_string(), format!("{m1:.3}"), format!("{m2:.3}")]);
        sink.record(&[("d", d.into()), ("median_err", m1.into()), ("mean_err", m2.into())]);
    }
    table2.print();
    sink.flush();
    println!("\nexpected: Var[TS]/Var[FCS] ≥ 1 at every J (Proposition 1).");
}
