//! Fig. 2 — rank-15 approximation of the *Watercolors*-like HSI cube
//! (procedural substitute, DESIGN.md §5) by plain / TS / FCS asymmetric
//! RTPM. PSNR (dB) + running time across J ∈ {5000..8000}, D ∈ {10, 15};
//! TS and FCS share equalized hash draws.

use fcs::bench::{fmt_secs, quick_mode, ResultSink, Table};
use fcs::cpd::{rtpm_asymmetric, RtpmConfig};
use fcs::data::{hsi_cube, psnr};
use fcs::metrics::rel_error;
use fcs::sketch::{build_equalized, ContractionEstimator, PlainEstimator};
use fcs::util::prng::Rng;
use fcs::util::timing::Stopwatch;

fn main() {
    let full = std::env::var("FCS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    // paper: 512×512×31; default reduces the spatial dims to keep `cargo
    // bench` practical — the sketching-accuracy comparison is unchanged.
    let (h, w, bands, rank) = if quick_mode() {
        (96usize, 96usize, 31usize, 8usize)
    } else if full {
        (512, 512, 31, 15)
    } else {
        (256, 256, 31, 15)
    };
    let (lens, ds, n_init, n_iter): (Vec<usize>, Vec<usize>, usize, usize) = if quick_mode() {
        (vec![5000], vec![10], 2, 6)
    } else if full {
        (vec![5000, 6000, 7000, 8000], vec![10, 15], 5, 10)
    } else {
        (vec![5000, 8000], vec![10], 3, 8)
    };

    let mut rng = Rng::seed_from_u64(0xF162);
    let t = hsi_cube(&mut rng, h, w, bands, rank.min(12), 0.01);
    let shape = [h, w, bands];

    let mut table = Table::new(
        "Fig. 2 — Watercolors-like HSI, rank-15 RTPM approximation",
        &["method", "J", "D", "PSNR(dB)", "rel_err", "time"],
    );
    let mut sink = ResultSink::new("fig2_watercolors");

    // plain
    {
        let cfg = RtpmConfig { rank, n_init, n_iter, seed: 5 };
        let sw = Stopwatch::start();
        let mut est = PlainEstimator::new(t.clone());
        let cp = rtpm_asymmetric(&mut est, &shape, &cfg);
        let secs = sw.elapsed_secs();
        let approx = cp.to_dense();
        let p = psnr(&approx, &t, 1.0);
        let re = rel_error(&approx, &t);
        table.row(vec![
            "plain".into(),
            "-".into(),
            "-".into(),
            format!("{p:.2}"),
            format!("{re:.4}"),
            fmt_secs(secs),
        ]);
        sink.record(&[
            ("method", "plain".into()),
            ("j", 0usize.into()),
            ("d", 0usize.into()),
            ("psnr", p.into()),
            ("rel_err", re.into()),
            ("secs", secs.into()),
        ]);
        eprintln!("[fig2] plain done ({})", fmt_secs(secs));
    }

    for &d in &ds {
        for &j in &lens {
            let cfg = RtpmConfig { rank, n_init, n_iter, seed: 5 };
            let sw = Stopwatch::start();
            let (mut ts, mut fcs) = build_equalized(&t, d, j, &mut rng);
            let shared_build = sw.elapsed_secs() / 2.0;
            for (name, est) in [
                ("ts", &mut ts as &mut dyn ContractionEstimator),
                ("fcs", &mut fcs as &mut dyn ContractionEstimator),
            ] {
                let sw = Stopwatch::start();
                let cp = rtpm_asymmetric(est, &shape, &cfg);
                let secs = sw.elapsed_secs() + shared_build;
                let approx = cp.to_dense();
                let p = psnr(&approx, &t, 1.0);
                let re = rel_error(&approx, &t);
                table.row(vec![
                    name.into(),
                    j.to_string(),
                    d.to_string(),
                    format!("{p:.2}"),
                    format!("{re:.4}"),
                    fmt_secs(secs),
                ]);
                sink.record(&[
                    ("method", name.into()),
                    ("j", j.into()),
                    ("d", d.into()),
                    ("psnr", p.into()),
                    ("rel_err", re.into()),
                    ("secs", secs.into()),
                ]);
            }
            eprintln!("[fig2] J={j} D={d} done");
        }
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: FCS PSNR > TS PSNR (gap largest at small J);\n\
         both sketched runs much faster than plain."
    );
}
