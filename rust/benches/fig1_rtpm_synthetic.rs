//! Fig. 1 — plain / CS / TS / FCS RTPM on a synthetic symmetric CP rank-10
//! tensor `T ∈ R^{100×100×100}`, σ = 0.01, D = 2, L = 15, T = 20.
//! Residual norm (vs the noisy input (‖noise‖=√σ)) and running time vs hash length
//! J ∈ [1000, 10000]. TS and FCS share equalized hash draws.
//!
//! Modes: FCS_BENCH_QUICK=1 (small sweep), default (paper protocol at
//! reduced L/T), FCS_BENCH_FULL=1 (paper protocol exactly).

use fcs::bench::{fmt_secs, quick_mode, ResultSink, Table};
use fcs::cpd::{rtpm_symmetric, RtpmConfig};
use fcs::data::synthetic_cp;
use fcs::metrics::residual_norm;
use fcs::sketch::{build_equalized, ContractionEstimator, CsEstimator, PlainEstimator};
use fcs::util::prng::Rng;
use fcs::util::timing::Stopwatch;

fn main() {
    let full = std::env::var("FCS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let (dim, rank, d) = (100usize, 10usize, 2usize);
    let sigma = 0.01;
    let (lens, n_init, n_iter): (Vec<usize>, usize, usize) = if quick_mode() {
        (vec![1000, 4000, 10000], 4, 8)
    } else if full {
        (vec![1000, 2000, 3000, 4000, 5000, 6000, 7000, 8000, 9000, 10000], 15, 20)
    } else {
        (vec![1000, 2500, 5000, 7500, 10000], 8, 12)
    };

    let mut rng = Rng::seed_from_u64(0xF161);
    let (t, _clean_cp) = synthetic_cp(&mut rng, &[dim, dim, dim], rank, sigma, true);
    
    let cfg = RtpmConfig { rank, n_init, n_iter, seed: 7 };

    let mut table = Table::new(
        "Fig. 1 — RTPM on synthetic 100³ rank-10 (residual vs noisy input)",
        &["method", "J", "residual", "time"],
    );
    let mut sink = ResultSink::new("fig1_rtpm_synthetic");

    // plain baseline (J-independent)
    {
        let sw = Stopwatch::start();
        let mut est = PlainEstimator::new(t.clone());
        let cp = rtpm_symmetric(&mut est, dim, &cfg);
        let secs = sw.elapsed_secs();
        let res = residual_norm(&cp, &t);
        table.row(vec!["plain".into(), "-".into(), format!("{res:.4}"), fmt_secs(secs)]);
        sink.record(&[
            ("method", "plain".into()),
            ("j", 0usize.into()),
            ("residual", res.into()),
            ("secs", secs.into()),
        ]);
    }

    for &j in &lens {
        // CS (independent long hash)
        {
            let sw = Stopwatch::start();
            let mut est = CsEstimator::build(&t, d, j, &mut rng);
            let cp = rtpm_symmetric(&mut est, dim, &cfg);
            let secs = sw.elapsed_secs();
            let res = residual_norm(&cp, &t);
            table.row(vec!["cs".into(), j.to_string(), format!("{res:.4}"), fmt_secs(secs)]);
            sink.record(&[
                ("method", "cs".into()),
                ("j", j.into()),
                ("residual", res.into()),
                ("secs", secs.into()),
            ]);
        }
        // TS and FCS with equalized hashes
        let sw = Stopwatch::start();
        let (mut ts, mut fcs) = build_equalized(&t, d, j, &mut rng);
        let shared_build = sw.elapsed_secs() / 2.0;
        for (name, est) in [
            ("ts", &mut ts as &mut dyn ContractionEstimator),
            ("fcs", &mut fcs as &mut dyn ContractionEstimator),
        ] {
            let sw = Stopwatch::start();
            let cp = rtpm_symmetric(est, dim, &cfg);
            let secs = sw.elapsed_secs() + shared_build;
            let res = residual_norm(&cp, &t);
            table.row(vec![name.into(), j.to_string(), format!("{res:.4}"), fmt_secs(secs)]);
            sink.record(&[
                ("method", name.into()),
                ("j", j.into()),
                ("residual", res.into()),
                ("secs", secs.into()),
            ]);
        }
        eprintln!("[fig1] J={j} done");
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: FCS residual < TS residual < CS residual at equal J;\n\
         FCS slower than TS but much faster than CS and plain at small J."
    );
}
