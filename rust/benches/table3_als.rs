//! Table 3 — plain / TS / FCS ALS on a synthetic asymmetric CP rank-10
//! tensor `T ∈ R^{400×400×400}`, σ ∈ {0.01, 0.1}, J ∈ {3000..7000},
//! D ∈ {10, 15, 20}. Residual norm (vs noisy input) + running time.

use fcs::bench::{fmt_secs, quick_mode, ResultSink, Table};
use fcs::cpd::{als_plain, als_sketched, AlsConfig};
use fcs::data::synthetic_cp;
use fcs::metrics::residual_norm;
use fcs::sketch::build_equalized;
use fcs::util::prng::Rng;
use fcs::util::timing::Stopwatch;

fn main() {
    let full = std::env::var("FCS_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let rank = 10usize;
    let (dim, lens, ds, sigmas, n_iter): (usize, Vec<usize>, Vec<usize>, Vec<f64>, usize) =
        if quick_mode() {
            (120, vec![3000], vec![10], vec![0.01], 8)
        } else if full {
            (
                400,
                vec![3000, 4000, 5000, 6000, 7000],
                vec![10, 15, 20],
                vec![0.01, 0.1],
                20,
            )
        } else {
            (256, vec![3000, 5000, 7000], vec![10, 20], vec![0.01, 0.1], 12)
        };

    let mut table = Table::new(
        "Table 3 — ALS on synthetic asymmetric rank-10 (residual vs noisy input)",
        &["sigma", "method", "J", "D", "residual", "time"],
    );
    let mut sink = ResultSink::new("table3_als");

    for &sigma in &sigmas {
        let mut rng = Rng::seed_from_u64(0x7AB3 ^ sigma.to_bits());
        let (t, _clean_cp) = synthetic_cp(&mut rng, &[dim, dim, dim], rank, sigma, false);
        
        let cfg = AlsConfig { rank, n_iter, seed: 11 };

        // plain (J/D-independent, once per sigma)
        {
            let sw = Stopwatch::start();
            let cp = als_plain(&t, &cfg);
            let secs = sw.elapsed_secs();
            let res = residual_norm(&cp, &t);
            table.row(vec![
                format!("{sigma}"),
                "plain".into(),
                "-".into(),
                "-".into(),
                format!("{res:.4}"),
                fmt_secs(secs),
            ]);
            sink.record(&[
                ("sigma", sigma.into()),
                ("method", "plain".into()),
                ("j", 0usize.into()),
                ("d", 0usize.into()),
                ("residual", res.into()),
                ("secs", secs.into()),
            ]);
            eprintln!("[table3] sigma={sigma} plain done ({})", fmt_secs(secs));
        }

        for &d in &ds {
            for &j in &lens {
                let sw = Stopwatch::start();
                let (ts, fcs) = build_equalized(&t, d, j, &mut rng);
                let shared_build = sw.elapsed_secs() / 2.0;
                for (name, est) in [("ts", &ts as &dyn fcs::sketch::ContractionEstimator), ("fcs", &fcs)] {
                    let sw = Stopwatch::start();
                    let cp = als_sketched(&t.shape, est, &t, &cfg);
                    let secs = sw.elapsed_secs() + shared_build;
                    let res = residual_norm(&cp, &t);
                    table.row(vec![
                        format!("{sigma}"),
                        name.into(),
                        j.to_string(),
                        d.to_string(),
                        format!("{res:.4}"),
                        fmt_secs(secs),
                    ]);
                    sink.record(&[
                        ("sigma", sigma.into()),
                        ("method", name.into()),
                        ("j", j.into()),
                        ("d", d.into()),
                        ("residual", res.into()),
                        ("secs", secs.into()),
                    ]);
                }
                eprintln!("[table3] sigma={sigma} D={d} J={j} done");
            }
        }
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: FCS residual < TS residual everywhere; the accuracy\n\
         gap widens as J shrinks; both sketched ALS runs beat plain ALS time."
    );
}
