//! Table 4 — classification accuracy of CS / TS / FCS-sketched CP-TRL on
//! the FMNIST-like dataset across compression ratios, trained end-to-end
//! through the AOT XLA train-step artifacts (Rust drives; Python absent).
//!
//! Needs `make artifacts` (default CR subset) or `make artifacts-full`
//! (all 10 paper CRs).

use fcs::bench::{quick_mode, ResultSink, Table};
use fcs::runtime::spawn_runtime;
use fcs::trn::{available_cr_tags, train_and_eval, TrnMethod, TrnRunConfig};

fn main() {
    let rt = match spawn_runtime(None) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("table4_trn: {e}\nrun `make artifacts` first");
            return;
        }
    };
    let (steps, train_size, test_size) = if quick_mode() {
        (60usize, 1280usize, 256usize)
    } else {
        (300, 6400, 1024)
    };

    let mut table = Table::new(
        "Table 4 — sketched CP-TRL accuracy on FMNIST-like data",
        &["CR", "method", "accuracy", "final_loss", "train_time(s)"],
    );
    let mut sink = ResultSink::new("table4_trn");

    let tags = available_cr_tags(&rt, TrnMethod::Fcs);
    for (cr, tag) in &tags {
        for method in [TrnMethod::Cs, TrnMethod::Ts, TrnMethod::Fcs] {
            let cfg = TrnRunConfig {
                method,
                cr_tag: tag.clone(),
                steps,
                lr: 0.05,
                train_size,
                test_size,
                seed: 1234,
                log_every: 0,
            };
            match train_and_eval(&rt, &cfg) {
                Ok(res) => {
                    table.row(vec![
                        format!("{cr:.2}"),
                        method.name().into(),
                        format!("{:.4}", res.accuracy),
                        format!("{:.4}", res.losses.last().unwrap()),
                        format!("{:.1}", res.train_secs),
                    ]);
                    sink.record(&[
                        ("cr", (*cr).into()),
                        ("method", method.name().into()),
                        ("accuracy", res.accuracy.into()),
                        ("final_loss", (*res.losses.last().unwrap()).into()),
                        ("train_secs", res.train_secs.into()),
                    ]);
                    eprintln!("[table4] CR={cr} {} acc={:.4}", method.name(), res.accuracy);
                }
                Err(e) => eprintln!("[table4] CR={cr} {}: {e}", method.name()),
            }
        }
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: FCS accuracy ≥ CS ≥ TS at almost every CR, and\n\
         FCS degrades most gracefully as CR grows."
    );
}
