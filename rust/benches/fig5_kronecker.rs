//! Fig. 5 — Kronecker product compression: `A ∈ R^{30×40} ⊗ B ∈ R^{40×50}`,
//! entries U[-5, 5], D = 20. Four panels vs CR: compressing time,
//! decompressing time, relative error, hash memory — CS vs HCS vs FCS.

use fcs::bench::{fmt_secs, quick_mode, ResultSink, Table};
use fcs::compress::{Codec, KronCodec};
use fcs::linalg::Matrix;
use fcs::util::prng::Rng;

fn main() {
    let d = 20usize;
    let crs: Vec<f64> = if quick_mode() {
        vec![2.0, 8.0]
    } else {
        vec![1.0, 2.0, 4.0, 8.0, 16.0]
    };
    let mut rng = Rng::seed_from_u64(0xF165);
    let a = Matrix::from_data(30, 40, rng.uniform_vec(30 * 40, -5.0, 5.0));
    let b = Matrix::from_data(40, 50, rng.uniform_vec(40 * 50, -5.0, 5.0));

    let mut table = Table::new(
        "Fig. 5 — Kronecker product compression (A 30×40 ⊗ B 40×50, D=20)",
        &["CR", "codec", "compress", "decompress", "rel_error", "hash_mem(KB)"],
    );
    let mut sink = ResultSink::new("fig5_kronecker");

    for &cr in &crs {
        for codec in [Codec::Cs, Codec::Hcs, Codec::Fcs] {
            let stats = KronCodec::evaluate(codec, &a, &b, cr, d, &mut rng);
            table.row(vec![
                format!("{cr:.0}"),
                stats.codec.into(),
                fmt_secs(stats.compress_secs),
                fmt_secs(stats.decompress_secs),
                format!("{:.4}", stats.rel_error),
                format!("{:.1}", stats.hash_bytes as f64 / 1024.0),
            ]);
            sink.record(&[
                ("cr", cr.into()),
                ("codec", stats.codec.into()),
                ("compress_secs", stats.compress_secs.into()),
                ("decompress_secs", stats.decompress_secs.into()),
                ("rel_error", stats.rel_error.into()),
                ("hash_bytes", stats.hash_bytes.into()),
            ]);
        }
        eprintln!("[fig5] CR={cr} done");
    }

    table.print();
    sink.flush();
    println!(
        "\npaper shape check: FCS compresses faster than CS at small CR; FCS hash\n\
         memory ≈ 10% of CS; HCS compresses fastest but has the largest error\n\
         and the slowest decompression; errors near/above 1 at CR=16 for all."
    );
}
