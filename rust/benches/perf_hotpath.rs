//! §Perf — hot-path microbenchmarks used by the optimization pass:
//!   (i)  FCS dense apply throughput (GB/s) vs a memcpy-style roofline,
//!   (ii) rank-R FFT path vs the R·J̃·log(J̃) flop model,
//!   (iii) RTPM t_iuu / t_uuu per-call latency,
//!   (iv) coordinator throughput / latency percentiles.
//! Results feed EXPERIMENTS.md §Perf (before/after per iteration).

use fcs::bench::{fmt_secs, measure, quick_mode, ResultSink, Table};
use fcs::coordinator::{Request, Service, ServiceConfig};
use fcs::fft::FftWorkspace;
use fcs::hash::ModeHashes;
use fcs::sketch::{FastCountSketch, FcsEstimator, TensorSketch};
use fcs::tensor::{CpTensor, Tensor};
use fcs::util::prng::Rng;

fn main() {
    let reps = if quick_mode() { 5 } else { 20 };
    let mut table = Table::new("§Perf — hot paths", &["path", "metric", "value"]);
    let mut sink = ResultSink::new("perf_hotpath");

    // (i) dense FCS apply vs copy roofline
    {
        let dim = 200usize;
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&mut rng, &[dim, dim, dim]);
        let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], 4000);
        let fcs = FastCountSketch::new(mh);
        let mut out = vec![0.0; fcs.j_tilde];
        let s = measure(2, reps, || fcs.apply_dense_into(&t, &mut out));
        let bytes = t.numel() as f64 * 8.0;
        let gbps = bytes / s.median / 1e9;
        // copy roofline
        let mut dst = vec![0.0f64; t.numel()];
        let sc = measure(2, reps, || dst.copy_from_slice(&t.data));
        let roof = bytes / sc.median / 1e9;
        table.row(vec!["fcs dense apply (200³)".into(), "GB/s".into(), format!("{gbps:.2}")]);
        table.row(vec!["memcpy roofline".into(), "GB/s".into(), format!("{roof:.2}")]);
        table.row(vec!["fcs/memcpy".into(), "ratio".into(), format!("{:.2}", gbps / roof)]);
        sink.record(&[("path", "fcs_dense_apply".into()), ("gbps", gbps.into()), ("roof_gbps", roof.into())]);
    }

    // (ii) rank-R FFT path: spectral accumulation (one IFFT total, workspace
    // reuse, rank fan-out) vs the per-rank-IFFT baseline it replaced. The
    // acceptance gate for the spectral engine is ≥2× at R ≥ 16.
    {
        let dim = 100usize;
        let j = 4000usize;
        let mut rng = Rng::seed_from_u64(2);
        let mh = ModeHashes::draw_uniform(&mut rng, &[dim, dim, dim], j);
        let fcs = FastCountSketch::new(mh.clone());
        for rank in [10usize, 16, 32] {
            let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
            let s_new = measure(2, reps, || fcs.apply_cp(&cp));
            let s_base = measure(2, reps, || fcs.apply_cp_per_rank(&cp));
            // Serial zero-alloc workspace path (what the coordinator workers
            // and the ALS inner loop run).
            let mut ws = FftWorkspace::new();
            let mut out = Vec::new();
            let s_ws = measure(2, reps, || fcs.apply_cp_into(&cp, &mut ws, &mut out));
            let speedup = s_base.median / s_new.median;
            table.row(vec![
                format!("fcs rank-R spectral (J=4000,R={rank})"),
                "time".into(),
                fmt_secs(s_new.median),
            ]);
            table.row(vec![
                format!("fcs rank-R per-rank-IFFT baseline (R={rank})"),
                "time".into(),
                fmt_secs(s_base.median),
            ]);
            table.row(vec![
                format!("fcs rank-R workspace serial (R={rank})"),
                "time".into(),
                fmt_secs(s_ws.median),
            ]);
            table.row(vec![
                format!("fcs spectral vs baseline (R={rank})"),
                "speedup".into(),
                format!("{speedup:.2}x"),
            ]);
            sink.record(&[
                ("path", "fcs_rank_r_fft".into()),
                ("rank", (rank as f64).into()),
                ("secs_spectral", s_new.median.into()),
                ("secs_per_rank_baseline", s_base.median.into()),
                ("secs_workspace_serial", s_ws.median.into()),
                ("speedup", speedup.into()),
            ]);
        }
        let rank = 10usize;
        let cp = CpTensor::randn(&mut rng, &[dim, dim, dim], rank);
        let ts = TensorSketch::new(mh);
        let s2 = measure(2, reps, || ts.apply_cp(&cp));
        let s2b = measure(2, reps, || ts.apply_cp_per_rank(&cp));
        table.row(vec!["ts rank-R spectral (same hashes, R=10)".into(), "time".into(), fmt_secs(s2.median)]);
        table.row(vec![
            "ts spectral vs per-rank baseline".into(),
            "speedup".into(),
            format!("{:.2}x", s2b.median / s2.median),
        ]);
        sink.record(&[
            ("path", "ts_rank_r_fft".into()),
            ("secs_spectral", s2.median.into()),
            ("secs_per_rank_baseline", s2b.median.into()),
        ]);
    }

    // (iii) estimator query latency
    {
        let dim = 100usize;
        let j = 5000usize;
        let mut rng = Rng::seed_from_u64(3);
        let cp = CpTensor::random_orthogonal_symmetric(&mut rng, dim, 10, 3);
        let mut t = cp.to_dense();
        t.add_noise(&mut rng, 0.01);
        use fcs::sketch::ContractionEstimator;
        let est = FcsEstimator::build(&t, 2, j, &mut rng);
        let mut u = rng.normal_vec(dim);
        fcs::linalg::normalize(&mut u);
        let s_iuu = measure(2, reps, || est.t_iuu(&u));
        let s_uuu = measure(2, reps, || est.t_uuu(&u));
        table.row(vec!["fcs t_iuu (I=100,J=5000,D=2)".into(), "time".into(), fmt_secs(s_iuu.median)]);
        table.row(vec!["fcs t_uuu".into(), "time".into(), fmt_secs(s_uuu.median)]);
        sink.record(&[
            ("path", "estimator_query".into()),
            ("t_iuu_secs", s_iuu.median.into()),
            ("t_uuu_secs", s_uuu.median.into()),
        ]);
    }

    // (iv) coordinator throughput/latency (pure-Rust path + XLA if present)
    for (label, runtime) in [
        ("coordinator(rust)", None),
        ("coordinator(xla)", fcs::runtime::spawn_runtime(None).ok()),
    ] {
        if label.contains("xla") && runtime.is_none() {
            eprintln!("[perf] skipping XLA coordinator (no artifacts)");
            continue;
        }
        let svc = Service::start(ServiceConfig::default(), runtime).unwrap();
        let h = svc.handle();
        let n = if quick_mode() { 200 } else { 2000 };
        let mut rng = Rng::seed_from_u64(4);
        let reqs: Vec<Vec<f64>> = (0..64).map(|_| rng.normal_vec(h.cs_in_dim)).collect();
        let sw = fcs::util::timing::Stopwatch::start();
        let mut pend = Vec::new();
        for i in 0..n {
            loop {
                match h.submit(Request::CsVec { x: reqs[i % reqs.len()].clone() }) {
                    Ok(rx) => {
                        pend.push(rx);
                        break;
                    }
                    Err(fcs::coordinator::ServiceError::Busy) => {
                        // drain a little
                        if let Some(rx) = pend.pop() {
                            let _ = rx.recv();
                        }
                    }
                    Err(e) => panic!("{e}"),
                }
            }
        }
        for rx in pend {
            let _ = rx.recv();
        }
        let secs = sw.elapsed_secs();
        let report = svc.stats();
        let cs = report.per_op.iter().find(|o| o.op == "cs_vec").unwrap();
        table.row(vec![label.into(), "req/s".into(), format!("{:.0}", n as f64 / secs)]);
        table.row(vec![label.into(), "p50/p95/p99 µs".into(),
            format!("{:.0}/{:.0}/{:.0}", cs.p50_us, cs.p95_us, cs.p99_us)]);
        table.row(vec![label.into(), "mean batch fill".into(), format!("{:.1}", report.mean_batch_fill)]);
        sink.record(&[
            ("path", label.into()),
            ("rps", (n as f64 / secs).into()),
            ("p50_us", cs.p50_us.into()),
            ("p99_us", cs.p99_us.into()),
            ("mean_batch_fill", report.mean_batch_fill.into()),
        ]);
        svc.shutdown();
    }

    table.print();
    sink.flush();
}
